import os
import sys
import types

# Make `compile` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# hypothesis fallback: this image is offline and may lack the package. The
# property tests only use a tiny slice of the API (given/settings and the
# sampled_from/integers/floats/tuples strategies), so when hypothesis is
# missing we install a deterministic stand-in that runs each property twice —
# once on every strategy's smallest example, once on its largest — instead of
# skipping the suite outright. With real hypothesis installed (CI), the shim
# is inert and the full randomized sweep runs.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:

    import itertools

    class _Strategy:
        def __init__(self, examples):
            self.examples = examples

        def filter(self, pred):
            kept = [e for e in self.examples if pred(e)]
            if not kept:
                raise ValueError("hypothesis fallback: filter removed every example")
            return _Strategy(kept)

    def _sampled_from(options):
        return _Strategy(list(options))

    def _integers(lo, hi):
        return _Strategy([lo, hi])

    def _floats(lo, hi):
        return _Strategy([lo, hi])

    def _tuples(*strategies):
        return _Strategy(
            [tuple(t) for t in itertools.product(*(s.examples for s in strategies))]
        )

    def _given(**named):
        def deco(fn):
            def runner(*args, **kwargs):
                for i in (0, -1):
                    drawn = {k: s.examples[i] for k, s in named.items()}
                    fn(*args, **drawn, **kwargs)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco

    def _settings(**_kwargs):
        def deco(fn):
            return fn

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.sampled_from = _sampled_from
    _st.integers = _integers
    _st.floats = _floats
    _st.tuples = _tuples

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
