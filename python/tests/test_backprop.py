"""BackProp MXU kernels and explicit-gradient training step vs oracles."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import matmul_plain, matmul_sigmoid
from compile.kernels.ref import matmul_plain_ref, matmul_sigmoid_ref

DIMS = st.tuples(
    st.sampled_from([8, 16, 32]),   # m
    st.sampled_from([8, 16, 64]),   # k
    st.sampled_from([4, 8, 16]),    # n
    st.sampled_from([4, 8]),        # block_m
).filter(lambda t: t[0] % t[3] == 0)


@settings(max_examples=20, deadline=None)
@given(dims=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_sigmoid_matches_ref(dims, seed):
    m, k, n, bm = dims
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32) * 0.2)
    got = matmul_sigmoid(x, w, block_m=bm)
    np.testing.assert_allclose(got, matmul_sigmoid_ref(x, w), rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(dims=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_plain_matches_ref(dims, seed):
    m, k, n, bm = dims
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    got = matmul_plain(x, w, block_m=bm)
    np.testing.assert_allclose(got, matmul_plain_ref(x, w), rtol=1e-4, atol=1e-4)


def test_bf16_inputs_accumulate_in_f32(rng):
    # MXU-style: bf16 operands, f32 accumulation (preferred_element_type).
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32)).astype(jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32)).astype(jnp.bfloat16)
    got = matmul_plain(x, w, block_m=8)
    assert got.dtype == jnp.float32
    want = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_train_step_reduces_loss(rng):
    x = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
    w1 = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32) * 0.1)
    w2 = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32) * 0.1)
    target = jnp.asarray(rng.uniform(0.2, 0.8, size=(32, 8)).astype(np.float32))

    def loss(w1_):
        out = model.backprop_out(x, w1_, w2)
        return float(jnp.mean((target - out) ** 2))

    w1_new = model.backprop_w1(x, w1, w2, target)
    assert loss(w1_new) < loss(w1)


def test_train_step_matches_jax_grad(rng):
    # The explicit Rodinia formulas must agree with autodiff of 0.5*sum(err^2)
    # wrt w1 (through pure-jnp forward).
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    w1 = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32) * 0.3)
    w2 = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32) * 0.3)
    target = jnp.asarray(rng.uniform(size=(8, 4)).astype(np.float32))

    def neg_half_sq_err(w1_):
        h = matmul_sigmoid_ref(x, w1_)
        out = matmul_sigmoid_ref(h, w2)
        return -0.5 * jnp.sum((target - out) ** 2)

    g = jax.grad(neg_half_sq_err)(w1)
    want = w1 + model.LR * g  # ascent on -loss == descent on the loss
    got = model.backprop_w1(x, w1, w2, target)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
