"""AOT pipeline: exports lower to parseable HLO text with a manifest."""

import os

import pytest

from compile import aot, model


def test_export_registry_shapes_are_consistent():
    import jax

    for name, (fn, specs) in model.EXPORTS.items():
        out = jax.eval_shape(fn, *specs)
        assert out.dtype.name == "float32", name
        assert len(out.shape) in (1, 2), name


def test_lower_one_writes_hlo_text(tmp_path):
    line = aot.lower_one("knn", str(tmp_path))
    assert line.startswith("knn;in=float32[1024,8],float32[1,8];out=float32[1024,1]")
    text = (tmp_path / "knn.hlo.txt").read_text()
    assert text.startswith("HloModule")
    # return_tuple=True: entry computation root must be a tuple
    assert "tuple(" in text


def test_main_subset_writes_manifest(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--only", "pagerank"])
    assert rc == 0
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert manifest == ["pagerank;in=float32[128,128],float32[128,1];out=float32[128,1]"]
    assert (tmp_path / "pagerank.hlo.txt").exists()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.txt")),
    reason="artifacts not built",
)
def test_built_artifacts_cover_all_exports():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    names = {
        line.split(";")[0]
        for line in open(os.path.join(root, "manifest.txt"))
        if line.strip()
    }
    assert names == set(model.EXPORTS)
    for n in names:
        assert os.path.getsize(os.path.join(root, f"{n}.hlo.txt")) > 200
