"""Masked neighbour-min (the paper's Fig. 2 reduction) vs oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import neighbor_min
from compile.kernels.neighbor_min import BIG
from compile.kernels.ref import neighbor_min_ref


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([16, 32, 64, 128]),
    br=st.sampled_from([8, 16]),
    density=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref(n, br, density, seed):
    rng = np.random.default_rng(seed)
    adj = (rng.uniform(size=(n, n)) < density).astype(np.float32)
    vals = rng.normal(size=(1, n)).astype(np.float32)
    act = (rng.uniform(size=(1, n)) < 0.5).astype(np.float32)
    got = neighbor_min(jnp.asarray(adj), jnp.asarray(vals), jnp.asarray(act), block_rows=br)
    want = neighbor_min_ref(jnp.asarray(adj), jnp.asarray(vals), jnp.asarray(act))
    np.testing.assert_allclose(got, want)


def test_isolated_nodes_get_big():
    n = 16
    adj = jnp.zeros((n, n), jnp.float32)
    vals = jnp.ones((1, n), jnp.float32)
    act = jnp.ones((1, n), jnp.float32)
    out = np.asarray(neighbor_min(adj, vals, act, block_rows=8))
    assert np.all(out == BIG)


def test_min_is_over_active_neighbors_only(rng):
    n = 32
    adj = np.ones((n, n), np.float32)
    vals = np.arange(n, dtype=np.float32).reshape(1, n)
    act = np.zeros((1, n), np.float32)
    act[0, 5] = 1.0  # only node 5 is active
    out = np.asarray(
        neighbor_min(jnp.asarray(adj), jnp.asarray(vals), jnp.asarray(act), block_rows=8)
    )
    np.testing.assert_allclose(out, 5.0)
