"""Floyd–Warshall Pallas kernel and full-run model vs oracles."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import fw_step
from compile.kernels.ref import fw_full_ref, fw_step_ref


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([16, 32, 48, 64]),
    br=st.sampled_from([4, 8, 16]),
    k=st.integers(0, 15),
    seed=st.integers(0, 2**31 - 1),
)
def test_step_matches_ref(n, br, k, seed):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.uniform(0.0, 100.0, size=(n, n)).astype(np.float32))
    colk = d[:, k : k + 1]
    rowk = d[k : k + 1, :]
    got = fw_step(d, colk, rowk, block_rows=br)
    np.testing.assert_allclose(got, fw_step_ref(d, colk, rowk), rtol=1e-6)


def test_full_run_matches_numpy_fw(rng):
    n = 64
    d = rng.uniform(1.0, 50.0, size=(n, n)).astype(np.float32)
    np.fill_diagonal(d, 0.0)
    want = d.copy()
    for k in range(n):
        want = np.minimum(want, want[:, k : k + 1] + want[k : k + 1, :])
    got = np.asarray(model.fw(jnp.asarray(d)))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_full_run_matches_jnp_ref(rng):
    d = jnp.asarray(rng.uniform(0.0, 10.0, size=(64, 64)).astype(np.float32))
    np.testing.assert_allclose(model.fw(d), fw_full_ref(d), rtol=1e-5)


def test_triangle_inequality_holds_after_fw(rng):
    d = rng.uniform(1.0, 20.0, size=(32, 32)).astype(np.float32)
    np.fill_diagonal(d, 0.0)
    sp = np.asarray(fw_full_ref(jnp.asarray(d)))
    # Property: no path can be shortened any further.
    for k in range(32):
        assert np.all(sp <= sp[:, k : k + 1] + sp[k : k + 1, :] + 1e-3)
