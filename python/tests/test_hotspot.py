"""Hotspot Pallas kernel vs pure-jnp oracle (hypothesis shape sweep)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import hotspot_step
from compile.kernels.ref import hotspot_step_ref

SHAPES = st.tuples(
    st.sampled_from([8, 16, 24, 32, 64]),  # rows
    st.sampled_from([4, 8, 16, 33, 64]),   # cols (non-multiple-of-8 allowed)
    st.sampled_from([2, 4, 8]),            # block_rows
).filter(lambda t: t[0] % t[2] == 0)


@settings(max_examples=20, deadline=None)
@given(shape=SHAPES, seed=st.integers(0, 2**31 - 1))
def test_matches_ref(shape, seed):
    rows, cols, br = shape
    rng = np.random.default_rng(seed)
    temp = jnp.asarray(rng.normal(50.0, 10.0, size=(rows, cols)).astype(np.float32))
    power = jnp.asarray(rng.uniform(0.0, 1.0, size=(rows, cols)).astype(np.float32))
    got = hotspot_step(temp, power, block_rows=br)
    want = hotspot_step_ref(temp, power)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_constant_grid_stays_at_equilibrium():
    # With temp == AMB everywhere and zero power, the update is a fixed point.
    from compile.kernels.hotspot import AMB

    temp = jnp.full((16, 16), AMB, jnp.float32)
    power = jnp.zeros((16, 16), jnp.float32)
    out = hotspot_step(temp, power, block_rows=4)
    np.testing.assert_allclose(out, temp, rtol=1e-6)


def test_rejects_bad_block_rows():
    import pytest

    temp = jnp.zeros((10, 8), jnp.float32)
    with pytest.raises(ValueError):
        hotspot_step(temp, temp, block_rows=4)


def test_block_rows_invariance(rng):
    temp = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    power = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    a = hotspot_step(temp, power, block_rows=4)
    b = hotspot_step(temp, power, block_rows=16)
    np.testing.assert_allclose(a, b, rtol=1e-6)
