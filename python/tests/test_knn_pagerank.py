"""KNN distance and PageRank step kernels vs oracles."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import knn_dists, pagerank_step
from compile.kernels.ref import knn_dists_ref, pagerank_step_ref


@settings(max_examples=20, deadline=None)
@given(
    p=st.sampled_from([64, 128, 256, 1024]),
    d=st.sampled_from([2, 4, 8]),
    bp=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_knn_matches_ref(p, d, bp, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.normal(size=(p, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(1, d)).astype(np.float32))
    got = knn_dists(pts, q, block_points=bp)
    np.testing.assert_allclose(got, knn_dists_ref(pts, q), rtol=1e-4, atol=1e-4)


def test_knn_nearest_is_self(rng):
    pts = jnp.asarray(rng.normal(size=(128, 8)).astype(np.float32))
    q = pts[7:8, :]
    dists = np.asarray(knn_dists(pts, q, block_points=32)).ravel()
    assert dists.argmin() == 7
    assert dists[7] <= 1e-6


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([32, 64, 128]),
    br=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pagerank_matches_ref(n, br, seed):
    rng = np.random.default_rng(seed)
    a = rng.uniform(size=(n, n)).astype(np.float32)
    a = a / a.sum(axis=0, keepdims=True)
    pr = np.full((n, 1), 1.0 / n, np.float32)
    got = pagerank_step(jnp.asarray(a), jnp.asarray(pr), block_rows=br)
    np.testing.assert_allclose(
        got, pagerank_step_ref(jnp.asarray(a), jnp.asarray(pr)), rtol=1e-5
    )


def test_pagerank_preserves_probability_mass(rng):
    n = 128
    a = rng.uniform(size=(n, n)).astype(np.float32)
    a = a / a.sum(axis=0, keepdims=True)
    pr = np.full((n, 1), 1.0 / n, np.float32)
    out = pr
    for _ in range(20):
        out = np.asarray(model.pagerank(jnp.asarray(a), jnp.asarray(out)))
    np.testing.assert_allclose(out.sum(), 1.0, rtol=1e-3)
    # converged: one more step barely moves it
    nxt = np.asarray(model.pagerank(jnp.asarray(a), jnp.asarray(out)))
    assert np.abs(nxt - out).max() < 1e-3
