"""Pure-jnp oracles for every Pallas kernel — the build-time correctness
signal.  Each function mirrors the contract of its kernel exactly; pytest
(+ hypothesis) sweeps shapes and asserts allclose.
"""

import jax.numpy as jnp

from .hotspot import AMB, RX, RY, RZ, SDC
from .neighbor_min import BIG


def hotspot_step_ref(temp, power):
    padded = jnp.pad(temp, 1, mode="edge")
    t = padded[1:-1, 1:-1]
    n = padded[:-2, 1:-1]
    s = padded[2:, 1:-1]
    w = padded[1:-1, :-2]
    e = padded[1:-1, 2:]
    return t + SDC * (
        power + (n + s - 2.0 * t) * RY + (e + w - 2.0 * t) * RX + (AMB - t) * RZ
    )


def fw_step_ref(dist, colk, rowk):
    return jnp.minimum(dist, colk + rowk)


def fw_full_ref(dist):
    """Reference full Floyd–Warshall (host loop over pivots)."""
    n = dist.shape[0]
    for k in range(n):
        dist = jnp.minimum(dist, dist[:, k : k + 1] + dist[k : k + 1, :])
    return dist


def matmul_sigmoid_ref(x, w):
    z = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    return 1.0 / (1.0 + jnp.exp(-z))


def matmul_plain_ref(x, w):
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def knn_dists_ref(points, query):
    diff = points - query
    return jnp.sum(diff * diff, axis=1, keepdims=True)


def pagerank_step_ref(a_norm, pr, damping=0.85):
    n = a_norm.shape[0]
    return (1.0 - damping) / float(n) + damping * jnp.dot(a_norm, pr)


def neighbor_min_ref(adj_mask, vals, active):
    eligible = adj_mask * active
    candidates = jnp.where(eligible > 0.5, vals, BIG)
    return jnp.min(candidates, axis=1, keepdims=True)
