"""PageRank power-iteration step as a row-blocked Pallas matvec.

Pannotia's PageRank is an irregular gather over CSR; the paper reports it
gains ~nothing from the feed-forward split (0.96x) because its baseline is
already memory-bandwidth saturated.  The dense-matvec substitution keeps
the same roofline position (pure streaming, one MAC per loaded word) while
being expressible as a regular TPU kernel; the Rust IR version keeps the
irregular CSR form (see DESIGN.md substitution table).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, pr_ref, out_ref, *, damping: float, n: int):
    contrib = jnp.dot(a_ref[...], pr_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = (1.0 - damping) / float(n) + damping * contrib


def pagerank_step(a_norm: jax.Array, pr: jax.Array, *, damping: float = 0.85, block_rows: int = 16) -> jax.Array:
    """pr' = (1-d)/n + d * A_norm @ pr, with A_norm column-normalized, pr (N, 1)."""
    n, m = a_norm.shape
    if n != m:
        raise ValueError("a_norm must be square")
    if pr.shape != (n, 1):
        raise ValueError(f"pr must be ({n}, 1)")
    if n % block_rows != 0:
        raise ValueError(f"n={n} not divisible by block_rows={block_rows}")
    kernel = functools.partial(_kernel, damping=damping, n=n)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=True,
    )(a_norm, pr)
