"""Layer-1 Pallas kernels (build-time only).

Each kernel realizes the paper's feed-forward (decoupled access/execute)
structure on TPU-shaped hardware: the BlockSpec index maps express the
HBM->VMEM streaming schedule (the paper's *memory kernel* / pipes), the
kernel body touches only VMEM-resident Refs (the paper's *compute kernel*).
All kernels are lowered with ``interpret=True`` so the AOT artifacts run on
the CPU PJRT client; see DESIGN.md §Hardware-Adaptation.
"""

from .hotspot import hotspot_step
from .fw import fw_step
from .backprop import matmul_sigmoid, matmul_plain
from .knn import knn_dists
from .pagerank import pagerank_step
from .neighbor_min import neighbor_min

__all__ = [
    "hotspot_step",
    "fw_step",
    "matmul_sigmoid",
    "matmul_plain",
    "knn_dists",
    "pagerank_step",
    "neighbor_min",
]
