"""Masked neighbour-min kernel — the dense analogue of the paper's Fig. 2.

The paper's worked example (from Pannotia MIS) computes, for every node,
the minimum ``node_value`` over its *uncolored* neighbours.  The CSR gather
is irregular; the dense-mask substitution (adjacency as a 0/1 matrix)
preserves the reduction structure and produces a golden reference the Rust
interpreter's CSR version is checked against on Tiny graphs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1.0e30  # the paper's BIGNUM sentinel


def _kernel(mask_ref, vals_ref, active_ref, out_ref):
    mask = mask_ref[...]  # (bn, N) 0/1
    vals = vals_ref[...]  # (1, N)
    active = active_ref[...]  # (1, N) 1.0 where the neighbour is still unprocessed
    eligible = mask * active  # neighbour exists and is active
    candidates = jnp.where(eligible > 0.5, vals, BIG)
    out_ref[...] = jnp.min(candidates, axis=1, keepdims=True)


def neighbor_min(adj_mask: jax.Array, vals: jax.Array, active: jax.Array, *, block_rows: int = 16) -> jax.Array:
    """Per-row min of ``vals`` over active neighbours; BIG where none. -> (N, 1)."""
    n, m = adj_mask.shape
    if n != m:
        raise ValueError("adj_mask must be square")
    if vals.shape != (1, n) or active.shape != (1, n):
        raise ValueError(f"vals/active must be (1, {n})")
    if n % block_rows != 0:
        raise ValueError(f"n={n} not divisible by block_rows={block_rows}")
    return pl.pallas_call(
        _kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=True,
    )(adj_mask, vals, active)
