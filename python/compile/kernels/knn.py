"""k-Nearest-Neighbours distance kernel (Rodinia `nn`).

The baseline streams a flat array of reference points and computes the
Euclidean distance of each to one query point — a perfectly sequential,
regular access pattern, i.e. exactly the kind of load the paper's
prefetching LSU (and our BlockSpec streaming pipeline) accelerates.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(pts_ref, q_ref, out_ref):
    diff = pts_ref[...] - q_ref[...]  # (bp, D) - (1, D)
    out_ref[...] = jnp.sum(diff * diff, axis=1, keepdims=True)


def knn_dists(points: jax.Array, query: jax.Array, *, block_points: int = 64) -> jax.Array:
    """Squared L2 distance of each of (P, D) points to the (1, D) query -> (P, 1)."""
    p, d = points.shape
    if query.shape != (1, d):
        raise ValueError(f"query must be (1, {d}), got {query.shape}")
    if p % block_points != 0:
        raise ValueError(f"P={p} not divisible by block_points={block_points}")
    return pl.pallas_call(
        _kernel,
        grid=(p // block_points,),
        in_specs=[
            pl.BlockSpec((block_points, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_points, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, 1), jnp.float32),
        interpret=True,
    )(points, query)
