"""Rodinia Hotspot 5-point stencil as a feed-forward Pallas kernel.

The paper's Hotspot baseline streams a 2D temperature grid and a power grid
through a single work-item loop nest.  The feed-forward transform decouples
the global loads (memory kernel) from the arithmetic (compute kernel).  On
TPU the same decoupling is expressed with BlockSpecs: the grid iterates over
row blocks, and *three* input views of the (row-padded) temperature grid —
the block above, the centre block, and the block below — are streamed
HBM->VMEM by the Pallas pipeline (the "memory kernel"), double-buffered
ahead of the compute body (the "compute kernel"), which only touches VMEM.

Layout contract (see :func:`hotspot_step`):
  * ``temp``  — (R, C) temperature grid.
  * ``power`` — (R, C) dissipated power.
  * boundary handling is edge replication, as in Rodinia's OpenCL port.

Physics (Rodinia formulation)::

  out = t + sdc * (p + (n + s - 2t) * ry + (e + w - 2t) * rx + (amb - t) * rz)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rodinia-flavoured constants (the exact values only scale the update; the
# Rust-side IR benchmark and ref.py use the same ones).
SDC = 0.1
RX = 0.5
RY = 0.4
RZ = 0.05
AMB = 80.0


def _kernel(top_ref, mid_ref, bot_ref, pow_ref, out_ref, *, block_rows: int):
    """Compute one output row-block from three padded input row-blocks.

    ``top_ref``/``mid_ref``/``bot_ref`` are consecutive (block_rows, C+2)
    views of the row/column padded grid; ``mid_ref`` holds the rows this
    program instance produces.  Only VMEM-resident data is touched here —
    the feed-forward contract.
    """
    mid = mid_ref[...]
    # North/south neighbours: shift the centre block by one row, importing
    # the single halo row from the adjacent blocks.
    north = jnp.concatenate([top_ref[block_rows - 1 :, :], mid[:-1, :]], axis=0)
    south = jnp.concatenate([mid[1:, :], bot_ref[:1, :]], axis=0)
    # East/west neighbours come from the column halo inside the block.
    t = mid[:, 1:-1]
    w = mid[:, :-2]
    e = mid[:, 2:]
    n = north[:, 1:-1]
    s = south[:, 1:-1]
    p = pow_ref[...]
    out_ref[...] = t + SDC * (
        p + (n + s - 2.0 * t) * RY + (e + w - 2.0 * t) * RX + (AMB - t) * RZ
    )


def hotspot_step(temp: jax.Array, power: jax.Array, *, block_rows: int = 8) -> jax.Array:
    """One Hotspot time step over an (R, C) grid; returns the (R, C) update.

    R must be divisible by ``block_rows``.
    """
    rows, cols = temp.shape
    if rows % block_rows != 0:
        raise ValueError(f"rows={rows} not divisible by block_rows={block_rows}")
    nblocks = rows // block_rows
    # Pad columns by one (edge replication) and rows by one full block so
    # that the top neighbour of block 0 / bottom neighbour of the last block
    # are resident without clamped index maps.
    padded = jnp.pad(temp, ((block_rows, block_rows), (1, 1)), mode="edge")

    grid = (nblocks,)
    pcols = cols + 2
    kernel = functools.partial(_kernel, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # block above (the memory kernel streams three staggered views)
            pl.BlockSpec((block_rows, pcols), lambda i: (i, 0)),
            # centre block
            pl.BlockSpec((block_rows, pcols), lambda i: (i + 1, 0)),
            # block below
            pl.BlockSpec((block_rows, pcols), lambda i: (i + 2, 0)),
            # power needs no halo
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), temp.dtype),
        interpret=True,
    )(padded, padded, padded, power)
