"""Floyd–Warshall relaxation step as a feed-forward Pallas kernel.

The paper's FW benchmark (Pannotia) is the kernel with the largest headline
speedup (65x): its single work-item loop has a *false* memory loop-carried
dependency (load of ``dist[i][k]``/``dist[k][j]`` vs store of ``dist[i][j]``)
that the offline compiler cannot disprove, so the loop serializes at II=285.
The feed-forward split streams the loads through pipes at II=1.

The TPU analogue: for a fixed pivot ``k`` the update

    dist'[i, j] = min(dist[i, j], dist[i, k] + dist[k, j])

is a data-parallel rank-1 relaxation.  The memory-kernel role is played by
the BlockSpec pipeline streaming row blocks of ``dist`` plus the pivot
column/row slices; the compute kernel is a pure VMEM min/add.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(dist_ref, colk_ref, rowk_ref, out_ref):
    d = dist_ref[...]
    through_k = colk_ref[...] + rowk_ref[...]  # (br,1) + (1,N) -> (br,N)
    out_ref[...] = jnp.minimum(d, through_k)


def fw_step(dist: jax.Array, colk: jax.Array, rowk: jax.Array, *, block_rows: int = 16) -> jax.Array:
    """One pivot relaxation.  ``colk`` is dist[:, k:k+1], ``rowk`` is dist[k:k+1, :]."""
    n, m = dist.shape
    if n != m:
        raise ValueError("dist must be square")
    if n % block_rows != 0:
        raise ValueError(f"n={n} not divisible by block_rows={block_rows}")
    nblocks = n // block_rows
    return pl.pallas_call(
        _kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), dist.dtype),
        interpret=True,
    )(dist, colk, rowk)
