"""Rodinia BackProp layer kernels: MXU-shaped blocked matmul (+ sigmoid).

The paper's BackProp single work-item baseline serializes its weight-update
loop at II=416 because of a false MLCD between the weight loads and the
weight stores.  The feed-forward model streams the loads at II=1.

On TPU the compute hot-spot is a matmul: we tile it for the 128x128 MXU
systolic array (block_m x K resident in VMEM, ``jnp.dot`` with
``preferred_element_type=float32`` so low-precision inputs still accumulate
in f32).  The BlockSpec row-block pipeline is the memory kernel; the MXU
dot is the compute kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, out_ref, *, activation: str):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    if activation == "sigmoid":
        acc = 1.0 / (1.0 + jnp.exp(-acc))
    out_ref[...] = acc.astype(out_ref.dtype)


def _blocked_matmul(x: jax.Array, w: jax.Array, *, block_m: int, activation: str) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    if m % block_m != 0:
        raise ValueError(f"m={m} not divisible by block_m={block_m}")
    kernel = functools.partial(_mm_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def matmul_sigmoid(x: jax.Array, w: jax.Array, *, block_m: int = 8) -> jax.Array:
    """sigmoid(x @ w), row-block tiled."""
    return _blocked_matmul(x, w, block_m=block_m, activation="sigmoid")


def matmul_plain(x: jax.Array, w: jax.Array, *, block_m: int = 8) -> jax.Array:
    """x @ w, row-block tiled (used for the delta/update matmuls)."""
    return _blocked_matmul(x, w, block_m=block_m, activation="none")
