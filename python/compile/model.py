"""Layer-2 JAX models (build-time only; never imported at runtime).

Each function here is the numeric core of one of the paper's benchmarks,
written in JAX and calling the Layer-1 Pallas kernels for its hot loop.
``aot.py`` lowers each entry of :data:`EXPORTS` once to HLO text; the Rust
coordinator loads the artifacts through PJRT and uses them as the golden
numeric reference for the IR interpreter at Tiny scale (and as the compute
payload of the end-to-end example).

All exports are single-output (the xla 0.1.6 crate unwraps 1-tuples
cleanly), f32, with fixed Tiny shapes recorded in the manifest.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import (
    fw_step,
    hotspot_step,
    knn_dists,
    matmul_plain,
    matmul_sigmoid,
    neighbor_min,
    pagerank_step,
)

LR = 0.3  # Rodinia backprop learning rate
DAMPING = 0.85


# --------------------------------------------------------------------------
# Benchmark models
# --------------------------------------------------------------------------

def hotspot(temp, power):
    """One Hotspot stencil step (the per-launch unit the coordinator drives)."""
    return hotspot_step(temp, power, block_rows=8)


def hotspot_multi(temp, power, steps: int = 8):
    """``steps`` Hotspot iterations via lax.fori_loop (no Python unrolling)."""
    def body(_, t):
        return hotspot_step(t, power, block_rows=8)

    return lax.fori_loop(0, steps, body, temp)


def fw(dist):
    """Full Floyd–Warshall: fori_loop over pivots, Pallas relaxation inside."""
    n = dist.shape[0]

    def body(k, d):
        colk = lax.dynamic_slice(d, (0, k), (n, 1))
        rowk = lax.dynamic_slice(d, (k, 0), (1, n))
        return fw_step(d, colk, rowk, block_rows=16)

    return lax.fori_loop(0, n, body, dist)


def backprop_out(x, w1, w2):
    """BackProp forward pass: sigmoid MLP, both layers on the MXU kernel."""
    hidden = matmul_sigmoid(x, w1, block_m=8)
    return matmul_sigmoid(hidden, w2, block_m=8)


def backprop_w1(x, w1, w2, target):
    """One BackProp training step; returns the updated input->hidden weights.

    Rodinia's explicit-gradient formulation (no autodiff through the Pallas
    call needed):
      delta_o = (target - out) * out * (1 - out)
      delta_h = h * (1 - h) * (delta_o @ w2^T)
      w1'     = w1 + lr * x^T @ delta_h
    """
    hidden = matmul_sigmoid(x, w1, block_m=8)
    out = matmul_sigmoid(hidden, w2, block_m=8)
    delta_o = (target - out) * out * (1.0 - out)
    delta_h = hidden * (1.0 - hidden) * matmul_plain(delta_o, w2.T, block_m=8)
    return w1 + LR * matmul_plain(x.T, delta_h, block_m=8)


def knn(points, query):
    """Squared distances of all reference points to one query point."""
    return knn_dists(points, query, block_points=64)


def pagerank(a_norm, pr):
    """One damped power-iteration step."""
    return pagerank_step(a_norm, pr, damping=DAMPING, block_rows=16)


def mis_neighbor_min(adj_mask, vals, active):
    """The paper's Fig. 2 reduction: per-node min over active neighbours."""
    return neighbor_min(adj_mask, vals, active, block_rows=16)


# --------------------------------------------------------------------------
# AOT export registry: name -> (fn, [input ShapeDtypeStructs])
# --------------------------------------------------------------------------

def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


EXPORTS = {
    "hotspot": (hotspot, [_f32(64, 64), _f32(64, 64)]),
    "hotspot_multi": (hotspot_multi, [_f32(64, 64), _f32(64, 64)]),
    "fw": (fw, [_f32(64, 64)]),
    "backprop_out": (backprop_out, [_f32(32, 64), _f32(64, 16), _f32(16, 8)]),
    "backprop_w1": (
        backprop_w1,
        [_f32(32, 64), _f32(64, 16), _f32(16, 8), _f32(32, 8)],
    ),
    "knn": (knn, [_f32(1024, 8), _f32(1, 8)]),
    "pagerank": (pagerank, [_f32(128, 128), _f32(128, 1)]),
    "mis_neighbor_min": (
        mis_neighbor_min,
        [_f32(128, 128), _f32(1, 128), _f32(1, 128)],
    ),
}
