"""AOT lowering: JAX models -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile().serialize()`` / serialized HloModuleProto)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py.

Outputs (under --out-dir, default ../artifacts):
  <name>.hlo.txt   one per EXPORTS entry
  manifest.txt     `name;in=f32[64,64],f32[64,64];out=f32[64,64]` lines

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import EXPORTS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_str(s) -> str:
    return f"{s.dtype}[{','.join(str(d) for d in s.shape)}]"


def lower_one(name: str, out_dir: str) -> str:
    fn, in_specs = EXPORTS[name]
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_spec = jax.eval_shape(fn, *in_specs)
    ins = ",".join(spec_str(s) for s in in_specs)
    return f"{name};in={ins};out={spec_str(out_spec)}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", nargs="*", help="subset of export names")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    names = args.only or list(EXPORTS)
    manifest_lines = []
    for name in names:
        line = lower_one(name, args.out_dir)
        manifest_lines.append(line)
        print(f"lowered {line}")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(names)} artifacts to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
