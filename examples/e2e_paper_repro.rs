//! End-to-end paper reproduction — the full system in one run:
//!
//!  1. all three layers compose: the Rust coordinator loads the AOT-
//!     compiled JAX/Pallas artifacts through PJRT and uses them as the
//!     golden numeric reference for the IR benchmarks;
//!  2. every table and figure of the paper's evaluation is regenerated
//!     on the simulated PAC-A10 substrate (CSVs under results/);
//!  3. the headline claims are compared against the paper's numbers.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_paper_repro [-- --scale small]
//! ```
//! Tiny scale (default) finishes in well under a minute; small is the
//! calibrated configuration recorded in EXPERIMENTS.md (~4 minutes).

use pipefwd::coordinator;
use pipefwd::runtime::{golden, Runtime};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::workloads::Scale;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let scale = if args.iter().any(|a| a == "small") || args.windows(2).any(|w| w[0] == "--scale" && w[1] == "small") {
        Scale::Small
    } else {
        Scale::Tiny
    };
    let cfg = DeviceConfig::pac_a10();

    println!("==============================================================");
    println!(" pipefwd end-to-end reproduction");
    println!(" paper: Enabling the Feed-Forward Design Model in OpenCL");
    println!("        Using Pipes (camera-ready: Improving the Efficiency");
    println!("        of OpenCL Kernels through Pipes)");
    println!("==============================================================\n");

    // ---- Phase 1: three-layer composition (L1 Pallas -> L2 JAX -> L3 Rust)
    println!("[1/3] PJRT golden validation (IR interpreter vs AOT Pallas/JAX)");
    match Runtime::open_default() {
        Ok(rt) => match golden::check_all(&rt) {
            Ok(results) => {
                for (name, d) in results {
                    println!("      {name:>18}: max |diff| = {d:.2e}  OK");
                }
            }
            Err(e) => {
                eprintln!("      GOLDEN VALIDATION FAILED: {e:#}");
                std::process::exit(1);
            }
        },
        Err(e) => {
            println!("      skipped ({e:#}); run `make artifacts` for the full check");
        }
    }

    // ---- Phase 2: the complete evaluation ---------------------------------
    println!("\n[2/3] regenerating every table and figure at {scale:?} scale");
    let t0 = std::time::Instant::now();
    let tables = coordinator::full_evaluation(scale, &cfg, true);
    for t in &tables {
        println!();
        print!("{}", t.to_markdown());
    }
    println!("\n      ({} tables in {:.1}s; CSVs in results/)", tables.len(), t0.elapsed().as_secs_f64());

    // ---- Phase 3: headline comparison --------------------------------------
    println!("\n[3/3] headline claims vs the paper");
    let h = coordinator::headline(scale, &cfg);
    println!("      max feed-forward speedup : {:>6.1}x   paper: up to 65x", h.max_ff_speedup);
    println!("      avg speedup (gainers)    : {:>6.1}x   paper: ~20x average", h.avg_ff_speedup_gainers);
    println!("      best with M2C2           : {:>6.1}x   paper: up to 86x", h.max_total_speedup);

    let ok = h.max_ff_speedup > 20.0 && h.avg_ff_speedup_gainers > 5.0;
    println!(
        "\nend-to-end reproduction {}",
        if ok { "SUCCEEDED: the paper's shape holds on the simulated substrate" } else { "FAILED" }
    );
    if !ok {
        std::process::exit(1);
    }
}
