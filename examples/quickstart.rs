//! Quickstart: take the paper's Fig. 2 kernel, run the feed-forward
//! transformation recipe, look at what the offline compiler sees before
//! and after, execute both on the simulated board, and compare.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use pipefwd::analysis::program_report;
use pipefwd::ir::{pretty, Program, Ty};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::sim::exec::{run_group, ExecOptions};
use pipefwd::sim::mem::MemoryImage;
use pipefwd::sim::perf::PerfModel;
use pipefwd::transform::{examples::fig2_kernel, feedforward};
use pipefwd::workloads::datagen;

fn main() {
    let cfg = DeviceConfig::pac_a10();

    // 1. The baseline single work-item kernel (paper Fig. 2a).
    let baseline = fig2_kernel();
    println!("=== baseline (Fig. 2a) ===");
    print!("{}", pretty::kernel_to_string(&baseline));

    // 2. Apply the feed-forward split (steps 5-11 of the recipe).
    let ff = feedforward(&baseline, 1).expect("no true MLCD -> feasible");
    println!("\n=== feed-forward design (Fig. 2b/2c) ===");
    print!("{}", pretty::program_to_string(&ff));

    // 3. What the offline compiler thinks of each design.
    println!("\n=== early-stage analysis reports ===");
    let base_prog = Program::single(baseline.clone());
    print!("{}", program_report(&base_prog, &cfg).render());
    print!("{}", program_report(&ff, &cfg).render());

    // 4. Run both on a small graph and check the split preserves results.
    let g = datagen::circuit_graph(4096, 8, 7);
    let values = datagen::node_values(g.n, 8);
    let image = || {
        let mut m = MemoryImage::new();
        m.add_i64s("row", &g.row)
            .add_i64s("col", &g.col)
            .add_i64s("c_array", &vec![-1; g.n])
            .add_f32s("node_value", &values)
            .add_zeros("min_array", Ty::F32, g.n)
            .add_zeros("stop", Ty::I32, 1);
        m.set_i("num_nodes", g.n as i64).set_i("num_edges", g.edges() as i64);
        m
    };

    let img_base = image();
    let run_base = run_group(&base_prog, &img_base, &ExecOptions::default()).unwrap();
    let t_base = PerfModel::new(&base_prog, &cfg).estimate(&run_base.profiles);

    let img_ff = image();
    let run_ff = run_group(&ff, &img_ff, &ExecOptions::default()).unwrap();
    let t_ff = PerfModel::new(&ff, &cfg).estimate(&run_ff.profiles);

    assert_eq!(
        img_base.buf("min_array").unwrap().to_f32s(),
        img_ff.buf("min_array").unwrap().to_f32s(),
        "the split must preserve semantics"
    );
    println!("\n=== modelled execution on the PAC-A10 substrate ===");
    println!("baseline     : {:>10.3} ms", t_base.seconds * 1e3);
    println!("feed-forward : {:>10.3} ms", t_ff.seconds * 1e3);
    println!(
        "speedup      : {:>10.2}x  (results identical; this isolated kernel\n\
         \t\t has no MLCD, so it was already pipelined — the gains come\n\
         \t\t from serialized kernels, below)",
        t_base.seconds / t_ff.seconds
    );

    // 5. The same recipe on the full MIS application, whose gather kernel
    //    carries the false MLCD the paper talks about (208 -> 2116 MB/s).
    use pipefwd::transform::Variant;
    use pipefwd::workloads::{by_name, run_workload, Scale};
    let mis = by_name("mis").unwrap();
    let b = run_workload(mis.as_ref(), Variant::Baseline, Scale::Tiny, &cfg).unwrap();
    let f = run_workload(mis.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg)
        .unwrap();
    println!("\n=== full MIS application (serialized baseline) ===");
    println!("baseline II  : {:>10}   (conservative MLCD on min_array)", b.max_ii);
    println!("ff II        : {:>10}", f.max_ii);
    println!("baseline     : {:>10.3} ms", b.metrics.seconds * 1e3);
    println!("feed-forward : {:>10.3} ms", f.metrics.seconds * 1e3);
    println!(
        "speedup      : {:>10.2}x   (paper: 6.47x)",
        b.metrics.seconds / f.metrics.seconds
    );
}
