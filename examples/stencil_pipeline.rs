//! Stencil pipeline: Hotspot through the design variants, cross-checked
//! against the Pallas AOT artifact via PJRT — the regular-grid side of the
//! evaluation, where the feed-forward model costs a little (0.85x) and
//! M2C2 buys it back (the paper's 7340 -> 13660 MB/s bandwidth claim).
//!
//! ```sh
//! make artifacts && cargo run --release --example stencil_pipeline
//! ```

use pipefwd::report::mbps;
use pipefwd::runtime::{golden, Runtime};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::{by_name, run_workload, Scale};

fn main() {
    let cfg = DeviceConfig::pac_a10();

    // 1. Numerics: IR interpreter vs the Pallas kernel through PJRT.
    match Runtime::open_default() {
        Ok(rt) => {
            let d = golden::check_hotspot(&rt).expect("hotspot golden check");
            println!("hotspot vs Pallas artifact (PJRT): max |diff| = {d:.2e}  OK");
        }
        Err(e) => println!("skipping PJRT golden check: {e:#} (run `make artifacts`)"),
    }

    // 2. Performance: the three designs on the simulated board.
    let w = by_name("hotspot").unwrap();
    let mut rows = vec![];
    for variant in [
        Variant::Baseline,
        Variant::FeedForward { depth: 1 },
        Variant::MxCx { parts: 2, depth: 1 },
    ] {
        let h = run_workload(w.as_ref(), variant, Scale::Small, &cfg).unwrap();
        let bw = h.bw_by_unit[w.dominant()];
        println!(
            "{:<12} time {:>8.3} ms   max BW {:>7} MB/s   logic {:>5.2}%",
            variant.label(),
            h.metrics.seconds * 1e3,
            mbps(bw),
            h.area.logic_pct()
        );
        rows.push((variant.label(), h.metrics.seconds, bw));
    }
    let base = rows[0].1;
    let ff = rows[1].1;
    let m2 = rows[2].1;
    println!();
    println!("FF vs baseline : {:.2}x   (paper: 0.85x — channel overhead)", base / ff);
    println!("M2C2 vs FF     : {:.2}x   (paper: ~1.9x, 'up to 93%')", ff / m2);
    println!(
        "M2C2 bandwidth : {} -> {} MB/s   (paper: 7340 -> 13660)",
        mbps(rows[1].2),
        mbps(rows[2].2)
    );
}
