//! Graph analytics on the simulated FPGA: run the three Pannotia-style
//! irregular workloads (BFS, MIS, Coloring) through the full variant
//! matrix and print a mini evaluation — the workloads the paper's intro
//! motivates ("irregular applications suffering from unpredictable
//! control flow and memory accesses").
//!
//! ```sh
//! cargo run --release --example graph_analytics [--scale tiny|small]
//! ```

use pipefwd::report::{fx, mbps, Table};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::{by_name, run_workload, Scale};

fn main() {
    let scale = match std::env::args().nth(2).as_deref() {
        Some("small") => Scale::Small,
        _ => Scale::Tiny,
    };
    let cfg = DeviceConfig::pac_a10();
    let mut t = Table::new(
        "Graph analytics on the simulated PAC-A10",
        &["Benchmark", "Variant", "Time (ms)", "Max BW (MB/s)", "Max II", "Logic (%)"],
    );
    for name in ["bfs", "mis", "color"] {
        let w = by_name(name).unwrap();
        for variant in [
            Variant::Baseline,
            Variant::FeedForward { depth: 1 },
            Variant::MxCx { parts: 2, depth: 1 },
        ] {
            match run_workload(w.as_ref(), variant, scale, &cfg) {
                Ok(h) => {
                    let bw = h
                        .bw_by_unit
                        .get(w.dominant())
                        .copied()
                        .unwrap_or(h.metrics.bw_bytes_per_s);
                    t.row(vec![
                        name.into(),
                        variant.label(),
                        format!("{:.2}", h.metrics.seconds * 1e3),
                        mbps(bw),
                        h.max_ii.to_string(),
                        format!("{:.1}", h.area.logic_pct()),
                    ]);
                }
                Err(e) => {
                    t.row(vec![name.into(), variant.label(), format!("failed: {e}"), "-".into(), "-".into(), "-".into()]);
                }
            }
        }
    }
    print!("{}", t.to_markdown());

    // Paper §3 headline for MIS: bandwidth utilisation rises when the
    // false MLCD goes away (208 -> 2116 MB/s on the authors' board).
    let w = by_name("mis").unwrap();
    let base = run_workload(w.as_ref(), Variant::Baseline, scale, &cfg).unwrap();
    let ff = run_workload(w.as_ref(), Variant::FeedForward { depth: 1 }, scale, &cfg).unwrap();
    let b_bw = base.bw_by_unit[w.dominant()];
    let f_bw = ff.bw_by_unit[w.dominant()];
    println!(
        "MIS dominant-kernel bandwidth: {} -> {} MB/s ({}x; paper: 208 -> 2116)",
        mbps(b_bw),
        mbps(f_bw),
        fx(f_bw / b_bw)
    );
    println!(
        "MIS speedup: {}x (paper: 6.47x)",
        fx(base.metrics.seconds / ff.metrics.seconds)
    );
}
