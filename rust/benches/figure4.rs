//! Bench: regenerate the paper's Figure 4 (M2C2 speedup and resource
//! overhead over the feed-forward baseline) plus the §3 Hotspot M2C2
//! bandwidth claim (7340 -> 13660 MB/s).

use pipefwd::coordinator;
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::bench::{bench_scale, BenchReport};

fn main() {
    let cfg = DeviceConfig::pac_a10();
    let scale = bench_scale();
    let mut b = BenchReport::new("figure4");
    let table = b.sample("generate", || coordinator::figure4(scale, &cfg));
    print!("{}", table.to_markdown());
    let _ = table.save_csv("figure4");
    let (ff_bw, m2_bw) = b.sample("hotspot_bw", || coordinator::hotspot_m2c2_bw(scale, &cfg));
    println!(
        "hotspot bandwidth: FF {:.0} MB/s -> M2C2 {:.0} MB/s ({:+.0}%)   (paper: 7340 -> 13660)",
        ff_bw / 1e6,
        m2_bw / 1e6,
        (m2_bw / ff_bw - 1.0) * 100.0
    );
    b.finish();
}
