//! Bench: regenerate the paper's Figure 4 (M2C2 speedup and resource
//! overhead over the feed-forward baseline) plus the §3 Hotspot M2C2
//! bandwidth claim (7340 -> 13660 MB/s), through the experiment engine —
//! the hotspot feed-forward point is a cache hit from the figure run.

use pipefwd::coordinator::{Engine, ExperimentId};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::bench::{bench_jobs, bench_scale, BenchReport};

fn main() {
    let scale = bench_scale();
    let engine = Engine::new(DeviceConfig::pac_a10(), bench_jobs());
    let mut b = BenchReport::new("figure4");
    b.sample("prewarm_parallel", || engine.prewarm(ExperimentId::E2, scale));
    let table = b.sample("generate", || engine.figure4(scale));
    print!("{}", table.to_markdown());
    let _ = table.save_csv("figure4");
    let (ff_bw, m2_bw) = b.sample("hotspot_bw", || engine.hotspot_m2c2_bw(scale));
    println!(
        "hotspot bandwidth: FF {:.0} MB/s -> M2C2 {:.0} MB/s ({:+.0}%)   (paper: 7340 -> 13660)",
        ff_bw / 1e6,
        m2_bw / 1e6,
        (m2_bw / ff_bw - 1.0) * 100.0
    );
    println!(
        "engine: {} unique configs, {} cache hits, {} jobs",
        engine.cache_len(),
        engine.cache_hits(),
        engine.jobs
    );
    b.finish();
}
