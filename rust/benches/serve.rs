//! Bench: the measurement daemon (PR-6 tentpole). Spawns an in-process
//! `pipefwd serve` over a loopback port, then hits it with N concurrent
//! clients all requesting the same E2 grid. The §Perf signal is the
//! comparison against one serial cold run of that grid: the daemon's
//! wall clock should track ONE cold grid (plus transport noise), not N,
//! and the printed counters prove it — `simulations`/`trace_runs` equal
//! the serial run's, with the overlap answered from the claim/fulfil
//! memo (`requests_deduped`). A final warm client pass shows the
//! fully-memoized round-trip cost (pure wire + encode/decode).

use pipefwd::coordinator::{grid, net, service, Engine, ExperimentId, Service, ServiceRequest};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::bench::{bench_jobs, bench_scale, BenchReport};
use std::sync::Arc;

const CLIENTS: usize = 4;

fn main() {
    let scale = bench_scale();
    let exps = vec![ExperimentId::E2];
    let mut b = BenchReport::new("serve");

    // the cost ceiling: one cold serial grid, no daemon involved
    let reference = Engine::new(DeviceConfig::pac_a10(), bench_jobs());
    let cells = grid(ExperimentId::E2, scale);
    b.sample("serial_cold_grid", || reference.run_cells(&cells));
    println!(
        "serial: {} simulated, {} trace runs",
        reference.simulations(),
        reference.trace_runs()
    );

    let svc = Arc::new(Service::daemon(Engine::new(DeviceConfig::pac_a10(), bench_jobs())));
    let server = net::Server::spawn(
        Arc::clone(&svc),
        "127.0.0.1:0",
        net::ServerConfig { workers: CLIENTS, queue_cap: 64, ..Default::default() },
    )
    .expect("binding a loopback port");
    let addr = server.addr().to_string();

    let fan_out = |b: &mut BenchReport, label: &str| {
        let responses = b.sample(label, || {
            let handles: Vec<_> = (0..CLIENTS)
                .map(|_| {
                    let addr = addr.clone();
                    let exps = exps.clone();
                    std::thread::spawn(move || {
                        net::request(
                            &addr,
                            &ServiceRequest::Run {
                                experiments: exps,
                                scale,
                                shard: None,
                                device: None,
                            },
                        )
                        .expect("daemon answers")
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        });
        for items in &responses {
            let bench = service::cells_to_bench(items, scale, &exps).expect("client sink");
            assert_eq!(
                bench,
                reference.bench_json(scale, &exps),
                "daemon sink must match the serial path byte-for-byte"
            );
        }
    };

    fan_out(&mut b, &format!("cold_grid_x{CLIENTS}_clients"));
    println!(
        "daemon cold: {} simulated, {} trace runs, {} requests deduped, \
         {} clients served, queue depth max {}",
        svc.engine().simulations(),
        svc.engine().trace_runs(),
        svc.requests_deduped(),
        svc.clients_served(),
        svc.queue_depth_max()
    );
    assert_eq!(
        svc.engine().simulations(),
        reference.simulations(),
        "{CLIENTS} overlapping clients must cost one cold grid, not {CLIENTS}"
    );
    assert_eq!(svc.engine().trace_runs(), reference.trace_runs());

    // warm pass: the grid is fully memoized, so this measures the pure
    // transport + codec round-trip
    fan_out(&mut b, &format!("warm_grid_x{CLIENTS}_clients"));
    println!(
        "daemon warm: {} simulated (expect unchanged), {} requests deduped",
        svc.engine().simulations(),
        svc.requests_deduped()
    );
    assert_eq!(svc.engine().simulations(), reference.simulations());

    server.shutdown();
    b.finish();
}
