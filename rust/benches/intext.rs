//! Bench: regenerate the in-text numbers (E4a/E4b) — per-benchmark II
//! before/after the split and max global-memory bandwidth, plus the
//! early-stage compiler reports for FW (the paper's worked example of
//! II 285 -> 1 with a prefetching LSU) — through the experiment engine.

use pipefwd::coordinator::engine::INTEXT_NAMES;
use pipefwd::coordinator::{Cell, Engine};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::util::bench::{bench_jobs, bench_scale, BenchReport};
use pipefwd::workloads::by_name;

fn main() {
    let cfg = DeviceConfig::pac_a10();
    let scale = bench_scale();
    let engine = Engine::new(cfg.clone(), bench_jobs());
    let mut b = BenchReport::new("intext");
    b.sample("prewarm_parallel", || {
        let cells: Vec<Cell> = INTEXT_NAMES
            .iter()
            .flat_map(|n| {
                [Variant::Baseline, Variant::FeedForward { depth: 1 }]
                    .into_iter()
                    .map(|v| Cell::new(n, v, scale))
                    .collect::<Vec<_>>()
            })
            .collect();
        let _ = engine.run_cells(&cells);
    });
    let table = b.sample("metrics", || engine.intext(scale));
    print!("{}", table.to_markdown());
    let _ = table.save_csv("intext");

    b.sample("fw_reports", || {
        let fw = by_name("fw").unwrap();
        for variant in [Variant::Baseline, Variant::FeedForward { depth: 1 }] {
            let app = fw.build(variant).unwrap();
            let rep = pipefwd::analysis::program_report(&app.union_program(), &cfg);
            println!("--- fw {} ---", variant.label());
            print!("{}", rep.render());
        }
    });
    b.finish();
}
