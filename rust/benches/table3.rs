//! Bench: regenerate the paper's Table 3 (microbenchmarks: M2C2 vs
//! baseline across access pattern and divergence) and the extended
//! parametrized family (the paper's future-work sweep).

use pipefwd::coordinator;
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::bench::{bench_scale, BenchReport};

fn main() {
    let cfg = DeviceConfig::pac_a10();
    let scale = bench_scale();
    let mut b = BenchReport::new("table3");
    let table = b.sample("table3", || coordinator::table3(scale, &cfg));
    print!("{}", table.to_markdown());
    let _ = table.save_csv("table3");
    if std::env::var("PIPEFWD_BENCH_FAMILY").is_ok() {
        let fam = b.sample("family", || coordinator::micro_family(scale, &cfg));
        print!("{}", fam.to_markdown());
        let _ = fam.save_csv("micro_family");
    }
    b.finish();
}
