//! Bench: regenerate the paper's Table 3 (microbenchmarks: M2C2 vs
//! baseline across access pattern and divergence) and the extended
//! parametrized family (the paper's future-work sweep), through the
//! experiment engine.

use pipefwd::coordinator::{Engine, ExperimentId};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::bench::{bench_jobs, bench_scale, BenchReport};

fn main() {
    let scale = bench_scale();
    let engine = Engine::new(DeviceConfig::pac_a10(), bench_jobs());
    let mut b = BenchReport::new("table3");
    b.sample("prewarm_parallel", || engine.prewarm(ExperimentId::E3, scale));
    let table = b.sample("table3", || engine.table3(scale));
    print!("{}", table.to_markdown());
    let _ = table.save_csv("table3");
    if std::env::var("PIPEFWD_BENCH_FAMILY").is_ok() {
        b.sample("family_prewarm", || engine.prewarm(ExperimentId::E5, scale));
        let fam = b.sample("family", || engine.micro_family(scale));
        print!("{}", fam.to_markdown());
        let _ = fam.save_csv("micro_family");
    }
    println!(
        "engine: {} unique configs, {} cache hits, {} jobs",
        engine.cache_len(),
        engine.cache_hits(),
        engine.jobs
    );
    b.finish();
}
