//! Bench: regenerate the paper's Table 2 (feed-forward vs single
//! work-item baseline across the benchmark suite).
//!
//! `PIPEFWD_BENCH_SCALE=tiny|small|paper` selects the dataset scale
//! (default small — the calibrated configuration reported in
//! EXPERIMENTS.md).

use pipefwd::coordinator;
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::bench::{bench_scale, BenchReport};

fn main() {
    let cfg = DeviceConfig::pac_a10();
    let scale = bench_scale();
    let mut b = BenchReport::new("table2");
    let table = b.sample("generate", || coordinator::table2(scale, &cfg));
    print!("{}", table.to_markdown());
    let _ = table.save_csv("table2");
    b.finish();
}
