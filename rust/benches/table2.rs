//! Bench: regenerate the paper's Table 2 (feed-forward vs single
//! work-item baseline across the benchmark suite) through the parallel,
//! cache-aware experiment engine.
//!
//! `PIPEFWD_BENCH_SCALE=tiny|small|paper` selects the dataset scale
//! (default small — the calibrated configuration reported in
//! EXPERIMENTS.md). `PIPEFWD_BENCH_JOBS=N` overrides the worker count.

use pipefwd::coordinator::{Engine, ExperimentId};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::bench::{bench_jobs, bench_scale, BenchReport};

fn main() {
    let scale = bench_scale();
    let engine = Engine::new(DeviceConfig::pac_a10(), bench_jobs());
    let mut b = BenchReport::new("table2");
    b.sample("prewarm_parallel", || engine.prewarm(ExperimentId::E1, scale));
    let table = b.sample("generate", || engine.table2(scale));
    print!("{}", table.to_markdown());
    let _ = table.save_csv("table2");
    println!(
        "engine: {} unique configs, {} cache hits, {} jobs",
        engine.cache_len(),
        engine.cache_hits(),
        engine.jobs
    );
    b.finish();
}
