//! Bench: the measurement hot path (PR-4 tentpole). Three ablations, each
//! printing the "before" and "after" legs side by side:
//!
//! 1. **Pipe transport** — the same feed-forward stream pair interpreted
//!    with per-token transfers (depth 1, the historical path: chunk size
//!    derives from declared depth) vs chunked transfers (depth 1024 →
//!    512-token chunks + buffer recycling).
//! 2. **DES scheduler** — `simulate_reference` (O(P) linear scan + the
//!    ever-growing `Vec` DRAM ledger) vs `simulate` (binary heap + epoch
//!    ring) at chunk 1, the scheduling-heaviest configuration; also
//!    prints the two ledgers' live-epoch footprints.
//! 3. **Two-tier measurement pipeline** — a depth ladder through one
//!    engine (interpreter runs once, other rungs replay the shared
//!    trace) vs isolated per-depth engines (the pre-PR-4 cost: one
//!    interpreter run per rung).

use pipefwd::coordinator::Engine;
use pipefwd::ir::build::*;
use pipefwd::ir::{KernelKind, Program, Ty};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::sim::exec::{run_group, ExecOptions};
use pipefwd::sim::mem::MemoryImage;
use pipefwd::sim::perf::PerfModel;
use pipefwd::transform::{feedforward, Variant};
use pipefwd::util::bench::{bench_scale, BenchReport};
use pipefwd::workloads::by_name;

fn stream_pair(depth: usize, n: usize) -> (Program, MemoryImage) {
    let k = KernelBuilder::new("s", KernelKind::SingleWorkItem)
        .buf_ro("a", Ty::F32)
        .buf_wo("o", Ty::F32)
        .scalar("n", Ty::I32)
        .body(vec![for_(
            "i",
            i(0),
            p("n"),
            vec![store("o", v("i"), ld("a", v("i")) * f(2.0))],
        )])
        .finish();
    let ff = feedforward(&k, depth).unwrap();
    let mut m = MemoryImage::new();
    m.add_f32s("a", &vec![1.0; n]).add_zeros("o", Ty::F32, n).set_i("n", n as i64);
    (ff, m)
}

fn main() {
    let mut b = BenchReport::new("interp");
    let n = 200_000usize;

    // 1. per-token vs chunked pipe transfers (2n tokens each)
    let (p1, m1) = stream_pair(1, n);
    let r1 =
        b.sample("pipes_per_token_d1", || run_group(&p1, &m1, &ExecOptions::default()).unwrap());
    let (p2, m2) = stream_pair(1024, n);
    let r2 = b.sample("pipes_chunked_d1024", || {
        run_group(&p2, &m2, &ExecOptions::default()).unwrap()
    });
    assert_eq!(
        r1.profiles.iter().map(|p| p.pipe_writes).sum::<u64>(),
        r2.profiles.iter().map(|p| p.pipe_writes).sum::<u64>(),
        "chunking must not change token counts"
    );

    // 2. DES: linear scan + growing ledger vs heap + epoch ring
    let cfg = DeviceConfig::pac_a10();
    let model = PerfModel::new(&p2, &cfg);
    let lin = b.sample("des_linear_scan_chunk1", || {
        pipefwd::sim::des::simulate_reference(&p2, &model, &r2.profiles, &cfg, 1)
    });
    let heap = b.sample("des_heap_ring_chunk1", || {
        pipefwd::sim::des::simulate(&p2, &model, &r2.profiles, &cfg, 1)
    });
    assert_eq!(lin.cycles, heap.cycles, "the schedulers must agree exactly");
    println!(
        "  des ledgers: Vec reference held {} epochs, epoch ring peaked at {}",
        lin.dram_window, heap.dram_window
    );

    // 3. depth ladder with and without the shared trace tier
    let scale = bench_scale();
    let depths = [1usize, 100, 1000];
    b.sample("depth_ladder_shared_trace", || {
        let e = Engine::serial(DeviceConfig::pac_a10());
        let w = by_name("fw").unwrap();
        for d in depths {
            e.measure(w.as_ref(), Variant::FeedForward { depth: d }, scale).unwrap();
        }
        assert_eq!(e.trace_runs(), 1, "the ladder must share one trace");
    });
    b.sample("depth_ladder_isolated_engines", || {
        let w = by_name("fw").unwrap();
        for d in depths {
            let e = Engine::serial(DeviceConfig::pac_a10());
            e.measure(w.as_ref(), Variant::FeedForward { depth: d }, scale).unwrap();
        }
    });

    b.finish();
}
