//! Bench: the simulator itself — §Perf hot-path measurements (interpreter
//! throughput, pipe overhead, perf-model cost) and the analytic-vs-DES
//! ablation. These are the numbers the EXPERIMENTS.md §Perf log tracks.

use pipefwd::ir::build::*;
use pipefwd::ir::{KernelKind, Program, Ty};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::sim::exec::{run_group, ExecOptions};
use pipefwd::sim::perf::PerfModel;
use pipefwd::util::bench::BenchReport;

fn stream_kernel() -> pipefwd::ir::Kernel {
    KernelBuilder::new("s", KernelKind::SingleWorkItem)
        .buf_ro("a", Ty::F32)
        .buf_ro("b", Ty::F32)
        .buf_wo("o", Ty::F32)
        .scalar("n", Ty::I32)
        .body(vec![for_(
            "i",
            i(0),
            p("n"),
            vec![store(
                "o",
                v("i"),
                ld("a", v("i")) * f(0.5) + ld("b", v("i")).max(f(0.0)),
            )],
        )])
        .finish()
}

fn image(n: usize) -> pipefwd::sim::mem::MemoryImage {
    let mut m = pipefwd::sim::mem::MemoryImage::new();
    m.add_f32s("a", &vec![1.0; n]).add_f32s("b", &vec![2.0; n]).add_zeros("o", Ty::F32, n);
    m.set_i("n", n as i64);
    m
}

fn main() {
    let cfg = DeviceConfig::pac_a10();
    let n = 2_000_000usize;
    let mut b = BenchReport::new("simulator");

    // interpreter throughput, single kernel (profiling on/off)
    for profile in [true, false] {
        let prog = Program::single(stream_kernel());
        let img = image(n);
        let label = if profile { "interp_profiled" } else { "interp_raw" };
        let t0 = std::time::Instant::now();
        b.sample(label, || {
            run_group(&prog, &img, &ExecOptions { profile, ..ExecOptions::default() }).unwrap();
        });
        let dt = t0.elapsed().as_secs_f64();
        println!("{:>40}  {:.1} M iters/s", " ", n as f64 / dt / 1e6);
    }

    // pipe throughput: feed-forward pair moves 2 tokens per element
    {
        let ff = pipefwd::transform::feedforward(&stream_kernel(), 64).unwrap();
        let img = image(n / 4);
        let t0 = std::time::Instant::now();
        b.sample("interp_ff_pipes", || {
            run_group(&ff, &img, &ExecOptions::default()).unwrap();
        });
        let dt = t0.elapsed().as_secs_f64();
        println!("{:>40}  {:.1} M tokens/s", " ", (n / 4 * 2) as f64 / dt / 1e6);
    }

    // perf-model estimation cost + analytic vs DES ablation
    {
        let prog = Program::single(stream_kernel());
        let img = image(n);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let model = PerfModel::new(&prog, &cfg);
        let a = b.sample("analytic_model_x1000", || {
            let mut last = 0.0;
            for _ in 0..1000 {
                last = model.estimate(&run.profiles).cycles;
            }
            last
        });
        let d = b.sample("des_chunk64", || {
            pipefwd::sim::des::simulate(&prog, &model, &run.profiles, &cfg, 64).cycles
        });
        let d1 = b.sample("des_chunk1024", || {
            pipefwd::sim::des::simulate(&prog, &model, &run.profiles, &cfg, 1024).cycles
        });
        println!(
            "{:>40}  analytic {a:.3e} c, DES64 {d:.3e} c ({:+.1}%), DES1024 {d1:.3e} c",
            "ablation",
            (d / a - 1.0) * 100.0
        );
    }
    b.finish();
}
