//! Bench: the paper's sweeps — channel depth (E4c: no significant
//! effect), producer/consumer counts (E4d: plateau past 2x2, shared
//! producer worse) and the vector-type case study (E4e: FW gains ~3x,
//! MIS degrades; Intel's SDK crashed here, our substrate completes it).

use pipefwd::coordinator;
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::bench::{bench_scale, BenchReport};

fn main() {
    let cfg = DeviceConfig::pac_a10();
    let scale = bench_scale();
    let mut b = BenchReport::new("sweeps");
    let names = ["fw", "hotspot", "mis"];
    let t = b.sample("depth_sweep", || coordinator::depth_sweep(&names, scale, &cfg));
    print!("{}", t.to_markdown());
    let _ = t.save_csv("depth_sweep");
    let t = b.sample("pc_sweep", || coordinator::pc_sweep(&names, scale, &cfg));
    print!("{}", t.to_markdown());
    let _ = t.save_csv("pc_sweep");
    let t = b.sample("vector_study", || coordinator::vector_study(scale, &cfg));
    print!("{}", t.to_markdown());
    let _ = t.save_csv("vector_study");
    b.finish();
}
