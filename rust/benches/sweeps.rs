//! Bench: the paper's sweeps — channel depth (E4c: no significant
//! effect), producer/consumer counts (E4d: plateau past 2x2, shared
//! producer worse) and the vector-type case study (E4e: FW gains ~3x,
//! MIS degrades; Intel's SDK crashed here, our substrate completes it).
//!
//! One engine serves all three tables, so the shared feed-forward
//! baselines simulate once (the cache-hit count printed at the end is
//! the §Perf signal for the PR-1 memoization layer).

use pipefwd::coordinator::engine::SWEEP_TRIO;
use pipefwd::coordinator::experiments::DEPTHS;
use pipefwd::coordinator::{Engine, ExperimentId};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::bench::{bench_jobs, bench_scale, BenchReport};

fn main() {
    let scale = bench_scale();
    let engine = Engine::new(DeviceConfig::pac_a10(), bench_jobs());
    let mut b = BenchReport::new("sweeps");
    b.sample("prewarm_parallel", || engine.prewarm(ExperimentId::E4, scale));
    let t = b.sample("depth_sweep", || engine.depth_sweep(&SWEEP_TRIO, scale, &DEPTHS));
    print!("{}", t.to_markdown());
    let _ = t.save_csv("depth_sweep");
    let t = b.sample("pc_sweep", || engine.pc_sweep(&SWEEP_TRIO, scale));
    print!("{}", t.to_markdown());
    let _ = t.save_csv("pc_sweep");
    let t = b.sample("vector_study", || engine.vector_study(scale));
    print!("{}", t.to_markdown());
    let _ = t.save_csv("vector_study");
    println!(
        "engine: {} unique configs, {} cache hits, {} jobs",
        engine.cache_len(),
        engine.cache_hits(),
        engine.jobs
    );
    b.finish();
}
