//! Bench: the persistent measurement store (PR-2 tentpole). Runs the E2
//! grid cold (simulate + persist), then warm from a fresh engine (every
//! cell answered by the store) — the cold/warm wall-clock ratio is the
//! §Perf signal for cross-process caching, and the printed simulation
//! counts prove the warm pass did no work.

use pipefwd::coordinator::{grid, Engine, ExperimentId, Store};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::bench::{bench_jobs, bench_scale, BenchReport};

fn main() {
    let scale = bench_scale();
    let dir = std::env::temp_dir().join(format!("pipefwd-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cells = grid(ExperimentId::E2, scale);
    let mut b = BenchReport::new("store");

    let cold = Engine::new(DeviceConfig::pac_a10(), bench_jobs())
        .with_store(Store::open(&dir).expect("store opens"));
    b.sample("cold_run_and_persist", || cold.run_cells(&cells));
    println!(
        "cold: {} simulated, {} store hits, {} entries persisted",
        cold.simulations(),
        cold.store_hits(),
        cold.store().unwrap().len()
    );

    let warm = Engine::new(DeviceConfig::pac_a10(), bench_jobs())
        .with_store(Store::open(&dir).expect("store opens"));
    b.sample("warm_run_from_store", || warm.run_cells(&cells));
    println!(
        "warm: {} simulated (expect 0), {} store hits",
        warm.simulations(),
        warm.store_hits()
    );

    b.sample("merge_bench_json", || {
        pipefwd::coordinator::merge_bench_json(
            &[Store::open(&dir).expect("store opens")],
            &[ExperimentId::E2],
            scale,
            &DeviceConfig::pac_a10(),
            false,
        )
        .expect("complete store merges")
    });

    let _ = std::fs::remove_dir_all(&dir);
    b.finish();
}
