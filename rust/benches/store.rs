//! Bench: the persistent measurement store (PR-2 tentpole). Runs the E2
//! grid cold (simulate + persist), then warm from a fresh engine (every
//! cell answered by the store) — the cold/warm wall-clock ratio is the
//! §Perf signal for cross-process caching, and the printed simulation
//! counts prove the warm pass did no work.
//!
//! PR 5 adds two sections:
//! * **Profile pool dedup** — a pagerank convergence trace (every power
//!   iteration re-launches byte-identical kernels) persisted through the
//!   v4 pool vs. what the inline (v3) encoding would have written:
//!   on-disk bytes, dedup ratio, and put/get wall clock.
//! * **Vouch leverage** — the bfs and pagerank depth ladders with and
//!   without the benign-race vouch. bfs is where the vouch is
//!   load-bearing (its split shares the writable `cost`, so stripping
//!   the vouch costs one interpreter run per rung: 3 vs 1 — the biggest
//!   remaining `trace_runs` hot spot before PR 5). pagerank is the
//!   control: its split already passes the syntactic
//!   `unit_depth_invariant` check, so both columns read 1 and the vouch
//!   is documentation, not a key change.

use pipefwd::coordinator::{grid, Engine, ExperimentId, Store};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::util::bench::{bench_jobs, bench_scale, BenchReport};
use pipefwd::workloads::{by_name, run_built_workload_recorded, Scale, Workload};

/// `inner` with its benign-race vouch stripped — what the PR-4 engine
/// saw for bfs (for already-syntactically-invariant workloads like
/// pagerank this changes nothing, which is the control the bench
/// prints). Same kernels, same datasets, same validation; only the
/// vouch bit differs.
struct Unvouched(Box<dyn Workload>);

impl Workload for Unvouched {
    fn name(&self) -> &'static str {
        self.0.name()
    }
    fn suite(&self) -> &'static str {
        self.0.suite()
    }
    fn dwarf(&self) -> &'static str {
        self.0.dwarf()
    }
    fn pattern(&self) -> &'static str {
        self.0.pattern()
    }
    fn dataset_desc(&self, scale: Scale) -> String {
        self.0.dataset_desc(scale)
    }
    fn dominant(&self) -> &'static str {
        self.0.dominant()
    }
    fn kernels(&self) -> Vec<pipefwd::ir::Kernel> {
        self.0.kernels()
    }
    fn privatize_first(&self) -> Vec<&'static str> {
        self.0.privatize_first()
    }
    fn supports_replication(&self) -> bool {
        self.0.supports_replication()
    }
    fn benign_cross_kernel_races(&self) -> bool {
        false // the point of the wrapper
    }
    fn image(&self, scale: Scale) -> pipefwd::sim::mem::MemoryImage {
        self.0.image(scale)
    }
    fn run(
        &self,
        app: &pipefwd::workloads::App,
        img: &mut pipefwd::sim::mem::MemoryImage,
        h: &mut pipefwd::workloads::Harness,
    ) -> Result<(), pipefwd::sim::exec::ExecError> {
        self.0.run(app, img, h)
    }
    fn validate(&self, img: &pipefwd::sim::mem::MemoryImage, scale: Scale) -> Result<(), String> {
        self.0.validate(img, scale)
    }
}

/// How many interpreter runs a depth ladder costs with vs. without the
/// workload's benign-race vouch (cold engines, no store).
/// `expect_unvouched` makes the printed signal honest: 3 where the vouch
/// is load-bearing (bfs), 1 where the syntactic check already masks
/// depth and the vouch only documents the semantics (pagerank).
fn vouch_ladder(b: &mut BenchReport, name: &str, expect_unvouched: u64) {
    let depths = [1usize, 100, 1000];
    let vouched = Engine::new(DeviceConfig::pac_a10(), 1);
    b.sample(&format!("{name}_ladder_vouched"), || {
        let w = by_name(name).unwrap();
        for d in depths {
            let _ = vouched.measure(w.as_ref(), Variant::FeedForward { depth: d }, Scale::Tiny);
        }
    });
    let plain = Engine::new(DeviceConfig::pac_a10(), 1);
    b.sample(&format!("{name}_ladder_unvouched"), || {
        let w = Unvouched(by_name(name).unwrap());
        for d in depths {
            let _ = plain.measure(&w, Variant::FeedForward { depth: d }, Scale::Tiny);
        }
    });
    assert_eq!(vouched.trace_runs(), 1, "{name}: vouched ladder must share one trace");
    assert_eq!(
        plain.trace_runs(),
        expect_unvouched,
        "{name}: unvouched ladder expectation drifted"
    );
    println!(
        "{name} depth ladder: vouched {} interpreter runs, unvouched {} \
         (trace hits {} vs {}){}",
        vouched.trace_runs(),
        plain.trace_runs(),
        vouched.trace_hits(),
        plain.trace_hits(),
        if expect_unvouched == 1 { "  [control: syntactic check already masks]" } else { "" },
    );
}

fn main() {
    let scale = bench_scale();
    let dir = std::env::temp_dir().join(format!("pipefwd-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cells = grid(ExperimentId::E2, scale);
    let mut b = BenchReport::new("store");

    let cold = Engine::new(DeviceConfig::pac_a10(), bench_jobs())
        .with_store(Store::open(&dir).expect("store opens"));
    b.sample("cold_run_and_persist", || cold.run_cells(&cells));
    println!(
        "cold: {} simulated, {} store hits, {} entries persisted",
        cold.simulations(),
        cold.store_hits(),
        cold.store().unwrap().len()
    );

    let warm = Engine::new(DeviceConfig::pac_a10(), bench_jobs())
        .with_store(Store::open(&dir).expect("store opens"));
    b.sample("warm_run_from_store", || warm.run_cells(&cells));
    println!(
        "warm: {} simulated (expect 0), {} store hits",
        warm.simulations(),
        warm.store_hits()
    );

    b.sample("merge_bench_json", || {
        pipefwd::coordinator::merge_bench_json(
            &[Store::open(&dir).expect("store opens")],
            &[ExperimentId::E2],
            scale,
            &DeviceConfig::pac_a10(),
            false,
        )
        .expect("complete store merges")
    });

    // -- profile-pool dedup on a convergence trace (PR 5) -------------------
    let pr = by_name("pagerank").unwrap();
    let app = pr.build(Variant::FeedForward { depth: 1 }).unwrap();
    let (_, trace) =
        run_built_workload_recorded(pr.as_ref(), &app, Scale::Tiny, &DeviceConfig::pac_a10(), false)
            .expect("pagerank tiny records");
    let inline_bytes = trace.to_json().to_compact().len();
    let pool_dir =
        std::env::temp_dir().join(format!("pipefwd-bench-pool-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&pool_dir);
    let pool = Store::open(&pool_dir).expect("pool store opens");
    let tkey = pipefwd::coordinator::trace_key(
        "pagerank",
        pr.benign_cross_kernel_races(),
        &app,
        Scale::Tiny,
    );
    b.sample("pool_put_convergence_trace", || {
        pool.put_trace(tkey, &Ok(trace.clone())).expect("trace persists")
    });
    b.sample("pool_get_convergence_trace", || {
        pool.get_trace(tkey).expect("trace resolves").expect("trace is ok")
    });
    let stats = pool.stats();
    println!(
        "pagerank convergence trace: {} launches, {} profile refs -> {} pooled \
         (dedup {:.1}x); pooled {} B (trace {} + pool {}) vs inline {} B ({:.1}% of inline)",
        trace.launches.len(),
        stats.profile_refs,
        stats.profiles.count,
        stats.dedup_ratio(),
        stats.traces.bytes + stats.profiles.bytes,
        stats.traces.bytes,
        stats.profiles.bytes,
        inline_bytes,
        100.0 * (stats.traces.bytes + stats.profiles.bytes) as f64 / inline_bytes as f64,
    );
    let _ = std::fs::remove_dir_all(&pool_dir);

    // -- vouch leverage: graph-trio depth ladders (PR 5) --------------------
    vouch_ladder(&mut b, "bfs", 3); // vouch is load-bearing: 3 -> 1
    vouch_ladder(&mut b, "pagerank", 1); // control: already syntactically invariant

    let _ = std::fs::remove_dir_all(&dir);
    b.finish();
}
