//! LSU (load/store unit) selection, mirroring §2.2 of the paper.
//!
//! The offline compiler instantiates one LSU per global-memory site:
//!
//! * **Burst-coalesced** — the resource-hungry default; buffers requests
//!   until the largest possible burst can be issued.
//! * **Prefetching** — FIFO streaming; chosen for *loads* with a proven
//!   sequential pattern when nothing else may write the buffer during the
//!   kernel's execution (this is the LSU the feed-forward memory kernel
//!   unlocks — the paper's FW gets one on 1 of its 3 loads).
//! * **Pipelined** — cheap, submits accesses as they come; used for
//!   loop-invariant scalar-ish accesses.
//!
//! Site numbering is pre-order over the kernel body and must match the
//! interpreter's numbering (`sim::exec` walks the same IR the same way).

use super::pattern::{classify_index, AccessPattern};
use super::LoopCtx;
use crate::ir::{Access, Expr, Kernel, LoopId, Stmt};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LsuKind {
    BurstCoalesced,
    Prefetching,
    Pipelined,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemSiteKind {
    Load,
    Store,
}

/// One static global-memory access site.
#[derive(Debug, Clone, PartialEq)]
pub struct MemSite {
    /// Pre-order site id (loads and stores share one numbering space).
    pub site: usize,
    pub kind: MemSiteKind,
    pub buf: String,
    pub pattern: AccessPattern,
    /// Innermost enclosing loop, if any.
    pub loop_id: Option<LoopId>,
    pub lsu: LsuKind,
}

/// Enumerate all memory sites of a kernel and select LSUs.
pub fn select_lsus(kernel: &Kernel) -> Vec<MemSite> {
    // A buffer is "quiescent" for prefetching if this kernel never stores
    // to it and it is not declared read-write (another concurrent kernel
    // could be writing a ReadWrite buffer — conservative, like the SDK).
    let mut stored: Vec<String> = vec![];
    crate::ir::stmt::visit_body(&kernel.body, &mut |s| {
        if let Stmt::Store { buf, .. } = s {
            if !stored.contains(buf) {
                stored.push(buf.clone());
            }
        }
    });

    // Loop-variance tracking: a variable declared *inside* the innermost
    // loop body (e.g. `j = col[e]`) varies per iteration even though it is
    // not the induction variable; an index referencing it must not be
    // classified LoopInvariant (it is data-dependent, i.e. Irregular
    // unless it is affine in the induction variable itself).
    fn classify(
        idx: &crate::ir::Expr,
        innermost: Option<&LoopCtx>,
        variant_vars: &std::collections::HashSet<String>,
    ) -> AccessPattern {
        let base = classify_index(idx, innermost.map(|c| c.var.as_str()));
        if matches!(base, AccessPattern::LoopInvariant) {
            let mut data_dep = false;
            idx.visit(&mut |e| {
                if let Expr::Var(v) = e {
                    if variant_vars.contains(v) {
                        data_dep = true;
                    }
                }
            });
            if data_dep {
                return AccessPattern::Irregular;
            }
        }
        base
    }

    struct W<'a> {
        kernel: &'a crate::ir::Kernel,
        stored: Vec<String>,
        sites: Vec<MemSite>,
        next: usize,
    }

    impl<'a> W<'a> {
        fn stmt_sites(
            &mut self,
            s: &Stmt,
            stack: &[LoopCtx],
            variant: &std::collections::HashSet<String>,
        ) {
            let innermost = stack.last();
            s.visit_own_exprs(&mut |e| {
                e.visit(&mut |node| {
                    if let Expr::Load { buf, idx } = node {
                        let pattern = classify(idx, innermost, variant);
                        let quiescent = !self.stored.contains(buf)
                            && self
                                .kernel
                                .buf(buf)
                                .map(|b| b.access == Access::ReadOnly)
                                .unwrap_or(false);
                        let lsu = match pattern {
                            AccessPattern::Sequential if quiescent => LsuKind::Prefetching,
                            AccessPattern::LoopInvariant => LsuKind::Pipelined,
                            _ => LsuKind::BurstCoalesced,
                        };
                        self.sites.push(MemSite {
                            site: self.next,
                            kind: MemSiteKind::Load,
                            buf: buf.clone(),
                            pattern,
                            loop_id: innermost.map(|c| c.id),
                            lsu,
                        });
                        self.next += 1;
                    }
                });
            });
            if let Stmt::Store { buf, idx, .. } = s {
                let pattern = classify(idx, innermost, variant);
                self.sites.push(MemSite {
                    site: self.next,
                    kind: MemSiteKind::Store,
                    buf: buf.clone(),
                    pattern,
                    loop_id: innermost.map(|c| c.id),
                    lsu: LsuKind::BurstCoalesced,
                });
                self.next += 1;
            }
        }

        fn go(
            &mut self,
            body: &[Stmt],
            stack: &mut Vec<LoopCtx>,
            variant: &mut std::collections::HashSet<String>,
        ) {
            for s in body {
                self.stmt_sites(s, stack, variant);
                match s {
                    Stmt::For { id, var, body, .. } => {
                        stack.push(LoopCtx { id: *id, var: var.clone() });
                        // fresh variance scope for the new innermost loop
                        let mut inner_variant = std::collections::HashSet::new();
                        self.go(body, stack, &mut inner_variant);
                        stack.pop();
                    }
                    Stmt::If { then_b, else_b, .. } => {
                        self.go(then_b, stack, variant);
                        self.go(else_b, stack, variant);
                    }
                    Stmt::Let { var, .. } | Stmt::PipeRead { var, .. } => {
                        variant.insert(var.clone());
                    }
                    _ => {}
                }
            }
        }
    }

    let mut w = W { kernel, stored, sites: vec![], next: 0 };
    let mut stack = vec![];
    let mut variant = std::collections::HashSet::new();
    let body = kernel.body.clone();
    w.go(&body, &mut stack, &mut variant);
    w.sites
}

/// Count sites by LSU kind (area model input).
pub fn lsu_counts(sites: &[MemSite]) -> (usize, usize, usize) {
    let mut bc = 0;
    let mut pf = 0;
    let mut pl = 0;
    for s in sites {
        match s.lsu {
            LsuKind::BurstCoalesced => bc += 1,
            LsuKind::Prefetching => pf += 1,
            LsuKind::Pipelined => pl += 1,
        }
    }
    (bc, pf, pl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, Ty};

    #[test]
    fn sequential_readonly_gets_prefetching() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("a", v("i")))],
            )])
            .finish();
        let sites = select_lsus(&k);
        assert_eq!(sites.len(), 2);
        assert_eq!(sites[0].kind, MemSiteKind::Load);
        assert_eq!(sites[0].lsu, LsuKind::Prefetching);
        assert_eq!(sites[0].pattern, AccessPattern::Sequential);
        assert_eq!(sites[1].kind, MemSiteKind::Store);
        assert_eq!(sites[1].lsu, LsuKind::BurstCoalesced);
    }

    #[test]
    fn rw_buffer_load_is_burst_coalesced_even_if_sequential() {
        // Same-buffer store elsewhere in the kernel forbids prefetching.
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_rw("a", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("a", v("i"), ld("a", v("i")) * f(2.0))],
            )])
            .finish();
        let sites = select_lsus(&k);
        assert_eq!(sites[0].lsu, LsuKind::BurstCoalesced);
    }

    #[test]
    fn indirect_load_is_irregular_burst_coalesced() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("col", Ty::I32)
            .buf_ro("val", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("val", ld("col", v("i"))))],
            )])
            .finish();
        let sites = select_lsus(&k);
        // pre-order inside the store's value: val[col[i]] visits val first
        // (outer), then col (inner index).
        let val_site = sites.iter().find(|s| s.buf == "val").unwrap();
        assert_eq!(val_site.pattern, AccessPattern::Irregular);
        assert_eq!(val_site.lsu, LsuKind::BurstCoalesced);
        let col_site = sites.iter().find(|s| s.buf == "col").unwrap();
        assert_eq!(col_site.pattern, AccessPattern::Sequential);
        assert_eq!(col_site.lsu, LsuKind::Prefetching);
    }

    #[test]
    fn loop_invariant_gets_pipelined() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .scalar("base", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("a", p("base")))],
            )])
            .finish();
        let sites = select_lsus(&k);
        assert_eq!(sites[0].lsu, LsuKind::Pipelined);
        assert_eq!(sites[0].pattern, AccessPattern::LoopInvariant);
    }

    #[test]
    fn site_ids_are_dense_preorder() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![
                    let_f("x", ld("a", v("i"))),
                    let_f("y", ld("a", v("i") + i(1))),
                    store("o", v("i"), v("x") + v("y")),
                ],
            )])
            .finish();
        let sites = select_lsus(&k);
        assert_eq!(sites.iter().map(|s| s.site).collect::<Vec<_>>(), vec![0, 1, 2]);
    }
}
