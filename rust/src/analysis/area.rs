//! Resource-utilization model (logic %, BRAM, DSP).
//!
//! The paper reports logic utilization as a percentage of the board's half
//! ALMs and BRAM as M20K block counts (Table 2/3, Fig. 4). The model sums:
//! board shell + per-kernel control + per-arith-op logic + per-LSU blocks +
//! per-channel endpoints, with constants in [`DeviceConfig`] calibrated so
//! the Table 2 baselines land in the paper's 16-25% / 400-800 BRAM range.

use super::lsu::{select_lsus, LsuKind, MemSite, MemSiteKind};
use crate::ir::{BinOp, Expr, Kernel, Program, Stmt, UnOp};
use crate::sim::device::DeviceConfig;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaEstimate {
    /// Fraction of device logic (0..1), shell included.
    pub logic_frac: f64,
    /// M20K blocks, shell included.
    pub brams: u32,
    /// DSP blocks.
    pub dsps: u32,
}

impl AreaEstimate {
    pub fn logic_pct(&self) -> f64 {
        self.logic_frac * 100.0
    }
}

/// ALM/DSP cost of one operator instance.
fn op_cost(op: &BinOp, float: bool) -> (f64, u32) {
    use BinOp::*;
    match op {
        Add | Sub => {
            if float {
                (620.0, 0)
            } else {
                (34.0, 0)
            }
        }
        Mul => {
            if float {
                (130.0, 1)
            } else {
                (60.0, 1)
            }
        }
        Div | Rem => {
            if float {
                (1_900.0, 4)
            } else {
                (900.0, 0)
            }
        }
        Min | Max => {
            if float {
                (540.0, 0)
            } else {
                (40.0, 0)
            }
        }
        _ => (24.0, 0), // comparisons / logic
    }
}

fn un_cost(op: &UnOp) -> (f64, u32) {
    use UnOp::*;
    match op {
        Sqrt => (2_300.0, 6),
        Exp => (3_400.0, 10),
        IToF | FToI => (180.0, 0),
        Neg | Not | Abs => (30.0, 0),
    }
}

fn expr_area(e: &Expr, alms: &mut f64, dsps: &mut u32) {
    e.visit(&mut |node| match node {
        Expr::Bin(op, ..) => {
            // Float-ness of individual nodes is approximated: benchmarks
            // mix int index math (cheap either way) and float datapath.
            let (a, d) = op_cost(op, true);
            let (ai, _) = op_cost(op, false);
            // Weighted blend: index arithmetic dominates op counts ~2:1.
            *alms += 0.4 * a + 0.6 * ai;
            *dsps += d;
        }
        Expr::Un(op, _) => {
            let (a, d) = un_cost(op);
            *alms += a;
            *dsps += d;
        }
        Expr::Select(..) => *alms += 60.0,
        _ => {}
    });
}

/// Area of one kernel (its body logic + its LSUs), without shell.
pub fn kernel_area(kernel: &Kernel, cfg: &DeviceConfig) -> (f64, u32, u32) {
    let mut alms = cfg.kernel_alms;
    let mut brams = cfg.kernel_brams;
    let mut dsps = 0u32;

    crate::ir::stmt::visit_body(&kernel.body, &mut |s| {
        match s {
            Stmt::Let { expr, .. } | Stmt::Assign { expr, .. } => expr_area(expr, &mut alms, &mut dsps),
            Stmt::Store { idx, val, .. } => {
                expr_area(idx, &mut alms, &mut dsps);
                expr_area(val, &mut alms, &mut dsps);
            }
            Stmt::If { cond, .. } => expr_area(cond, &mut alms, &mut dsps),
            Stmt::For { lo, hi, .. } => {
                expr_area(lo, &mut alms, &mut dsps);
                expr_area(hi, &mut alms, &mut dsps);
                alms += 120.0; // loop control
            }
            Stmt::PipeWrite { val, .. } => {
                expr_area(val, &mut alms, &mut dsps);
                alms += cfg.channel_alms;
            }
            Stmt::PipeRead { .. } => alms += cfg.channel_alms,
        }
    });

    // LSU area: the offline compiler shares one physical LSU per
    // (buffer, access kind) — unrolled sibling sites multiplex into it, so
    // additional sites on the same port only add a small mux/arbiter.
    let mut seen: Vec<(String, MemSiteKind, LsuKind)> = vec![];
    for site in select_lsus(kernel) {
        let key = (site.buf.clone(), site.kind, site.lsu);
        let (a, b) = lsu_area(&site, cfg);
        if seen.contains(&key) {
            alms += a * 0.15;
        } else {
            alms += a;
            brams += b;
            seen.push(key);
        }
    }
    (alms, brams, dsps)
}

fn lsu_area(site: &MemSite, cfg: &DeviceConfig) -> (f64, u32) {
    match site.lsu {
        LsuKind::BurstCoalesced => (cfg.lsu_burst_alms, cfg.lsu_burst_brams),
        LsuKind::Prefetching => (cfg.lsu_prefetch_alms, cfg.lsu_prefetch_brams),
        LsuKind::Pipelined => (cfg.lsu_pipelined_alms, cfg.lsu_pipelined_brams),
    }
}

/// Area of a whole program (shell + kernels + channel FIFOs).
pub fn estimate_program_area(prog: &Program, cfg: &DeviceConfig) -> AreaEstimate {
    let mut alms = cfg.shell_logic_frac * cfg.total_alms;
    let mut brams = cfg.shell_brams;
    let mut dsps = 0u32;
    for k in &prog.kernels {
        let (a, b, d) = kernel_area(k, cfg);
        alms += a;
        brams += b;
        dsps += d;
    }
    for pipe in &prog.pipes {
        // FIFO storage: shallow channels fit in registers; deep ones use
        // M20Ks (512 32-bit words per block).
        brams += (pipe.depth / cfg.channel_words_per_bram) as u32;
        if pipe.depth > 16 {
            brams += 1;
        }
    }
    AreaEstimate { logic_frac: alms / cfg.total_alms, brams, dsps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, PipeDecl, Program, Ty};

    fn simple_kernel() -> Kernel {
        KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("a", v("i")) * f(2.0) + f(1.0))],
            )])
            .finish()
    }

    #[test]
    fn baseline_lands_in_paper_band() {
        let cfg = DeviceConfig::pac_a10();
        let prog = Program::single(simple_kernel());
        let a = estimate_program_area(&prog, &cfg);
        // Paper baselines: 16-25% logic, 400-810 BRAM.
        assert!(a.logic_pct() > 14.5 && a.logic_pct() < 26.0, "logic={}", a.logic_pct());
        assert!(a.brams >= 390 && a.brams <= 820, "brams={}", a.brams);
    }

    #[test]
    fn split_program_costs_more_logic() {
        let cfg = DeviceConfig::pac_a10();
        let single = Program::single(simple_kernel());
        let mut split = Program::single(simple_kernel());
        split.kernels.push(
            KernelBuilder::new("k2", KernelKind::SingleWorkItem)
                .buf_ro("a", Ty::F32)
                .scalar("n", Ty::I32)
                .body(vec![for_("i", i(0), p("n"), vec![pwrite("c0", ld("a", v("i")))])])
                .finish(),
        );
        split.pipes.push(PipeDecl { name: "c0".into(), ty: Ty::F32, depth: 1 });
        let a1 = estimate_program_area(&single, &cfg);
        let a2 = estimate_program_area(&split, &cfg);
        assert!(a2.logic_frac > a1.logic_frac);
        assert!(a2.brams >= a1.brams);
    }

    #[test]
    fn deep_channels_use_brams() {
        let cfg = DeviceConfig::pac_a10();
        let mut p1 = Program::single(simple_kernel());
        p1.pipes.push(PipeDecl { name: "c".into(), ty: Ty::F32, depth: 1 });
        let mut p2 = p1.clone();
        p2.pipes[0].depth = 1024;
        let shallow = estimate_program_area(&p1, &cfg).brams;
        let deep = estimate_program_area(&p2, &cfg).brams;
        assert!(deep > shallow);
    }
}
