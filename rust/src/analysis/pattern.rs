//! Static memory-access-pattern classification.
//!
//! The offline compiler infers, per load/store site, how its address moves
//! as the *innermost enclosing loop* advances (§2.2: this drives LSU
//! selection). We classify the index expression symbolically:
//!
//! * `Sequential`   — affine with stride ±1 in the loop var (prefetchable)
//! * `Strided(c)`   — affine with literal stride |c| > 1
//! * `LoopInvariant`— does not move with the loop (scalar-cacheable)
//! * `Irregular`    — anything else, in particular indirect (`a[b[i]]`)

use crate::ir::{BinOp, Expr, UnOp};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    Sequential,
    Strided(i64),
    LoopInvariant,
    Irregular,
}

impl AccessPattern {
    pub fn is_regular(self) -> bool {
        !matches!(self, AccessPattern::Irregular)
    }
}

/// Symbolic affine decomposition of `e` with respect to `var`:
/// `e = stride * var + offset + residue`, where `residue` must not contain
/// `var`. Returns `(stride, const_offset, residue_fingerprint)`.
/// `None` means not affine in `var` (e.g. contains a load, `var*var`, ...).
pub fn affine_wrt(e: &Expr, var: &str) -> Option<(i64, i64, String)> {
    match e {
        Expr::I(c) => Some((0, *c, String::new())),
        Expr::F(_) => None,
        Expr::Var(v) => {
            if v == var {
                Some((1, 0, String::new()))
            } else {
                Some((0, 0, format!("v:{v}")))
            }
        }
        Expr::Param(p) => Some((0, 0, format!("p:{p}"))),
        Expr::GlobalId(d) => Some((0, 0, format!("g:{d}"))),
        Expr::Load { .. } => None,
        Expr::Un(UnOp::Neg, a) => {
            let (s, c, r) = affine_wrt(a, var)?;
            let rr = if r.is_empty() { r } else { format!("neg({r})") };
            Some((-s, -c, rr))
        }
        Expr::Un(_, _) => None,
        Expr::Select(..) => None,
        Expr::Bin(op, a, b) => {
            let (sa, ca, ra) = affine_wrt(a, var)?;
            let (sb, cb, rb) = affine_wrt(b, var)?;
            match op {
                BinOp::Add => Some((sa + sb, ca + cb, join(&ra, "+", &rb))),
                BinOp::Sub => Some((sa - sb, ca - cb, join(&ra, "-", &rb))),
                BinOp::Mul => {
                    // Only (affine * literal-const) stays affine.
                    if sb == 0 && rb.is_empty() {
                        Some((sa * cb, ca * cb, scale(&ra, cb)))
                    } else if sa == 0 && ra.is_empty() {
                        Some((sb * ca, cb * ca, scale(&rb, ca)))
                    } else if sa == 0 && sb == 0 {
                        // var-free product: residue only
                        Some((0, 0, format!("({ra}#{ca})*({rb}#{cb})")))
                    } else {
                        None
                    }
                }
                _ => {
                    // Division/remainder/comparisons: treat as var-free
                    // residue when neither side moves with the loop.
                    if sa == 0 && sb == 0 {
                        Some((0, 0, format!("({ra}#{ca}){}({rb}#{cb})", op.c_symbol())))
                    } else {
                        None
                    }
                }
            }
        }
    }
}

fn join(a: &str, op: &str, b: &str) -> String {
    match (a.is_empty(), b.is_empty()) {
        (true, true) => String::new(),
        (false, true) => a.to_string(),
        (true, false) => {
            if op == "-" {
                format!("-({b})")
            } else {
                b.to_string()
            }
        }
        (false, false) => format!("({a}){op}({b})"),
    }
}

fn scale(r: &str, c: i64) -> String {
    if r.is_empty() {
        String::new()
    } else {
        format!("{c}*({r})")
    }
}

/// Classify an index expression with respect to the innermost loop variable
/// (`None` = the access is not inside any loop).
pub fn classify_index(idx: &Expr, innermost_var: Option<&str>) -> AccessPattern {
    let var = match innermost_var {
        Some(v) => v,
        None => return AccessPattern::LoopInvariant,
    };
    match affine_wrt(idx, var) {
        None => AccessPattern::Irregular,
        Some((0, _, _)) => AccessPattern::LoopInvariant,
        Some((1, _, _)) | Some((-1, _, _)) => AccessPattern::Sequential,
        Some((s, _, _)) => AccessPattern::Strided(s),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;

    #[test]
    fn sequential() {
        assert_eq!(classify_index(&v("i"), Some("i")), AccessPattern::Sequential);
        assert_eq!(classify_index(&(v("i") + i(5)), Some("i")), AccessPattern::Sequential);
        assert_eq!(
            classify_index(&(v("base") + v("i")), Some("i")),
            AccessPattern::Sequential
        );
    }

    #[test]
    fn strided() {
        assert_eq!(classify_index(&(v("i") * i(4)), Some("i")), AccessPattern::Strided(4));
        assert_eq!(
            classify_index(&(v("i") * i(4) + v("j")), Some("i")),
            AccessPattern::Strided(4)
        );
    }

    #[test]
    fn invariant() {
        assert_eq!(classify_index(&v("j"), Some("i")), AccessPattern::LoopInvariant);
        assert_eq!(
            classify_index(&(p("n") * v("j") + i(3)), Some("i")),
            AccessPattern::LoopInvariant
        );
        assert_eq!(classify_index(&v("i"), None), AccessPattern::LoopInvariant);
    }

    #[test]
    fn irregular_indirect() {
        let e = ld("col", v("i"));
        assert_eq!(classify_index(&e, Some("i")), AccessPattern::Irregular);
        // a[col[i]] style
        assert_eq!(
            classify_index(&(ld("col", v("i")) + i(1)), Some("i")),
            AccessPattern::Irregular
        );
    }

    #[test]
    fn irregular_nonaffine() {
        assert_eq!(classify_index(&(v("i") * v("i")), Some("i")), AccessPattern::Irregular);
        // symbolic (parameter) stride is not provably regular
        assert_eq!(classify_index(&(v("i") * p("n")), Some("i")), AccessPattern::Irregular);
    }

    #[test]
    fn affine_distance_fingerprints() {
        // m[i*w + j] vs m[i*w + j - 1]: same residue, offsets differ by 1.
        let a = v("i") * i(64) + v("j");
        let b = v("i") * i(64) + v("j") - i(1);
        let (sa, ca, ra) = affine_wrt(&a, "j").unwrap();
        let (sb, cb, rb) = affine_wrt(&b, "j").unwrap();
        assert_eq!((sa, sb), (1, 1));
        assert_eq!(ra, rb);
        assert_eq!(ca - cb, 1);
    }
}
