//! Loop-carried-dependency analysis (§3 of the paper).
//!
//! Two kinds are modelled, matching the paper's taxonomy:
//!
//! * **MLCD** (memory LCD): a loop contains a global store and a global
//!   load of the *same buffer*. Like Intel's offline compiler, the model is
//!   deliberately conservative: unless the pair is provably same-iteration
//!   *and* the programmer vouches for independence, the innermost loop
//!   containing both accesses is serialized. This conservatism is exactly
//!   the false-MLCD behaviour the feed-forward transformation removes
//!   (FW II=285, BackProp II=416 in the paper).
//!
//! * **DLCD** (data LCD): a scalar recurrence (`acc = f(acc, ...)`) whose
//!   chain latency lower-bounds the loop II (Fig. 3b). The feed-forward
//!   split moves the DLCD into the compute kernel so the memory kernel
//!   still streams at II=1.
//!
//! A **provably true** MLCD (affine indices on the same buffer, same
//! residue, non-zero constant iteration distance — e.g. NW's
//! `m[idx] = f(m[idx-1])`) makes the feed-forward model *infeasible*
//! (paper §3 "Limitations"); `transform::feasibility` consumes this.

use super::pattern::affine_wrt;
use super::{innermost_common_loop, walk_with_loops, LoopCtx};
use crate::ir::{Expr, Kernel, LoopId, Stmt};

/// A memory loop-carried dependency attached to a loop.
#[derive(Debug, Clone, PartialEq)]
pub struct MlcdInfo {
    pub loop_id: LoopId,
    pub buf: String,
    /// Iteration distance if provable (0 = same-iteration).
    pub distance: Option<i64>,
    /// Provably a real cross-iteration dependency (distance != 0 proven).
    pub provably_true: bool,
}

/// A data (scalar-recurrence) loop-carried dependency.
#[derive(Debug, Clone, PartialEq)]
pub struct DlcdInfo {
    pub loop_id: LoopId,
    pub var: String,
    /// Latency of the recurrence chain in cycles (lower bound on II).
    pub chain_latency: u32,
}

#[derive(Debug, Clone, Default)]
pub struct LcdAnalysis {
    pub mlcds: Vec<MlcdInfo>,
    pub dlcds: Vec<DlcdInfo>,
}

impl LcdAnalysis {
    pub fn mlcd_on(&self, l: LoopId) -> Option<&MlcdInfo> {
        self.mlcds.iter().find(|m| m.loop_id == l)
    }

    pub fn dlcd_on(&self, l: LoopId) -> Option<&DlcdInfo> {
        self.dlcds.iter().find(|d| d.loop_id == l)
    }

    pub fn mlcd_bufs_on(&self, l: LoopId) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .mlcds
            .iter()
            .filter(|m| m.loop_id == l)
            .map(|m| m.buf.as_str())
            .collect();
        v.dedup();
        v
    }

    /// Any provably-true (non-removable) MLCD in the kernel?
    pub fn has_true_mlcd(&self) -> bool {
        self.mlcds.iter().any(|m| m.provably_true)
    }
}

struct Access {
    buf: String,
    idx: Expr,
    stack: Vec<LoopCtx>,
}

/// Collect every global load/store with its loop stack.
fn collect_accesses(kernel: &Kernel) -> (Vec<Access>, Vec<Access>) {
    let mut loads = vec![];
    let mut stores = vec![];
    walk_with_loops(kernel, &mut |s, stack| {
        // loads: in every expression of this statement (own exprs only —
        // nested statements are visited separately by the walker)
        s.visit_own_exprs(&mut |e| {
            e.visit(&mut |node| {
                if let Expr::Load { buf, idx } = node {
                    loads.push(Access { buf: buf.clone(), idx: (**idx).clone(), stack: stack.to_vec() });
                }
            });
        });
        if let Stmt::Store { buf, idx, .. } = s {
            stores.push(Access { buf: buf.clone(), idx: idx.clone(), stack: stack.to_vec() });
        }
    });
    (loads, stores)
}

/// Provable iteration distance between a store and a load of the same
/// buffer within loop `var`: both indices affine in `var` with equal stride
/// and identical symbolic residue.
fn provable_distance(store_idx: &Expr, load_idx: &Expr, var: &str) -> Option<i64> {
    let (ss, cs, rs) = affine_wrt(store_idx, var)?;
    let (sl, cl, rl) = affine_wrt(load_idx, var)?;
    if ss == sl && rs == rl && ss != 0 {
        // store in iter i hits address of load in iter i + (cs-cl)/stride
        let diff = cs - cl;
        if diff % ss == 0 {
            return Some(diff / ss);
        }
    }
    None
}

/// Latency table for the recurrence-chain model (cycles at kernel clock).
/// These are the same constants the II model uses; see `ii.rs`.
pub fn op_latency(op: &crate::ir::BinOp, float: bool) -> u32 {
    use crate::ir::BinOp::*;
    match op {
        Add | Sub => {
            if float {
                8
            } else {
                1
            }
        }
        // min/max are a comparator + mux on the fabric — far shorter than
        // a float adder pipeline.
        Min | Max => {
            if float {
                2
            } else {
                1
            }
        }
        Mul => {
            if float {
                5
            } else {
                3
            }
        }
        Div | Rem => {
            if float {
                28
            } else {
                12
            }
        }
        _ => 1,
    }
}

fn un_latency(op: &crate::ir::UnOp) -> u32 {
    use crate::ir::UnOp::*;
    match op {
        Sqrt => 28,
        Exp => 60,
        _ => 1,
    }
}

/// Total latency of an expression tree, *excluding* loads (the recurrence
/// chains the paper's Fig. 3b shows are arithmetic; the load latency is
/// accounted by the MLCD/II model separately). Float-ness is approximated
/// per-node from literal/buffer types being unavailable here: callers pass
/// a `float` hint; reductions in the benchmarks are float.
pub fn expr_latency(e: &Expr, float_hint: bool) -> u32 {
    match e {
        Expr::Bin(op, a, b) => {
            op_latency(op, float_hint)
                + expr_latency(a, float_hint).max(expr_latency(b, float_hint))
        }
        Expr::Un(op, a) => un_latency(op) + expr_latency(a, float_hint),
        Expr::Select(c, t, f) => {
            1 + expr_latency(c, float_hint)
                .max(expr_latency(t, float_hint))
                .max(expr_latency(f, float_hint))
        }
        Expr::Load { .. } => 0,
        _ => 0,
    }
}

/// Run the conservative LCD analysis over one kernel.
pub fn analyze_lcd(kernel: &Kernel) -> LcdAnalysis {
    let (loads, stores) = collect_accesses(kernel);
    let mut out = LcdAnalysis::default();

    // ---- MLCD: same-buffer store+load pairs --------------------------------
    for st in &stores {
        for ld in &loads {
            if st.buf != ld.buf {
                continue;
            }
            let common = match innermost_common_loop(&st.stack, &ld.stack) {
                Some(l) => l,
                None => continue, // not under a common loop: no LCD
            };
            // The loop var of the common loop:
            let var = st
                .stack
                .iter()
                .find(|c| c.id == common)
                .map(|c| c.var.clone())
                .unwrap();
            let distance = provable_distance(&st.idx, &ld.idx, &var);
            let provably_true = matches!(distance, Some(d) if d != 0);
            // Conservative: record the MLCD even when distance == 0 is
            // provable (Intel's compiler serializes these too — the paper's
            // BackProp case). Deduplicate per (loop, buf).
            if !out
                .mlcds
                .iter()
                .any(|m| m.loop_id == common && m.buf == st.buf && m.provably_true == provably_true)
            {
                out.mlcds.push(MlcdInfo { loop_id: common, buf: st.buf.clone(), distance, provably_true });
            }
        }
    }

    // ---- DLCD: scalar recurrences ------------------------------------------
    // A self-referencing assignment is only loop-carried if the variable
    // was *declared outside* the innermost loop — an accumulator re-
    // initialized each iteration (e.g. KNN's per-point `acc`) is a plain
    // intra-iteration chain the scheduler pipelines away.
    fn dlcd_walk(
        body: &[Stmt],
        depth: usize,
        decls: &mut Vec<(String, usize)>,
        stack: &mut Vec<LoopId>,
        out: &mut LcdAnalysis,
    ) {
        let scope_mark = decls.len();
        for s in body {
            match s {
                Stmt::Let { var, .. } | Stmt::PipeRead { var, .. } => {
                    decls.push((var.clone(), depth));
                }
                Stmt::Assign { var, expr } => {
                    let mut self_ref = false;
                    expr.visit(&mut |e| {
                        if matches!(e, Expr::Var(v) if v == var) {
                            self_ref = true;
                        }
                    });
                    if self_ref && !stack.is_empty() {
                        let decl_depth = decls
                            .iter()
                            .rev()
                            .find(|(n, _)| n == var)
                            .map(|(_, d)| *d)
                            .unwrap_or(0);
                        if decl_depth < depth {
                            let l = *stack.last().unwrap();
                            // Arria 10 hard-FP DSPs have a single-cycle
                            // accumulator mode: `acc = acc + <expr>` (the
                            // expr feeding an FMA chain) recurs at II=1.
                            // Other recurrences (min/max, multiplies into
                            // the carried value) pay their chain latency.
                            let accumulator = matches!(
                                expr,
                                Expr::Bin(crate::ir::BinOp::Add, a, b)
                                    if matches!(&**a, Expr::Var(x) if x == var)
                                        || matches!(&**b, Expr::Var(x) if x == var)
                            );
                            let lat = if accumulator {
                                1
                            } else {
                                expr_latency(expr, true).max(1)
                            };
                            if !out.dlcds.iter().any(|d| d.loop_id == l && &d.var == var) {
                                out.dlcds.push(DlcdInfo {
                                    loop_id: l,
                                    var: var.clone(),
                                    chain_latency: lat,
                                });
                            }
                        }
                    }
                }
                Stmt::If { then_b, else_b, .. } => {
                    dlcd_walk(then_b, depth, decls, stack, out);
                    dlcd_walk(else_b, depth, decls, stack, out);
                }
                Stmt::For { id, var, body, .. } => {
                    decls.push((var.clone(), depth + 1));
                    stack.push(*id);
                    dlcd_walk(body, depth + 1, decls, stack, out);
                    stack.pop();
                }
                _ => {}
            }
        }
        decls.truncate(scope_mark);
    }
    let mut decls = vec![];
    let mut stack = vec![];
    dlcd_walk(&kernel.body, 0, &mut decls, &mut stack, &mut out);

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, Ty};

    /// FW-like: same-buffer load+store with unprovable distance.
    #[test]
    fn fw_like_conservative_mlcd() {
        let k = KernelBuilder::new("fw", KernelKind::SingleWorkItem)
            .buf_rw("dist", Ty::F32)
            .scalar("n", Ty::I32)
            .scalar("k", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![for_(
                    "j",
                    i(0),
                    p("n"),
                    vec![store(
                        "dist",
                        v("i") * p("n") + v("j"),
                        ld("dist", v("i") * p("n") + v("j"))
                            .min(ld("dist", v("i") * p("n") + p("k")) + ld("dist", p("k") * p("n") + v("j"))),
                    )],
                )],
            )])
            .finish();
        let lcd = analyze_lcd(&k);
        assert!(!lcd.mlcds.is_empty());
        // Attached to the innermost (j) loop, LoopId(1).
        assert!(lcd.mlcd_on(crate::ir::LoopId(1)).is_some());
        // store dist[i*n+j] vs load dist[i*n+j]: provable distance 0 (not true);
        // vs dist[i*n+k]: loop-invariant load -> stride 0 -> unprovable.
        assert!(!lcd.has_true_mlcd());
    }

    /// NW-like: provably-true distance-1 dependency.
    #[test]
    fn nw_like_true_mlcd() {
        let k = KernelBuilder::new("nw", KernelKind::SingleWorkItem)
            .buf_rw("m", Ty::I32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "j",
                i(1),
                p("n"),
                vec![store("m", v("j"), ld("m", v("j") - i(1)) + i(1))],
            )])
            .finish();
        let lcd = analyze_lcd(&k);
        assert!(lcd.has_true_mlcd());
        let m = lcd.mlcds.iter().find(|m| m.provably_true).unwrap();
        assert_eq!(m.distance, Some(1));
    }

    /// Cross-buffer load/store: no MLCD (hotspot-like).
    #[test]
    fn cross_buffer_no_mlcd() {
        let k = KernelBuilder::new("hs", KernelKind::SingleWorkItem)
            .buf_ro("t", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(1),
                p("n"),
                vec![store("o", v("i"), ld("t", v("i") - i(1)) + ld("t", v("i") + i(1)))],
            )])
            .finish();
        let lcd = analyze_lcd(&k);
        assert!(lcd.mlcds.is_empty());
    }

    /// Store in outer loop + load of same buffer in inner loop attaches the
    /// MLCD to the outer loop (the BFS/MIS shape).
    #[test]
    fn mlcd_attaches_to_common_loop() {
        let k = KernelBuilder::new("mis", KernelKind::SingleWorkItem)
            .buf_rw("c", Ty::I32)
            .buf_ro("col", Ty::I32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "t",
                i(0),
                p("n"),
                vec![
                    for_("e", i(0), i(4), vec![let_i("x", ld("c", ld("col", v("e"))))]),
                    store("c", v("t"), i(1)),
                ],
            )])
            .finish();
        let lcd = analyze_lcd(&k);
        assert_eq!(lcd.mlcds.len(), 1);
        assert_eq!(lcd.mlcds[0].loop_id, crate::ir::LoopId(0)); // outer
        assert!(!lcd.mlcds[0].provably_true); // irregular load: unprovable
    }

    /// Reduction detection (Fig. 3b).
    #[test]
    fn dlcd_detection() {
        let k = KernelBuilder::new("red", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "t",
                i(0),
                p("n"),
                vec![
                    let_f("acc", f(0.0)),
                    for_("j", i(0), i(5), vec![assign("acc", v("acc") + ld("a", v("t") - v("j")))]),
                    store("o", v("t"), v("acc")),
                ],
            )])
            .finish();
        let lcd = analyze_lcd(&k);
        assert_eq!(lcd.dlcds.len(), 1);
        let d = &lcd.dlcds[0];
        assert_eq!(d.var, "acc");
        assert_eq!(d.loop_id, crate::ir::LoopId(1));
        assert_eq!(d.chain_latency, 1); // hard-FP accumulator mode
        assert!(lcd.mlcds.is_empty()); // a vs o: cross-buffer
    }

    #[test]
    fn min_reduction_chain_latency() {
        let k = KernelBuilder::new("m", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![
                let_f("mn", f(1e30)),
                for_("j", i(0), p("n"), vec![assign("mn", v("mn").min(ld("a", v("j"))))]),
                store("o", i(0), v("mn")),
            ])
            .finish();
        let lcd = analyze_lcd(&k);
        assert_eq!(lcd.dlcds[0].chain_latency, 2); // fmin: cmp+mux
    }
}
