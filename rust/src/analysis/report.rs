//! The "early stage analysis report" (§3) — our stand-in for the report
//! file Intel's offline compiler generates, which the paper repeatedly
//! tells programmers to consult. Experiments E4a/E4b print these before
//! and after the feed-forward transformation (FW: II 285 -> 1, etc.).

use super::area::{estimate_program_area, AreaEstimate};
use super::ii::{loop_iis, LoopII};
use super::lcd::{analyze_lcd, LcdAnalysis};
use super::lsu::{select_lsus, LsuKind, MemSite, MemSiteKind};
use crate::ir::{Kernel, Program};
use crate::sim::device::DeviceConfig;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct KernelReport {
    pub name: String,
    pub lcd: LcdAnalysis,
    pub loops: Vec<LoopII>,
    pub sites: Vec<MemSite>,
}

impl KernelReport {
    pub fn for_kernel(kernel: &Kernel) -> KernelReport {
        let lcd = analyze_lcd(kernel);
        let loops = loop_iis(kernel, &lcd);
        let sites = select_lsus(kernel);
        KernelReport { name: kernel.name.clone(), lcd, loops, sites }
    }

    /// Maximum II over all loops (the headline number the paper quotes).
    pub fn max_ii(&self) -> u32 {
        self.loops.iter().map(|l| l.ii).max().unwrap_or(1)
    }

    pub fn serialized_loops(&self) -> usize {
        self.loops.iter().filter(|l| l.serialized_by.is_some()).count()
    }

    pub fn prefetching_loads(&self) -> usize {
        self.sites
            .iter()
            .filter(|s| s.kind == MemSiteKind::Load && s.lsu == LsuKind::Prefetching)
            .count()
    }
}

#[derive(Debug, Clone)]
pub struct CompilerReport {
    pub program: String,
    pub kernels: Vec<KernelReport>,
    pub area: AreaEstimate,
    pub fmax_hz: f64,
}

/// Analyze a whole program.
pub fn program_report(prog: &Program, cfg: &DeviceConfig) -> CompilerReport {
    let area = estimate_program_area(prog, cfg);
    let fmax_hz = cfg.fmax_for_area(area.logic_frac);
    CompilerReport {
        program: prog.name.clone(),
        kernels: prog.kernels.iter().map(KernelReport::for_kernel).collect(),
        area,
        fmax_hz,
    }
}

impl CompilerReport {
    /// Render in the spirit of Intel's `report.html` loop-analysis pane.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "=== Early-stage analysis report: {} ===", self.program);
        let _ = writeln!(
            out,
            "estimated area: logic {:.2}%  BRAM {}  DSP {}   fmax {:.0} MHz",
            self.area.logic_pct(),
            self.area.brams,
            self.area.dsps,
            self.fmax_hz / 1e6
        );
        for k in &self.kernels {
            let _ = writeln!(out, "kernel {}:", k.name);
            if k.loops.is_empty() {
                let _ = writeln!(out, "  (no loops)");
            }
            for l in &k.loops {
                let mut notes = vec![];
                if let Some(b) = &l.serialized_by {
                    notes.push(format!(
                        "serialized: memory loop-carried dependency on global pointer `{b}`"
                    ));
                }
                if let Some(v) = &l.dlcd_var {
                    notes.push(format!("data loop-carried dependency on `{v}`"));
                }
                let note = if notes.is_empty() { "pipelined".to_string() } else { notes.join("; ") };
                let _ = writeln!(
                    out,
                    "  loop L{} (depth {}): II = {:<4} {}",
                    l.loop_id.0, l.depth, l.ii, note
                );
            }
            for s in &k.sites {
                let kind = match s.kind {
                    MemSiteKind::Load => "LD",
                    MemSiteKind::Store => "ST",
                };
                let _ = writeln!(
                    out,
                    "  {kind} site {:<3} buf `{}` pattern {:?} -> {:?} LSU",
                    s.site, s.buf, s.pattern, s.lsu
                );
            }
        }
        out
    }

    pub fn kernel(&self, name: &str) -> Option<&KernelReport> {
        self.kernels.iter().find(|k| k.name == name)
    }

    /// Max II across all kernels (program headline).
    pub fn max_ii(&self) -> u32 {
        self.kernels.iter().map(|k| k.max_ii()).max().unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, Program, Ty};

    #[test]
    fn report_shows_serialization_and_lsus() {
        let k = KernelBuilder::new("fw", KernelKind::SingleWorkItem)
            .buf_rw("dist", Ty::F32)
            .scalar("n", Ty::I32)
            .scalar("piv", Ty::I32)
            .body(vec![for_(
                "j",
                i(0),
                p("n"),
                vec![store(
                    "dist",
                    v("j"),
                    ld("dist", v("j")).min(ld("dist", p("piv")) + ld("dist", p("piv") * p("n") + v("j"))),
                )],
            )])
            .finish();
        let prog = Program::single(k);
        let cfg = DeviceConfig::pac_a10();
        let rep = program_report(&prog, &cfg);
        assert_eq!(rep.kernels.len(), 1);
        assert!(rep.max_ii() > 100);
        let text = rep.render();
        assert!(text.contains("serialized: memory loop-carried dependency"));
        assert!(text.contains("BurstCoalesced"));
    }
}
