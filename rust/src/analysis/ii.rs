//! Initiation-interval (II) model.
//!
//! II is "the number of clock cycles between the launch of successive loop
//! iterations" (§3). The offline-compiler model computes, per loop:
//!
//! * **Serialized (MLCD) loops**: the II is the latency of the RAW cycle
//!   through global memory — a store must complete and the dependent load
//!   return before the next iteration may issue:
//!   `II = LD_LAT + ST_LAT + arith chain + (extra serialized buffers) * LD_LAT`.
//!   With the PAC-A10-calibrated latencies below this lands FW at II=285
//!   (one MLCD buffer, fmin+fadd chain = 10) and BackProp in the 400s (two
//!   MLCD buffers), matching the paper's reported IIs.
//! * **DLCD loops**: II = recurrence chain latency (e.g. 8 for an fadd/fmin
//!   accumulator).
//! * Otherwise II = 1 (fully pipelined).

use super::lcd::{expr_latency, LcdAnalysis};
use crate::ir::{Kernel, LoopId, Stmt};
use std::collections::HashMap;

/// Global-memory round-trip components at kernel clock (~240 MHz), DDR4.
pub const LD_LAT: u32 = 138;
pub const ST_LAT: u32 = 137;

#[derive(Debug, Clone, PartialEq)]
pub struct LoopII {
    pub loop_id: LoopId,
    /// Scheduled initiation interval.
    pub ii: u32,
    /// Loop was serialized by a (possibly false) MLCD on this buffer.
    pub serialized_by: Option<String>,
    /// II bound induced by a scalar recurrence, if any.
    pub dlcd_var: Option<String>,
    /// Nesting depth (0 = top-level loop of the kernel body).
    pub depth: usize,
}

/// Arithmetic chain latency of a loop's *direct* body statements (nested
/// loops excluded — their II is reported separately), used as the
/// dependent-chain component of a serialized loop's II.
fn direct_chain_latency(body: &[Stmt]) -> u32 {
    let mut lat = 0;
    for s in body {
        match s {
            Stmt::Let { expr, .. } | Stmt::Assign { expr, .. } => lat += expr_latency(expr, true),
            Stmt::Store { val, .. } => lat += expr_latency(val, true),
            Stmt::If { cond, .. } => lat += expr_latency(cond, true),
            _ => {}
        }
    }
    lat
}

/// Compute the II of every loop in a kernel given its LCD analysis.
pub fn loop_iis(kernel: &Kernel, lcd: &LcdAnalysis) -> Vec<LoopII> {
    let mut out = vec![];
    fn go(body: &[Stmt], depth: usize, lcd: &LcdAnalysis, out: &mut Vec<LoopII>) {
        for s in body {
            match s {
                Stmt::For { id, body, .. } => {
                    let mlcd_bufs = lcd.mlcd_bufs_on(*id);
                    let dlcd = lcd.dlcd_on(*id);
                    let mut ii = 1u32;
                    let mut serialized_by = None;
                    if !mlcd_bufs.is_empty() {
                        let chain = direct_chain_latency(body);
                        let extra = (mlcd_bufs.len() as u32).saturating_sub(1);
                        ii = LD_LAT + ST_LAT + chain + extra * LD_LAT;
                        serialized_by = Some(mlcd_bufs[0].to_string());
                    }
                    if let Some(d) = dlcd {
                        ii = ii.max(d.chain_latency);
                    }
                    out.push(LoopII {
                        loop_id: *id,
                        ii: ii.max(1),
                        serialized_by,
                        dlcd_var: dlcd.map(|d| d.var.clone()),
                        depth,
                    });
                    go(body, depth + 1, lcd, out);
                }
                Stmt::If { then_b, else_b, .. } => {
                    go(then_b, depth, lcd, out);
                    go(else_b, depth, lcd, out);
                }
                _ => {}
            }
        }
    }
    go(&kernel.body, 0, lcd, &mut out);
    out
}

/// II lookup keyed by loop id.
pub fn ii_map(iis: &[LoopII]) -> HashMap<LoopId, u32> {
    iis.iter().map(|l| (l.loop_id, l.ii)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze_lcd;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, Ty};

    /// The FW inner loop must come out at the paper's reported II=285:
    /// LD(135) + ST(134) + fmin(8) + fadd(8) = 285.
    #[test]
    fn fw_ii_is_285() {
        let k = KernelBuilder::new("fw", KernelKind::SingleWorkItem)
            .buf_rw("dist", Ty::F32)
            .scalar("n", Ty::I32)
            .scalar("k", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![for_(
                    "j",
                    i(0),
                    p("n"),
                    vec![store(
                        "dist",
                        v("i") * p("n") + v("j"),
                        ld("dist", v("i") * p("n") + v("j"))
                            .min(ld("dist", v("i") * p("n") + p("k")) + ld("dist", p("k") * p("n") + v("j"))),
                    )],
                )],
            )])
            .finish();
        let lcd = analyze_lcd(&k);
        let iis = loop_iis(&k, &lcd);
        let inner = iis.iter().find(|l| l.depth == 1).unwrap();
        assert_eq!(inner.ii, 285);
        assert_eq!(inner.serialized_by.as_deref(), Some("dist"));
        // outer loop: the MLCD is attached to the inner loop only
        let outer = iis.iter().find(|l| l.depth == 0).unwrap();
        assert_eq!(outer.ii, 1);
    }

    #[test]
    fn pipelined_loop_ii_1() {
        let k = KernelBuilder::new("hs", KernelKind::SingleWorkItem)
            .buf_ro("t", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("t", v("i")) * f(2.0))],
            )])
            .finish();
        let lcd = analyze_lcd(&k);
        let iis = loop_iis(&k, &lcd);
        assert_eq!(iis[0].ii, 1);
        assert!(iis[0].serialized_by.is_none());
    }

    #[test]
    fn dlcd_min_reduction_ii_is_chain_latency() {
        let k = KernelBuilder::new("red", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![
                let_f("acc", f(1e30)),
                // min-reduction: no hard-FP accumulator mode, II = cmp+mux
                for_("j", i(0), p("n"), vec![assign("acc", v("acc").min(ld("a", v("j"))))]),
                store("o", i(0), v("acc")),
            ])
            .finish();
        let lcd = analyze_lcd(&k);
        let iis = loop_iis(&k, &lcd);
        assert_eq!(iis[0].ii, 2);
        assert_eq!(iis[0].dlcd_var.as_deref(), Some("acc"));
    }

    /// Two serialized buffers push the II into the paper's BackProp range.
    #[test]
    fn two_mlcd_buffers_ii_in_backprop_range() {
        let k = KernelBuilder::new("bp", KernelKind::SingleWorkItem)
            .buf_rw("w", Ty::F32)
            .buf_rw("oldw", Ty::F32)
            .buf_ro("x", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![
                    let_f("nw", ld("w", v("i")) + f(0.3) * ld("x", v("i")) + f(0.3) * ld("oldw", v("i"))),
                    store("w", v("i"), v("nw")),
                    store("oldw", v("i"), v("nw")),
                ],
            )])
            .finish();
        let lcd = analyze_lcd(&k);
        let iis = loop_iis(&k, &lcd);
        let ii = iis[0].ii;
        assert!((390..=470).contains(&ii), "ii={ii} outside BackProp band");
    }
}
