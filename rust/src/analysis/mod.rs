//! The offline-compiler model.
//!
//! Reproduces the *decision procedure* of Intel's OpenCL-to-FPGA offline
//! compiler as documented in the Best Practices Guide and as characterized
//! by the paper (§2.2, §3): loop-carried-dependency analysis (conservative
//! for global memory), access-pattern classification, LSU selection,
//! initiation-interval computation, and area estimation. The early-analysis
//! "report file" the paper tells programmers to consult is
//! [`report::CompilerReport`].

pub mod area;
pub mod deps;
pub mod ii;
pub mod lcd;
pub mod lsu;
pub mod pattern;
pub mod report;

pub use area::{estimate_program_area, AreaEstimate};
pub use deps::{DepEdge, DepKind, LaunchDag, LaunchNode};
pub use ii::{loop_iis, LoopII};
pub use lcd::{analyze_lcd, DlcdInfo, LcdAnalysis, MlcdInfo};
pub use lsu::{select_lsus, LsuKind, MemSite, MemSiteKind};
pub use pattern::{classify_index, AccessPattern};
pub use report::{program_report, CompilerReport, KernelReport};

use crate::ir::{Kernel, LoopId, Stmt};

/// One entry of the enclosing-loop stack during a walk.
#[derive(Debug, Clone, PartialEq)]
pub struct LoopCtx {
    pub id: LoopId,
    pub var: String,
}

/// Walk every statement of a kernel with its enclosing-loop stack.
pub fn walk_with_loops(kernel: &Kernel, f: &mut impl FnMut(&Stmt, &[LoopCtx])) {
    fn go(body: &[Stmt], stack: &mut Vec<LoopCtx>, f: &mut impl FnMut(&Stmt, &[LoopCtx])) {
        for s in body {
            f(s, stack);
            match s {
                Stmt::For { id, var, body, .. } => {
                    stack.push(LoopCtx { id: *id, var: var.clone() });
                    go(body, stack, f);
                    stack.pop();
                }
                Stmt::If { then_b, else_b, .. } => {
                    go(then_b, stack, f);
                    go(else_b, stack, f);
                }
                _ => {}
            }
        }
    }
    let mut stack = vec![];
    go(&kernel.body, &mut stack, f);
}

/// The innermost loop common to two loop stacks (used to attach an MLCD to
/// the loop the offline compiler would serialize).
pub fn innermost_common_loop(a: &[LoopCtx], b: &[LoopCtx]) -> Option<LoopId> {
    let mut common = None;
    for (x, y) in a.iter().zip(b.iter()) {
        if x.id == y.id {
            common = Some(x.id);
        } else {
            break;
        }
    }
    common
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::KernelKind;

    #[test]
    fn loop_stack_tracks_nesting() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_rw("a", crate::ir::Ty::I32)
            .scalar("n", crate::ir::Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![for_("j", i(0), p("n"), vec![store("a", v("j"), v("i"))])],
            )])
            .finish();
        let mut depth_of_store = None;
        walk_with_loops(&k, &mut |s, stack| {
            if matches!(s, crate::ir::Stmt::Store { .. }) {
                depth_of_store = Some(stack.len());
            }
        });
        assert_eq!(depth_of_store, Some(2));
    }

    #[test]
    fn common_loop() {
        let l = |n| LoopCtx { id: crate::ir::LoopId(n), var: format!("v{n}") };
        assert_eq!(innermost_common_loop(&[l(0), l(1)], &[l(0), l(2)]), Some(crate::ir::LoopId(0)));
        assert_eq!(innermost_common_loop(&[l(0)], &[l(0)]), Some(crate::ir::LoopId(0)));
        assert_eq!(innermost_common_loop(&[], &[l(0)]), None);
    }
}
