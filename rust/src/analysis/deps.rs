//! Launch-dependence analysis: from an execution trace to a launch DAG.
//!
//! The execution model's scheduling unit used to be one host launch; this
//! pass is what lets it become a launch *graph* (the MKPipe observation:
//! independent pipe-connected kernels from different launches should
//! overlap). Given a built [`App`] and the [`ExecTrace`] the interpreter
//! recorded, it derives each launch's buffer read/write sets from the
//! launch unit's kernel signatures and emits conservative
//! RAW / WAR / WAW dependence edges between every pair of launches that
//! share a buffer.
//!
//! § Vouches as edge-removal rules. The suite's documented benign-race
//! vouches ([`crate::workloads::Workload::benign_cross_kernel_races`])
//! state that whatever value a racing read observes, results and profiles
//! are identical — e.g. bfs's concurrent `cost` stores all write the
//! idempotent `level + 1`, and its `updating` mask is a monotonic OR.
//! Under a vouch, anti- (WAR) and output- (WAW) dependences between
//! launches stop constraining the schedule: reordering a read before an
//! overwrite, or two writes against each other, can only expose a racing
//! value the vouch already declares immaterial. True dataflow (RAW)
//! edges are **always kept** — a vouch never licenses consuming a value
//! before it is produced. NW vouches nothing, and its single `m` buffer
//! is read-write in every launch, so repeated NW launches chain through
//! RAW (and WAR/WAW) edges no matter what: the DAG provably refuses to
//! overlap its depth-sensitive recurrence.
//!
//! Host-side ping-pong swaps (`MemoryImage::swap_bufs`, pagerank/color)
//! are invisible at this layer by design: the trace names buffers as the
//! kernels declare them, so `pr` and `pr_next` stay distinct names and an
//! iteration's gather never RAW-depends on the next iteration's contrib.
//! That is exactly the legalization `transform::task_sequence` models —
//! cross-iteration values flow through inter-iteration pipes instead of a
//! reread of the swapped buffer — and it is sound precisely when the
//! workload carries a vouch; see `docs/SCHEDULING.md` for the worked
//! table.

use crate::ir::Access;
use crate::workloads::{App, ExecTrace};
use std::collections::BTreeSet;

/// One launch of the trace, with the buffer sets the dependence test uses.
#[derive(Debug, Clone)]
pub struct LaunchNode {
    /// Index into the trace's launch list (host order).
    pub index: usize,
    /// Launch-unit name (`LaunchRecord::unit`).
    pub unit: String,
    /// Buffers any kernel of the unit may read (ReadOnly | ReadWrite).
    pub reads: BTreeSet<String>,
    /// Buffers any kernel of the unit may write (WriteOnly | ReadWrite).
    pub writes: BTreeSet<String>,
}

/// Dependence kind between two launches sharing a buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DepKind {
    /// True dataflow: an earlier launch writes what a later launch reads.
    /// Never removable.
    Raw,
    /// Anti-dependence: an earlier launch reads what a later launch
    /// writes. Removed under a benign-race vouch.
    War,
    /// Output dependence: two launches write the same buffer. Removed
    /// under a benign-race vouch.
    Waw,
}

impl DepKind {
    pub fn label(self) -> &'static str {
        match self {
            DepKind::Raw => "RAW",
            DepKind::War => "WAR",
            DepKind::Waw => "WAW",
        }
    }
}

/// One ordering edge: launch `from` must complete before launch `to`.
#[derive(Debug, Clone)]
pub struct DepEdge {
    pub from: usize,
    pub to: usize,
    pub kind: DepKind,
    /// The shared buffer inducing the edge.
    pub buf: String,
}

/// The launch-dependence DAG plus its topological wavefront assignment.
/// Edges always point forward in host-launch order, so the node index
/// order is already topological.
#[derive(Debug, Clone)]
pub struct LaunchDag {
    pub nodes: Vec<LaunchNode>,
    pub edges: Vec<DepEdge>,
    /// `levels[i]` = longest dependence-edge path ending at launch `i`.
    /// Launches with equal level are mutually unordered and may be
    /// co-scheduled (one DES wavefront).
    pub levels: Vec<usize>,
}

impl LaunchDag {
    /// Build the DAG for a recorded trace of `app`. `benign` is the
    /// workload's cross-kernel benign-race vouch: when set, WAR and WAW
    /// edges are dropped (see the module docs); RAW edges are kept
    /// unconditionally.
    pub fn build(app: &App, trace: &ExecTrace, benign: bool) -> Result<LaunchDag, String> {
        let mut nodes = Vec::with_capacity(trace.launches.len());
        for (index, rec) in trace.launches.iter().enumerate() {
            let Some(unit) = app.units.iter().find(|u| u.name == rec.unit) else {
                return Err(format!(
                    "deps: trace launch {index}: no unit `{}` in app {}",
                    rec.unit, app.name
                ));
            };
            let mut reads = BTreeSet::new();
            let mut writes = BTreeSet::new();
            for k in &unit.kernels {
                for b in &k.bufs {
                    match b.access {
                        Access::ReadOnly => {
                            reads.insert(b.name.clone());
                        }
                        Access::WriteOnly => {
                            writes.insert(b.name.clone());
                        }
                        Access::ReadWrite => {
                            reads.insert(b.name.clone());
                            writes.insert(b.name.clone());
                        }
                    }
                }
            }
            nodes.push(LaunchNode { index, unit: rec.unit.clone(), reads, writes });
        }

        let mut edges = vec![];
        for j in 0..nodes.len() {
            for i in 0..j {
                for buf in &nodes[i].writes {
                    if nodes[j].reads.contains(buf) {
                        edges.push(DepEdge {
                            from: i,
                            to: j,
                            kind: DepKind::Raw,
                            buf: buf.clone(),
                        });
                    }
                    if !benign && nodes[j].writes.contains(buf) {
                        edges.push(DepEdge {
                            from: i,
                            to: j,
                            kind: DepKind::Waw,
                            buf: buf.clone(),
                        });
                    }
                }
                if !benign {
                    for buf in &nodes[i].reads {
                        if nodes[j].writes.contains(buf) {
                            edges.push(DepEdge {
                                from: i,
                                to: j,
                                kind: DepKind::War,
                                buf: buf.clone(),
                            });
                        }
                    }
                }
            }
        }

        // Edges always point forward in host-launch order (`from < to`),
        // so index order is topological and one pass computes the
        // longest-path level of every node.
        let mut levels = vec![0usize; nodes.len()];
        for j in 0..nodes.len() {
            let mut lvl = 0usize;
            for e in edges.iter().filter(|e| e.to == j) {
                lvl = lvl.max(levels[e.from] + 1);
            }
            levels[j] = lvl;
        }

        Ok(LaunchDag { nodes, edges, levels })
    }

    /// Launch indices grouped by level, ascending — the co-schedulable
    /// wavefronts in execution order.
    pub fn wavefronts(&self) -> Vec<Vec<usize>> {
        let max = self.levels.iter().copied().max().unwrap_or(0);
        let mut waves = vec![vec![]; if self.nodes.is_empty() { 0 } else { max + 1 }];
        for (i, &lvl) in self.levels.iter().enumerate() {
            waves[lvl].push(i);
        }
        waves
    }

    pub fn wavefront_count(&self) -> usize {
        self.wavefronts().len()
    }

    /// True when the DAG admits no overlap at all: every launch is its
    /// own wavefront (a full chain). This is the property the scheduler
    /// checks before refusing to co-schedule — NW's repeated launches
    /// are provably a chain.
    pub fn is_chain(&self) -> bool {
        self.wavefront_count() == self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::Variant;
    use crate::workloads::{by_name, LaunchRecord};

    fn synthetic_trace(units: &[&str]) -> ExecTrace {
        ExecTrace {
            launches: units
                .iter()
                .map(|u| LaunchRecord { unit: (*u).to_string(), profiles: vec![] })
                .collect(),
        }
    }

    /// Repeated NW launches chain fully: `m` is read-write every launch,
    /// so RAW edges alone force one wavefront per launch — with or
    /// without a vouch. This is the acceptance-criteria proof that the
    /// dependence layer refuses to overlap NW's depth-sensitive
    /// recurrence.
    #[test]
    fn nw_repeated_launches_are_never_overlapped() {
        let w = by_name("nw").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let trace = synthetic_trace(&["nw_kernel"; 6]);
        for benign in [false, true] {
            let dag = LaunchDag::build(&app, &trace, benign).unwrap();
            assert!(dag.is_chain(), "nw chain must never overlap (benign={benign})");
            assert_eq!(dag.wavefront_count(), 6);
            assert_eq!(dag.levels, vec![0, 1, 2, 3, 4, 5]);
            // the chain is carried by true dataflow on `m`, which no
            // vouch may remove
            assert!(dag
                .edges
                .iter()
                .any(|e| e.kind == DepKind::Raw && e.buf == "m"));
        }
        // unvouched, the anti/output dependences are reported too
        let dag = LaunchDag::build(&app, &trace, false).unwrap();
        assert!(dag.edges.iter().any(|e| e.kind == DepKind::War));
        assert!(dag.edges.iter().any(|e| e.kind == DepKind::Waw));
    }

    /// bfs's vouch turns its 3-launch-per-level chain into overlapping
    /// wavefronts: clears read nothing (level 0 forever), and the RAW
    /// backbone clear/kernel -> update -> next kernel remains.
    #[test]
    fn bfs_vouch_admits_overlap_but_keeps_raw_backbone() {
        let w = by_name("bfs").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        // two host levels of the convergence loop
        let trace = synthetic_trace(&[
            "bfs_clear", "bfs_kernel", "bfs_update",
            "bfs_clear", "bfs_kernel", "bfs_update",
        ]);
        let dag = LaunchDag::build(&app, &trace, true).unwrap();
        assert!(dag.edges.iter().all(|e| e.kind == DepKind::Raw), "vouch removes WAR/WAW");
        // clears have no reads at all: always schedulable immediately
        assert_eq!(dag.levels[0], 0);
        assert_eq!(dag.levels[3], 0);
        // updates consume `updating` written by clear+kernel of their level
        assert!(dag.levels[2] > dag.levels[1]);
        // next level's kernel reads frontier/visited from the update
        assert!(dag.levels[4] > dag.levels[2]);
        assert!(
            dag.wavefront_count() < dag.nodes.len(),
            "vouched bfs must overlap: {} wavefronts for {} launches",
            dag.wavefront_count(),
            dag.nodes.len()
        );
        // without the vouch, WAW on `updating` (clear vs kernel) and WAR
        // edges restore a denser order
        let strict = LaunchDag::build(&app, &trace, false).unwrap();
        assert!(strict.edges.len() > dag.edges.len());
        assert!(strict.wavefront_count() >= dag.wavefront_count());
    }

    /// pagerank's ping-pong iteration collapses to two wavefronts under
    /// the vouch: every contrib is independent (reads `pr`, which no
    /// launch writes by name — the swap is host-side), every gather only
    /// RAW-depends on contribs.
    #[test]
    fn pagerank_pingpong_collapses_to_two_wavefronts() {
        let w = by_name("pagerank").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let trace = synthetic_trace(&[
            "pagerank_contrib", "pagerank_kernel",
            "pagerank_contrib", "pagerank_kernel",
            "pagerank_contrib", "pagerank_kernel",
        ]);
        let dag = LaunchDag::build(&app, &trace, true).unwrap();
        assert_eq!(dag.wavefront_count(), 2, "levels: {:?}", dag.levels);
        assert_eq!(dag.levels, vec![0, 1, 0, 1, 0, 1]);
        assert!(!dag.is_chain());
        // unvouched, WAW on `contrib` chains the contribs
        let strict = LaunchDag::build(&app, &trace, false).unwrap();
        assert!(strict.wavefront_count() > 2);
    }

    #[test]
    fn unknown_unit_is_a_clean_error() {
        let w = by_name("nw").unwrap();
        let app = w.build(Variant::Baseline).unwrap();
        let trace = synthetic_trace(&["no_such_unit"]);
        assert!(LaunchDag::build(&app, &trace, false).is_err());
    }

    #[test]
    fn empty_trace_has_no_wavefronts() {
        let w = by_name("nw").unwrap();
        let app = w.build(Variant::Baseline).unwrap();
        let dag = LaunchDag::build(&app, &ExecTrace::default(), false).unwrap();
        assert_eq!(dag.wavefront_count(), 0);
        assert!(dag.wavefronts().is_empty());
    }
}
