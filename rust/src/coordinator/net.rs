//! `pipefwd serve`: the measurement daemon (PR-6 tentpole, transport
//! layer).
//!
//! A deliberately small std-only HTTP/1.1 server over
//! [`std::net::TcpListener`]: one accept thread feeding a *bounded*
//! connection queue, a fixed pool of worker threads draining it, and
//! one shared [`Service`] handling every request. Backpressure is the
//! queue bound — when it is full the accept thread answers `503` with a
//! structured error line instead of buffering unboundedly, and the
//! observed depth is reported through the v2 counters document
//! (`queue_depth_max`).
//!
//! Cross-client dedup needs no code here: all workers share one
//! `Service`, so concurrent requests for the same cell meet in the
//! engine's claim/fulfil memo table — the first claims and computes,
//! the rest block on the claim and are fulfilled from it. A client that
//! disconnects mid-computation releases nothing: its worker computes to
//! completion and fulfils the claim (the write of the response simply
//! fails), so a second client asking for the same cell still gets the
//! memoized result.
//!
//! Wire format: `POST /api/v1` with one `pipefwd-api-v1` request
//! document; the response body is newline-delimited compact JSON ending
//! in a `done` terminator (see [`super::service`]). `GET /stats`
//! returns the live counters + store footprint as one pretty document.

use super::service::{self, Service, ServiceRequest};
use crate::util::json::{self, Json};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Request-body cap: a `store_push` of a large store fits comfortably;
/// anything bigger is rejected with `413` before allocation.
pub const MAX_BODY_BYTES: usize = 32 * 1024 * 1024;
/// Request-head cap (request line + headers).
pub const MAX_HEAD_BYTES: u64 = 16 * 1024;
/// Server-side socket timeout: bounds how long a worker can be held by
/// a stalled peer (reading the request or writing the response). The
/// *compute* between the two is unbounded by design — paper-scale
/// grids take as long as they take.
pub const IO_TIMEOUT: Duration = Duration::from_secs(30);

#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Connection-handling worker threads.
    pub workers: usize,
    /// Bounded queue capacity: accepted-but-unhandled connections.
    pub queue_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig { workers: 4, queue_cap: 64 }
    }
}

/// The bounded hand-off between the accept thread and the workers.
struct Queue {
    inner: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    items: VecDeque<TcpStream>,
    open: bool,
}

impl Queue {
    fn new() -> Queue {
        Queue { inner: Mutex::new(QueueState { items: VecDeque::new(), open: true }), ready: Condvar::new() }
    }

    /// Enqueue, or hand the stream back when full/closed (the caller
    /// turns that into a `503`). Returns the depth after the push — the
    /// number the backpressure counter tracks.
    fn push(&self, stream: TcpStream, cap: usize) -> Result<usize, TcpStream> {
        let mut st = self.inner.lock().unwrap();
        if !st.open || st.items.len() >= cap {
            return Err(stream);
        }
        st.items.push_back(stream);
        let depth = st.items.len();
        self.ready.notify_one();
        Ok(depth)
    }

    /// Blocking pop; `None` once closed *and* drained, so in-flight
    /// work finishes before workers exit.
    fn pop(&self) -> Option<TcpStream> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(s) = st.items.pop_front() {
                return Some(s);
            }
            if !st.open {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    fn close(&self) {
        self.inner.lock().unwrap().open = false;
        self.ready.notify_all();
    }
}

/// A running daemon. [`Server::join`] blocks forever (the CLI `serve`
/// arm); [`Server::shutdown`] (or drop) stops the accept loop, drains
/// in-flight work, and joins every thread — what the in-process tests
/// and benches use.
pub struct Server {
    addr: SocketAddr,
    queue: Arc<Queue>,
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (`HOST:PORT`; port 0 picks a free one) and start the
    /// accept thread + worker pool over one shared service.
    pub fn spawn(service: Arc<Service>, addr: &str, cfg: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let queue = Arc::new(Queue::new());
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = vec![];
        for _ in 0..cfg.workers.max(1) {
            let q = Arc::clone(&queue);
            let svc = Arc::clone(&service);
            handles.push(std::thread::spawn(move || worker_loop(&q, &svc)));
        }
        {
            let q = Arc::clone(&queue);
            let svc = Arc::clone(&service);
            let stop = Arc::clone(&stop);
            let cap = cfg.queue_cap.max(1);
            handles.push(std::thread::spawn(move || accept_loop(&listener, &q, &svc, &stop, cap)));
        }
        Ok(Server { addr, queue, stop, handles })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until the process dies (the CLI foreground mode).
    pub fn join(mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Stop accepting, finish in-flight requests, join every thread.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queue.close();
        // unblock the accept loop so it observes the stop flag
        let _ = TcpStream::connect(self.addr);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: &TcpListener,
    queue: &Queue,
    service: &Service,
    stop: &AtomicBool,
    cap: usize,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        match queue.push(stream, cap) {
            Ok(depth) => service.note_queue_depth(depth),
            Err(mut stream) => {
                // backpressure: answer, don't buffer
                let line =
                    service::request_error_line("busy: request queue is full — retry later");
                let _ = write_http(&mut stream, 503, "Service Unavailable", &[line]);
            }
        }
    }
    queue.close();
}

fn worker_loop(queue: &Queue, service: &Service) {
    while let Some(stream) = queue.pop() {
        service.note_client_served();
        // one malformed or panicking request must never take the worker
        // (and with it the daemon's capacity) down
        let _ = catch_unwind(AssertUnwindSafe(|| handle_connection(stream, service)));
    }
}

fn handle_connection(stream: TcpStream, service: &Service) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut out = stream;
    let mut reader = BufReader::new(read_half).take(MAX_HEAD_BYTES);

    let mut request_line = String::new();
    if reader.read_line(&mut request_line).unwrap_or(0) == 0 {
        return; // closed (or stalled) before a request arrived
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_length: Option<usize> = None;
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            // EOF before the blank separator: truncated or oversized head
            Ok(0) => {
                respond_error(&mut out, 400, "Bad Request", "request: truncated head");
                return;
            }
            Ok(_) => {}
            Err(_) => return,
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse::<usize>().ok();
            }
        }
    }

    match (method.as_str(), path.as_str()) {
        ("GET", "/stats") => {
            let _ = write_http_raw(&mut out, 200, "OK", &service.stats_doc().to_pretty());
        }
        ("POST", "/api/v1") => {
            let Some(len) = content_length else {
                respond_error(&mut out, 411, "Length Required", "request: missing Content-Length");
                return;
            };
            if len > MAX_BODY_BYTES {
                respond_error(
                    &mut out,
                    413,
                    "Payload Too Large",
                    &format!("request: body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte cap"),
                );
                return;
            }
            let mut body = vec![0u8; len];
            if reader.into_inner().read_exact(&mut body).is_err() {
                respond_error(&mut out, 400, "Bad Request", "request: truncated body");
                return;
            }
            let Ok(text) = String::from_utf8(body) else {
                respond_error(&mut out, 400, "Bad Request", "request: body is not UTF-8");
                return;
            };
            let doc = match json::parse(&text) {
                Ok(d) => d,
                Err(e) => {
                    respond_error(&mut out, 400, "Bad Request", &format!("request: {e}"));
                    return;
                }
            };
            let req = match service::decode_request(&doc) {
                Ok(r) => r,
                Err(e) => {
                    respond_error(&mut out, 400, "Bad Request", &e);
                    return;
                }
            };
            // application-level failures are a 200 with a structured
            // error line: the request was understood, the operation
            // failed — clients surface `MeasureError::render`
            let lines = match service.handle(&req) {
                Ok(resp) => service::response_lines(&resp),
                Err(e) => vec![service::error_line(&e)],
            };
            let _ = write_http(&mut out, 200, "OK", &lines);
        }
        (_, p) if method == "GET" || method == "POST" => {
            respond_error(&mut out, 404, "Not Found", &format!("request: unknown path `{p}`"));
        }
        _ => {
            respond_error(
                &mut out,
                405,
                "Method Not Allowed",
                &format!("request: unsupported method `{method}`"),
            );
        }
    }
}

fn respond_error(out: &mut TcpStream, status: u16, reason: &str, msg: &str) {
    let _ = write_http(out, status, reason, &[service::request_error_line(msg)]);
}

fn write_http(
    out: &mut TcpStream,
    status: u16,
    reason: &str,
    lines: &[String],
) -> std::io::Result<()> {
    let mut body = lines.join("\n");
    body.push('\n');
    write_http_raw(out, status, reason, &body)
}

fn write_http_raw(
    out: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    out.write_all(head.as_bytes())?;
    out.write_all(body.as_bytes())?;
    out.flush()
}

// ---------------------------------------------------------------------------
// Client side (`pipefwd client`, the serve tests/benches)
// ---------------------------------------------------------------------------

/// Send one request, return the response items (the `done` terminator
/// verified and stripped). Server-side failures surface as `Err` with
/// the error's store-form rendering.
pub fn request(addr: &str, req: &ServiceRequest) -> Result<Vec<Json>, String> {
    let body = service::encode_request(req).to_compact();
    let (status, text) = http(addr, "POST", "/api/v1", Some(&body))?;
    let lines = parse_ndjson(&text)?;
    match service::decode_response_lines(&lines) {
        Ok(items) if status == 200 => Ok(items),
        Ok(_) => Err(format!("server returned HTTP {status}")),
        Err(e) => Err(e),
    }
}

/// `GET /stats` as one parsed document.
pub fn get_stats(addr: &str) -> Result<Json, String> {
    let (status, text) = http(addr, "GET", "/stats", None)?;
    if status != 200 {
        let lines = parse_ndjson(&text).unwrap_or_default();
        return Err(service::decode_response_lines(&lines)
            .err()
            .unwrap_or_else(|| format!("server returned HTTP {status}")));
    }
    json::parse(&text)
}

/// Minimal HTTP/1.1 exchange: write the request, read status + headers,
/// then the body to EOF (the server always answers `Connection: close`).
/// No read timeout — a paper-scale grid legitimately computes for a
/// long time before the first response byte.
fn http(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String), String> {
    let mut stream =
        TcpStream::connect(addr).map_err(|e| format!("connecting to {addr}: {e}"))?;
    let content = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        content.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(content.as_bytes()))
        .map_err(|e| format!("sending request to {addr}: {e}"))?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader
        .read_line(&mut status_line)
        .map_err(|e| format!("reading response from {addr}: {e}"))?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            format!("malformed HTTP status line from {addr}: `{}`", status_line.trim_end())
        })?;
    loop {
        let mut line = String::new();
        let n = reader
            .read_line(&mut line)
            .map_err(|e| format!("reading response from {addr}: {e}"))?;
        if n == 0 || line.trim_end().is_empty() {
            break;
        }
    }
    let mut text = String::new();
    reader
        .read_to_string(&mut text)
        .map_err(|e| format!("reading response from {addr}: {e}"))?;
    Ok((status, text))
}

/// Parse a newline-delimited JSON body (blank lines ignored).
pub fn parse_ndjson(text: &str) -> Result<Vec<Json>, String> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| json::parse(l).map_err(|e| format!("response line `{l}`: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A bound listener keeps the pushed streams alive for the queue
    /// tests without touching the network beyond loopback binds.
    fn dummy_stream(listener: &TcpListener) -> TcpStream {
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client);
        server_side
    }

    #[test]
    fn queue_bounds_and_drains_after_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let q = Queue::new();
        assert_eq!(q.push(dummy_stream(&listener), 2).ok(), Some(1));
        assert_eq!(q.push(dummy_stream(&listener), 2).ok(), Some(2));
        // full: the stream comes back for the 503 path
        assert!(q.push(dummy_stream(&listener), 2).is_err());
        q.close();
        // closed: rejects new pushes but drains what it holds
        assert!(q.push(dummy_stream(&listener), 2).is_err());
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none());
    }

    #[test]
    fn ndjson_parses_lines_and_rejects_garbage() {
        let docs = parse_ndjson("{\"a\": 1}\n\n{\"b\": 2}\n").unwrap();
        assert_eq!(docs.len(), 2);
        assert!(parse_ndjson("{\"a\": 1}\nnot json\n").is_err());
    }
}
