//! `pipefwd serve`: the measurement daemon (PR-6 tentpole, transport
//! layer).
//!
//! A deliberately small std-only HTTP/1.1 server over
//! [`std::net::TcpListener`]: one accept thread feeding a *bounded*
//! connection queue, a fixed pool of worker threads draining it, and
//! one shared [`Service`] handling every request. Backpressure is the
//! queue bound — when it is full the accept thread answers `503` with a
//! structured error line instead of buffering unboundedly, and the
//! observed depth is reported through the v2 counters document
//! (`queue_depth_max`).
//!
//! Cross-client dedup needs no code here: all workers share one
//! `Service`, so concurrent requests for the same cell meet in the
//! engine's claim/fulfil memo table — the first claims and computes,
//! the rest block on the claim and are fulfilled from it. A client that
//! disconnects mid-computation releases nothing: its worker computes to
//! completion and fulfils the claim (the write of the response simply
//! fails), so a second client asking for the same cell still gets the
//! memoized result.
//!
//! Wire format: `POST /api/v1` with one `pipefwd-api-v1` request
//! document; the response body is newline-delimited compact JSON ending
//! in a `done` terminator (see [`super::service`]). `GET /stats`
//! returns the live counters + store footprint as one pretty document.
//!
//! Admission control (PR-10): the queue stamps every accepted
//! connection, and a request that declares a `deadline_ms` on its
//! document is shed with `503` + `Retry-After` *before* any engine work
//! when its queue wait already exceeds the deadline — the client's
//! retry budget is spent on attempts that can still succeed, not on
//! answers it has stopped waiting for. A per-client fair-share cap
//! (keyed by presented token, else non-loopback peer IP) bounds how
//! many workers one client can hold at once; `GET /readyz` reports
//! queue and store-budget pressure so orchestrators can steer load.
//!
//! Connections are **kept alive** (HTTP/1.1 default): a worker serves
//! requests off one connection until the client sends
//! `Connection: close`, the peer disconnects, framing breaks (the only
//! safe answer to a truncated or unread body is to close), or
//! [`MAX_REQUESTS_PER_CONN`] is reached — a fairness bound so one
//! chatty client cannot pin a pool slot forever. Idle kept-alive
//! connections die at [`IO_TIMEOUT`]. Each request served beyond a
//! connection's first bumps the `connections_reused` counter; a sweep
//! that drives many requests through one [`Client`] shows its saved
//! handshakes there.

use super::service::{self, Service, ServiceRequest};
use crate::util::fault;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Request-body cap: a `store_push` of a large store fits comfortably;
/// anything bigger is rejected with `413` before allocation.
pub const MAX_BODY_BYTES: usize = 32 * 1024 * 1024;
/// Request-head cap (request line + headers).
pub const MAX_HEAD_BYTES: u64 = 16 * 1024;
/// Server-side socket timeout: bounds how long a worker can be held by
/// a stalled peer (reading the request or writing the response). The
/// *compute* between the two is unbounded by design — paper-scale
/// grids take as long as they take.
pub const IO_TIMEOUT: Duration = Duration::from_secs(30);
/// Requests one keep-alive connection may carry before the daemon
/// answers `Connection: close` and frees the worker for the queue — a
/// fairness bound, not a correctness one (clients reconnect
/// transparently).
pub const MAX_REQUESTS_PER_CONN: usize = 100;

/// Seconds advertised in `Retry-After` on every `503` — the server's
/// hint for the client's backoff policy (which caps it at its own
/// `max_delay`).
pub const RETRY_AFTER_SECS: u64 = 1;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Connection-handling worker threads.
    pub workers: usize,
    /// Bounded queue capacity: accepted-but-unhandled connections.
    pub queue_cap: usize,
    /// Shared-secret auth token (`--token` / `PIPEFWD_TOKEN`). When
    /// set, requests from non-loopback peers must carry
    /// `Authorization: Bearer <token>` (constant-time compared) or are
    /// answered `401`. Loopback peers are exempt by default; the
    /// `/healthz` and `/readyz` probe endpoints are always exempt.
    pub token: Option<String>,
    /// Enforce the token for loopback peers too. Off by default — the
    /// local operator already owns the process; tests flip it on to
    /// exercise the 401 path without a second network interface.
    pub token_all: bool,
    /// Fair-share cap: the most requests one client may have in flight
    /// at once, keyed by presented token (else non-loopback peer IP);
    /// an anonymous loopback peer is exempt. `0` = auto:
    /// `max(1, workers - 1)`, so a single client can never monopolise
    /// the whole pool while others queue.
    pub per_client_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            workers: 4,
            queue_cap: 64,
            token: None,
            token_all: false,
            per_client_cap: 0,
        }
    }
}

/// The bounded hand-off between the accept thread and the workers.
struct Queue {
    inner: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    /// Each connection carries its enqueue instant, so a worker can
    /// tell a deadline-carrying request how long it already waited.
    items: VecDeque<(TcpStream, Instant)>,
    open: bool,
}

impl Queue {
    fn new() -> Queue {
        Queue { inner: Mutex::new(QueueState { items: VecDeque::new(), open: true }), ready: Condvar::new() }
    }

    /// Enqueue, or hand the stream back when full/closed (the caller
    /// turns that into a `503`). Returns the depth after the push — the
    /// number the backpressure counter tracks.
    fn push(&self, stream: TcpStream, cap: usize) -> Result<usize, TcpStream> {
        let mut st = self.inner.lock().unwrap();
        if !st.open || st.items.len() >= cap {
            return Err(stream);
        }
        st.items.push_back((stream, Instant::now()));
        let depth = st.items.len();
        self.ready.notify_one();
        Ok(depth)
    }

    /// Accepted-but-unhandled connections right now (`/readyz`'s
    /// headroom check).
    fn depth(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// Blocking pop; `None` once closed *and* drained, so in-flight
    /// work finishes before workers exit.
    fn pop(&self) -> Option<(TcpStream, Instant)> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(s) = st.items.pop_front() {
                return Some(s);
            }
            if !st.open {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    fn close(&self) {
        self.inner.lock().unwrap().open = false;
        self.ready.notify_all();
    }
}

/// Everything a worker needs to answer a request: the shared service
/// plus the queue/config/stop-flag state the probe and drain endpoints
/// report on.
struct ServerCtx {
    service: Arc<Service>,
    queue: Arc<Queue>,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
    /// In-flight request count per client key — the fair-share ledger.
    active: Mutex<HashMap<String, usize>>,
}

impl ServerCtx {
    /// Graceful drain — the SIGTERM-equivalent shutdown path (std has
    /// no signal handling, so `POST /shutdown` and [`Server::shutdown`]
    /// both funnel here): stop accepting, let the workers finish every
    /// queued and in-flight request, then the joined `serve` arm
    /// flushes its counters and exits.
    fn begin_drain(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queue.close();
        // unblock the accept loop so it observes the stop flag
        let _ = TcpStream::connect(self.addr);
    }
}

/// A running daemon. [`Server::join`] blocks until the daemon drains
/// (`POST /shutdown`) or the process dies — the CLI `serve` arm;
/// [`Server::shutdown`] (or drop) stops the accept loop, drains
/// in-flight work, and joins every thread — what the in-process tests
/// and benches use.
pub struct Server {
    ctx: Arc<ServerCtx>,
    handles: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (`HOST:PORT`; port 0 picks a free one) and start the
    /// accept thread + worker pool over one shared service.
    pub fn spawn(service: Arc<Service>, addr: &str, cfg: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let ctx = Arc::new(ServerCtx {
            service,
            queue: Arc::new(Queue::new()),
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
            addr,
            active: Mutex::new(HashMap::new()),
        });
        let mut handles = vec![];
        for _ in 0..ctx.cfg.workers.max(1) {
            let ctx = Arc::clone(&ctx);
            handles.push(std::thread::spawn(move || worker_loop(&ctx)));
        }
        {
            let ctx = Arc::clone(&ctx);
            handles.push(std::thread::spawn(move || accept_loop(&listener, &ctx)));
        }
        Ok(Server { ctx, handles })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.ctx.addr
    }

    /// Serve until drained (`POST /shutdown`) or the process dies (the
    /// CLI foreground mode).
    pub fn join(mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Stop accepting, finish in-flight requests, join every thread.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.ctx.begin_drain();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: &TcpListener, ctx: &ServerCtx) {
    let cap = ctx.cfg.queue_cap.max(1);
    for conn in listener.incoming() {
        if ctx.stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        // `net.accept` injection site: the peer's connection resets
        // before a byte is exchanged (half-open drop, conntrack flush)
        if fault::fire("net.accept") {
            drop(stream);
            continue;
        }
        match ctx.queue.push(stream, cap) {
            Ok(depth) => ctx.service.note_queue_depth(depth),
            Err(mut stream) => {
                // backpressure: answer, don't buffer — and tell the
                // client's retry policy how long to hold off
                let line =
                    service::request_error_line("busy: request queue is full — retry later");
                let _ = write_http_ex(
                    &mut stream,
                    503,
                    "Service Unavailable",
                    &format!("{line}\n"),
                    false,
                    &[("Retry-After", &RETRY_AFTER_SECS.to_string())],
                );
            }
        }
    }
    ctx.queue.close();
}

fn worker_loop(ctx: &ServerCtx) {
    while let Some((stream, queued_at)) = ctx.queue.pop() {
        ctx.service.note_client_served();
        // one malformed or panicking request must never take the worker
        // (and with it the daemon's capacity) down
        let _ = catch_unwind(AssertUnwindSafe(|| handle_connection(stream, queued_at, ctx)));
    }
}

fn handle_connection(stream: TcpStream, queued_at: Instant, ctx: &ServerCtx) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut out = stream;
    let mut reader = BufReader::new(read_half);
    // keep-alive loop: serve until the client closes or asks to, the
    // framing breaks, or the per-connection request cap is reached.
    // Only a connection's first request spent time in the accept queue;
    // later requests on the kept socket carry no queue wait.
    for served in 0..MAX_REQUESTS_PER_CONN {
        let last = served + 1 == MAX_REQUESTS_PER_CONN;
        let waited = (served == 0).then_some(queued_at);
        if !handle_one_request(&mut reader, &mut out, ctx, served > 0, last, waited) {
            return;
        }
    }
}

/// Serve one request off an open connection. Returns `true` iff the
/// connection stays open for another request — only after a response
/// whose head advertised `keep-alive` and whose request body was fully
/// consumed (the stream is aligned on the next request boundary).
fn handle_one_request(
    reader: &mut BufReader<TcpStream>,
    out: &mut TcpStream,
    ctx: &ServerCtx,
    reused: bool,
    last: bool,
    queued_at: Option<Instant>,
) -> bool {
    let service = &*ctx.service;
    // the head cap applies per request; the Take wrapper borrows the
    // reader so the body read below sees any bytes it buffered
    let mut head = reader.by_ref().take(MAX_HEAD_BYTES);
    let mut request_line = String::new();
    if head.read_line(&mut request_line).unwrap_or(0) == 0 {
        return false; // peer closed (or stalled) between requests
    }
    if reused {
        service.note_connection_reused();
    }
    // `net.read` injection site: the daemon stalls briefly, then the
    // connection dies mid-request (peer reset, conntrack timeout) —
    // no response is written, so the client's retry policy kicks in
    if fault::fire("net.read") {
        std::thread::sleep(Duration::from_millis(25));
        return false;
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_length: Option<usize> = None;
    let mut close_requested = false;
    let mut auth: Option<String> = None;
    loop {
        let mut line = String::new();
        match head.read_line(&mut line) {
            // EOF before the blank separator: truncated or oversized head
            Ok(0) => {
                respond_error(out, 400, "Bad Request", "request: truncated head", false);
                return false;
            }
            Ok(_) => {}
            Err(_) => return false,
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse::<usize>().ok();
            }
            if k.eq_ignore_ascii_case("connection") && v.trim().eq_ignore_ascii_case("close") {
                close_requested = true;
            }
            if k.eq_ignore_ascii_case("authorization") {
                auth = Some(v.trim().to_string());
            }
        }
    }
    drop(head);
    let keep = !close_requested && !last;

    // probe endpoints answer before auth — an orchestrator's health
    // checker does not hold credentials
    match (method.as_str(), path.as_str()) {
        ("GET", "/healthz") => {
            // liveness: the process is up and a worker answered
            let keep = keep && content_length.unwrap_or(0) == 0;
            let _ = write_http_raw(out, 200, "OK", "{\"ok\": true}\n", keep);
            return keep;
        }
        ("GET", "/readyz") => {
            // readiness: accepting work (not draining), queue headroom,
            // and the store still writable — plus the budget and shed
            // pressure gauges an orchestrator steers load by
            let keep = keep && content_length.unwrap_or(0) == 0;
            let draining = ctx.stop.load(Ordering::SeqCst);
            let depth = ctx.queue.depth();
            let cap = ctx.cfg.queue_cap.max(1);
            let degraded = service.store_degraded();
            let ready = !draining && depth < cap && !degraded;
            let (store_bytes, store_max) = service.store_pressure();
            let body = format!(
                "{{\"ready\": {ready}, \"draining\": {draining}, \"queue_depth\": {depth}, \
                 \"queue_cap\": {cap}, \"store_degraded\": {degraded}, \
                 \"store_bytes\": {store_bytes}, \"store_max_bytes\": {}, \
                 \"deadline_sheds\": {}, \"fair_sheds\": {}}}\n",
                store_max.map(|m| m.to_string()).unwrap_or_else(|| "null".into()),
                service.deadline_sheds(),
                service.fair_sheds(),
            );
            let _ = if ready {
                write_http_raw(out, 200, "OK", &body, keep)
            } else {
                write_http_ex(
                    out,
                    503,
                    "Service Unavailable",
                    &body,
                    keep,
                    &[("Retry-After", &RETRY_AFTER_SECS.to_string())],
                )
            };
            return keep;
        }
        _ => {}
    }

    if !authorized(ctx, out, auth.as_deref()) {
        // the request body (if any) is unread — never reuse the stream
        respond_error(out, 401, "Unauthorized", "request: missing or invalid token", false);
        return false;
    }

    match (method.as_str(), path.as_str()) {
        ("POST", "/shutdown") => {
            // graceful drain (the SIGTERM equivalent): acknowledge,
            // then stop accepting; queued + in-flight requests finish
            // and the `serve` arm flushes counters after join
            let _ = write_http_raw(out, 200, "OK", "{\"draining\": true}\n", false);
            ctx.begin_drain();
            false
        }
        ("GET", "/stats") => {
            // a GET carrying a body would desync the framing — close then
            let keep = keep && content_length.unwrap_or(0) == 0;
            let _ = write_http_raw(out, 200, "OK", &service.stats_doc().to_pretty(), keep);
            keep
        }
        ("POST", "/api/v1") => {
            let Some(len) = content_length else {
                respond_error(
                    out,
                    411,
                    "Length Required",
                    "request: missing Content-Length",
                    false,
                );
                return false;
            };
            if len > MAX_BODY_BYTES {
                respond_error(
                    out,
                    413,
                    "Payload Too Large",
                    &format!("request: body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte cap"),
                    false,
                );
                return false;
            }
            let mut body = vec![0u8; len];
            if reader.read_exact(&mut body).is_err() {
                respond_error(out, 400, "Bad Request", "request: truncated body", false);
                return false;
            }
            // from here the body is fully consumed: even an invalid
            // request leaves the stream request-aligned, so keep-alive
            // survives validation failures
            let Ok(text) = String::from_utf8(body) else {
                respond_error(out, 400, "Bad Request", "request: body is not UTF-8", keep);
                return keep;
            };
            let doc = match json::parse(&text) {
                Ok(d) => d,
                Err(e) => {
                    respond_error(out, 400, "Bad Request", &format!("request: {e}"), keep);
                    return keep;
                }
            };
            // admission, step 1 — deadline shed: a request that rode on
            // its document an optional `deadline_ms` (absent = today's
            // behavior, old clients interoperate) and already waited in
            // the accept queue past it is answered 503 *before* a
            // worker burns compute on an answer the client gave up on
            if let (Some(deadline), Some(at)) =
                (doc.get("deadline_ms").and_then(|v| v.as_u64()), queued_at)
            {
                let waited = at.elapsed().as_millis() as u64;
                if waited > deadline {
                    service.note_deadline_shed();
                    respond_busy(
                        out,
                        &format!(
                            "busy: queued {waited} ms, past the {deadline} ms deadline — \
                             retry later"
                        ),
                        keep,
                    );
                    return keep;
                }
            }
            // admission, step 2 — fair share: one client may not hold
            // more than its share of the worker pool at once
            let _share = match try_acquire_share(ctx, client_share_key(out, auth.as_deref())) {
                Ok(guard) => guard,
                Err(cap) => {
                    service.note_fair_shed();
                    respond_busy(
                        out,
                        &format!(
                            "busy: client already holds {cap} in-flight request(s) — \
                             retry later"
                        ),
                        keep,
                    );
                    return keep;
                }
            };
            let req = match service::decode_request(&doc) {
                Ok(r) => r,
                Err(e) => {
                    respond_error(out, 400, "Bad Request", &e, keep);
                    return keep;
                }
            };
            // application-level failures are a 200 with a structured
            // error line: the request was understood, the operation
            // failed — clients surface `MeasureError::render`
            let lines = match service.handle(&req) {
                Ok(resp) => service::response_lines(&resp),
                Err(e) => vec![service::error_line(&e)],
            };
            let _ = write_http(out, 200, "OK", &lines, keep);
            keep
        }
        (_, p) if method == "GET" || method == "POST" => {
            // an unknown path may carry an unread body — never reuse
            respond_error(out, 404, "Not Found", &format!("request: unknown path `{p}`"), false);
            false
        }
        _ => {
            respond_error(
                out,
                405,
                "Method Not Allowed",
                &format!("request: unsupported method `{method}`"),
                false,
            );
            false
        }
    }
}

/// Gate for authenticated endpoints. Open when no token is configured;
/// otherwise the request must carry `Authorization: Bearer <token>` —
/// except from loopback peers, who are exempt unless `token_all` is on
/// (the local operator already owns the process).
fn authorized(ctx: &ServerCtx, out: &TcpStream, auth: Option<&str>) -> bool {
    let Some(token) = ctx.cfg.token.as_deref() else { return true };
    let loopback = out.peer_addr().map(|a| a.ip().is_loopback()).unwrap_or(false);
    if loopback && !ctx.cfg.token_all {
        return true;
    }
    let presented = auth
        .and_then(|v| {
            let (scheme, rest) = v.split_once(' ')?;
            scheme.eq_ignore_ascii_case("bearer").then(|| rest.trim())
        })
        .unwrap_or("");
    constant_time_eq(presented.as_bytes(), token.as_bytes())
}

/// Length-safe constant-time comparison: the work done is a function of
/// the *presented* value's length only, never of how many leading bytes
/// happen to match the secret — no early exit for a timing oracle to
/// measure.
fn constant_time_eq(a: &[u8], b: &[u8]) -> bool {
    let mut diff = a.len() ^ b.len();
    for i in 0..a.len().max(b.len()) {
        let x = a.get(i).copied().unwrap_or(0);
        let y = b.get(i).copied().unwrap_or(0);
        diff |= usize::from(x ^ y);
    }
    diff == 0
}

/// The identity a request's fair share is charged to: the presented
/// bearer token when there is one (shared fleets authenticate), else
/// the non-loopback peer IP. An anonymous loopback peer gets no key —
/// the local operator already owns the process, and local shard
/// pipelines must not shed themselves.
fn client_share_key(out: &TcpStream, auth: Option<&str>) -> Option<String> {
    if let Some(v) = auth {
        if let Some((scheme, rest)) = v.split_once(' ') {
            if scheme.eq_ignore_ascii_case("bearer") {
                return Some(format!("token:{}", rest.trim()));
            }
        }
    }
    match out.peer_addr() {
        Ok(a) if !a.ip().is_loopback() => Some(format!("ip:{}", a.ip())),
        _ => None,
    }
}

/// A held fair-share slot. Dropping it releases the client's in-flight
/// count — RAII, so a handler that panics under `engine.panic` can
/// never leak its slot and starve the client out permanently.
struct ShareGuard<'a> {
    active: &'a Mutex<HashMap<String, usize>>,
    key: String,
}

impl Drop for ShareGuard<'_> {
    fn drop(&mut self) {
        let mut map = self.active.lock().unwrap();
        if let Some(n) = map.get_mut(&self.key) {
            *n -= 1;
            if *n == 0 {
                map.remove(&self.key);
            }
        }
    }
}

/// Charge one in-flight request to `key`'s share, or report the cap it
/// would exceed. `Ok(None)` means the client is exempt (no key).
fn try_acquire_share<'a>(
    ctx: &'a ServerCtx,
    key: Option<String>,
) -> Result<Option<ShareGuard<'a>>, usize> {
    let Some(key) = key else { return Ok(None) };
    let cap = match ctx.cfg.per_client_cap {
        0 => ctx.cfg.workers.max(2) - 1, // auto: max(1, workers - 1)
        n => n,
    };
    let mut map = ctx.active.lock().unwrap();
    let n = map.entry(key.clone()).or_insert(0);
    if *n >= cap {
        return Err(cap);
    }
    *n += 1;
    drop(map);
    Ok(Some(ShareGuard { active: &ctx.active, key }))
}

/// The admission-control `503`: same shape as the accept loop's
/// backpressure answer, so the client retry policy treats every shed
/// identically (transient, honor `Retry-After`).
fn respond_busy(out: &mut TcpStream, msg: &str, keep: bool) {
    let line = service::request_error_line(msg);
    let _ = write_http_ex(
        out,
        503,
        "Service Unavailable",
        &format!("{line}\n"),
        keep,
        &[("Retry-After", &RETRY_AFTER_SECS.to_string())],
    );
}

fn respond_error(out: &mut TcpStream, status: u16, reason: &str, msg: &str, keep: bool) {
    let _ = write_http(out, status, reason, &[service::request_error_line(msg)], keep);
}

fn write_http(
    out: &mut TcpStream,
    status: u16,
    reason: &str,
    lines: &[String],
    keep: bool,
) -> std::io::Result<()> {
    let mut body = lines.join("\n");
    body.push('\n');
    write_http_raw(out, status, reason, &body, keep)
}

fn write_http_raw(
    out: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &str,
    keep: bool,
) -> std::io::Result<()> {
    write_http_ex(out, status, reason, body, keep, &[])
}

fn write_http_ex(
    out: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &str,
    keep: bool,
    extra_headers: &[(&str, &str)],
) -> std::io::Result<()> {
    let connection = if keep { "keep-alive" } else { "close" };
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {connection}\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    // `net.write` injection site: the full head goes out advertising
    // the real Content-Length, then the connection dies half-way
    // through the body — the client sees a short read (truncated
    // NDJSON, no `done` line) and must retry
    if fault::fire("net.write") {
        out.write_all(head.as_bytes())?;
        out.write_all(&body.as_bytes()[..body.len() / 2])?;
        let _ = out.flush();
        let _ = out.shutdown(std::net::Shutdown::Both);
        return Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "fault: injected truncated response at `net.write`",
        ));
    }
    out.write_all(head.as_bytes())?;
    out.write_all(body.as_bytes())?;
    out.flush()
}

// ---------------------------------------------------------------------------
// Client side (`pipefwd client`, the serve tests/benches)
// ---------------------------------------------------------------------------

/// Send one request on a fresh `Connection: close` connection, return
/// the response items (the `done` terminator verified and stripped).
/// Server-side failures surface as `Err` with the error's store-form
/// rendering — no retries (hold a [`Client`] for those). A caller
/// issuing many requests should hold a [`Client`] anyway and pay the
/// handshake once.
pub fn request(addr: &str, req: &ServiceRequest) -> Result<Vec<Json>, String> {
    let body = service::encode_request(req).to_compact();
    let raw = http(addr, "POST", "/api/v1", Some(&body))?;
    decode_api_response(&raw).map_err(AttemptError::into_message)
}

/// `GET /stats` as one parsed document (fresh connection per call).
pub fn get_stats(addr: &str) -> Result<Json, String> {
    let raw = http(addr, "GET", "/stats", None)?;
    decode_stats_response(&raw).map_err(AttemptError::into_message)
}

/// Why an attempt failed, from the retry policy's point of view:
/// transient failures (connect/IO errors, 5xx, truncated streams) are
/// retried with backoff, permanent ones (4xx, application errors)
/// surface immediately.
enum AttemptError {
    Transient { msg: String, retry_after: Option<u64> },
    Permanent(String),
}

impl AttemptError {
    fn transient(msg: String) -> AttemptError {
        AttemptError::Transient { msg, retry_after: None }
    }

    fn into_message(self) -> String {
        match self {
            AttemptError::Transient { msg, .. } | AttemptError::Permanent(msg) => msg,
        }
    }
}

fn decode_api_response(raw: &RawResponse) -> Result<Vec<Json>, AttemptError> {
    if raw.status >= 500 {
        // 503 from the accept loop's backpressure path (carrying
        // Retry-After) or any other server-side failure: retryable
        let msg = parse_ndjson(&raw.body)
            .ok()
            .and_then(|lines| service::decode_response_lines(&lines).err())
            .unwrap_or_else(|| format!("server returned HTTP {}", raw.status));
        return Err(AttemptError::Transient { msg, retry_after: raw.retry_after });
    }
    // garbage on the wire after a 200 head usually means the stream was
    // cut mid-line — retryable, same as an unterminated response
    let lines = parse_ndjson(&raw.body).map_err(|e| {
        if raw.status == 200 { AttemptError::transient(e) } else { AttemptError::Permanent(e) }
    })?;
    match service::decode_response_lines(&lines) {
        Ok(items) if raw.status == 200 => Ok(items),
        Ok(_) => Err(AttemptError::Permanent(format!("server returned HTTP {}", raw.status))),
        Err(e) if raw.status == 200 && service::is_truncated_response(&e) => {
            Err(AttemptError::transient(e))
        }
        Err(e) => Err(AttemptError::Permanent(e)),
    }
}

fn decode_stats_response(raw: &RawResponse) -> Result<Json, AttemptError> {
    if raw.status >= 500 {
        let msg = parse_ndjson(&raw.body)
            .ok()
            .and_then(|lines| service::decode_response_lines(&lines).err())
            .unwrap_or_else(|| format!("server returned HTTP {}", raw.status));
        return Err(AttemptError::Transient { msg, retry_after: raw.retry_after });
    }
    if raw.status != 200 {
        let lines = parse_ndjson(&raw.body).unwrap_or_default();
        return Err(AttemptError::Permanent(
            service::decode_response_lines(&lines)
                .err()
                .unwrap_or_else(|| format!("server returned HTTP {}", raw.status)),
        ));
    }
    // a half-written stats document fails to parse: retryable
    json::parse(&raw.body).map_err(AttemptError::transient)
}

/// One-shot HTTP/1.1 exchange on a fresh connection, declaring
/// `Connection: close`.
fn http(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<RawResponse, String> {
    let mut stream =
        TcpStream::connect(addr).map_err(|e| format!("connecting to {addr}: {e}"))?;
    send_head(&mut stream, addr, method, path, body.unwrap_or(""), true, None)?;
    let mut reader = BufReader::new(stream);
    read_response(&mut reader, addr)
}

fn send_head(
    stream: &mut TcpStream,
    addr: &str,
    method: &str,
    path: &str,
    content: &str,
    close: bool,
    token: Option<&str>,
) -> Result<(), String> {
    let connection = if close { "close" } else { "keep-alive" };
    let auth = match token {
        Some(t) => format!("Authorization: Bearer {t}\r\n"),
        None => String::new(),
    };
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {connection}\r\n{auth}\r\n",
        content.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(content.as_bytes()))
        .and_then(|()| stream.flush())
        .map_err(|e| format!("sending request to {addr}: {e}"))
}

/// One parsed HTTP response, plus the headers the retry policy cares
/// about.
struct RawResponse {
    status: u16,
    body: String,
    /// The server said `Connection: close` (or implied it) — the socket
    /// must not be reused.
    server_close: bool,
    /// `Retry-After` seconds from a `503`, if the server sent one.
    retry_after: Option<u64>,
}

/// Read one HTTP response, framed by `Content-Length` — mandatory for
/// keep-alive, where read-to-EOF would block forever on the open
/// socket. A response without the header falls back to read-to-EOF and
/// implies close. No read timeout — a paper-scale grid legitimately
/// computes for a long time before the first response byte.
fn read_response(
    reader: &mut BufReader<TcpStream>,
    addr: &str,
) -> Result<RawResponse, String> {
    let fail = |e| format!("reading response from {addr}: {e}");
    let mut status_line = String::new();
    if reader.read_line(&mut status_line).map_err(fail)? == 0 {
        return Err(format!("connection to {addr} closed before a response arrived"));
    }
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            format!("malformed HTTP status line from {addr}: `{}`", status_line.trim_end())
        })?;
    let mut content_length: Option<usize> = None;
    let mut server_close = false;
    let mut retry_after: Option<u64> = None;
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).map_err(fail)?;
        if n == 0 || line.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse::<usize>().ok();
            }
            if k.eq_ignore_ascii_case("connection") && v.trim().eq_ignore_ascii_case("close") {
                server_close = true;
            }
            if k.eq_ignore_ascii_case("retry-after") {
                retry_after = v.trim().parse::<u64>().ok();
            }
        }
    }
    let body = match content_length {
        Some(len) => {
            let mut buf = vec![0u8; len];
            reader.read_exact(&mut buf).map_err(fail)?;
            String::from_utf8(buf)
                .map_err(|_| format!("response body from {addr} is not UTF-8"))?
        }
        None => {
            let mut t = String::new();
            reader.read_to_string(&mut t).map_err(fail)?;
            server_close = true;
            t
        }
    };
    Ok(RawResponse { status, body, server_close, retry_after })
}

/// Capped-exponential-backoff retry with deterministic jitter — what a
/// [`Client`] does with transient failures.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Attempts per call, counting the first (1 = no retries).
    pub max_attempts: u32,
    /// Delay before the first retry; doubles per retry up to
    /// `max_delay`.
    pub base_delay: Duration,
    /// Cap on any single delay — also caps an honored `Retry-After`.
    pub max_delay: Duration,
    /// Wall-clock budget per call: no retry *starts* past this.
    pub deadline: Duration,
    /// Seed for the jitter stream, so two runs with the same seed sleep
    /// the same schedule (the fault soak depends on this).
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 6,
            base_delay: Duration::from_millis(50),
            max_delay: Duration::from_secs(2),
            deadline: Duration::from_secs(120),
            jitter_seed: 0x70697065, // "pipe"
        }
    }
}

/// The delay before retry number `retry` (0-based). An honored
/// `Retry-After` overrides the exponential schedule (capped at
/// `max_delay`); otherwise the delay is drawn deterministically from
/// `[cap/2, cap]` where `cap = min(base · 2^retry, max_delay)` — full
/// determinism, half the herd alignment.
fn backoff_delay(policy: &RetryPolicy, retry: u32, rng: &mut Rng, retry_after: Option<u64>) -> Duration {
    if let Some(secs) = retry_after {
        return Duration::from_secs(secs).min(policy.max_delay);
    }
    let cap = policy
        .base_delay
        .saturating_mul(1u32 << retry.min(16))
        .min(policy.max_delay);
    let ms = cap.as_millis() as u64;
    Duration::from_millis(ms / 2 + rng.below(ms / 2 + 1))
}

/// A persistent daemon connection: every call reuses one keep-alive
/// HTTP/1.1 socket, reconnecting transparently when the server closes
/// it (per-connection request cap, idle timeout, daemon restart), and
/// retrying transient failures under a [`RetryPolicy`]. A stale kept
/// socket (the server closed it between calls) gets one immediate
/// free reconnect before the backoff schedule engages — reconnection
/// after the request cap stays instant. The free
/// [`request`]/[`get_stats`] helpers remain the
/// connection-per-request, no-retry path.
pub struct Client {
    addr: String,
    conn: Option<(TcpStream, BufReader<TcpStream>)>,
    policy: RetryPolicy,
    rng: Rng,
    retries: u64,
    token: Option<String>,
    deadline_ms: Option<u64>,
}

impl Client {
    /// Lazy: no connection is made until the first call.
    pub fn new(addr: &str) -> Client {
        let policy = RetryPolicy::default();
        let rng = Rng::new(policy.jitter_seed);
        Client {
            addr: addr.to_string(),
            conn: None,
            policy,
            rng,
            retries: 0,
            token: None,
            deadline_ms: None,
        }
    }

    /// Replace the retry policy (builder-style).
    pub fn with_retry(mut self, policy: RetryPolicy) -> Client {
        self.rng = Rng::new(policy.jitter_seed);
        self.policy = policy;
        self
    }

    /// Attach a shared-secret token, sent as `Authorization: Bearer`
    /// on every request (builder-style).
    pub fn with_token(mut self, token: Option<String>) -> Client {
        self.token = token;
        self
    }

    /// Declare a freshness deadline, carried as `deadline_ms` on every
    /// API request document (builder-style). The daemon sheds the
    /// request with `503` before doing any work if it already sat in
    /// the accept queue longer than this; `None` (the default) keeps
    /// today's wire documents byte-identical, so old daemons
    /// interoperate.
    pub fn with_deadline(mut self, deadline_ms: Option<u64>) -> Client {
        self.deadline_ms = deadline_ms;
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Retries performed over this client's lifetime (stale-socket
    /// reconnects included; first attempts are not retries).
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Send one API request over the persistent connection.
    pub fn request(&mut self, req: &ServiceRequest) -> Result<Vec<Json>, String> {
        let mut doc = service::encode_request(req);
        if let (Some(d), Json::Obj(pairs)) = (self.deadline_ms, &mut doc) {
            pairs.push(("deadline_ms".to_string(), Json::Num(d as f64)));
        }
        let body = doc.to_compact();
        self.call("POST", "/api/v1", &body, decode_api_response)
    }

    /// `GET /stats` over the persistent connection.
    pub fn get_stats(&mut self) -> Result<Json, String> {
        self.call("GET", "/stats", "", decode_stats_response)
    }

    fn connect(&mut self) -> Result<(), String> {
        let err = |e| format!("connecting to {}: {e}", self.addr);
        let stream = TcpStream::connect(&self.addr).map_err(err)?;
        let read_half = stream.try_clone().map_err(err)?;
        self.conn = Some((stream, BufReader::new(read_half)));
        Ok(())
    }

    /// The retry loop: run attempts until one succeeds, fails
    /// permanently, exhausts `max_attempts`, or would sleep past the
    /// deadline.
    fn call<T>(
        &mut self,
        method: &str,
        path: &str,
        content: &str,
        decode: fn(&RawResponse) -> Result<T, AttemptError>,
    ) -> Result<T, String> {
        let start = Instant::now();
        let mut free_retry_used = false;
        let mut retry: u32 = 0;
        loop {
            let reused = self.conn.is_some();
            let (msg, retry_after) = match self.attempt_once(method, path, content, decode) {
                Ok(v) => return Ok(v),
                Err(AttemptError::Permanent(e)) => return Err(e),
                Err(AttemptError::Transient { msg, retry_after }) => (msg, retry_after),
            };
            // never reuse a connection an attempt just failed on
            self.conn = None;
            if reused && !free_retry_used {
                // the kept socket went stale between calls (request
                // cap, idle timeout, restart): retry immediately
                free_retry_used = true;
                self.retries += 1;
                continue;
            }
            if retry + 1 >= self.policy.max_attempts {
                return Err(format!(
                    "giving up on {method} {path} after {} attempts: {msg}",
                    self.policy.max_attempts
                ));
            }
            let delay = backoff_delay(&self.policy, retry, &mut self.rng, retry_after);
            if start.elapsed() + delay > self.policy.deadline {
                return Err(format!(
                    "deadline of {:?} exceeded retrying {method} {path}: {msg}",
                    self.policy.deadline
                ));
            }
            std::thread::sleep(delay);
            retry += 1;
            self.retries += 1;
        }
    }

    fn attempt_once<T>(
        &mut self,
        method: &str,
        path: &str,
        content: &str,
        decode: fn(&RawResponse) -> Result<T, AttemptError>,
    ) -> Result<T, AttemptError> {
        let addr = self.addr.clone();
        let token = self.token.clone();
        if self.conn.is_none() {
            self.connect().map_err(AttemptError::transient)?;
        }
        let conn = self.conn.as_mut().unwrap();
        let io = send_head(&mut conn.0, &addr, method, path, content, false, token.as_deref())
            .and_then(|()| read_response(&mut conn.1, &addr));
        let raw = match io {
            Ok(raw) => raw,
            Err(e) => {
                self.conn = None;
                return Err(AttemptError::transient(e));
            }
        };
        if raw.server_close {
            self.conn = None;
        }
        decode(&raw)
    }
}

/// Parse a newline-delimited JSON body (blank lines ignored).
pub fn parse_ndjson(text: &str) -> Result<Vec<Json>, String> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| json::parse(l).map_err(|e| format!("response line `{l}`: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A bound listener keeps the pushed streams alive for the queue
    /// tests without touching the network beyond loopback binds.
    fn dummy_stream(listener: &TcpListener) -> TcpStream {
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client);
        server_side
    }

    #[test]
    fn queue_bounds_and_drains_after_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let q = Queue::new();
        assert_eq!(q.push(dummy_stream(&listener), 2).ok(), Some(1));
        assert_eq!(q.push(dummy_stream(&listener), 2).ok(), Some(2));
        // full: the stream comes back for the 503 path
        assert!(q.push(dummy_stream(&listener), 2).is_err());
        q.close();
        // closed: rejects new pushes but drains what it holds
        assert!(q.push(dummy_stream(&listener), 2).is_err());
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none());
    }

    #[test]
    fn ndjson_parses_lines_and_rejects_garbage() {
        let docs = parse_ndjson("{\"a\": 1}\n\n{\"b\": 2}\n").unwrap();
        assert_eq!(docs.len(), 2);
        assert!(parse_ndjson("{\"a\": 1}\nnot json\n").is_err());
    }

    /// A persistent [`Client`] reuses one connection across requests
    /// (the daemon counts every request after a connection's first as a
    /// reuse); the one-shot helper still opens a fresh connection and
    /// sends `Connection: close`, which the server honors.
    #[test]
    fn keep_alive_reuses_connections_and_close_is_honored() {
        use crate::coordinator::engine::Engine;
        use crate::sim::device::DeviceConfig;
        let svc = Arc::new(Service::daemon(Engine::new(DeviceConfig::pac_a10(), 1)));
        let server = Server::spawn(
            Arc::clone(&svc),
            "127.0.0.1:0",
            ServerConfig { workers: 1, queue_cap: 4, ..Default::default() },
        )
        .unwrap();
        let addr = server.addr().to_string();

        // three requests over one client socket = one connection, two
        // reuses; mixing POST and GET keeps the framing request-aligned
        let mut client = Client::new(&addr);
        assert!(client.request(&ServiceRequest::Stats).is_ok());
        assert!(client.request(&ServiceRequest::Stats).is_ok());
        assert!(client.get_stats().is_ok());
        assert_eq!(svc.clients_served(), 1);
        assert_eq!(svc.connections_reused(), 2);

        // a validation failure is answered but leaves the connection
        // usable (the body was fully read)
        let bad = ServiceRequest::Measure {
            workload: "fw".into(),
            variant: crate::transform::Variant::Baseline,
            scale: crate::workloads::Scale::Tiny,
            device: Some("stratix10-hbm".into()), // not this engine's device
        };
        assert!(client.request(&bad).unwrap_err().contains("device mismatch"));
        assert!(client.request(&ServiceRequest::Stats).is_ok());
        assert_eq!(svc.clients_served(), 1);

        // drop the client so the single worker is freed for the
        // one-shot helper, which closes per request: a new connection
        // and no further reuse
        drop(client);
        assert!(request(&addr, &ServiceRequest::Stats).is_ok());
        assert_eq!(svc.clients_served(), 2);
        assert_eq!(svc.connections_reused(), 4);

        server.shutdown();
    }

    /// The per-connection request cap recycles the socket; the client
    /// reconnects transparently and every request still succeeds.
    #[test]
    fn request_cap_recycles_the_connection_transparently() {
        use crate::coordinator::engine::Engine;
        use crate::sim::device::DeviceConfig;
        let svc = Arc::new(Service::daemon(Engine::new(DeviceConfig::pac_a10(), 1)));
        let server = Server::spawn(
            Arc::clone(&svc),
            "127.0.0.1:0",
            ServerConfig { workers: 1, queue_cap: 4, ..Default::default() },
        )
        .unwrap();
        let mut client = Client::new(&server.addr().to_string());
        for _ in 0..MAX_REQUESTS_PER_CONN + 1 {
            assert!(client.get_stats().is_ok());
        }
        // request MAX_REQUESTS_PER_CONN came back `Connection: close`,
        // so the final request opened a second connection — and because
        // the server *announced* the close, no request ever failed and
        // the retry machinery never engaged
        assert_eq!(svc.clients_served(), 2);
        assert_eq!(svc.connections_reused(), (MAX_REQUESTS_PER_CONN - 1) as u64);
        assert_eq!(client.retries(), 0);
        drop(client);
        server.shutdown();
    }

    fn test_server(cfg: ServerConfig) -> (Arc<Service>, Server) {
        use crate::coordinator::engine::Engine;
        use crate::sim::device::DeviceConfig;
        let svc = Arc::new(Service::daemon(Engine::new(DeviceConfig::pac_a10(), 1)));
        let server = Server::spawn(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();
        (svc, server)
    }

    /// Raw one-shot exchange, for cases the [`Client`] cannot express
    /// (custom headers, mid-burst `Connection: close`).
    fn raw_http(addr: &str, head_and_body: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(head_and_body.as_bytes()).unwrap();
        s.flush().unwrap();
        let mut reader = BufReader::new(s);
        let raw = read_response(&mut reader, addr).unwrap();
        (raw.status, raw.body)
    }

    /// A request landing exactly *at* the per-connection cap is served
    /// normally with `Connection: close` — not rejected, not off by
    /// one.
    #[test]
    fn request_exactly_at_cap_is_served_then_closed() {
        let (svc, server) =
            test_server(ServerConfig { workers: 1, queue_cap: 4, ..Default::default() });
        let addr = server.addr().to_string();
        let mut s = TcpStream::connect(&addr).unwrap();
        let read_half = s.try_clone().unwrap();
        let mut reader = BufReader::new(read_half);
        for i in 1..=MAX_REQUESTS_PER_CONN {
            send_head(&mut s, &addr, "GET", "/stats", "", false, None).unwrap();
            let raw = read_response(&mut reader, &addr).unwrap();
            assert_eq!(raw.status, 200, "request {i} should succeed");
            // the cap-th response must advertise close; earlier ones must not
            assert_eq!(raw.server_close, i == MAX_REQUESTS_PER_CONN, "request {i}");
        }
        // the server hung up: the next read sees EOF
        let mut probe = String::new();
        assert_eq!(reader.read_line(&mut probe).unwrap_or(0), 0);
        assert_eq!(svc.clients_served(), 1);
        assert_eq!(svc.connections_reused(), (MAX_REQUESTS_PER_CONN - 1) as u64);
        drop((s, reader));
        server.shutdown();
    }

    /// `Connection: close` sent mid-burst is honored immediately: the
    /// response says close, the socket dies, and a fresh connection
    /// carries the rest of the burst.
    #[test]
    fn connection_close_mid_burst_is_honored() {
        let (svc, server) =
            test_server(ServerConfig { workers: 1, queue_cap: 4, ..Default::default() });
        let addr = server.addr().to_string();
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        send_head(&mut s, &addr, "GET", "/stats", "", false, None).unwrap();
        assert!(!read_response(&mut reader, &addr).unwrap().server_close);
        // second request of the burst asks to close
        send_head(&mut s, &addr, "GET", "/stats", "", true, None).unwrap();
        let raw = read_response(&mut reader, &addr).unwrap();
        assert_eq!(raw.status, 200);
        assert!(raw.server_close, "the server must echo the requested close");
        let mut probe = String::new();
        assert_eq!(reader.read_line(&mut probe).unwrap_or(0), 0, "socket should be closed");
        drop((s, reader));
        // the burst finishes on a new connection
        assert!(request(&addr, &ServiceRequest::Stats).is_ok());
        assert_eq!(svc.clients_served(), 2);
        server.shutdown();
    }

    /// Backpressure end to end: with the queue full, the accept thread
    /// answers `503` + `Retry-After`, and a [`Client`] rides it out by
    /// backing off until capacity frees up.
    #[test]
    fn full_queue_answers_503_with_retry_after_and_client_recovers() {
        let (_svc, server) =
            test_server(ServerConfig { workers: 1, queue_cap: 1, ..Default::default() });
        let addr = server.addr().to_string();

        // occupy the single worker with a connection that never sends a
        // request, and fill the one queue slot with another
        let worker_pin = TcpStream::connect(&addr).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        let queue_pin = TcpStream::connect(&addr).unwrap();
        std::thread::sleep(Duration::from_millis(100));

        // a third connection is answered straight from the accept loop
        let mut s = TcpStream::connect(&addr).unwrap();
        send_head(&mut s, &addr, "GET", "/stats", "", true, None).unwrap();
        let mut reader = BufReader::new(s);
        let raw = read_response(&mut reader, &addr).unwrap();
        assert_eq!(raw.status, 503);
        assert_eq!(raw.retry_after, Some(RETRY_AFTER_SECS));
        assert!(raw.body.contains("queue is full"));
        drop(reader);

        // free capacity from another thread while a retrying client is
        // mid-backoff — it must succeed without surfacing the 503s
        let unpin = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            drop(worker_pin);
            drop(queue_pin);
        });
        let mut client = Client::new(&addr).with_retry(RetryPolicy {
            base_delay: Duration::from_millis(100),
            max_delay: Duration::from_millis(200),
            ..Default::default()
        });
        assert!(client.get_stats().is_ok());
        assert!(client.retries() > 0, "the 503s should have been retried");
        unpin.join().unwrap();
        server.shutdown();
    }

    /// A queued request carrying `deadline_ms` that waited past its
    /// deadline is shed with a 503 before any engine work: the
    /// simulation counter never moves, and the identical request
    /// without a deadline still computes (old-client interop).
    #[test]
    fn expired_deadline_sheds_before_work() {
        let (svc, server) =
            test_server(ServerConfig { workers: 1, queue_cap: 4, ..Default::default() });
        let addr = server.addr().to_string();

        // pin the single worker with a connection that never sends, so
        // the next connection sits in the accept queue
        let worker_pin = TcpStream::connect(&addr).unwrap();
        std::thread::sleep(Duration::from_millis(100));

        // queue a measure request whose deadline is shorter than the pin
        let req = ServiceRequest::Measure {
            workload: "fw".into(),
            variant: crate::transform::Variant::Baseline,
            scale: crate::workloads::Scale::Tiny,
            device: None,
        };
        let mut doc = service::encode_request(&req);
        if let Json::Obj(pairs) = &mut doc {
            pairs.push(("deadline_ms".to_string(), Json::Num(50.0)));
        }
        let body = doc.to_compact();
        let mut s = TcpStream::connect(&addr).unwrap();
        send_head(&mut s, &addr, "POST", "/api/v1", &body, true, None).unwrap();
        let mut reader = BufReader::new(s);

        // hold the worker well past the deadline, then free it
        std::thread::sleep(Duration::from_millis(200));
        drop(worker_pin);

        let raw = read_response(&mut reader, &addr).unwrap();
        assert_eq!(raw.status, 503);
        assert_eq!(raw.retry_after, Some(RETRY_AFTER_SECS));
        assert!(raw.body.contains("deadline"), "unexpected body: {}", raw.body);
        assert_eq!(svc.engine().simulations(), 0, "shed must happen before any work");
        assert_eq!(svc.deadline_sheds(), 1);
        drop(reader);

        // the same request without a deadline computes normally
        let items = request(&addr, &req).unwrap();
        assert_eq!(items.len(), 2); // head + 1 cell
        assert!(svc.engine().simulations() > 0);
        server.shutdown();
    }

    /// The fair-share ledger: a client at its cap is rejected until a
    /// slot releases; other clients and anonymous loopback peers are
    /// unaffected; dropping the guard frees the slot.
    #[test]
    fn fair_share_counts_cap_and_release() {
        let (_svc, server) = test_server(ServerConfig {
            workers: 2,
            queue_cap: 4,
            per_client_cap: 1,
            ..Default::default()
        });
        let ctx = &server.ctx;
        let g1 = try_acquire_share(ctx, Some("token:a".into())).unwrap();
        assert!(g1.is_some());
        // same client, cap 1: rejected while g1 is held
        assert_eq!(try_acquire_share(ctx, Some("token:a".into())).err(), Some(1));
        // a different client has its own share
        let g2 = try_acquire_share(ctx, Some("token:b".into())).unwrap();
        assert!(g2.is_some());
        // anonymous loopback is exempt: no key, no accounting
        assert!(try_acquire_share(ctx, None).unwrap().is_none());
        drop(g1);
        // released: the slot is free again
        let g3 = try_acquire_share(ctx, Some("token:a".into())).unwrap();
        assert!(g3.is_some());
        drop((g2, g3));
        server.shutdown();
    }

    /// `/healthz` always answers; `/readyz` flips to 503 once the
    /// daemon starts draining.
    #[test]
    fn health_and_ready_probes_report_drain() {
        let (_svc, server) =
            test_server(ServerConfig { workers: 2, queue_cap: 4, ..Default::default() });
        let addr = server.addr().to_string();
        let head = |path: &str| {
            format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: 0\r\nConnection: close\r\n\r\n")
        };
        let (status, body) = raw_http(&addr, &head("/healthz"));
        assert_eq!(status, 200);
        assert!(body.contains("\"ok\": true"));
        let (status, body) = raw_http(&addr, &head("/readyz"));
        assert_eq!(status, 200);
        assert!(body.contains("\"ready\": true"), "unexpected readyz body: {body}");

        // POST /shutdown drains gracefully: the probe flips before the
        // workers finish, and join() returns without process death
        let (status, body) = raw_http(
            &addr,
            &format!("POST /shutdown HTTP/1.1\r\nHost: {addr}\r\nContent-Length: 0\r\nConnection: close\r\n\r\n"),
        );
        assert_eq!(status, 200);
        assert!(body.contains("\"draining\": true"));
        server.join(); // must return: drain stops the accept loop and closes the queue
    }

    /// With a token and `token_all`, an unauthenticated request gets a
    /// 401, the right token opens the door, and the probe endpoints
    /// stay exempt.
    #[test]
    fn token_auth_rejects_and_admits() {
        let (_svc, server) = test_server(ServerConfig {
            workers: 1,
            queue_cap: 4,
            token: Some("s3cret".into()),
            token_all: true,
        });
        let addr = server.addr().to_string();

        let mut no_token = Client::new(&addr)
            .with_retry(RetryPolicy { max_attempts: 1, ..Default::default() });
        let err = no_token.get_stats().unwrap_err();
        assert!(err.contains("invalid token"), "unexpected error: {err}");

        let mut wrong = Client::new(&addr)
            .with_retry(RetryPolicy { max_attempts: 1, ..Default::default() })
            .with_token(Some("nope".into()));
        assert!(wrong.get_stats().is_err());

        let mut right = Client::new(&addr).with_token(Some("s3cret".into()));
        assert!(right.get_stats().is_ok());
        assert!(right.request(&ServiceRequest::Stats).is_ok());

        // probes never require credentials — health checkers hold none
        let (status, _) = raw_http(
            &addr,
            &format!("GET /healthz HTTP/1.1\r\nHost: {addr}\r\nContent-Length: 0\r\nConnection: close\r\n\r\n"),
        );
        assert_eq!(status, 200);
        server.shutdown();
    }

    #[test]
    fn constant_time_eq_compares_correctly() {
        assert!(constant_time_eq(b"abc", b"abc"));
        assert!(!constant_time_eq(b"abc", b"abd"));
        assert!(!constant_time_eq(b"abc", b"abcd"));
        assert!(!constant_time_eq(b"", b"a"));
        assert!(constant_time_eq(b"", b""));
    }

    /// The backoff schedule: deterministic for a seed, exponential up
    /// to the cap, jittered within [cap/2, cap], `Retry-After` honored
    /// but clamped.
    #[test]
    fn backoff_schedule_is_capped_jittered_and_deterministic() {
        let policy = RetryPolicy::default();
        let schedule = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..8).map(|i| backoff_delay(&policy, i, &mut rng, None)).collect::<Vec<_>>()
        };
        let a = schedule(7);
        assert_eq!(a, schedule(7), "same seed, same schedule");
        for (i, d) in a.iter().enumerate() {
            let cap = policy.base_delay.saturating_mul(1 << i).min(policy.max_delay);
            assert!(*d >= cap / 2 && *d <= cap, "retry {i}: {d:?} outside [{:?}, {cap:?}]", cap / 2);
        }
        // far retries sit at the cap's window, not 2^n
        assert!(a[7] <= policy.max_delay);
        // Retry-After wins, but never past max_delay
        let mut rng = Rng::new(7);
        assert_eq!(backoff_delay(&policy, 0, &mut rng, Some(1)), Duration::from_secs(1));
        assert_eq!(backoff_delay(&policy, 0, &mut rng, Some(3600)), policy.max_delay);
    }
}
