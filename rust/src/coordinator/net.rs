//! `pipefwd serve`: the measurement daemon (PR-6 tentpole, transport
//! layer).
//!
//! A deliberately small std-only HTTP/1.1 server over
//! [`std::net::TcpListener`]: one accept thread feeding a *bounded*
//! connection queue, a fixed pool of worker threads draining it, and
//! one shared [`Service`] handling every request. Backpressure is the
//! queue bound — when it is full the accept thread answers `503` with a
//! structured error line instead of buffering unboundedly, and the
//! observed depth is reported through the v2 counters document
//! (`queue_depth_max`).
//!
//! Cross-client dedup needs no code here: all workers share one
//! `Service`, so concurrent requests for the same cell meet in the
//! engine's claim/fulfil memo table — the first claims and computes,
//! the rest block on the claim and are fulfilled from it. A client that
//! disconnects mid-computation releases nothing: its worker computes to
//! completion and fulfils the claim (the write of the response simply
//! fails), so a second client asking for the same cell still gets the
//! memoized result.
//!
//! Wire format: `POST /api/v1` with one `pipefwd-api-v1` request
//! document; the response body is newline-delimited compact JSON ending
//! in a `done` terminator (see [`super::service`]). `GET /stats`
//! returns the live counters + store footprint as one pretty document.
//!
//! Connections are **kept alive** (HTTP/1.1 default): a worker serves
//! requests off one connection until the client sends
//! `Connection: close`, the peer disconnects, framing breaks (the only
//! safe answer to a truncated or unread body is to close), or
//! [`MAX_REQUESTS_PER_CONN`] is reached — a fairness bound so one
//! chatty client cannot pin a pool slot forever. Idle kept-alive
//! connections die at [`IO_TIMEOUT`]. Each request served beyond a
//! connection's first bumps the `connections_reused` counter; a sweep
//! that drives many requests through one [`Client`] shows its saved
//! handshakes there.

use super::service::{self, Service, ServiceRequest};
use crate::util::json::{self, Json};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Request-body cap: a `store_push` of a large store fits comfortably;
/// anything bigger is rejected with `413` before allocation.
pub const MAX_BODY_BYTES: usize = 32 * 1024 * 1024;
/// Request-head cap (request line + headers).
pub const MAX_HEAD_BYTES: u64 = 16 * 1024;
/// Server-side socket timeout: bounds how long a worker can be held by
/// a stalled peer (reading the request or writing the response). The
/// *compute* between the two is unbounded by design — paper-scale
/// grids take as long as they take.
pub const IO_TIMEOUT: Duration = Duration::from_secs(30);
/// Requests one keep-alive connection may carry before the daemon
/// answers `Connection: close` and frees the worker for the queue — a
/// fairness bound, not a correctness one (clients reconnect
/// transparently).
pub const MAX_REQUESTS_PER_CONN: usize = 100;

#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Connection-handling worker threads.
    pub workers: usize,
    /// Bounded queue capacity: accepted-but-unhandled connections.
    pub queue_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig { workers: 4, queue_cap: 64 }
    }
}

/// The bounded hand-off between the accept thread and the workers.
struct Queue {
    inner: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    items: VecDeque<TcpStream>,
    open: bool,
}

impl Queue {
    fn new() -> Queue {
        Queue { inner: Mutex::new(QueueState { items: VecDeque::new(), open: true }), ready: Condvar::new() }
    }

    /// Enqueue, or hand the stream back when full/closed (the caller
    /// turns that into a `503`). Returns the depth after the push — the
    /// number the backpressure counter tracks.
    fn push(&self, stream: TcpStream, cap: usize) -> Result<usize, TcpStream> {
        let mut st = self.inner.lock().unwrap();
        if !st.open || st.items.len() >= cap {
            return Err(stream);
        }
        st.items.push_back(stream);
        let depth = st.items.len();
        self.ready.notify_one();
        Ok(depth)
    }

    /// Blocking pop; `None` once closed *and* drained, so in-flight
    /// work finishes before workers exit.
    fn pop(&self) -> Option<TcpStream> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(s) = st.items.pop_front() {
                return Some(s);
            }
            if !st.open {
                return None;
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    fn close(&self) {
        self.inner.lock().unwrap().open = false;
        self.ready.notify_all();
    }
}

/// A running daemon. [`Server::join`] blocks forever (the CLI `serve`
/// arm); [`Server::shutdown`] (or drop) stops the accept loop, drains
/// in-flight work, and joins every thread — what the in-process tests
/// and benches use.
pub struct Server {
    addr: SocketAddr,
    queue: Arc<Queue>,
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (`HOST:PORT`; port 0 picks a free one) and start the
    /// accept thread + worker pool over one shared service.
    pub fn spawn(service: Arc<Service>, addr: &str, cfg: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let queue = Arc::new(Queue::new());
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = vec![];
        for _ in 0..cfg.workers.max(1) {
            let q = Arc::clone(&queue);
            let svc = Arc::clone(&service);
            handles.push(std::thread::spawn(move || worker_loop(&q, &svc)));
        }
        {
            let q = Arc::clone(&queue);
            let svc = Arc::clone(&service);
            let stop = Arc::clone(&stop);
            let cap = cfg.queue_cap.max(1);
            handles.push(std::thread::spawn(move || accept_loop(&listener, &q, &svc, &stop, cap)));
        }
        Ok(Server { addr, queue, stop, handles })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until the process dies (the CLI foreground mode).
    pub fn join(mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Stop accepting, finish in-flight requests, join every thread.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queue.close();
        // unblock the accept loop so it observes the stop flag
        let _ = TcpStream::connect(self.addr);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: &TcpListener,
    queue: &Queue,
    service: &Service,
    stop: &AtomicBool,
    cap: usize,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        match queue.push(stream, cap) {
            Ok(depth) => service.note_queue_depth(depth),
            Err(mut stream) => {
                // backpressure: answer, don't buffer
                let line =
                    service::request_error_line("busy: request queue is full — retry later");
                let _ = write_http(&mut stream, 503, "Service Unavailable", &[line], false);
            }
        }
    }
    queue.close();
}

fn worker_loop(queue: &Queue, service: &Service) {
    while let Some(stream) = queue.pop() {
        service.note_client_served();
        // one malformed or panicking request must never take the worker
        // (and with it the daemon's capacity) down
        let _ = catch_unwind(AssertUnwindSafe(|| handle_connection(stream, service)));
    }
}

fn handle_connection(stream: TcpStream, service: &Service) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut out = stream;
    let mut reader = BufReader::new(read_half);
    // keep-alive loop: serve until the client closes or asks to, the
    // framing breaks, or the per-connection request cap is reached
    for served in 0..MAX_REQUESTS_PER_CONN {
        let last = served + 1 == MAX_REQUESTS_PER_CONN;
        if !handle_one_request(&mut reader, &mut out, service, served > 0, last) {
            return;
        }
    }
}

/// Serve one request off an open connection. Returns `true` iff the
/// connection stays open for another request — only after a response
/// whose head advertised `keep-alive` and whose request body was fully
/// consumed (the stream is aligned on the next request boundary).
fn handle_one_request(
    reader: &mut BufReader<TcpStream>,
    out: &mut TcpStream,
    service: &Service,
    reused: bool,
    last: bool,
) -> bool {
    // the head cap applies per request; the Take wrapper borrows the
    // reader so the body read below sees any bytes it buffered
    let mut head = reader.by_ref().take(MAX_HEAD_BYTES);
    let mut request_line = String::new();
    if head.read_line(&mut request_line).unwrap_or(0) == 0 {
        return false; // peer closed (or stalled) between requests
    }
    if reused {
        service.note_connection_reused();
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();

    let mut content_length: Option<usize> = None;
    let mut close_requested = false;
    loop {
        let mut line = String::new();
        match head.read_line(&mut line) {
            // EOF before the blank separator: truncated or oversized head
            Ok(0) => {
                respond_error(out, 400, "Bad Request", "request: truncated head", false);
                return false;
            }
            Ok(_) => {}
            Err(_) => return false,
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse::<usize>().ok();
            }
            if k.eq_ignore_ascii_case("connection") && v.trim().eq_ignore_ascii_case("close") {
                close_requested = true;
            }
        }
    }
    drop(head);
    let keep = !close_requested && !last;

    match (method.as_str(), path.as_str()) {
        ("GET", "/stats") => {
            // a GET carrying a body would desync the framing — close then
            let keep = keep && content_length.unwrap_or(0) == 0;
            let _ = write_http_raw(out, 200, "OK", &service.stats_doc().to_pretty(), keep);
            keep
        }
        ("POST", "/api/v1") => {
            let Some(len) = content_length else {
                respond_error(
                    out,
                    411,
                    "Length Required",
                    "request: missing Content-Length",
                    false,
                );
                return false;
            };
            if len > MAX_BODY_BYTES {
                respond_error(
                    out,
                    413,
                    "Payload Too Large",
                    &format!("request: body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte cap"),
                    false,
                );
                return false;
            }
            let mut body = vec![0u8; len];
            if reader.read_exact(&mut body).is_err() {
                respond_error(out, 400, "Bad Request", "request: truncated body", false);
                return false;
            }
            // from here the body is fully consumed: even an invalid
            // request leaves the stream request-aligned, so keep-alive
            // survives validation failures
            let Ok(text) = String::from_utf8(body) else {
                respond_error(out, 400, "Bad Request", "request: body is not UTF-8", keep);
                return keep;
            };
            let doc = match json::parse(&text) {
                Ok(d) => d,
                Err(e) => {
                    respond_error(out, 400, "Bad Request", &format!("request: {e}"), keep);
                    return keep;
                }
            };
            let req = match service::decode_request(&doc) {
                Ok(r) => r,
                Err(e) => {
                    respond_error(out, 400, "Bad Request", &e, keep);
                    return keep;
                }
            };
            // application-level failures are a 200 with a structured
            // error line: the request was understood, the operation
            // failed — clients surface `MeasureError::render`
            let lines = match service.handle(&req) {
                Ok(resp) => service::response_lines(&resp),
                Err(e) => vec![service::error_line(&e)],
            };
            let _ = write_http(out, 200, "OK", &lines, keep);
            keep
        }
        (_, p) if method == "GET" || method == "POST" => {
            // an unknown path may carry an unread body — never reuse
            respond_error(out, 404, "Not Found", &format!("request: unknown path `{p}`"), false);
            false
        }
        _ => {
            respond_error(
                out,
                405,
                "Method Not Allowed",
                &format!("request: unsupported method `{method}`"),
                false,
            );
            false
        }
    }
}

fn respond_error(out: &mut TcpStream, status: u16, reason: &str, msg: &str, keep: bool) {
    let _ = write_http(out, status, reason, &[service::request_error_line(msg)], keep);
}

fn write_http(
    out: &mut TcpStream,
    status: u16,
    reason: &str,
    lines: &[String],
    keep: bool,
) -> std::io::Result<()> {
    let mut body = lines.join("\n");
    body.push('\n');
    write_http_raw(out, status, reason, &body, keep)
}

fn write_http_raw(
    out: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &str,
    keep: bool,
) -> std::io::Result<()> {
    let connection = if keep { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {connection}\r\n\r\n",
        body.len()
    );
    out.write_all(head.as_bytes())?;
    out.write_all(body.as_bytes())?;
    out.flush()
}

// ---------------------------------------------------------------------------
// Client side (`pipefwd client`, the serve tests/benches)
// ---------------------------------------------------------------------------

/// Send one request on a fresh `Connection: close` connection, return
/// the response items (the `done` terminator verified and stripped).
/// Server-side failures surface as `Err` with the error's store-form
/// rendering. A caller issuing many requests should hold a [`Client`]
/// instead and pay the handshake once.
pub fn request(addr: &str, req: &ServiceRequest) -> Result<Vec<Json>, String> {
    let body = service::encode_request(req).to_compact();
    let (status, text) = http(addr, "POST", "/api/v1", Some(&body))?;
    decode_api_response(status, &text)
}

/// `GET /stats` as one parsed document (fresh connection per call).
pub fn get_stats(addr: &str) -> Result<Json, String> {
    let (status, text) = http(addr, "GET", "/stats", None)?;
    decode_stats_response(status, &text)
}

fn decode_api_response(status: u16, text: &str) -> Result<Vec<Json>, String> {
    let lines = parse_ndjson(text)?;
    match service::decode_response_lines(&lines) {
        Ok(items) if status == 200 => Ok(items),
        Ok(_) => Err(format!("server returned HTTP {status}")),
        Err(e) => Err(e),
    }
}

fn decode_stats_response(status: u16, text: &str) -> Result<Json, String> {
    if status != 200 {
        let lines = parse_ndjson(text).unwrap_or_default();
        return Err(service::decode_response_lines(&lines)
            .err()
            .unwrap_or_else(|| format!("server returned HTTP {status}")));
    }
    json::parse(text)
}

/// One-shot HTTP/1.1 exchange on a fresh connection, declaring
/// `Connection: close`.
fn http(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String), String> {
    let mut stream =
        TcpStream::connect(addr).map_err(|e| format!("connecting to {addr}: {e}"))?;
    send_head(&mut stream, addr, method, path, body.unwrap_or(""), true)?;
    let mut reader = BufReader::new(stream);
    let (status, text, _) = read_response(&mut reader, addr)?;
    Ok((status, text))
}

fn send_head(
    stream: &mut TcpStream,
    addr: &str,
    method: &str,
    path: &str,
    content: &str,
    close: bool,
) -> Result<(), String> {
    let connection = if close { "close" } else { "keep-alive" };
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {connection}\r\n\r\n",
        content.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(content.as_bytes()))
        .and_then(|()| stream.flush())
        .map_err(|e| format!("sending request to {addr}: {e}"))
}

/// Read one HTTP response, framed by `Content-Length` — mandatory for
/// keep-alive, where read-to-EOF would block forever on the open
/// socket. A response without the header falls back to read-to-EOF and
/// implies close. Returns `(status, body, server_says_close)`. No read
/// timeout — a paper-scale grid legitimately computes for a long time
/// before the first response byte.
fn read_response(
    reader: &mut BufReader<TcpStream>,
    addr: &str,
) -> Result<(u16, String, bool), String> {
    let fail = |e| format!("reading response from {addr}: {e}");
    let mut status_line = String::new();
    if reader.read_line(&mut status_line).map_err(fail)? == 0 {
        return Err(format!("connection to {addr} closed before a response arrived"));
    }
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            format!("malformed HTTP status line from {addr}: `{}`", status_line.trim_end())
        })?;
    let mut content_length: Option<usize> = None;
    let mut server_close = false;
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).map_err(fail)?;
        if n == 0 || line.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse::<usize>().ok();
            }
            if k.eq_ignore_ascii_case("connection") && v.trim().eq_ignore_ascii_case("close") {
                server_close = true;
            }
        }
    }
    let text = match content_length {
        Some(len) => {
            let mut buf = vec![0u8; len];
            reader.read_exact(&mut buf).map_err(fail)?;
            String::from_utf8(buf)
                .map_err(|_| format!("response body from {addr} is not UTF-8"))?
        }
        None => {
            let mut t = String::new();
            reader.read_to_string(&mut t).map_err(fail)?;
            server_close = true;
            t
        }
    };
    Ok((status, text, server_close))
}

/// A persistent daemon connection: every call reuses one keep-alive
/// HTTP/1.1 socket, reconnecting transparently when the server closes
/// it (per-connection request cap, idle timeout, daemon restart). The
/// free [`request`]/[`get_stats`] helpers remain the
/// connection-per-request path; anything issuing more than a couple of
/// requests should hold a `Client` — the daemon's `connections_reused`
/// counter shows the handshakes saved.
pub struct Client {
    addr: String,
    conn: Option<(TcpStream, BufReader<TcpStream>)>,
}

impl Client {
    /// Lazy: no connection is made until the first call.
    pub fn new(addr: &str) -> Client {
        Client { addr: addr.to_string(), conn: None }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Send one API request over the persistent connection.
    pub fn request(&mut self, req: &ServiceRequest) -> Result<Vec<Json>, String> {
        let body = service::encode_request(req).to_compact();
        let (status, text) = self.exchange("POST", "/api/v1", Some(&body))?;
        decode_api_response(status, &text)
    }

    /// `GET /stats` over the persistent connection.
    pub fn get_stats(&mut self) -> Result<Json, String> {
        let (status, text) = self.exchange("GET", "/stats", None)?;
        decode_stats_response(status, &text)
    }

    fn connect(&mut self) -> Result<(), String> {
        let err = |e| format!("connecting to {}: {e}", self.addr);
        let stream = TcpStream::connect(&self.addr).map_err(err)?;
        let read_half = stream.try_clone().map_err(err)?;
        self.conn = Some((stream, BufReader::new(read_half)));
        Ok(())
    }

    fn exchange(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String), String> {
        let content = body.unwrap_or("");
        let addr = self.addr.clone();
        let attempt = |conn: &mut (TcpStream, BufReader<TcpStream>)| {
            send_head(&mut conn.0, &addr, method, path, content, false)?;
            read_response(&mut conn.1, &addr)
        };
        let fresh = self.conn.is_none();
        if fresh {
            self.connect()?;
        }
        let mut r = attempt(self.conn.as_mut().unwrap());
        if r.is_err() && !fresh {
            // the kept socket went stale between calls (request cap,
            // idle timeout, restart): retry once on a fresh connection
            self.conn = None;
            self.connect()?;
            r = attempt(self.conn.as_mut().unwrap());
        }
        match r {
            Ok((status, text, server_close)) => {
                if server_close {
                    self.conn = None;
                }
                Ok((status, text))
            }
            Err(e) => {
                self.conn = None;
                Err(e)
            }
        }
    }
}

/// Parse a newline-delimited JSON body (blank lines ignored).
pub fn parse_ndjson(text: &str) -> Result<Vec<Json>, String> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| json::parse(l).map_err(|e| format!("response line `{l}`: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A bound listener keeps the pushed streams alive for the queue
    /// tests without touching the network beyond loopback binds.
    fn dummy_stream(listener: &TcpListener) -> TcpStream {
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client);
        server_side
    }

    #[test]
    fn queue_bounds_and_drains_after_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let q = Queue::new();
        assert_eq!(q.push(dummy_stream(&listener), 2).ok(), Some(1));
        assert_eq!(q.push(dummy_stream(&listener), 2).ok(), Some(2));
        // full: the stream comes back for the 503 path
        assert!(q.push(dummy_stream(&listener), 2).is_err());
        q.close();
        // closed: rejects new pushes but drains what it holds
        assert!(q.push(dummy_stream(&listener), 2).is_err());
        assert!(q.pop().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none());
    }

    #[test]
    fn ndjson_parses_lines_and_rejects_garbage() {
        let docs = parse_ndjson("{\"a\": 1}\n\n{\"b\": 2}\n").unwrap();
        assert_eq!(docs.len(), 2);
        assert!(parse_ndjson("{\"a\": 1}\nnot json\n").is_err());
    }

    /// A persistent [`Client`] reuses one connection across requests
    /// (the daemon counts every request after a connection's first as a
    /// reuse); the one-shot helper still opens a fresh connection and
    /// sends `Connection: close`, which the server honors.
    #[test]
    fn keep_alive_reuses_connections_and_close_is_honored() {
        use crate::coordinator::engine::Engine;
        use crate::sim::device::DeviceConfig;
        let svc = Arc::new(Service::daemon(Engine::new(DeviceConfig::pac_a10(), 1)));
        let server = Server::spawn(
            Arc::clone(&svc),
            "127.0.0.1:0",
            ServerConfig { workers: 1, queue_cap: 4 },
        )
        .unwrap();
        let addr = server.addr().to_string();

        // three requests over one client socket = one connection, two
        // reuses; mixing POST and GET keeps the framing request-aligned
        let mut client = Client::new(&addr);
        assert!(client.request(&ServiceRequest::Stats).is_ok());
        assert!(client.request(&ServiceRequest::Stats).is_ok());
        assert!(client.get_stats().is_ok());
        assert_eq!(svc.clients_served(), 1);
        assert_eq!(svc.connections_reused(), 2);

        // a validation failure is answered but leaves the connection
        // usable (the body was fully read)
        let bad = ServiceRequest::Measure {
            workload: "fw".into(),
            variant: crate::transform::Variant::Baseline,
            scale: crate::workloads::Scale::Tiny,
            device: Some("stratix10-hbm".into()), // not this engine's device
        };
        assert!(client.request(&bad).unwrap_err().contains("device mismatch"));
        assert!(client.request(&ServiceRequest::Stats).is_ok());
        assert_eq!(svc.clients_served(), 1);

        // drop the client so the single worker is freed for the
        // one-shot helper, which closes per request: a new connection
        // and no further reuse
        drop(client);
        assert!(request(&addr, &ServiceRequest::Stats).is_ok());
        assert_eq!(svc.clients_served(), 2);
        assert_eq!(svc.connections_reused(), 4);

        server.shutdown();
    }

    /// The per-connection request cap recycles the socket; the client
    /// reconnects transparently and every request still succeeds.
    #[test]
    fn request_cap_recycles_the_connection_transparently() {
        use crate::coordinator::engine::Engine;
        use crate::sim::device::DeviceConfig;
        let svc = Arc::new(Service::daemon(Engine::new(DeviceConfig::pac_a10(), 1)));
        let server = Server::spawn(
            Arc::clone(&svc),
            "127.0.0.1:0",
            ServerConfig { workers: 1, queue_cap: 4 },
        )
        .unwrap();
        let mut client = Client::new(&server.addr().to_string());
        for _ in 0..MAX_REQUESTS_PER_CONN + 1 {
            assert!(client.get_stats().is_ok());
        }
        // request MAX_REQUESTS_PER_CONN came back `Connection: close`,
        // so the final request opened a second connection
        assert_eq!(svc.clients_served(), 2);
        assert_eq!(svc.connections_reused(), (MAX_REQUESTS_PER_CONN - 1) as u64);
        drop(client);
        server.shutdown();
    }
}
