//! Experiment coordinator: orchestrates workloads x variants x scales,
//! validates against native and PJRT references, renders the paper's
//! tables/figures.

pub mod experiments;

pub use experiments::{
    best_ff, depth_sweep, figure4, headline, hotspot_m2c2_bw, intext, measure, micro_family,
    pc_sweep, table1, table2, table2_rows, table3, vector_study, Measurement,
};

use crate::report::Table;
use crate::sim::device::DeviceConfig;
use crate::workloads::Scale;

/// Run the complete evaluation (every table & figure) and return the
/// rendered tables in paper order. This is what the e2e example and the
/// `pipefwd all` CLI command drive.
pub fn full_evaluation(scale: Scale, cfg: &DeviceConfig, save_csv: bool) -> Vec<Table> {
    let mut out = vec![];
    out.push(table1(scale));
    out.push(table2(scale, cfg));
    out.push(figure4(scale, cfg));
    out.push(table3(scale, cfg));
    out.push(intext(scale, cfg));
    out.push(depth_sweep(&["fw", "hotspot", "mis"], scale, cfg));
    out.push(pc_sweep(&["fw", "hotspot", "mis"], scale, cfg));
    out.push(vector_study(scale, cfg));
    if save_csv {
        let names = [
            "table1", "table2", "figure4", "table3", "intext", "depth_sweep", "pc_sweep",
            "vector_study",
        ];
        for (t, n) in out.iter().zip(names) {
            let _ = t.save_csv(n);
        }
    }
    out
}

/// Parse a scale name.
pub fn parse_scale(s: &str) -> Option<Scale> {
    match s {
        "tiny" => Some(Scale::Tiny),
        "small" => Some(Scale::Small),
        "paper" => Some(Scale::Paper),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parsing() {
        assert_eq!(parse_scale("tiny"), Some(Scale::Tiny));
        assert_eq!(parse_scale("small"), Some(Scale::Small));
        assert_eq!(parse_scale("nope"), None);
    }

    #[test]
    fn table1_lists_all_ten() {
        let t = table1(Scale::Tiny);
        assert_eq!(t.rows.len(), 10);
    }
}
