//! Experiment coordinator: orchestrates workloads x variants x scales,
//! validates against native and PJRT references, renders the paper's
//! tables/figures.
//!
//! The [`engine`] module is the PR-1 parallel, cache-aware experiment
//! engine: grid fan-out across a worker pool, content-addressed
//! measurement memoization, and the BENCH_PR1.json results sink. The
//! [`store`] module (PR 2) persists that cache on disk so shards and
//! successive CI runs share work; [`engine::shard_cells`] +
//! [`engine::merge_bench_json`] split the grid across processes and
//! reassemble the byte-identical sink. The [`tune`] module (PR 3)
//! replaces exhaustive depth grids with budgeted search policies
//! (golden-section / successive halving) whose probes are ordinary
//! engine measurements — content-addressed, stored, replayable. PR 4
//! splits `Engine::measure` into two content-addressed tiers — trace
//! acquisition (the interpreter, keyed depth-invariantly) and modelling
//! (analytic/DES replay, keyed fully) — so depth ladders and tuner
//! searches pay the interpreter once per functional trace. PR 5 adds the
//! per-launch profile pool beneath the trace tier (store schema v4, one
//! canonical file per distinct `KernelProfile` shared across traces and
//! shards), the [`gc`] module's grid-replay reachability for
//! `pipefwd store gc`/`store stats`, and the bfs/color/pagerank
//! benign-race vouches that collapse the irregular graph workloads'
//! depth ladders to one interpreter run each. PR 6 puts every engine
//! capability behind the [`service`] module's typed `Service` facade
//! (requests/responses with a versioned `pipefwd-api-v1` wire schema)
//! and adds the [`net`] module's `pipefwd serve` daemon — a bounded-
//! queue TCP/HTTP front end whose concurrent clients dedup through the
//! same claim/fulfil memo table a single process uses. PR 7 adds the
//! device zoo: a [`crate::sim::device::DeviceRegistry`] of calibrated
//! memory-controller profiles, a `--device` axis on every measuring
//! command (per-device measurement keys, device-free trace keys), the E8
//! cross-device portability grid, and [`cross_device_table`] to stitch a
//! `--device all` run's per-engine slices into one comparison table.
//! PR 8 moves the scheduling unit from a launch to a launch *graph*:
//! [`crate::analysis::deps`] derives a dependence DAG from the recorded
//! trace, [`crate::transform::task_sequence`] rewrites the launch chain
//! into co-schedulable wavefronts, and the engine's `--overlap` axis
//! (store schema v6: a trailing `overlap=on` key line, off-keys
//! unchanged) replays them through the graph DES — the E9 study
//! ([`engine::Engine::overlap_study`]) measures both schedules through
//! one engine. The daemon gained HTTP/1.1 keep-alive (the
//! `connections_reused` counter) and `run --device all` fans one worker
//! per registry profile. PR 9 is the robustness layer: a seeded
//! fault-injection harness ([`crate::util::fault`], armed by
//! `--fault-plan`) fires deterministic failures through the IO/network
//! seams — store reads/writes, the daemon's accept/read/write paths, an
//! engine worker panicking under claim — and the recovery machinery
//! makes every one of them invisible in the sink: the [`net`] client
//! retries transients under a capped-backoff `RetryPolicy` (honoring
//! `Retry-After`), the [`store`] rolls a crash-time `journal/` intent
//! log forward or discards it at open and degrades to read-only when
//! its directory is unwritable, and the daemon serves `GET /healthz` /
//! `GET /readyz` probes, drains gracefully on `POST /shutdown`, and
//! guards non-loopback peers with a constant-time shared-secret token
//! (counters schema v3: `retries`, `journal_replays`, `store_degraded`).
//! PR 10 is the resource-governance layer: the store takes a byte
//! budget (`--max-bytes`) enforced by access-stamped, coldest-first,
//! journal-intent eviction batches (pinned claims and pool liveness
//! respected; over-tight budgets degrade to write-through-skip), the
//! daemon sheds work it cannot serve usefully (`deadline_ms`
//! shed-before-work, a per-client fair-share cap, store pressure on
//! `/readyz`), and `store_push` became a verified write-back path —
//! pushed records are re-hashed and re-validated server-side, admitted
//! through the budget, and can fulfil a worker's in-flight claim. The
//! governance counters (`store_evictions`, `store_budget_skips`,
//! `deadline_sheds`) ride the v3 schema additively.

pub mod engine;
pub mod experiments;
pub mod gc;
pub mod net;
pub mod service;
pub mod store;
pub mod tune;

pub use engine::{
    bench_doc, content_key, cross_device_table, dedup_cells, grid, grid_for, merge_bench_json,
    normalize_depths, resolve_workload, shard_cells, trace_key, trace_signature, Cell, Engine,
    ExperimentId,
};
pub use gc::{reachable_keys, run_gc, Reachable};
pub use service::{Mode, Service, ServiceRequest, ServiceResponse, API_SCHEMA};
pub use store::{ExportRecord, GcReport, ImportReport, Store, StoreStats, Tier};
pub use experiments::{
    best_ff, depth_sweep, figure4, headline, hotspot_m2c2_bw, intext, measure, micro_family,
    pc_sweep, table1, table2, table2_rows, table3, vector_study, Measurement,
};
pub use tune::{run_tune, Policy, TuneConfig, TuneReport, TuneRequest, TuneSpec};

use crate::report::Table;
use crate::sim::device::DeviceConfig;
use crate::workloads::Scale;

/// Run the complete evaluation (every table & figure) and return the
/// rendered tables in paper order. This is what the e2e example and the
/// `pipefwd all` CLI command drive. One host-parallel engine serves every
/// table, so shared configurations (the feed-forward baselines above all)
/// simulate once.
pub fn full_evaluation(scale: Scale, cfg: &DeviceConfig, save_csv: bool) -> Vec<Table> {
    let e = Engine::host_parallel(cfg.clone());
    let mut out = vec![];
    out.push(table1(scale));
    out.extend(e.run_experiment(ExperimentId::E1, scale));
    out.extend(e.run_experiment(ExperimentId::E2, scale));
    out.extend(e.run_experiment(ExperimentId::E3, scale));
    out.extend(e.run_experiment(ExperimentId::E4, scale));
    if save_csv {
        let names = [
            "table1", "table2", "figure4", "table3", "intext", "depth_sweep", "pc_sweep",
            "vector_study",
        ];
        for (t, n) in out.iter().zip(names) {
            let _ = t.save_csv(n);
        }
    }
    out
}

/// Parse a scale name.
pub fn parse_scale(s: &str) -> Option<Scale> {
    match s {
        "tiny" => Some(Scale::Tiny),
        "small" => Some(Scale::Small),
        "paper" => Some(Scale::Paper),
        _ => None,
    }
}

/// Inverse of [`parse_scale`] (used for cache keys and the results sink).
pub fn scale_label(s: Scale) -> &'static str {
    match s {
        Scale::Tiny => "tiny",
        Scale::Small => "small",
        Scale::Paper => "paper",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parsing() {
        assert_eq!(parse_scale("tiny"), Some(Scale::Tiny));
        assert_eq!(parse_scale("small"), Some(Scale::Small));
        assert_eq!(parse_scale("nope"), None);
        for s in [Scale::Tiny, Scale::Small, Scale::Paper] {
            assert_eq!(parse_scale(scale_label(s)), Some(s));
        }
    }

    #[test]
    fn table1_lists_all_ten() {
        let t = table1(Scale::Tiny);
        assert_eq!(t.rows.len(), 10);
    }
}
