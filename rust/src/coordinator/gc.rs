//! Reachability for `pipefwd store gc` (the PR-5 satellite of the
//! profile-pool tentpole).
//!
//! The store grows monotonically: every probe of every sweep, search, and
//! CI run persists forever, and after a transform or grid change the old
//! keys are dead weight that no replay will ever look up again. GC asks
//! the only question that matters for a content-addressed cache: *could
//! the current code still request this key?* The answer is computed the
//! same way `merge` validates shard coverage — by replaying the grid
//! construction (IR transforms only, zero simulation):
//!
//! * **Experiment grids** — every cell of `grid_for(E1..E9)` contributes
//!   its measurement keys (analytic *and* DES, sequential *and* overlap —
//!   each is one `--des` / `--overlap` away) and its depth-invariant
//!   trace key, at every dataset scale.
//! * **Tuner ladders** — `pipefwd tune` probes the
//!   [`DEPTH_LADDER`] × [`PART_LADDER`] product space for any registered
//!   workload (suite + microbenchmarks) at the target scale and the
//!   cheap fidelity rungs, so the full product space at every scale is
//!   reachable.
//!
//! Grid shape and app construction are scale-independent (only the
//! dataset is scaled), so each unique (workload, variant) builds once and
//! fans its keys out across scales. Keys outside this set — e.g. a
//! custom `sweep --depths 7` probe — are deleted by `store gc`; rerunning
//! that sweep simply re-simulates and re-persists them.
//!
//! **The device axis (PR 7):** measurement keys are per device, so
//! reachability fans each app across every [`DeviceRegistry`] profile
//! *plus* the caller's config (normally one of the four — the union is a
//! no-op then, but a daemon serving a custom-calibrated config must not
//! have its own records collected). Trace keys are device-free and
//! computed once per (workload, scale) regardless of how many devices are
//! in play — the same sharing that makes a `--device all` sweep pay the
//! interpreter once.

use super::engine::{
    content_key, content_key_with, grid_for, resolve_workload, trace_key, ExperimentId,
};
use super::tune::{TuneConfig, DEPTH_LADDER, PART_LADDER};
use crate::sim::device::{DeviceConfig, DeviceRegistry};
use crate::workloads::micro::MicroSpec;
use crate::workloads::{suite, App, Scale, Workload};
use std::collections::HashSet;

/// Every dataset scale a run can request (`--scale tiny|small|paper`).
pub const ALL_SCALES: [Scale; 3] = [Scale::Tiny, Scale::Small, Scale::Paper];

/// The key sets `store gc` keeps (pooled-profile reachability is derived
/// from the surviving traces by [`super::store::Store::gc`] itself).
#[derive(Debug, Default)]
pub struct Reachable {
    pub entries: HashSet<u64>,
    pub traces: HashSet<u64>,
}

impl Reachable {
    /// Add every key one built app can be asked under at one scale:
    /// measurement keys for both estimators **and both scheduling modes**
    /// (sequential and `--overlap` — the overlap-on keys carry the
    /// trailing `overlap=on` signature line) on every device in `cfgs`,
    /// plus the single device- and overlap-free trace key.
    fn add(&mut self, workload: &str, benign: bool, app: &App, scale: Scale, cfgs: &[DeviceConfig]) {
        for cfg in cfgs {
            for des in [false, true] {
                self.entries.insert(content_key(workload, app, scale, cfg, des));
                self.entries.insert(content_key_with(workload, app, scale, cfg, des, true));
            }
        }
        self.traces.insert(trace_key(workload, benign, app, scale));
    }
}

/// Every workload name the CLI can route into the engine: the Table-1
/// suite plus both generated microbenchmark families (the same registry
/// `resolve_workload` consults).
fn registry_names() -> Vec<String> {
    suite()
        .iter()
        .map(|w| w.name().to_string())
        .chain(MicroSpec::table3().into_iter().map(|s| s.label()))
        .chain(MicroSpec::family().into_iter().map(|s| s.label()))
        .collect()
}

/// Compute the reachable key sets for the current experiment grids and
/// tuner configuration space. Pure IR work — builds every unique app
/// exactly once and never touches a dataset or simulator. Entry keys fan
/// across the whole device registry ∪ `cfg` (a `--device` flag away);
/// trace keys are device-free and added once.
pub fn reachable_keys(cfg: &DeviceConfig) -> Reachable {
    let mut r = Reachable::default();
    let mut cfgs = DeviceRegistry::all();
    if !cfgs.iter().any(|c| c.name == cfg.name) {
        cfgs.push(cfg.clone());
    }

    // 1. The experiment grids, exactly like `merge` replays them. The
    //    grid's cell list is identical at every scale (only the cell's
    //    scale field differs), so build per Tiny cell and fan out.
    for cell in grid_for(&ExperimentId::all(), Scale::Tiny) {
        let Some(w) = resolve_workload(&cell.workload) else { continue };
        let Ok(app) = w.build(cell.variant) else { continue };
        for scale in ALL_SCALES {
            r.add(&cell.workload, w.benign_cross_kernel_races(), &app, scale, &cfgs);
        }
    }

    // 2. The tuner's probe space: depth × replication ladders for every
    //    registered workload (`tune --benches` accepts any of them), at
    //    every scale (successive halving probes cheap scales as
    //    low-fidelity rungs). Infeasible points (e.g. replication on NW)
    //    never produce a key, exactly as the tuner skips them.
    for name in registry_names() {
        let Some(w) = resolve_workload(&name) else { continue };
        for parts in PART_LADDER {
            for depth in DEPTH_LADDER {
                let config = TuneConfig { depth, parts };
                let Ok(app) = w.build(config.variant()) else { continue };
                for scale in ALL_SCALES {
                    r.add(&name, w.benign_cross_kernel_races(), &app, scale, &cfgs);
                }
            }
        }
    }
    r
}

/// Reachability + collection in one call: what the CLI's `store gc` arm
/// and the daemon's `store_gc` request both run (the `Service` facade
/// keeps them one code path).
pub fn run_gc(
    store: &super::store::Store,
    cfg: &DeviceConfig,
    dry_run: bool,
) -> std::io::Result<super::store::GcReport> {
    let r = reachable_keys(cfg);
    store.gc(&r.entries, &r.traces, dry_run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::grid;
    use crate::transform::Variant;

    /// Every key the E1–E9 grids and the tuner ladder can request must be
    /// in the reachable set — spot-checked across tiers, estimators,
    /// scales, and both probe families.
    #[test]
    fn reachable_covers_grid_and_tuner_keys() {
        let cfg = DeviceConfig::pac_a10();
        let r = reachable_keys(&cfg);
        assert!(!r.entries.is_empty() && !r.traces.is_empty());

        // an E2 grid cell, both estimators, every scale
        for cell in grid(ExperimentId::E2, Scale::Tiny) {
            let w = resolve_workload(&cell.workload).unwrap();
            let Ok(app) = w.build(cell.variant) else { continue };
            for scale in ALL_SCALES {
                for des in [false, true] {
                    let k = content_key(&cell.workload, &app, scale, &cfg, des);
                    assert!(r.entries.contains(&k), "grid cell missing: {cell:?} des={des}");
                }
                let t = trace_key(&cell.workload, w.benign_cross_kernel_races(), &app, scale);
                assert!(r.traces.contains(&t), "grid trace missing: {cell:?}");
            }
        }

        // a deep tuner-only probe (depth 512 is on no experiment grid)
        let w = resolve_workload("fw").unwrap();
        let app = w.build(Variant::FeedForward { depth: 512 }).unwrap();
        assert!(r.entries.contains(&content_key("fw", &app, Scale::Small, &cfg, false)));

        // the overlap-keyed twin of an E9 cell survives gc too
        let bfs = resolve_workload("bfs").unwrap();
        let bapp = bfs.build(Variant::FeedForward { depth: 1 }).unwrap();
        for des in [false, true] {
            let k = content_key_with("bfs", &bapp, Scale::Tiny, &cfg, des, true);
            assert!(r.entries.contains(&k), "overlap key missing (des={des})");
        }

        // an off-ladder key is NOT reachable (custom sweep probes die)
        let odd = w.build(Variant::FeedForward { depth: 7 }).unwrap();
        assert!(!r.entries.contains(&content_key("fw", &odd, Scale::Tiny, &cfg, false)));

        // stability: the replay is deterministic
        let again = reachable_keys(&cfg);
        assert_eq!(r.entries, again.entries);
        assert_eq!(r.traces, again.traces);
    }

    /// A store serving a `--device all` sweep must survive gc run under
    /// any single device: entry keys fan across the whole registry, and
    /// the set is identical whichever registered device the caller holds
    /// (so shard gc is order-independent).
    #[test]
    fn reachable_fans_entries_across_the_device_registry() {
        let r = reachable_keys(&DeviceConfig::pac_a10());
        let w = resolve_workload("fw").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        for cfg in DeviceRegistry::all() {
            let k = content_key("fw", &app, Scale::Tiny, &cfg, false);
            assert!(r.entries.contains(&k), "device {} missing from reachability", cfg.name);
        }
        let from_hbm = reachable_keys(&DeviceConfig::stratix10_hbm());
        assert_eq!(r.entries, from_hbm.entries, "reachability must not depend on caller device");
        assert_eq!(r.traces, from_hbm.traces);
    }
}
