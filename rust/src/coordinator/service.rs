//! The `Service` facade: every engine capability behind one typed
//! request/response pair (PR-6 tentpole).
//!
//! The CLI arms of `main.rs` and the daemon of [`super::net`] are both
//! thin clients of [`Service::handle`] — one code path decides what a
//! `run`, `sweep`, `tune`, `merge`, or store operation means, so the
//! daemon cannot drift from the CLI semantics it mirrors. The facade
//! owns the [`Engine`] (and through it the optional persistent
//! [`Store`]) plus the daemon-only counters (`clients_served`,
//! `queue_depth_max`); dedup across concurrent clients is not a new
//! mechanism but the engine's existing claim/fulfil memo table observed
//! from many connection threads at once.
//!
//! The wire schema is versioned as [`API_SCHEMA`] (`pipefwd-api-v1`):
//! requests are single JSON documents, responses are newline-delimited
//! compact JSON ending in a `done` terminator line (so a client can
//! distinguish a complete stream from a mid-stream disconnect). Every
//! request field is validated by the same `*_from` parsers the CLI's
//! declarative arg table uses — one consistent error shape everywhere.
//! `Engine`'s public constructors (`new`/`serial`/`host_parallel`,
//! `with_store`/`with_des`/`with_tuner`) are untouched: benches and
//! tests that build engines directly keep working, and a `Service` is
//! just an engine plus a mode wrapped after construction.

use super::engine::{
    bench_doc, grid_for, merge_bench_json, normalize_depths, resolve_workload, shard_cells, Cell,
    Engine, ExperimentId,
};
use super::experiments::{canonical_sort, Measurement};
use super::store::{key_hex, ExportRecord, GcReport, Store, StoreStats, Tier};
use super::tune::{run_tune, Policy, TuneReport, TuneRequest};
use super::{parse_scale, scale_label};
use crate::transform::Variant;
use crate::util::json::Json;
use crate::workloads::{MeasureError, Scale};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Wire-protocol version: requests carry it, daemons reject mismatches.
pub const API_SCHEMA: &str = "pipefwd-api-v1";
/// `--counters` document schema (v3 adds the reliability counters
/// `retries` / `journal_replays` / `store_degraded` from the
/// fault-injection PR; v2 added the daemon counters `queue_depth_max` /
/// `clients_served` / `requests_deduped`, with `connections_reused`
/// joining later *without* a bump — fields are additive and diffs
/// render missing ones as absent, so old artifacts stay comparable).
pub const COUNTERS_SCHEMA: &str = "pipefwd-counters-v3";
/// The daemon-era counters schema — still accepted by `report --diff`
/// and the CI bench gates (old artifacts remain comparable).
pub const COUNTERS_SCHEMA_V2: &str = "pipefwd-counters-v2";
/// The pre-daemon counters schema — still accepted by `report --diff`
/// and the CI bench gates (old artifacts remain comparable).
pub const COUNTERS_SCHEMA_V1: &str = "pipefwd-counters-v1";

/// Counter fields a counters document may carry, in canonical order.
/// v1 documents stop at `trace_runs` + `wall_ms`, v2 at
/// `connections_reused`; missing fields render as absent in diffs
/// rather than failing them. The resource-governance counters
/// (`store_evictions` / `store_budget_skips` / `deadline_sheds`)
/// joined v3 without a bump, by the same additive-field precedent as
/// `connections_reused`.
pub const COUNTER_FIELDS: &[&str] = &[
    "cache_hits",
    "store_hits",
    "simulations",
    "trace_hits",
    "trace_runs",
    "queue_depth_max",
    "clients_served",
    "requests_deduped",
    "connections_reused",
    "retries",
    "journal_replays",
    "store_degraded",
    "store_evictions",
    "store_budget_skips",
    "deadline_sheds",
    "wall_ms",
];

/// Who is driving the facade. Daemon-only counters read zero in CLI
/// mode: a plain `pipefwd run` re-measuring shared baselines produces
/// cache hits, but those are not *deduplicated client requests*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Cli,
    Daemon,
}

/// Everything a client can ask of the facade — the typed form both the
/// CLI arg table and [`decode_request`] produce.
#[derive(Debug, Clone, PartialEq)]
pub enum ServiceRequest {
    /// One (workload, variant, scale) measurement. `device` (here and on
    /// the other measuring requests) is the client's expectation of which
    /// profile the serving engine models: `None` defers to the daemon's
    /// engine (and is omitted on the wire, so pre-zoo daemons accept the
    /// request), `Some` is checked against it — a silent cross-device
    /// answer would be worse than an error.
    Measure { workload: String, variant: Variant, scale: Scale, device: Option<String> },
    /// One or more experiment grids, optionally one disjoint shard.
    Run {
        experiments: Vec<ExperimentId>,
        scale: Scale,
        shard: Option<(usize, usize)>,
        device: Option<String>,
    },
    /// Feed-forward depth sweep over arbitrary benches × depths.
    Sweep { benches: Vec<String>, depths: Vec<usize>, scale: Scale, device: Option<String> },
    /// Budgeted depth × replication search per workload.
    Tune {
        benches: Vec<String>,
        policy: Policy,
        budget: usize,
        replication: bool,
        scale: Scale,
        reference: bool,
        device: Option<String>,
    },
    /// Union shard stores into the local store and emit the canonical
    /// merged results sink.
    Merge { dirs: Vec<String>, experiments: Vec<ExperimentId>, scale: Scale },
    StoreStats,
    StoreGc { dry_run: bool },
    /// Export every valid store record (store exchange, pull side).
    StorePull,
    /// Import records exported by another store (push side).
    StorePush { records: Vec<ExportRecord> },
    /// Daemon liveness + counters + store footprint.
    Stats,
}

/// What [`Service::handle`] returns. No derives: [`TuneReport`] is
/// carried by value and deliberately implements neither `Clone` nor
/// `PartialEq`.
pub enum ServiceResponse {
    /// Measured cells in request order. `grid_cells` is the full unique
    /// grid size (so a shard response still reports the whole).
    Cells { grid_cells: usize, cells: Vec<(Cell, Result<Measurement, MeasureError>)> },
    Tune { report: TuneReport },
    Merged { imported: usize, bench: String },
    StoreStats { stats: StoreStats },
    Gc { report: GcReport },
    Records { records: Vec<ExportRecord> },
    /// `store_push` outcome: records written, records rejected by
    /// validation (each skipped without poisoning the batch), and
    /// outstanding in-memory claims the pushed entries fulfilled.
    Imported { count: usize, rejected: usize, fulfilled: usize },
    Stats { doc: Json },
}

/// The facade. Owns the engine; shared immutably across the daemon's
/// connection workers (everything inside is `&self` + atomics, exactly
/// like [`Engine::run_cells`]'s scoped worker threads).
pub struct Service {
    engine: Engine,
    mode: Mode,
    started: Instant,
    clients_served: AtomicU64,
    queue_depth_max: AtomicU64,
    connections_reused: AtomicU64,
    net_retries: AtomicU64,
    deadline_sheds: AtomicU64,
    fair_sheds: AtomicU64,
}

impl Service {
    pub fn new(engine: Engine, mode: Mode) -> Service {
        Service {
            engine,
            mode,
            started: Instant::now(),
            clients_served: AtomicU64::new(0),
            queue_depth_max: AtomicU64::new(0),
            connections_reused: AtomicU64::new(0),
            net_retries: AtomicU64::new(0),
            deadline_sheds: AtomicU64::new(0),
            fair_sheds: AtomicU64::new(0),
        }
    }

    pub fn cli(engine: Engine) -> Service {
        Service::new(engine, Mode::Cli)
    }

    pub fn daemon(engine: Engine) -> Service {
        Service::new(engine, Mode::Daemon)
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// Record one accepted connection (called by the daemon per client).
    pub fn note_client_served(&self) {
        self.clients_served.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an observed request-queue depth; the maximum is reported
    /// through the v2 counters document (backpressure visibility).
    pub fn note_queue_depth(&self, depth: usize) {
        self.queue_depth_max.fetch_max(depth as u64, Ordering::Relaxed);
    }

    pub fn clients_served(&self) -> u64 {
        self.clients_served.load(Ordering::Relaxed)
    }

    pub fn queue_depth_max(&self) -> u64 {
        self.queue_depth_max.load(Ordering::Relaxed)
    }

    /// Record one HTTP request served over an already-used connection
    /// (the daemon calls this for every request after a connection's
    /// first — keep-alive effectiveness visibility).
    pub fn note_connection_reused(&self) {
        self.connections_reused.fetch_add(1, Ordering::Relaxed);
    }

    pub fn connections_reused(&self) -> u64 {
        self.connections_reused.load(Ordering::Relaxed)
    }

    /// Record network retries performed against a remote daemon (the
    /// CLI `client` arm folds in [`super::net::Client::retries`] so the
    /// counters document shows how rough the network was).
    pub fn note_retries(&self, n: u64) {
        self.net_retries.fetch_add(n, Ordering::Relaxed);
    }

    pub fn retries(&self) -> u64 {
        self.net_retries.load(Ordering::Relaxed)
    }

    /// Record a request shed because its queue wait already exceeded
    /// the client's `deadline_ms` — answered 503 *before* any engine
    /// work ran (admission control).
    pub fn note_deadline_shed(&self) {
        self.deadline_sheds.fetch_add(1, Ordering::Relaxed);
    }

    pub fn deadline_sheds(&self) -> u64 {
        self.deadline_sheds.load(Ordering::Relaxed)
    }

    /// Record a request shed by the per-client fair-share concurrency
    /// cap (one tenant may not monopolize the worker pool).
    pub fn note_fair_shed(&self) {
        self.fair_sheds.fetch_add(1, Ordering::Relaxed);
    }

    pub fn fair_sheds(&self) -> u64 {
        self.fair_sheds.load(Ordering::Relaxed)
    }

    /// Store budget pressure for `GET /readyz`: (governed bytes, armed
    /// budget). `(0, None)` with no store attached.
    pub fn store_pressure(&self) -> (u64, Option<u64>) {
        match self.engine.store() {
            Some(s) => (s.governed_bytes(), s.max_bytes()),
            None => (0, None),
        }
    }

    /// Whether the attached store has dropped to read-only degraded
    /// mode (cache dir unwritable) — the `/readyz` probe's store check.
    /// No store attached means nothing can degrade.
    pub fn store_degraded(&self) -> bool {
        self.engine.store().map(|s| s.is_degraded()).unwrap_or(false)
    }

    /// Requests answered from the claim/fulfil memo instead of computed
    /// again. Only meaningful under concurrent clients, so CLI mode
    /// pins it to zero (a serial run's cache hits are table re-reads,
    /// not deduplicated requests).
    pub fn requests_deduped(&self) -> u64 {
        match self.mode {
            Mode::Daemon => self.engine.cache_hits(),
            Mode::Cli => 0,
        }
    }

    /// The `--counters PATH` document (schema [`COUNTERS_SCHEMA`]): v1's
    /// engine tiers plus the daemon counters, which read zero in CLI
    /// mode so the v1→v2 bump changes no existing gate's meaning.
    pub fn counters_doc(&self, command: &str, scale: &str, wall_ms: f64) -> Json {
        let c = self.engine.counters();
        Json::obj(vec![
            ("schema", Json::Str(COUNTERS_SCHEMA.into())),
            ("command", Json::Str(command.into())),
            ("scale", Json::Str(scale.into())),
            ("cache_hits", Json::Num(c.cache_hits as f64)),
            ("store_hits", Json::Num(c.store_hits as f64)),
            ("simulations", Json::Num(c.simulations as f64)),
            ("trace_hits", Json::Num(c.trace_hits as f64)),
            ("trace_runs", Json::Num(c.trace_runs as f64)),
            ("queue_depth_max", Json::Num(self.queue_depth_max() as f64)),
            ("clients_served", Json::Num(self.clients_served() as f64)),
            ("requests_deduped", Json::Num(self.requests_deduped() as f64)),
            ("connections_reused", Json::Num(self.connections_reused() as f64)),
            ("retries", Json::Num(self.retries() as f64)),
            ("journal_replays", Json::Num(c.journal_replays as f64)),
            ("store_degraded", Json::Num(c.store_degraded as f64)),
            ("store_evictions", Json::Num(c.store_evictions as f64)),
            ("store_budget_skips", Json::Num(c.store_budget_skips as f64)),
            ("deadline_sheds", Json::Num(self.deadline_sheds() as f64)),
            ("wall_ms", Json::Num(wall_ms)),
        ])
    }

    /// The `GET /stats` document: live counters + store footprint.
    pub fn stats_doc(&self) -> Json {
        let uptime_ms = self.started.elapsed().as_millis() as f64;
        let store =
            self.engine.store().map(|s| s.stats().to_json()).unwrap_or(Json::Null);
        Json::obj(vec![
            ("schema", Json::Str(API_SCHEMA.into())),
            ("type", Json::Str("stats".into())),
            ("counters", self.counters_doc("serve", "-", uptime_ms)),
            ("store", store),
        ])
    }

    fn store_or_err(&self, what: &str) -> Result<&Store, MeasureError> {
        self.engine.store().ok_or_else(|| {
            MeasureError::parse(&format!(
                "{what}: no persistent store attached (started with --no-cache?)"
            ))
        })
    }

    /// A measuring request naming a device must name *this* engine's
    /// device — the facade never silently answers with another profile's
    /// numbers (`None` defers to the engine, the pre-zoo behaviour).
    fn check_device(&self, device: &Option<String>) -> Result<(), MeasureError> {
        match device {
            Some(d) if d != self.engine.cfg.name => Err(MeasureError::parse(&format!(
                "device mismatch: request asks for `{d}` but this service models \
                 `{}` (restart with --device {d}, or drop the flag)",
                self.engine.cfg.name
            ))),
            _ => Ok(()),
        }
    }

    /// Execute one request. This is the single semantic authority: the
    /// CLI arms and the daemon route everything through here.
    pub fn handle(&self, req: &ServiceRequest) -> Result<ServiceResponse, MeasureError> {
        match req {
            ServiceRequest::Measure { workload, variant, scale, device } => {
                self.check_device(device)?;
                let w = resolve_workload(workload).ok_or_else(|| {
                    MeasureError::parse(&format!(
                        "unknown benchmark `{workload}` (see `pipefwd list`)"
                    ))
                })?;
                let cell = Cell::new(workload, *variant, *scale);
                let r = self.engine.measure(w.as_ref(), *variant, *scale);
                Ok(ServiceResponse::Cells { grid_cells: 1, cells: vec![pair(cell, r)] })
            }
            ServiceRequest::Run { experiments, scale, shard, device } => {
                self.check_device(device)?;
                let grid = grid_for(experiments, *scale);
                let grid_cells = grid.len();
                let cells = match shard {
                    Some((index, count)) => {
                        // a shard's only product is its store entries, so
                        // store problems are fatal here where a plain run
                        // merely warns
                        if self.engine.store().is_none() {
                            return Err(MeasureError::parse(
                                "run --shard: the persistent store is unavailable (or \
                                 --no-cache was given) — a shard's results have nowhere \
                                 to go",
                            ));
                        }
                        shard_cells(&grid, *index, *count)
                            .map_err(|e| MeasureError::parse(&e))?
                    }
                    None => grid,
                };
                let errors_before = self.engine.store_errors();
                let results = self.engine.run_cells(&cells);
                if shard.is_some() && self.engine.store_errors() > errors_before {
                    return Err(MeasureError::parse(&format!(
                        "run --shard: {} result(s) failed to persist — the merge would \
                         report this slice as missing",
                        self.engine.store_errors() - errors_before
                    )));
                }
                let cells =
                    cells.into_iter().zip(results).map(|(c, r)| pair(c, r)).collect();
                Ok(ServiceResponse::Cells { grid_cells, cells })
            }
            ServiceRequest::Sweep { benches, depths, scale, device } => {
                self.check_device(device)?;
                for b in benches {
                    bench_from(b).map_err(|e| MeasureError::parse(&e))?;
                }
                let cells: Vec<Cell> = benches
                    .iter()
                    .flat_map(|b| {
                        depths
                            .iter()
                            .map(|d| Cell::new(b, Variant::FeedForward { depth: *d }, *scale))
                            .collect::<Vec<_>>()
                    })
                    .collect();
                let results = self.engine.run_cells(&cells);
                let grid_cells = cells.len();
                let cells =
                    cells.into_iter().zip(results).map(|(c, r)| pair(c, r)).collect();
                Ok(ServiceResponse::Cells { grid_cells, cells })
            }
            ServiceRequest::Tune {
                benches,
                policy,
                budget,
                replication,
                scale,
                reference,
                device,
            } => {
                self.check_device(device)?;
                let req = TuneRequest {
                    benches: benches.clone(),
                    policy: *policy,
                    budget: *budget,
                    replication: *replication,
                    scale: *scale,
                    reference: *reference,
                };
                let report =
                    run_tune(&self.engine, &req).map_err(|e| MeasureError::parse(&e))?;
                Ok(ServiceResponse::Tune { report })
            }
            ServiceRequest::Merge { dirs, experiments, scale } => {
                if dirs.is_empty() {
                    return Err(MeasureError::parse(
                        "merge: at least one shard store directory required",
                    ));
                }
                let mut shards = vec![];
                for d in dirs {
                    shards.push(Store::open_existing(d).map_err(|e| {
                        MeasureError::parse(&format!("opening store {d}: {e}"))
                    })?);
                }
                // union the shard stores into the local store too, so the
                // merge host is warm for future runs (best-effort: the
                // canonical sink below replays against the shards)
                let mut imported = 0;
                if let Some(local) = self.engine.store() {
                    for s in &shards {
                        imported += local.merge_from(s).map_err(|e| {
                            MeasureError::parse(&format!("merging into local store: {e}"))
                        })?;
                    }
                    if let Err(e) = local.write_manifest() {
                        eprintln!("warning: writing store manifest: {e}");
                    }
                }
                let bench = merge_bench_json(
                    &shards,
                    experiments,
                    *scale,
                    &self.engine.cfg,
                    self.engine.use_des,
                )
                .map_err(|e| MeasureError::parse(&e))?;
                Ok(ServiceResponse::Merged { imported, bench })
            }
            ServiceRequest::StoreStats => {
                let s = self.store_or_err("store stats")?;
                Ok(ServiceResponse::StoreStats { stats: s.stats() })
            }
            ServiceRequest::StoreGc { dry_run } => {
                let s = self.store_or_err("store gc")?;
                let report = super::gc::run_gc(s, &self.engine.cfg, *dry_run)
                    .map_err(|e| MeasureError::parse(&format!("store gc: {e}")))?;
                Ok(ServiceResponse::Gc { report })
            }
            ServiceRequest::StorePull => {
                let s = self.store_or_err("store pull")?;
                Ok(ServiceResponse::Records { records: s.export_records() })
            }
            ServiceRequest::StorePush { records } => {
                let s = self.store_or_err("store push")?;
                // import_records re-verifies everything the wire could
                // corrupt — pool files re-hashed against their names,
                // traces resolved against the unioned pool, entries
                // decoded under the current schema — rejecting bad
                // records without poisoning the batch, then admits the
                // writes through the byte budget
                let report = s.import_records(records).map_err(|e| {
                    MeasureError::parse(&format!("store push: {e}"))
                })?;
                if let Err(e) = s.write_manifest() {
                    eprintln!("warning: writing store manifest: {e}");
                }
                // a pushed entry may be exactly the cell a worker is
                // mid-simulating for another client: fulfil the open
                // claim so its waiters answer from the push
                let mut fulfilled = 0;
                for r in records.iter().filter(|r| r.tier == super::store::Tier::Entries) {
                    if let Some(result) = super::store::decode_entry(&r.doc, r.key) {
                        if self.engine.fulfil_external(r.key, &result) {
                            fulfilled += 1;
                        }
                    }
                }
                Ok(ServiceResponse::Imported {
                    count: report.imported,
                    rejected: report.rejected,
                    fulfilled,
                })
            }
            ServiceRequest::Stats => Ok(ServiceResponse::Stats { doc: self.stats_doc() }),
        }
    }
}

fn pair(
    cell: Cell,
    r: Result<Measurement, String>,
) -> (Cell, Result<Measurement, MeasureError>) {
    (cell, r.map_err(|e| MeasureError::parse(&e)))
}

// ---------------------------------------------------------------------------
// Shared validators: the CLI's declarative arg table and the wire
// decoder both call these, so `pipefwd sweep --depths 0` and a daemon
// request with a zero depth produce the same message.
// ---------------------------------------------------------------------------

pub fn scale_from(s: &str) -> Result<Scale, String> {
    parse_scale(s).ok_or_else(|| format!("unknown scale `{s}` (tiny|small|paper)"))
}

pub fn policy_from(s: &str) -> Result<Policy, String> {
    Policy::parse(s).ok_or_else(|| format!("unknown policy `{s}` (golden|sh)"))
}

pub fn experiment_from(s: &str) -> Result<ExperimentId, String> {
    ExperimentId::parse(s.trim())
        .ok_or_else(|| format!("unknown experiment `{s}` (E1..E9 or all)"))
}

/// A device-zoo profile name. `all` is deliberately rejected here: fanning
/// a request across the registry is a CLI-side loop (`run --device all`),
/// never a single engine's request.
pub fn device_from(s: &str) -> Result<String, String> {
    if crate::sim::device::by_name(s).is_some() {
        Ok(s.to_string())
    } else {
        Err(format!(
            "unknown device `{s}` (one of: {})",
            crate::sim::device::DEVICE_NAMES.join(", ")
        ))
    }
}

/// `all` or a comma-separated experiment-id list.
pub fn experiments_from(s: &str) -> Result<Vec<ExperimentId>, String> {
    if s.eq_ignore_ascii_case("all") {
        return Ok(ExperimentId::all().to_vec());
    }
    s.split(',').map(experiment_from).collect()
}

pub fn bench_from(s: &str) -> Result<String, String> {
    if resolve_workload(s).is_some() {
        Ok(s.to_string())
    } else {
        Err(format!("unknown benchmark `{s}` (see `pipefwd list`)"))
    }
}

pub fn benches_from(s: &str) -> Result<Vec<String>, String> {
    s.split(',').map(|b| bench_from(b.trim())).collect()
}

pub fn depth_from(s: &str) -> Result<usize, String> {
    s.trim()
        .parse::<usize>()
        .ok()
        .filter(|n| *n > 0)
        .ok_or_else(|| format!("bad depth `{s}` (positive integer)"))
}

/// Comma-separated depth list, sorted + deduplicated (duplicate columns
/// would break the deterministic-output guarantees).
pub fn depths_from(s: &str) -> Result<Vec<usize>, String> {
    Ok(normalize_depths(s.split(',').map(depth_from).collect::<Result<Vec<_>, _>>()?))
}

/// `I/N`, 1-based.
pub fn shard_from(s: &str) -> Result<(usize, usize), String> {
    let bad = || format!("bad shard `{s}` (expected I/N with 1 <= I <= N)");
    let (i, n) = s.split_once('/').ok_or_else(bad)?;
    let i = i.trim().parse::<usize>().map_err(|_| bad())?;
    let n = n.trim().parse::<usize>().map_err(|_| bad())?;
    if n > 0 && (1..=n).contains(&i) {
        Ok((i, n))
    } else {
        Err(bad())
    }
}

pub fn posint_from(s: &str) -> Result<usize, String> {
    s.parse::<usize>()
        .ok()
        .filter(|n| *n > 0)
        .ok_or_else(|| format!("expected a positive integer, got `{s}`"))
}

pub fn threshold_from(s: &str) -> Result<f64, String> {
    s.parse::<f64>()
        .ok()
        .filter(|t| t.is_finite() && *t >= 0.0)
        .ok_or_else(|| format!("expected a percent >= 0, got `{s}`"))
}

pub fn addr_from(s: &str) -> Result<String, String> {
    if s.contains(':') {
        Ok(s.to_string())
    } else {
        Err(format!("bad address `{s}` (expected HOST:PORT)"))
    }
}

/// Inverse of [`Variant::label`]. `m1c1(dN)` parses as `M1Cx` — the
/// `MxCx {{ parts: 1 }}` spelling never occurs (a 1-part replication is
/// spelled `ff`), so the labels stay a bijection over reachable space.
pub fn variant_from(s: &str) -> Result<Variant, String> {
    let err = || {
        format!("unknown variant `{s}` (baseline | ff(dN) | m2c2(dN) | m1c2(dN) | ff_v4(dN))")
    };
    if s == "baseline" {
        return Ok(Variant::Baseline);
    }
    let body = s.strip_suffix(')').ok_or_else(err)?;
    let (head, depth) = body.split_once("(d").ok_or_else(err)?;
    let depth: usize = depth.parse().ok().filter(|d| *d > 0).ok_or_else(err)?;
    if head == "ff" {
        return Ok(Variant::FeedForward { depth });
    }
    if let Some(w) = head.strip_prefix("ff_v") {
        let width = w.parse().ok().filter(|x| *x > 0).ok_or_else(err)?;
        return Ok(Variant::Vectorized { width, depth });
    }
    if let Some(c) = head.strip_prefix("m1c") {
        let consumers = c.parse().ok().filter(|x| *x > 0).ok_or_else(err)?;
        return Ok(Variant::M1Cx { consumers, depth });
    }
    if let Some(rest) = head.strip_prefix('m') {
        if let Some((p, check)) = rest.split_once('c') {
            let parts: usize = p.parse().ok().filter(|x| *x > 1).ok_or_else(err)?;
            if check.parse::<usize>().ok() == Some(parts) {
                return Ok(Variant::MxCx { parts, depth });
            }
        }
    }
    Err(err())
}

// ---------------------------------------------------------------------------
// Wire codec (`pipefwd-api-v1`)
// ---------------------------------------------------------------------------

fn tagged(ty: &str, mut rest: Vec<(&str, Json)>) -> Json {
    let mut fields =
        vec![("schema", Json::Str(API_SCHEMA.into())), ("type", Json::Str(ty.into()))];
    fields.append(&mut rest);
    Json::obj(fields)
}

fn scale_json(s: Scale) -> Json {
    Json::Str(scale_label(s).into())
}

fn exps_json(exps: &[ExperimentId]) -> Json {
    Json::Arr(exps.iter().map(|e| Json::Str(e.label().into())).collect())
}

fn strs_json(ss: &[String]) -> Json {
    Json::Arr(ss.iter().map(|s| Json::Str(s.clone())).collect())
}

pub fn record_to_json(r: &ExportRecord) -> Json {
    Json::obj(vec![
        ("tier", Json::Str(r.tier.label().into())),
        ("key", Json::Str(key_hex(r.key))),
        ("doc", r.doc.clone()),
    ])
}

pub fn decode_record(v: &Json) -> Result<ExportRecord, String> {
    let tier = v
        .get("tier")
        .and_then(|t| t.as_str())
        .and_then(Tier::parse)
        .ok_or_else(|| "record: bad `tier` (entries|traces|profiles)".to_string())?;
    let key = v
        .get("key")
        .and_then(|k| k.as_str())
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .ok_or_else(|| "record: bad `key` (hex digits)".to_string())?;
    let doc = v.get("doc").cloned().ok_or_else(|| "record: missing `doc`".to_string())?;
    Ok(ExportRecord { tier, key, doc })
}

/// One request document. The client side of the wire.
pub fn encode_request(req: &ServiceRequest) -> Json {
    // `device: None` is omitted from the document, not encoded as null:
    // an old (pre-device-zoo) daemon then accepts the request unchanged.
    let push_device = |rest: &mut Vec<(&str, Json)>, device: &Option<String>| {
        if let Some(d) = device {
            rest.push(("device", Json::Str(d.clone())));
        }
    };
    match req {
        ServiceRequest::Measure { workload, variant, scale, device } => {
            let mut rest = vec![
                ("workload", Json::Str(workload.clone())),
                ("variant", Json::Str(variant.label())),
                ("scale", scale_json(*scale)),
            ];
            push_device(&mut rest, device);
            tagged("measure", rest)
        }
        ServiceRequest::Run { experiments, scale, shard, device } => {
            let mut rest = vec![
                ("experiments", exps_json(experiments)),
                ("scale", scale_json(*scale)),
            ];
            if let Some((i, n)) = shard {
                rest.push(("shard", Json::Str(format!("{i}/{n}"))));
            }
            push_device(&mut rest, device);
            tagged("run", rest)
        }
        ServiceRequest::Sweep { benches, depths, scale, device } => {
            let mut rest = vec![
                ("benches", strs_json(benches)),
                ("depths", Json::Arr(depths.iter().map(|d| Json::Num(*d as f64)).collect())),
                ("scale", scale_json(*scale)),
            ];
            push_device(&mut rest, device);
            tagged("sweep", rest)
        }
        ServiceRequest::Tune {
            benches,
            policy,
            budget,
            replication,
            scale,
            reference,
            device,
        } => {
            let mut rest = vec![
                ("benches", strs_json(benches)),
                ("policy", Json::Str(policy.label().into())),
                ("budget", Json::Num(*budget as f64)),
                ("replication", Json::Bool(*replication)),
                ("scale", scale_json(*scale)),
                ("reference", Json::Bool(*reference)),
            ];
            push_device(&mut rest, device);
            tagged("tune", rest)
        }
        ServiceRequest::Merge { dirs, experiments, scale } => tagged(
            "merge",
            vec![
                ("dirs", strs_json(dirs)),
                ("experiments", exps_json(experiments)),
                ("scale", scale_json(*scale)),
            ],
        ),
        ServiceRequest::StoreStats => tagged("store_stats", vec![]),
        ServiceRequest::StoreGc { dry_run } => {
            tagged("store_gc", vec![("dry_run", Json::Bool(*dry_run))])
        }
        ServiceRequest::StorePull => tagged("store_pull", vec![]),
        ServiceRequest::StorePush { records } => tagged(
            "store_push",
            vec![("records", Json::Arr(records.iter().map(record_to_json).collect()))],
        ),
        ServiceRequest::Stats => tagged("stats", vec![]),
    }
}

/// Parse + validate one request document. The daemon side of the wire;
/// every field goes through the same `*_from` validators as the CLI.
pub fn decode_request(doc: &Json) -> Result<ServiceRequest, String> {
    let schema = doc.get("schema").and_then(|s| s.as_str()).unwrap_or("(none)");
    if schema != API_SCHEMA {
        return Err(format!(
            "request: unsupported schema `{schema}` (this daemon speaks {API_SCHEMA})"
        ));
    }
    let ty = doc
        .get("type")
        .and_then(|s| s.as_str())
        .ok_or_else(|| "request: missing `type`".to_string())?;
    let str_field = |k: &str| -> Result<&str, String> {
        doc.get(k)
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("{ty} request: missing `{k}`"))
    };
    let bool_field = |k: &str| -> Result<bool, String> {
        doc.get(k)
            .and_then(|v| v.as_bool())
            .ok_or_else(|| format!("{ty} request: missing `{k}`"))
    };
    let str_list = |k: &str| -> Result<Vec<String>, String> {
        doc.get(k)
            .and_then(|v| v.as_array())
            .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect::<Vec<_>>())
            .filter(|v: &Vec<String>| {
                doc.get(k).and_then(|x| x.as_array()).map(|a| a.len()) == Some(v.len())
            })
            .ok_or_else(|| format!("{ty} request: missing `{k}` (array of strings)"))
    };
    // optional: absent on pre-device-zoo clients (and whenever the client
    // defers to the daemon's engine), validated like the CLI flag when
    // present
    let device = match doc.get("device") {
        None => None,
        Some(v) => Some(device_from(
            v.as_str().ok_or_else(|| format!("{ty} request: bad `device`"))?,
        )?),
    };
    match ty {
        "measure" => Ok(ServiceRequest::Measure {
            workload: bench_from(str_field("workload")?)?,
            variant: variant_from(str_field("variant")?)?,
            scale: scale_from(str_field("scale")?)?,
            device,
        }),
        "run" => {
            let experiments = str_list("experiments")?
                .iter()
                .map(|e| experiment_from(e))
                .collect::<Result<Vec<_>, _>>()?;
            let shard = match doc.get("shard") {
                None => None,
                Some(v) => Some(shard_from(
                    v.as_str().ok_or_else(|| "run request: bad `shard`".to_string())?,
                )?),
            };
            Ok(ServiceRequest::Run {
                experiments,
                scale: scale_from(str_field("scale")?)?,
                shard,
                device,
            })
        }
        "sweep" => {
            let benches = str_list("benches")?
                .iter()
                .map(|b| bench_from(b))
                .collect::<Result<Vec<_>, _>>()?;
            let depths = doc
                .get("depths")
                .and_then(|v| v.as_array())
                .ok_or_else(|| "sweep request: missing `depths` (array of integers)".to_string())?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .filter(|n| *n > 0)
                        .ok_or_else(|| "sweep request: bad depth (positive integer)".to_string())
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(ServiceRequest::Sweep {
                benches,
                depths: normalize_depths(depths),
                scale: scale_from(str_field("scale")?)?,
                device,
            })
        }
        "tune" => {
            let benches = str_list("benches")?
                .iter()
                .map(|b| bench_from(b))
                .collect::<Result<Vec<_>, _>>()?;
            let budget = doc
                .get("budget")
                .and_then(|v| v.as_usize())
                .filter(|n| *n > 0)
                .ok_or_else(|| "tune request: bad `budget` (positive integer)".to_string())?;
            Ok(ServiceRequest::Tune {
                benches,
                policy: policy_from(str_field("policy")?)?,
                budget,
                replication: bool_field("replication")?,
                scale: scale_from(str_field("scale")?)?,
                reference: bool_field("reference")?,
                device,
            })
        }
        "merge" => Ok(ServiceRequest::Merge {
            dirs: str_list("dirs")?,
            experiments: str_list("experiments")?
                .iter()
                .map(|e| experiment_from(e))
                .collect::<Result<Vec<_>, _>>()?,
            scale: scale_from(str_field("scale")?)?,
        }),
        "store_stats" => Ok(ServiceRequest::StoreStats),
        "store_gc" => Ok(ServiceRequest::StoreGc { dry_run: bool_field("dry_run")? }),
        "store_pull" => Ok(ServiceRequest::StorePull),
        "store_push" => {
            let records = doc
                .get("records")
                .and_then(|v| v.as_array())
                .ok_or_else(|| "store_push request: missing `records` (array)".to_string())?
                .iter()
                .map(decode_record)
                .collect::<Result<Vec<_>, _>>()?;
            Ok(ServiceRequest::StorePush { records })
        }
        "stats" => Ok(ServiceRequest::Stats),
        other => Err(format!("request: unknown type `{other}`")),
    }
}

/// Render a response as newline-delimited compact JSON: zero or more
/// item lines, then a `done` terminator carrying the item count so
/// clients detect mid-stream disconnects.
pub fn response_lines(resp: &ServiceResponse) -> Vec<String> {
    let line = |ty: &str, rest: Vec<(&str, Json)>| tagged(ty, rest).to_compact();
    let mut out = vec![];
    match resp {
        ServiceResponse::Cells { grid_cells, cells } => {
            out.push(line(
                "cells",
                vec![
                    ("grid_cells", Json::Num(*grid_cells as f64)),
                    ("count", Json::Num(cells.len() as f64)),
                ],
            ));
            for (cell, r) in cells {
                let mut rest = vec![
                    ("workload", Json::Str(cell.workload.clone())),
                    ("variant", Json::Str(cell.variant.label())),
                    ("scale", scale_json(cell.scale)),
                ];
                match r {
                    Ok(m) => {
                        rest.push(("status", Json::Str("ok".into())));
                        rest.push(("measurement", m.to_json()));
                    }
                    Err(e) => {
                        rest.push(("status", Json::Str("err".into())));
                        rest.push(("error", e.to_json()));
                    }
                }
                out.push(line("cell", rest));
            }
        }
        ServiceResponse::Tune { report } => {
            out.push(line("tune", vec![("report", report.to_json())]));
        }
        ServiceResponse::Merged { imported, bench } => out.push(line(
            "merged",
            vec![
                ("imported", Json::Num(*imported as f64)),
                ("bench", Json::Str(bench.clone())),
            ],
        )),
        ServiceResponse::StoreStats { stats } => {
            out.push(line("store_stats", vec![("stats", stats.to_json())]));
        }
        ServiceResponse::Gc { report } => {
            out.push(line("gc", vec![("report", report.to_json())]));
        }
        ServiceResponse::Records { records } => {
            for r in records {
                out.push(line(
                    "record",
                    vec![
                        ("tier", Json::Str(r.tier.label().into())),
                        ("key", Json::Str(key_hex(r.key))),
                        ("doc", r.doc.clone()),
                    ],
                ));
            }
        }
        ServiceResponse::Imported { count, rejected, fulfilled } => {
            out.push(line(
                "imported",
                vec![
                    ("count", Json::Num(*count as f64)),
                    ("rejected", Json::Num(*rejected as f64)),
                    ("fulfilled", Json::Num(*fulfilled as f64)),
                ],
            ));
        }
        ServiceResponse::Stats { doc } => out.push(doc.to_compact()),
    }
    let items = out.len();
    out.push(line("done", vec![("items", Json::Num(items as f64))]));
    out
}

/// A single-line error stream (no `done` — errors terminate).
pub fn error_line(e: &MeasureError) -> String {
    tagged("error", vec![("error", e.to_json())]).to_compact()
}

/// Errors raised before a request reaches [`Service::handle`]
/// (malformed JSON, schema mismatch, validation failures).
pub fn request_error_line(msg: &str) -> String {
    error_line(&MeasureError::parse(msg))
}

/// Whether a [`decode_response_lines`] error means the stream was cut
/// short rather than the request being wrong — the client retry
/// policy's transient/permanent split for application-level failures.
pub fn is_truncated_response(err: &str) -> bool {
    err.starts_with("truncated response") || err.starts_with("empty response")
}

/// Client-side stream check: surfaces the server's error line, verifies
/// the `done` terminator + item count, and strips the terminator.
pub fn decode_response_lines(lines: &[Json]) -> Result<Vec<Json>, String> {
    if let Some(err) = lines
        .iter()
        .find(|l| l.get("type").and_then(|t| t.as_str()) == Some("error"))
    {
        let e = err
            .get("error")
            .and_then(MeasureError::from_json)
            .unwrap_or_else(|| MeasureError::parse("malformed error line"));
        return Err(e.render());
    }
    let Some(last) = lines.last() else {
        return Err("empty response (connection closed early?)".to_string());
    };
    if last.get("type").and_then(|t| t.as_str()) != Some("done") {
        return Err(
            "truncated response (no `done` terminator — connection dropped mid-stream?)"
                .to_string(),
        );
    }
    let items = last.get("items").and_then(|v| v.as_usize());
    if items != Some(lines.len() - 1) {
        return Err(format!(
            "truncated response (`done` claims {items:?} items, received {})",
            lines.len() - 1
        ));
    }
    Ok(lines[..lines.len() - 1].to_vec())
}

/// Reassemble a client-side results sink from `cell` stream lines —
/// byte-identical to the server engine's own `bench_json` because both
/// canonically sort + dedup before [`bench_doc`].
pub fn cells_to_bench(
    items: &[Json],
    scale: Scale,
    exps: &[ExperimentId],
) -> Result<String, String> {
    let mut ms: Vec<Measurement> = vec![];
    for it in items {
        if it.get("type").and_then(|t| t.as_str()) != Some("cell") {
            continue;
        }
        if it.get("status").and_then(|s| s.as_str()) != Some("ok") {
            continue;
        }
        let m = it
            .get("measurement")
            .and_then(Measurement::from_json)
            .ok_or_else(|| "cell line: malformed `measurement`".to_string())?;
        ms.push(m);
    }
    canonical_sort(&mut ms);
    ms.dedup();
    Ok(bench_doc(scale, exps, &ms))
}

/// The counter fields present in a counters document, in canonical
/// order — `None` if the document is not a counters doc (v1, v2, or
/// v3). `report --diff` uses this to compare mixed-version artifacts.
pub fn counters_fields(doc: &Json) -> Option<Vec<(&'static str, f64)>> {
    let schema = doc.get("schema")?.as_str()?;
    if schema != COUNTERS_SCHEMA && schema != COUNTERS_SCHEMA_V2 && schema != COUNTERS_SCHEMA_V1 {
        return None;
    }
    let mut out = vec![];
    for k in COUNTER_FIELDS {
        if let Some(v) = doc.get(k).and_then(|v| v.as_f64()) {
            out.push((*k, v));
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;

    #[test]
    fn variant_labels_roundtrip() {
        for v in [
            Variant::Baseline,
            Variant::FeedForward { depth: 1 },
            Variant::FeedForward { depth: 1000 },
            Variant::MxCx { parts: 2, depth: 16 },
            Variant::MxCx { parts: 4, depth: 1 },
            Variant::M1Cx { consumers: 2, depth: 4 },
            Variant::M1Cx { consumers: 1, depth: 4 },
            Variant::Vectorized { width: 4, depth: 100 },
        ] {
            assert_eq!(variant_from(&v.label()), Ok(v), "label {}", v.label());
        }
        for bad in ["", "ff", "ff(d0)", "ff(dx)", "m2c3(d1)", "m0c0(d1)", "base"] {
            assert!(variant_from(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn request_codec_roundtrips_every_variant() {
        let reqs = vec![
            ServiceRequest::Measure {
                workload: "fw".into(),
                variant: Variant::FeedForward { depth: 100 },
                scale: Scale::Tiny,
                device: None,
            },
            ServiceRequest::Measure {
                workload: "fw".into(),
                variant: Variant::Baseline,
                scale: Scale::Tiny,
                device: Some("stratix10-hbm".into()),
            },
            ServiceRequest::Run {
                experiments: vec![ExperimentId::E2, ExperimentId::E4],
                scale: Scale::Small,
                shard: Some((2, 3)),
                device: Some("arria10".into()),
            },
            ServiceRequest::Run {
                experiments: vec![ExperimentId::E1, ExperimentId::E8],
                scale: Scale::Tiny,
                shard: None,
                device: None,
            },
            ServiceRequest::Sweep {
                benches: vec!["fw".into(), "hotspot".into()],
                depths: vec![1, 100],
                scale: Scale::Tiny,
                device: Some("cpu-like".into()),
            },
            ServiceRequest::Tune {
                benches: vec!["fw".into()],
                policy: Policy::Sh,
                budget: 12,
                replication: true,
                scale: Scale::Tiny,
                reference: false,
                device: Some("gpu-like".into()),
            },
            ServiceRequest::Merge {
                dirs: vec!["/tmp/a".into(), "/tmp/b".into()],
                experiments: vec![ExperimentId::E2],
                scale: Scale::Tiny,
            },
            ServiceRequest::StoreStats,
            ServiceRequest::StoreGc { dry_run: true },
            ServiceRequest::StorePull,
            ServiceRequest::StorePush {
                records: vec![ExportRecord {
                    tier: Tier::Entries,
                    key: 0xdead_beef,
                    doc: Json::obj(vec![("x", Json::Num(1.0))]),
                }],
            },
            ServiceRequest::Stats,
        ];
        for req in reqs {
            // through the textual wire form, exactly as the daemon sees it
            let text = encode_request(&req).to_compact();
            let doc = crate::util::json::parse(&text).unwrap();
            assert_eq!(decode_request(&doc), Ok(req.clone()), "{text}");
        }
    }

    #[test]
    fn decode_request_rejects_bad_schema_and_fields() {
        let doc = crate::util::json::parse(
            r#"{"schema": "pipefwd-api-v0", "type": "stats"}"#,
        )
        .unwrap();
        let e = decode_request(&doc).unwrap_err();
        assert!(e.contains("unsupported schema `pipefwd-api-v0`"), "{e}");

        let doc = crate::util::json::parse(
            r#"{"schema": "pipefwd-api-v1", "type": "sweep", "benches": ["nope"],
                "depths": [1], "scale": "tiny"}"#,
        )
        .unwrap();
        let e = decode_request(&doc).unwrap_err();
        assert!(e.contains("unknown benchmark `nope`"), "{e}");

        let doc = crate::util::json::parse(
            r#"{"schema": "pipefwd-api-v1", "type": "run", "experiments": ["E10"],
                "scale": "tiny"}"#,
        )
        .unwrap();
        assert!(decode_request(&doc).is_err());

        // the device field is validated against the registry, and `all`
        // is a CLI fan-out, not a wire value
        for bad in ["nope", "all"] {
            let doc = crate::util::json::parse(&format!(
                r#"{{"schema": "pipefwd-api-v1", "type": "run", "experiments": ["E1"],
                    "scale": "tiny", "device": "{bad}"}}"#,
            ))
            .unwrap();
            let e = decode_request(&doc).unwrap_err();
            assert!(e.contains(&format!("unknown device `{bad}`")), "{e}");
        }
    }

    /// A request naming a device other than the serving engine's is an
    /// error, never a silent wrong-device answer; naming the engine's own
    /// device (or none) passes through.
    #[test]
    fn handle_rejects_mismatched_device_requests() {
        let svc = Service::cli(Engine::new(DeviceConfig::pac_a10(), 1));
        let mk = |device: Option<String>| ServiceRequest::Measure {
            workload: "fw".into(),
            variant: Variant::Baseline,
            scale: Scale::Tiny,
            device,
        };
        assert!(svc.handle(&mk(None)).is_ok());
        assert!(svc.handle(&mk(Some("arria10".into()))).is_ok());
        let err = svc.handle(&mk(Some("gpu-like".into()))).unwrap_err();
        assert!(err.render().contains("device mismatch"), "{}", err.render());
    }

    #[test]
    fn counters_doc_is_v3_with_zero_daemon_counters_in_cli_mode() {
        let svc = Service::cli(Engine::new(DeviceConfig::pac_a10(), 1));
        let doc = svc.counters_doc("run", "tiny", 12.0);
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(COUNTERS_SCHEMA));
        for k in [
            "queue_depth_max",
            "clients_served",
            "requests_deduped",
            "connections_reused",
            "retries",
            "journal_replays",
            "store_degraded",
            "store_evictions",
            "store_budget_skips",
            "deadline_sheds",
        ] {
            assert_eq!(doc.get(k).unwrap().as_f64(), Some(0.0), "{k}");
        }
        let fields = counters_fields(&doc).unwrap();
        assert_eq!(fields.len(), COUNTER_FIELDS.len());

        // a v2 document (no reliability fields) still yields its own
        // fields — mixed-version diffs keep working
        let v2 = Json::obj(vec![
            ("schema", Json::Str(COUNTERS_SCHEMA_V2.into())),
            ("cache_hits", Json::Num(3.0)),
            ("connections_reused", Json::Num(4.0)),
            ("wall_ms", Json::Num(10.0)),
        ]);
        let fields = counters_fields(&v2).unwrap();
        assert_eq!(fields.len(), 3);
        assert_eq!(fields[1], ("connections_reused", 4.0));

        // a v1 document yields only its own fields, in the same order
        let v1 = Json::obj(vec![
            ("schema", Json::Str(COUNTERS_SCHEMA_V1.into())),
            ("command", Json::Str("run".into())),
            ("scale", Json::Str("tiny".into())),
            ("cache_hits", Json::Num(3.0)),
            ("store_hits", Json::Num(0.0)),
            ("simulations", Json::Num(5.0)),
            ("trace_hits", Json::Num(2.0)),
            ("trace_runs", Json::Num(1.0)),
            ("wall_ms", Json::Num(10.0)),
        ]);
        let fields = counters_fields(&v1).unwrap();
        assert_eq!(fields.len(), 6);
        assert_eq!(fields[0], ("cache_hits", 3.0));
        assert_eq!(fields[5], ("wall_ms", 10.0));
        assert!(counters_fields(&Json::obj(vec![("schema", Json::Str("x".into()))])).is_none());
    }

    #[test]
    fn response_stream_roundtrips_and_detects_truncation() {
        let svc = Service::cli(Engine::new(DeviceConfig::pac_a10(), 1));
        let resp = svc
            .handle(&ServiceRequest::Measure {
                workload: "fw".into(),
                variant: Variant::FeedForward { depth: 1 },
                scale: Scale::Tiny,
                device: None,
            })
            .unwrap();
        let lines = response_lines(&resp);
        assert_eq!(lines.len(), 3); // head + 1 cell + done
        let docs: Vec<Json> =
            lines.iter().map(|l| crate::util::json::parse(l).unwrap()).collect();
        let items = decode_response_lines(&docs).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[1].get("status").unwrap().as_str(), Some("ok"));

        // the daemon-side engine actually measured it
        assert_eq!(svc.engine().simulations(), 1);
        assert_eq!(svc.requests_deduped(), 0); // CLI mode pins to zero

        // reassembled sink == the engine's own sink
        let bench = cells_to_bench(&items, Scale::Tiny, &[]).unwrap();
        assert_eq!(bench, svc.engine().bench_json(Scale::Tiny, &[]));

        // dropping the terminator reads as truncation, not success —
        // and the client retry policy classifies it as transient
        let e = decode_response_lines(&docs[..2]).unwrap_err();
        assert!(is_truncated_response(&e), "{e}");
        assert!(is_truncated_response(&decode_response_lines(&[]).unwrap_err()));
        assert!(!is_truncated_response("validation: boom"));
        // an error line surfaces as the rendered store-form string
        let err_docs = vec![crate::util::json::parse(&request_error_line(
            "validation: boom",
        ))
        .unwrap()];
        assert_eq!(decode_response_lines(&err_docs), Err("validation: boom".to_string()));
    }

    #[test]
    fn records_roundtrip_through_the_wire_form() {
        let rec = ExportRecord {
            tier: Tier::Profiles,
            key: 0x0123_4567_89ab_cdef,
            doc: Json::obj(vec![("a", Json::Str("b".into()))]),
        };
        let doc = record_to_json(&rec);
        assert_eq!(decode_record(&doc), Ok(rec));
        assert!(decode_record(&Json::obj(vec![("tier", Json::Str("nope".into()))])).is_err());
    }
}
