//! Depth×replication autotuner (the PR-3 tentpole).
//!
//! The paper picks pipe depth by exhaustively sweeping {1, 100, 1000} per
//! kernel (§V, the Fig. 4-style sweeps). This module replaces the
//! exhaustive grid with budgeted search policies over the
//! (pipe depth, replication factor) configuration space, the ROADMAP's
//! "depth autotuning" item (cf. MKPipe's per-pipeline parameter search,
//! arXiv:2002.01614, and the per-kernel factor search of
//! arXiv:2208.11890):
//!
//! * [`GoldenSection`] — golden-section search over the log-spaced
//!   [`DEPTH_LADDER`], exploiting the (empirically) unimodal
//!   time-vs-depth curve; with replication enabled it finishes with a
//!   coordinate-descent pass over the replication factors at the chosen
//!   depth. The bracket is **seeded per device** (PR-8 satellite): a
//!   profile charging nonzero `channel_fill_cycles` amortizes that cost
//!   with depth, so its optimum sits deep in the ladder — the search
//!   starts its bracket at the first rung covering the fill cost
//!   (plus one shallow anchor probe), spending strictly fewer probes
//!   than the full ladder. Zero-fill devices (arria10, cpu-like) search
//!   the full ladder, bit-for-bit the unseeded behaviour.
//! * [`SuccessiveHalving`] — successive halving over the full
//!   depth×replication product space, using cheaper dataset scales as the
//!   low-fidelity rungs (arms are ranked at `tiny` before the survivors
//!   are re-measured at the target scale).
//!
//! Every probe goes through [`Engine::measure`], so it is
//! content-addressed and lands in the persistent store: a warm-store
//! rerun replays the whole search with **zero simulations** and a
//! byte-identical [`TuneReport`] (`tests/integration_tune.rs` proves it).
//! The budget caps the number of distinct probes — on a cold store, the
//! maximum number of simulations a search may spend.

use super::engine::{resolve_workload, Engine};
use super::experiments::Measurement;
use super::scale_label;
use crate::report::{fx, ms, pct, Table};
use crate::transform::Variant;
use crate::util::json::Json;
use crate::workloads::{is_infeasible_error, is_validation_error, Scale, Workload};
use std::collections::HashMap;

/// Candidate pipe depths: log-spaced, bracketing the paper's {1, 100,
/// 1000} sweep. Golden-section searches over the *index* of this ladder
/// (log-depth), so the unimodality assumption is about the ladder, not
/// raw depth values.
pub const DEPTH_LADDER: [usize; 11] = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024];

/// Candidate replication factors (`1` = plain feed-forward; the paper's
/// producer/consumer sweep plateaus at 2×2 and explores up to 4×4).
pub const PART_LADDER: [usize; 4] = [1, 2, 3, 4];

/// One point of the tuner's configuration space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuneConfig {
    pub depth: usize,
    /// Replication factor: 1 = feed-forward, R>1 = MxCx with R parts.
    pub parts: usize,
}

impl TuneConfig {
    pub fn variant(self) -> Variant {
        if self.parts <= 1 {
            Variant::FeedForward { depth: self.depth }
        } else {
            Variant::MxCx { parts: self.parts, depth: self.depth }
        }
    }

    pub fn label(self) -> String {
        self.variant().label()
    }
}

/// Which search policy drives the probes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Golden,
    Sh,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "golden" => Some(Policy::Golden),
            "sh" => Some(Policy::Sh),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Policy::Golden => "golden",
            Policy::Sh => "sh",
        }
    }
}

/// Tuner attachment for [`Engine`]: when set, `Engine::best_ff` searches
/// the depth ladder instead of sweeping the exhaustive `DEPTHS` grid, and
/// `Engine::depth_sweep` annotates each benchmark with the tuned choice.
#[derive(Debug, Clone, Copy)]
pub struct TuneSpec {
    pub policy: Policy,
    pub budget: usize,
}

/// The configuration space one search runs over.
pub struct Space {
    pub depths: Vec<usize>,
    pub parts: Vec<usize>,
    /// The scale the tuner optimizes for (low-fidelity rungs may probe
    /// cheaper scales, but "best" always means best at this one).
    pub scale: Scale,
}

impl Space {
    pub fn new(scale: Scale, replication: bool) -> Space {
        Space {
            depths: DEPTH_LADDER.to_vec(),
            parts: if replication { PART_LADDER.to_vec() } else { vec![1] },
            scale,
        }
    }

    /// The full product space in deterministic order (parts-major, so a
    /// strided subsample keeps depth coverage within every factor).
    pub fn configs(&self) -> Vec<TuneConfig> {
        let mut out = vec![];
        for &parts in &self.parts {
            for &depth in &self.depths {
                out.push(TuneConfig { depth, parts });
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.depths.len() * self.parts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.depths.is_empty() || self.parts.is_empty()
    }
}

/// Budgeted probe channel between a policy and the engine. Each distinct
/// `(config, scale)` pair costs one unit of budget (one simulation on a
/// cold store); repeats are memoized and free. Validation- and
/// feasibility-class failures describe the *configuration* and are
/// recorded as `None` — a policy treats them as infinitely slow and
/// searches away from them. Any other error class is a real defect: it
/// stops the search ([`Probe::fatal`]) and the driver propagates it.
pub struct Probe<'a> {
    engine: &'a Engine,
    workload: &'a dyn Workload,
    target: Scale,
    budget: usize,
    spent: usize,
    seen: HashMap<(usize, usize, &'static str), Option<f64>>,
    failures: Vec<(String, String)>,
    fatal: Option<String>,
    best: Option<(TuneConfig, f64)>,
}

impl<'a> Probe<'a> {
    pub fn new(
        engine: &'a Engine,
        workload: &'a dyn Workload,
        target: Scale,
        budget: usize,
    ) -> Probe<'a> {
        Probe {
            engine,
            workload,
            target,
            budget,
            spent: 0,
            seen: HashMap::new(),
            failures: vec![],
            fatal: None,
            best: None,
        }
    }

    /// Modelled seconds of `c` at `scale`, distinguishing the two
    /// non-answers: outer `None` = budget exhausted (the search must
    /// stop), `Some(None)` = the measurement failed (infinitely slow —
    /// search away from it).
    pub fn try_at(&mut self, c: TuneConfig, scale: Scale) -> Option<Option<f64>> {
        let key = (c.depth, c.parts, scale_label(scale));
        if let Some(v) = self.seen.get(&key) {
            return Some(*v);
        }
        if self.exhausted() {
            return None;
        }
        self.spent += 1;
        let v = match self.engine.measure(self.workload, c.variant(), scale) {
            Ok(m) => Some(m.seconds),
            Err(e) if is_validation_error(&e) || is_infeasible_error(&e) => {
                self.failures.push((format!("{}@{}", c.label(), scale_label(scale)), e));
                None
            }
            Err(e) => {
                // a real defect, not a property of this configuration:
                // stop the search and let the driver surface it
                self.fatal = Some(format!("{}@{}: {e}", c.label(), scale_label(scale)));
                return None;
            }
        };
        self.seen.insert(key, v);
        if scale == self.target {
            if let Some(s) = v {
                if self.best.map(|(_, b)| s < b).unwrap_or(true) {
                    self.best = Some((c, s));
                }
            }
        }
        Some(v)
    }

    /// Modelled seconds of `c` at `scale`; `None` if the measurement
    /// failed *or* the budget is exhausted (check [`Probe::exhausted`]).
    pub fn at(&mut self, c: TuneConfig, scale: Scale) -> Option<f64> {
        self.try_at(c, scale).flatten()
    }

    /// [`Probe::at`] the target scale.
    pub fn target(&mut self, c: TuneConfig) -> Option<f64> {
        self.at(c, self.target)
    }

    pub fn target_scale(&self) -> Scale {
        self.target
    }

    /// No further probes will be answered: the budget ran out or a fatal
    /// (non-configuration) error stopped the search.
    pub fn exhausted(&self) -> bool {
        self.spent >= self.budget || self.fatal.is_some()
    }

    /// The defect that stopped the search, if any.
    pub fn fatal(&self) -> Option<&str> {
        self.fatal.as_deref()
    }

    /// Distinct probes spent so far (= max simulations on a cold store).
    pub fn spent(&self) -> usize {
        self.spent
    }

    /// Best target-scale measurement seen so far (first-probed wins ties,
    /// so the outcome is deterministic).
    pub fn best(&self) -> Option<(TuneConfig, f64)> {
        self.best
    }

    pub fn take_failures(&mut self) -> Vec<(String, String)> {
        std::mem::take(&mut self.failures)
    }
}

/// A pluggable search policy: decides *where* to probe; the chosen config
/// is whatever the probe recorded as best, so even a misbehaving policy
/// cannot report a config it never measured.
pub trait SearchPolicy {
    fn name(&self) -> &'static str;
    fn search(&self, probe: &mut Probe<'_>, space: &Space);
}

pub fn policy_for(p: Policy) -> Box<dyn SearchPolicy> {
    match p {
        Policy::Golden => Box::new(GoldenSection),
        Policy::Sh => Box::new(SuccessiveHalving),
    }
}

/// Golden-section search over the indices `0..n` of a discrete (assumed
/// unimodal) cost curve. `f` returns the cost at an index, or `None` once
/// the probe budget is exhausted; failed configurations should come back
/// as `Some(f64::INFINITY)` so the bracket moves away from them. Probes
/// strictly fewer than `n` distinct points for `n > 5`.
fn golden_search(n: usize, f: &mut dyn FnMut(usize) -> Option<f64>) {
    if n == 0 {
        return;
    }
    const INV_PHI: f64 = 0.618_033_988_749_894_8;
    let (mut lo, mut hi) = (0usize, n - 1);
    while hi - lo > 3 {
        let step = ((hi - lo) as f64 * INV_PHI).round() as usize;
        let (x1, mut x2) = (hi - step, lo + step);
        if x1 == x2 {
            x2 += 1; // a span of 4 rounds both interior points together
        }
        // short-circuit between the pair: a probe after exhaustion is waste
        let Some(f1) = f(x1) else { return };
        let Some(f2) = f(x2) else { return };
        if f1 <= f2 {
            hi = x2;
        } else {
            lo = x1;
        }
    }
    for i in lo..=hi {
        if f(i).is_none() {
            return;
        }
    }
}

/// Ladder index where a device-seeded golden bracket starts: the first
/// rung whose depth covers the device's `channel_fill_cycles` (a pipe
/// shallower than its fill cost stalls on every activation, so the
/// optimum cannot sit left of it by more than the anchor probe checks).
/// Zero fill cost — or a ladder too short to narrow usefully — seeds
/// nothing (`0`, the full ladder); the clamp keeps at least a 3-point
/// window searchable.
pub fn device_seed_lo(fill_cycles: f64, depths: &[usize]) -> usize {
    if fill_cycles <= 0.0 || depths.len() < 6 {
        return 0;
    }
    depths
        .iter()
        .position(|&d| d as f64 >= fill_cycles)
        .unwrap_or(depths.len() - 1)
        .min(depths.len() - 3)
}

/// Golden-section over log-depth (the [`DEPTH_LADDER`] index). Depth
/// curves are unimodal in the model — deeper pipes only add BRAM/area —
/// so the bracket converges on the minimum with O(log n) probes. When the
/// space includes replication factors, a coordinate-descent pass tries
/// each factor at the chosen depth.
pub struct GoldenSection;

impl SearchPolicy for GoldenSection {
    fn name(&self) -> &'static str {
        "golden"
    }

    fn search(&self, probe: &mut Probe<'_>, space: &Space) {
        if space.is_empty() {
            return;
        }
        let depths = &space.depths;
        let target = probe.target_scale();
        // per-device seed: on a fill-cost device, bracket the deep end
        // of the ladder and spend one probe anchoring the shallow end
        // (if the optimum really is shallow, the anchor catches it and
        // `Probe::best` keeps it)
        let lo = device_seed_lo(probe.engine.cfg.mem.channel_fill_cycles, depths);
        if lo > 0 {
            probe.try_at(TuneConfig { depth: depths[0], parts: 1 }, target);
        }
        let window = &depths[lo..];
        golden_search(window.len(), &mut |i| {
            probe
                .try_at(TuneConfig { depth: window[i], parts: 1 }, target)
                .map(|v| v.unwrap_or(f64::INFINITY))
        });
        if space.parts.len() > 1 {
            if let Some((c, _)) = probe.best() {
                for &parts in &space.parts {
                    if parts != c.parts && !probe.exhausted() {
                        probe.target(TuneConfig { depth: c.depth, parts });
                    }
                }
            }
        }
    }
}

/// The low-to-high fidelity ladder ending at the target scale.
fn fidelity_rungs(target: Scale) -> Vec<Scale> {
    match target {
        Scale::Tiny => vec![Scale::Tiny],
        Scale::Small => vec![Scale::Tiny, Scale::Small],
        Scale::Paper => vec![Scale::Tiny, Scale::Small, Scale::Paper],
    }
}

/// Successive halving over the depth×replication product space: rank all
/// arms at the cheapest scale, keep the top half, re-rank the survivors
/// one rung up, and so on until the target scale. When the budget cannot
/// afford the full arm set, the first rung evenly subsamples the space
/// (deterministic stride), trading coverage for feasibility.
pub struct SuccessiveHalving;

impl SearchPolicy for SuccessiveHalving {
    fn name(&self) -> &'static str {
        "sh"
    }

    fn search(&self, probe: &mut Probe<'_>, space: &Space) {
        if space.is_empty() {
            return;
        }
        let rungs = fidelity_rungs(probe.target_scale());
        let mut arms = space.configs();
        // budget share of the first rung: the halving tail costs about as
        // much again, so cap the entry set at budget / rungs
        let cap = (probe.budget / rungs.len()).max(2);
        if arms.len() > cap {
            let stride = arms.len().div_ceil(cap);
            arms = arms.into_iter().step_by(stride).collect();
        }
        for (r, &scale) in rungs.iter().enumerate() {
            let mut ranked: Vec<(TuneConfig, f64)> = vec![];
            for &c in &arms {
                if probe.exhausted() {
                    break;
                }
                if let Some(s) = probe.at(c, scale) {
                    ranked.push((c, s));
                }
            }
            // deterministic rank: seconds, then the config itself
            ranked.sort_by(|a, b| {
                a.1.total_cmp(&b.1)
                    .then(a.0.parts.cmp(&b.0.parts))
                    .then(a.0.depth.cmp(&b.0.depth))
            });
            let keep =
                if r + 1 < rungs.len() { ranked.len().div_ceil(2).max(1) } else { ranked.len() };
            arms = ranked.into_iter().take(keep).map(|(c, _)| c).collect();
        }
        // make sure every surviving arm was measured at the target scale
        // (free when the last rung already was the target)
        for c in arms {
            if probe.exhausted() {
                break;
            }
            probe.target(c);
        }
    }
}

// ---------------------------------------------------------------------------
// Driver + report
// ---------------------------------------------------------------------------

/// One `pipefwd tune` invocation.
pub struct TuneRequest {
    pub benches: Vec<String>,
    pub policy: Policy,
    pub budget: usize,
    pub replication: bool,
    pub scale: Scale,
    /// Also compute the exhaustive best over the full space (the regret
    /// column). Budget-exempt: it is the *reference* the search is judged
    /// against, content-addressed like every probe, so it is free on a
    /// warm store.
    pub reference: bool,
}

/// Per-benchmark tuning outcome.
pub struct TuneOutcome {
    pub workload: String,
    /// Best config found by the search and its modelled seconds.
    pub chosen: Option<(TuneConfig, f64)>,
    /// Feed-forward depth-1 seconds (the speedup-vs-depth-1 reference).
    pub ff1_seconds: Option<f64>,
    /// Distinct probes the search spent (max simulations on a cold store).
    pub probes: usize,
    /// Size of the full product space at the target scale.
    pub space: usize,
    /// Exhaustive best over the full space (when requested).
    pub exhaustive: Option<(TuneConfig, f64)>,
    /// Failed probes: (config@scale, error).
    pub failures: Vec<(String, String)>,
}

impl TuneOutcome {
    pub fn speedup_vs_ff1(&self) -> Option<f64> {
        match (self.ff1_seconds, self.chosen) {
            (Some(ff1), Some((_, s))) if s > 0.0 => Some(ff1 / s),
            _ => None,
        }
    }

    /// Fractional regret vs the exhaustive best (0.0 = matched it).
    pub fn regret_frac(&self) -> Option<f64> {
        match (self.exhaustive, self.chosen) {
            (Some((_, e)), Some((_, s))) if e > 0.0 => Some(s / e - 1.0),
            _ => None,
        }
    }
}

/// The `tune` command's product: one row per benchmark, rendered through
/// the existing `report` table machinery and serialized to `TUNE.json`.
/// Deliberately excludes live counters (simulations, store hits — those
/// go to stderr): the document is byte-identical between a cold run and a
/// warm-store rerun.
pub struct TuneReport {
    pub policy: Policy,
    pub budget: usize,
    pub replication: bool,
    pub scale: Scale,
    /// Which device profile the probes were estimated on — the answer to
    /// "which depth on which device". Additive in `pipefwd-tune-v1`
    /// documents (old readers ignore it).
    pub device: &'static str,
    pub outcomes: Vec<TuneOutcome>,
}

impl TuneReport {
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "TuneReport: {} policy, budget {}, {} scale, {}{}",
                self.policy.label(),
                self.budget,
                scale_label(self.scale),
                self.device,
                if self.replication { ", with replication" } else { "" }
            ),
            &[
                "Benchmark",
                "Chosen",
                "Time (ms)",
                "vs ff(d1)",
                "Probes",
                "Space",
                "Exhaustive best",
                "Regret (%)",
            ],
        );
        for o in &self.outcomes {
            t.row(vec![
                o.workload.clone(),
                o.chosen.map(|(c, _)| c.label()).unwrap_or_else(|| "n/a".into()),
                o.chosen.map(|(_, s)| ms(s)).unwrap_or_else(|| "-".into()),
                o.speedup_vs_ff1().map(fx).unwrap_or_else(|| "-".into()),
                o.probes.to_string(),
                o.space.to_string(),
                o.exhaustive.map(|(c, _)| c.label()).unwrap_or_else(|| "-".into()),
                o.regret_frac().map(pct).unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    pub fn to_json(&self) -> Json {
        let outcomes = self
            .outcomes
            .iter()
            .map(|o| {
                Json::Obj(vec![
                    ("workload".into(), Json::Str(o.workload.clone())),
                    (
                        "chosen".into(),
                        o.chosen.map(|(c, _)| Json::Str(c.label())).unwrap_or(Json::Null),
                    ),
                    (
                        "seconds".into(),
                        o.chosen.map(|(_, s)| Json::Num(s)).unwrap_or(Json::Null),
                    ),
                    (
                        "ff1_seconds".into(),
                        o.ff1_seconds.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    ("probes".into(), Json::Num(o.probes as f64)),
                    ("space".into(), Json::Num(o.space as f64)),
                    (
                        "exhaustive".into(),
                        o.exhaustive.map(|(c, _)| Json::Str(c.label())).unwrap_or(Json::Null),
                    ),
                    (
                        "exhaustive_seconds".into(),
                        o.exhaustive.map(|(_, s)| Json::Num(s)).unwrap_or(Json::Null),
                    ),
                    (
                        "failures".into(),
                        Json::Arr(
                            o.failures
                                .iter()
                                .map(|(c, e)| {
                                    Json::Obj(vec![
                                        ("config".into(), Json::Str(c.clone())),
                                        ("error".into(), Json::Str(e.clone())),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("schema".into(), Json::Str("pipefwd-tune-v1".into())),
            ("policy".into(), Json::Str(self.policy.label().into())),
            ("budget".into(), Json::Num(self.budget as f64)),
            ("replication".into(), Json::Bool(self.replication)),
            ("scale".into(), Json::Str(scale_label(self.scale).into())),
            ("device".into(), Json::Str(self.device.into())),
            ("workloads".into(), Json::Arr(outcomes)),
        ])
    }

    /// Total probes spent across all benchmarks.
    pub fn total_probes(&self) -> usize {
        self.outcomes.iter().map(|o| o.probes).sum()
    }
}

/// Exhaustive best over the full space at the target scale (the regret
/// reference; also what `--tuned` is benchmarked against in tests).
pub fn exhaustive_best(
    engine: &Engine,
    w: &dyn Workload,
    space: &Space,
) -> Option<(TuneConfig, f64)> {
    let mut best: Option<(TuneConfig, f64)> = None;
    for c in space.configs() {
        if let Ok(m) = engine.measure(w, c.variant(), space.scale) {
            if best.map(|(_, b)| m.seconds < b).unwrap_or(true) {
                best = Some((c, m.seconds));
            }
        }
    }
    best
}

/// Run one tuning request end to end through an engine. Probes are
/// content-addressed measurements, so attaching a store makes warm reruns
/// replay the search with zero simulations.
pub fn run_tune(engine: &Engine, req: &TuneRequest) -> Result<TuneReport, String> {
    if req.benches.is_empty() {
        return Err("tune: no benchmarks given (--benches a,b,c)".into());
    }
    let space = Space::new(req.scale, req.replication);
    let policy = policy_for(req.policy);
    let mut outcomes = vec![];
    for name in &req.benches {
        let w = resolve_workload(name)
            .ok_or_else(|| format!("unknown benchmark `{name}` (see `pipefwd list`)"))?;
        let mut probe = Probe::new(engine, w.as_ref(), req.scale, req.budget);
        policy.search(&mut probe, &space);
        if let Some(e) = probe.fatal() {
            return Err(format!("tune {name}: {e}"));
        }
        let probes = probe.spent();
        let chosen = probe.best();
        let failures = probe.take_failures();
        // the report's reference columns are budget-exempt (see
        // TuneRequest::reference); both are memoized/store-backed probes
        let ff1 = engine
            .measure(w.as_ref(), Variant::FeedForward { depth: 1 }, req.scale)
            .ok()
            .map(|m| m.seconds);
        let exhaustive =
            if req.reference { exhaustive_best(engine, w.as_ref(), &space) } else { None };
        outcomes.push(TuneOutcome {
            workload: name.clone(),
            chosen,
            ff1_seconds: ff1,
            probes,
            space: space.len(),
            exhaustive,
            failures,
        });
    }
    Ok(TuneReport {
        policy: req.policy,
        budget: req.budget,
        replication: req.replication,
        scale: req.scale,
        device: engine.cfg.name,
        outcomes,
    })
}

/// Tuner-driven replacement for the exhaustive `Engine::best_ff` depth
/// sweep: search the depth ladder (feed-forward only — callers of
/// `best_ff` compare against replication separately) and return the full
/// measurement of the chosen depth.
pub fn best_ff_tuned(
    engine: &Engine,
    w: &dyn Workload,
    scale: Scale,
    spec: TuneSpec,
) -> Result<Measurement, String> {
    let space = Space::new(scale, false);
    let mut probe = Probe::new(engine, w, scale, spec.budget);
    policy_for(spec.policy).search(&mut probe, &space);
    if let Some(e) = probe.fatal() {
        return Err(format!("tuner: {}: {e}", w.name()));
    }
    match probe.best() {
        Some((c, _)) => engine.measure(w, c.variant(), scale),
        None => {
            let mut msg = format!(
                "tuner ({}, budget {}): no feasible feed-forward depth for {}",
                spec.policy.label(),
                spec.budget,
                w.name()
            );
            for (c, e) in probe.take_failures() {
                msg.push_str(&format!("\n  {c}: {e}"));
            }
            Err(msg)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parsing_roundtrips() {
        for p in [Policy::Golden, Policy::Sh] {
            assert_eq!(Policy::parse(p.label()), Some(p));
        }
        assert_eq!(Policy::parse("exhaustive"), None);
    }

    #[test]
    fn config_labels_match_variants() {
        assert_eq!(TuneConfig { depth: 16, parts: 1 }.label(), "ff(d16)");
        assert_eq!(TuneConfig { depth: 4, parts: 3 }.label(), "m3c3(d4)");
    }

    #[test]
    fn space_is_the_product_of_ladders() {
        let s = Space::new(Scale::Tiny, true);
        assert_eq!(s.len(), DEPTH_LADDER.len() * PART_LADDER.len());
        assert_eq!(s.configs().len(), s.len());
        let ff_only = Space::new(Scale::Tiny, false);
        assert_eq!(ff_only.len(), DEPTH_LADDER.len());
        assert!(ff_only.configs().iter().all(|c| c.parts == 1));
    }

    /// Golden-section on a synthetic unimodal curve: finds the minimum
    /// with strictly fewer probes than the exhaustive grid.
    #[test]
    fn golden_search_finds_unimodal_minimum_with_fewer_probes() {
        // V-shaped cost over 11 points, minimum at index 3
        let cost: Vec<f64> =
            (0..11).map(|i| ((i as f64) - 3.0).abs() + 1.0).collect();
        let mut probed = std::collections::BTreeSet::new();
        golden_search(cost.len(), &mut |i| {
            probed.insert(i);
            Some(cost[i])
        });
        assert!(probed.contains(&3), "minimum index must be probed: {probed:?}");
        assert!(
            probed.len() < cost.len(),
            "golden must probe strictly fewer than exhaustive ({probed:?})"
        );
    }

    /// Failed configurations (infinite cost) push the bracket away.
    #[test]
    fn golden_search_avoids_infeasible_tail() {
        // cost rises then "fails" (NW-style: deep pipes break validation)
        let cost: Vec<f64> = (0..11)
            .map(|i| if i >= 6 { f64::INFINITY } else { 1.0 + i as f64 })
            .collect();
        let mut probed = std::collections::BTreeSet::new();
        golden_search(cost.len(), &mut |i| {
            probed.insert(i);
            Some(cost[i])
        });
        assert!(probed.contains(&0), "must converge onto the feasible minimum");
    }

    #[test]
    fn golden_search_stops_when_budget_runs_out() {
        let mut calls = 0;
        golden_search(11, &mut |_| {
            calls += 1;
            if calls > 2 {
                None
            } else {
                Some(1.0)
            }
        });
        assert_eq!(calls, 3, "search must stop at the first exhausted probe");
    }

    /// The device seed maps fill cost to a ladder start index: zero
    /// fill cost leaves the full ladder (bit-for-bit the unseeded
    /// search), and deeper fill costs start deeper, monotonically.
    #[test]
    fn device_seed_starts_deeper_with_fill_cost() {
        let d = &DEPTH_LADDER;
        assert_eq!(device_seed_lo(0.0, d), 0, "zero fill cost must not seed");
        assert_eq!(device_seed_lo(-1.0, d), 0);
        // gpu-like (6 cycles) starts at the first rung >= 6 (depth 8)
        assert_eq!(device_seed_lo(6.0, d), 3);
        // stratix10-hbm (24 cycles) starts at depth 32
        assert_eq!(device_seed_lo(24.0, d), 5);
        // absurd fill costs still leave a 3-point window
        assert_eq!(device_seed_lo(1e12, d), d.len() - 3);
        let mut prev = 0;
        for f in [0.0, 1.0, 6.0, 24.0, 100.0, 1e6] {
            let lo = device_seed_lo(f, d);
            assert!(lo >= prev, "seed must be monotone in fill cost");
            prev = lo;
        }
        // short ladders are never narrowed
        assert_eq!(device_seed_lo(24.0, &d[..5]), 0);
    }

    #[test]
    fn fidelity_rungs_end_at_the_target() {
        assert_eq!(fidelity_rungs(Scale::Tiny), vec![Scale::Tiny]);
        assert_eq!(fidelity_rungs(Scale::Small), vec![Scale::Tiny, Scale::Small]);
        assert_eq!(
            fidelity_rungs(Scale::Paper),
            vec![Scale::Tiny, Scale::Small, Scale::Paper]
        );
    }
}
