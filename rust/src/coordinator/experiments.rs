//! Experiment definitions E1-E7 (see DESIGN.md experiment index): each
//! regenerates one table/figure of the paper from the live system.
//!
//! Since PR 1 the table builders live on [`Engine`] (parallel grid
//! fan-out + content-addressed measurement cache); the free functions
//! here keep the original `(scale, cfg)` signatures and delegate to a
//! fresh single-worker engine, so existing callers are unaffected.

use super::engine::Engine;
use super::scale_label;
use crate::report::Table;
use crate::sim::device::DeviceConfig;
use crate::transform::Variant;
use crate::util::json::Json;
use crate::workloads::{run_workload, suite, Harness, Scale, Workload};

/// The paper's channel-depth candidates (§4.2: best of 1/100/1000).
pub const DEPTHS: [usize; 3] = [1, 100, 1000];

/// Result of one (workload, variant, scale) measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    pub workload: String,
    pub variant: String,
    pub scale: String,
    pub seconds: f64,
    pub cycles: f64,
    pub logic_pct: f64,
    pub brams: u32,
    pub max_ii: u32,
    pub max_bw: f64,
    pub launches: u64,
}

impl Measurement {
    pub fn from_harness(
        w: &dyn Workload,
        variant: Variant,
        scale: Scale,
        h: &Harness,
    ) -> Measurement {
        // max BW of the *dominant* kernel's launch unit (what the paper's
        // profiler screenshots show), not the app-wide max
        let max_bw = h
            .bw_by_unit
            .get(w.dominant())
            .copied()
            .unwrap_or(h.metrics.bw_bytes_per_s);
        Measurement {
            workload: w.name().to_string(),
            variant: variant.label(),
            scale: scale_label(scale).to_string(),
            seconds: h.metrics.seconds,
            cycles: h.metrics.cycles,
            logic_pct: h.area.logic_pct(),
            brams: h.area.brams,
            max_ii: h.max_ii,
            max_bw,
            launches: h.launches,
        }
    }

    /// Measurement of an overlapped (launch-graph) replay. Two deliberate
    /// deviations from [`Measurement::from_harness`]: the variant label
    /// carries a `+ov` suffix so sequential and overlapped rows of one
    /// cell sort apart under [`canonical_sort`] (ties there would make
    /// sink bytes depend on cache iteration order), and `launches`
    /// reports DAG wavefronts — the scheduling unit under overlap —
    /// which also lets a warm-store E9 print the wavefront column
    /// without re-deriving the dependence graph.
    pub fn overlapped(
        w: &dyn Workload,
        variant: Variant,
        scale: Scale,
        h: &Harness,
        wavefronts: usize,
    ) -> Measurement {
        let mut m = Measurement::from_harness(w, variant, scale, h);
        m.variant.push_str("+ov");
        m.launches = wavefronts as u64;
        m
    }

    /// Serialize for the BENCH_PR1.json results sink (field order fixed —
    /// the determinism test compares bytes).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("workload".into(), Json::Str(self.workload.clone())),
            ("variant".into(), Json::Str(self.variant.clone())),
            ("scale".into(), Json::Str(self.scale.clone())),
            ("seconds".into(), Json::Num(self.seconds)),
            ("cycles".into(), Json::Num(self.cycles)),
            ("logic_pct".into(), Json::Num(self.logic_pct)),
            ("brams".into(), Json::Num(f64::from(self.brams))),
            ("max_ii".into(), Json::Num(f64::from(self.max_ii))),
            ("max_bw".into(), Json::Num(self.max_bw)),
            ("launches".into(), Json::Num(self.launches as f64)),
        ])
    }

    /// Inverse of [`Measurement::to_json`] (used by `pipefwd report`).
    pub fn from_json(v: &Json) -> Option<Measurement> {
        Some(Measurement {
            workload: v.get("workload")?.as_str()?.to_string(),
            variant: v.get("variant")?.as_str()?.to_string(),
            scale: v.get("scale")?.as_str()?.to_string(),
            seconds: v.get("seconds")?.as_f64()?,
            cycles: v.get("cycles")?.as_f64()?,
            logic_pct: v.get("logic_pct")?.as_f64()?,
            brams: v.get("brams")?.as_f64()? as u32,
            max_ii: v.get("max_ii")?.as_f64()? as u32,
            max_bw: v.get("max_bw")?.as_f64()?,
            launches: v.get("launches")?.as_f64()? as u64,
        })
    }
}

/// Canonical results-sink ordering: (workload, variant, scale). Every
/// producer of sink measurements — the engine, the store views, `merge` —
/// must sort through this one helper; the byte-identical guarantee
/// between serial, parallel, and sharded+merged runs depends on them
/// staying in lockstep.
pub fn canonical_sort(ms: &mut [Measurement]) {
    ms.sort_by(|a, b| {
        (&a.workload, &a.variant, &a.scale).cmp(&(&b.workload, &b.variant, &b.scale))
    });
}

/// Run one (workload, variant, scale) and collect the measurement — the
/// uncached primitive; prefer [`Engine::measure`] which memoizes.
pub fn measure(
    w: &dyn Workload,
    variant: Variant,
    scale: Scale,
    cfg: &DeviceConfig,
) -> Result<Measurement, String> {
    let h = run_workload(w, variant, scale, cfg)?;
    Ok(Measurement::from_harness(w, variant, scale, &h))
}

/// Best feed-forward measurement across the paper's depth sweep.
pub fn best_ff(w: &dyn Workload, scale: Scale, cfg: &DeviceConfig) -> Result<Measurement, String> {
    Engine::serial(cfg.clone()).best_ff(w, scale)
}

// ---------------------------------------------------------------------------
// E6 / Table 1 — benchmark characterisation
// ---------------------------------------------------------------------------

pub fn table1(scale: Scale) -> Table {
    let mut t = Table::new(
        "Table 1: benchmark characteristics",
        &["Suite", "Benchmark", "Dwarf", "Access Pattern", "Dataset"],
    );
    for w in suite() {
        t.row(vec![
            w.suite().into(),
            w.name().into(),
            w.dwarf().into(),
            w.pattern().into(),
            w.dataset_desc(scale),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// E1 / Table 2 — feed-forward vs single work-item baseline
// ---------------------------------------------------------------------------

pub struct Table2Row {
    pub base: Measurement,
    pub ff: Measurement,
}

pub fn table2_rows(scale: Scale, cfg: &DeviceConfig) -> Vec<Table2Row> {
    Engine::serial(cfg.clone()).table2_rows(scale)
}

pub fn table2(scale: Scale, cfg: &DeviceConfig) -> Table {
    Engine::serial(cfg.clone()).table2(scale)
}

// ---------------------------------------------------------------------------
// E2 / Figure 4 — M2C2 vs the feed-forward baseline
// ---------------------------------------------------------------------------

pub fn figure4(scale: Scale, cfg: &DeviceConfig) -> Table {
    Engine::serial(cfg.clone()).figure4(scale)
}

// ---------------------------------------------------------------------------
// E3 / Table 3 — microbenchmarks, M2C2 vs baseline
// ---------------------------------------------------------------------------

pub fn table3(scale: Scale, cfg: &DeviceConfig) -> Table {
    Engine::serial(cfg.clone()).table3(scale)
}

/// Extended microbenchmark family (the paper's future-work sweep).
pub fn micro_family(scale: Scale, cfg: &DeviceConfig) -> Table {
    Engine::serial(cfg.clone()).micro_family(scale)
}

// ---------------------------------------------------------------------------
// E4a/E4b — in-text compiler-report numbers (II, bandwidth)
// ---------------------------------------------------------------------------

pub fn intext(scale: Scale, cfg: &DeviceConfig) -> Table {
    Engine::serial(cfg.clone()).intext(scale)
}

/// Hotspot M2C2 bandwidth claim (§3: 7340 -> 13660 MB/s).
pub fn hotspot_m2c2_bw(scale: Scale, cfg: &DeviceConfig) -> (f64, f64) {
    Engine::serial(cfg.clone()).hotspot_m2c2_bw(scale)
}

// ---------------------------------------------------------------------------
// E4c/E4d/E4e — sweeps
// ---------------------------------------------------------------------------

/// Channel-depth sweep (paper: no significant effect).
pub fn depth_sweep(names: &[&str], scale: Scale, cfg: &DeviceConfig) -> Table {
    Engine::serial(cfg.clone()).depth_sweep(names, scale, &DEPTHS)
}

/// Producer/consumer count sweep incl. the 1-producer shape (paper: plateau
/// at 2x2; M1CN worse than MNCN).
pub fn pc_sweep(names: &[&str], scale: Scale, cfg: &DeviceConfig) -> Table {
    Engine::serial(cfg.clone()).pc_sweep(names, scale)
}

/// Vector-type case study (paper: FW ~3x further, MIS degrades; their SDK
/// crashed on pipes+vectors — our substrate completes the experiment).
pub fn vector_study(scale: Scale, cfg: &DeviceConfig) -> Table {
    Engine::serial(cfg.clone()).vector_study(scale)
}

// ---------------------------------------------------------------------------
// E7 — headline numbers
// ---------------------------------------------------------------------------

pub struct Headline {
    pub max_ff_speedup: f64,
    pub avg_ff_speedup_gainers: f64,
    pub max_total_speedup: f64,
}

/// "up to 65x, ~20x average across gainers, up to 86x with M2C2".
pub fn headline(scale: Scale, cfg: &DeviceConfig) -> Headline {
    Engine::serial(cfg.clone()).headline(scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    /// The dominant-kernel bandwidth selection: `from_harness` must quote
    /// the dominant launch unit's bandwidth when present…
    #[test]
    fn from_harness_prefers_dominant_unit_bandwidth() {
        let cfg = DeviceConfig::pac_a10();
        let w = by_name("fw").unwrap();
        let app = w.build(Variant::Baseline).unwrap();
        let mut h = Harness::new(&app, &cfg);
        h.metrics.bw_bytes_per_s = 42.0e9; // app-wide max
        h.bw_by_unit.insert(w.dominant().to_string(), 7.0e9);
        h.bw_by_unit.insert("some_other_unit".to_string(), 99.0e9);
        let m = Measurement::from_harness(w.as_ref(), Variant::Baseline, Scale::Tiny, &h);
        assert_eq!(m.max_bw, 7.0e9, "must pick the dominant unit, not the app max");
        assert_eq!(m.workload, "fw");
        assert_eq!(m.variant, "baseline");
        assert_eq!(m.scale, "tiny");
    }

    /// …and fall back to the app-wide number when the dominant unit has no
    /// recorded bandwidth (e.g. the unit never launched).
    #[test]
    fn from_harness_falls_back_to_app_max_bw() {
        let cfg = DeviceConfig::pac_a10();
        let w = by_name("fw").unwrap();
        let app = w.build(Variant::Baseline).unwrap();
        let mut h = Harness::new(&app, &cfg);
        h.metrics.bw_bytes_per_s = 42.0e9;
        h.bw_by_unit.insert("unrelated_unit".to_string(), 99.0e9);
        let m = Measurement::from_harness(w.as_ref(), Variant::Baseline, Scale::Small, &h);
        assert_eq!(m.max_bw, 42.0e9);
        assert_eq!(m.scale, "small");
    }

    #[test]
    fn measurement_json_roundtrips() {
        let m = Measurement {
            workload: "fw".into(),
            variant: "ff(d1)".into(),
            scale: "tiny".into(),
            seconds: 0.125,
            cycles: 3.0e7,
            logic_pct: 17.5,
            brams: 412,
            max_ii: 285,
            max_bw: 7.34e9,
            launches: 3,
        };
        let text = m.to_json().to_pretty();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(Measurement::from_json(&parsed), Some(m));
    }
}
