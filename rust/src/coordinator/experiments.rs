//! Experiment definitions E1-E7 (see DESIGN.md experiment index): each
//! regenerates one table/figure of the paper from the live system.

use crate::report::{fx, mbps, ms, Table};
use crate::sim::device::DeviceConfig;
use crate::transform::Variant;
use crate::workloads::{by_name, run_workload, suite, Harness, Scale, Workload};

/// The paper's channel-depth candidates (§4.2: best of 1/100/1000).
pub const DEPTHS: [usize; 3] = [1, 100, 1000];

/// Result of one (workload, variant) measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub workload: String,
    pub variant: String,
    pub seconds: f64,
    pub cycles: f64,
    pub logic_pct: f64,
    pub brams: u32,
    pub max_ii: u32,
    pub max_bw: f64,
    pub launches: u64,
}

impl Measurement {
    fn from_harness(w: &dyn Workload, variant: Variant, h: &Harness) -> Measurement {
        // max BW of the *dominant* kernel's launch unit (what the paper's
        // profiler screenshots show), not the app-wide max
        let max_bw = h
            .bw_by_unit
            .get(w.dominant())
            .copied()
            .unwrap_or(h.metrics.bw_bytes_per_s);
        Measurement {
            workload: w.name().to_string(),
            variant: variant.label(),
            seconds: h.metrics.seconds,
            cycles: h.metrics.cycles,
            logic_pct: h.area.logic_pct(),
            brams: h.area.brams,
            max_ii: h.max_ii,
            max_bw,
            launches: h.launches,
        }
    }
}

/// Run one (workload, variant, scale) and collect the measurement.
pub fn measure(
    w: &dyn Workload,
    variant: Variant,
    scale: Scale,
    cfg: &DeviceConfig,
) -> Result<Measurement, String> {
    let h = run_workload(w, variant, scale, cfg)?;
    Ok(Measurement::from_harness(w, variant, &h))
}

/// Best feed-forward measurement across the paper's depth sweep.
pub fn best_ff(w: &dyn Workload, scale: Scale, cfg: &DeviceConfig) -> Result<Measurement, String> {
    let mut best: Option<Measurement> = None;
    for d in DEPTHS {
        // NW is only safe below the row width (see workloads::nw docs);
        // the harness surfaces that as a validation error which we skip,
        // exactly as a paper author would drop an invalid configuration.
        match measure(w, Variant::FeedForward { depth: d }, scale, cfg) {
            Ok(m) => {
                if best.as_ref().map(|b| m.seconds < b.seconds).unwrap_or(true) {
                    best = Some(m);
                }
            }
            Err(e) => {
                if d == 1 {
                    return Err(e); // depth-1 must always work
                }
            }
        }
    }
    Ok(best.unwrap())
}

// ---------------------------------------------------------------------------
// E6 / Table 1 — benchmark characterisation
// ---------------------------------------------------------------------------

pub fn table1(scale: Scale) -> Table {
    let mut t = Table::new(
        "Table 1: benchmark characteristics",
        &["Suite", "Benchmark", "Dwarf", "Access Pattern", "Dataset"],
    );
    for w in suite() {
        t.row(vec![
            w.suite().into(),
            w.name().into(),
            w.dwarf().into(),
            w.pattern().into(),
            w.dataset_desc(scale),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// E1 / Table 2 — feed-forward vs single work-item baseline
// ---------------------------------------------------------------------------

pub struct Table2Row {
    pub base: Measurement,
    pub ff: Measurement,
}

pub fn table2_rows(scale: Scale, cfg: &DeviceConfig) -> Vec<Table2Row> {
    let mut rows = vec![];
    for w in suite() {
        let base = measure(w.as_ref(), Variant::Baseline, scale, cfg).expect("baseline runs");
        let ff = best_ff(w.as_ref(), scale, cfg).expect("feed-forward runs");
        rows.push(Table2Row { base, ff });
    }
    rows
}

pub fn table2(scale: Scale, cfg: &DeviceConfig) -> Table {
    let mut t = Table::new(
        "Table 2: feed-forward design vs single work-item baseline",
        &[
            "Benchmark",
            "Baseline time (ms)",
            "FF speedup",
            "Baseline logic (%)",
            "FF logic (%)",
            "Baseline BRAM",
            "FF BRAM",
        ],
    );
    for r in table2_rows(scale, cfg) {
        t.row(vec![
            r.base.workload.clone(),
            ms(r.base.seconds),
            fx(r.base.seconds / r.ff.seconds),
            format!("{:.2}", r.base.logic_pct),
            format!("{:.2}", r.ff.logic_pct),
            r.base.brams.to_string(),
            r.ff.brams.to_string(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// E2 / Figure 4 — M2C2 vs the feed-forward baseline
// ---------------------------------------------------------------------------

pub fn figure4(scale: Scale, cfg: &DeviceConfig) -> Table {
    let mut t = Table::new(
        "Figure 4: M2C2 speedup and resource overhead vs feed-forward baseline",
        &["Benchmark", "M2C2 speedup", "Logic overhead (%)", "BRAM overhead (%)"],
    );
    let mut speedups = vec![];
    for w in suite() {
        let ff = match measure(w.as_ref(), Variant::FeedForward { depth: 1 }, scale, cfg) {
            Ok(m) => m,
            Err(_) => continue,
        };
        let m2 = match measure(w.as_ref(), Variant::MxCx { parts: 2, depth: 1 }, scale, cfg) {
            Ok(m) => m,
            Err(e) => {
                t.row(vec![w.name().into(), format!("n/a ({e})"), "-".into(), "-".into()]);
                continue;
            }
        };
        let s = ff.seconds / m2.seconds;
        speedups.push(s);
        t.row(vec![
            w.name().into(),
            fx(s),
            format!("{:+.1}", (m2.logic_pct / ff.logic_pct - 1.0) * 100.0),
            format!("{:+.1}", (m2.brams as f64 / ff.brams as f64 - 1.0) * 100.0),
        ]);
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len().max(1) as f64;
    t.row(vec!["(average)".into(), fx(avg), "-".into(), "-".into()]);
    t
}

// ---------------------------------------------------------------------------
// E3 / Table 3 — microbenchmarks, M2C2 vs baseline
// ---------------------------------------------------------------------------

pub fn table3(scale: Scale, cfg: &DeviceConfig) -> Table {
    use crate::workloads::micro::{Micro, MicroSpec};
    let mut t = Table::new(
        "Table 3: microbenchmark speedup (M2C2 over baseline) and area",
        &[
            "Benchmark",
            "Baseline time (ms)",
            "Speedup",
            "Logic base (%)",
            "Logic M2C2 (%)",
            "BRAM base",
            "BRAM M2C2",
        ],
    );
    for spec in MicroSpec::table3() {
        let w = Micro::new(spec);
        let base = measure(&w, Variant::Baseline, scale, cfg).expect("micro baseline");
        let m2 = measure(&w, Variant::MxCx { parts: 2, depth: 1 }, scale, cfg).expect("micro m2c2");
        t.row(vec![
            spec.label(),
            ms(base.seconds),
            format!("{}x", fx(base.seconds / m2.seconds)),
            format!("{:.2}", base.logic_pct),
            format!("{:.2}", m2.logic_pct),
            base.brams.to_string(),
            m2.brams.to_string(),
        ]);
    }
    t
}

/// Extended microbenchmark family (the paper's future-work sweep).
pub fn micro_family(scale: Scale, cfg: &DeviceConfig) -> Table {
    use crate::workloads::micro::{Micro, MicroSpec};
    let mut t = Table::new(
        "Microbenchmark family: AI x pattern x divergence",
        &["Benchmark", "FF speedup", "M2C2 speedup (over FF)"],
    );
    for spec in MicroSpec::family() {
        let w = Micro::new(spec);
        let base = measure(&w, Variant::Baseline, scale, cfg).expect("family baseline");
        let ff = measure(&w, Variant::FeedForward { depth: 1 }, scale, cfg).expect("family ff");
        let m2 = measure(&w, Variant::MxCx { parts: 2, depth: 1 }, scale, cfg).expect("family m2c2");
        t.row(vec![
            spec.label(),
            fx(base.seconds / ff.seconds),
            fx(ff.seconds / m2.seconds),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// E4a/E4b — in-text compiler-report numbers (II, bandwidth)
// ---------------------------------------------------------------------------

pub fn intext(scale: Scale, cfg: &DeviceConfig) -> Table {
    let mut t = Table::new(
        "In-text metrics: II and max bandwidth, baseline vs feed-forward",
        &["Benchmark", "Baseline II", "FF II", "Baseline max BW (MB/s)", "FF max BW (MB/s)"],
    );
    for name in ["fw", "backprop", "mis", "bfs", "nw", "hotspot"] {
        let w = by_name(name).unwrap();
        let base = measure(w.as_ref(), Variant::Baseline, scale, cfg).expect("baseline");
        let ff = measure(w.as_ref(), Variant::FeedForward { depth: 1 }, scale, cfg).expect("ff");
        t.row(vec![
            name.into(),
            base.max_ii.to_string(),
            ff.max_ii.to_string(),
            mbps(base.max_bw),
            mbps(ff.max_bw),
        ]);
    }
    t
}

/// Hotspot M2C2 bandwidth claim (§3: 7340 -> 13660 MB/s).
pub fn hotspot_m2c2_bw(scale: Scale, cfg: &DeviceConfig) -> (f64, f64) {
    let w = by_name("hotspot").unwrap();
    let ff = measure(w.as_ref(), Variant::FeedForward { depth: 1 }, scale, cfg).unwrap();
    let m2 = measure(w.as_ref(), Variant::MxCx { parts: 2, depth: 1 }, scale, cfg).unwrap();
    (ff.max_bw, m2.max_bw)
}

// ---------------------------------------------------------------------------
// E4c/E4d/E4e — sweeps
// ---------------------------------------------------------------------------

/// Channel-depth sweep (paper: no significant effect).
pub fn depth_sweep(names: &[&str], scale: Scale, cfg: &DeviceConfig) -> Table {
    let mut t = Table::new(
        "Channel-depth sweep (feed-forward, seconds)",
        &["Benchmark", "depth 1", "depth 100", "depth 1000"],
    );
    for name in names {
        let w = by_name(name).unwrap();
        let mut cells = vec![name.to_string()];
        for d in DEPTHS {
            match measure(w.as_ref(), Variant::FeedForward { depth: d }, scale, cfg) {
                Ok(m) => cells.push(format!("{:.4}", m.seconds)),
                Err(_) => cells.push("invalid".into()),
            }
        }
        t.row(cells);
    }
    t
}

/// Producer/consumer count sweep incl. the 1-producer shape (paper: plateau
/// at 2x2; M1CN worse than MNCN).
pub fn pc_sweep(names: &[&str], scale: Scale, cfg: &DeviceConfig) -> Table {
    let mut t = Table::new(
        "Producer/consumer sweep (speedup over feed-forward baseline)",
        &["Benchmark", "m1c1", "m2c2", "m3c3", "m4c4", "m1c2"],
    );
    for name in names {
        let w = by_name(name).unwrap();
        let ff = measure(w.as_ref(), Variant::FeedForward { depth: 1 }, scale, cfg).unwrap();
        let mut cells = vec![name.to_string(), "1.00".into()];
        for parts in [2usize, 3, 4] {
            match measure(w.as_ref(), Variant::MxCx { parts, depth: 1 }, scale, cfg) {
                Ok(m) => cells.push(fx(ff.seconds / m.seconds)),
                Err(_) => cells.push("n/a".into()),
            }
        }
        match measure(w.as_ref(), Variant::M1Cx { consumers: 2, depth: 1 }, scale, cfg) {
            Ok(m) => cells.push(fx(ff.seconds / m.seconds)),
            Err(_) => cells.push("n/a".into()),
        }
        t.row(cells);
    }
    t
}

/// Vector-type case study (paper: FW ~3x further, MIS degrades; their SDK
/// crashed on pipes+vectors — our substrate completes the experiment).
pub fn vector_study(scale: Scale, cfg: &DeviceConfig) -> Table {
    let mut t = Table::new(
        "Vector-type case study (speedup of vec4 feed-forward over feed-forward)",
        &["Benchmark", "ff_v4 vs ff"],
    );
    for name in ["fw", "mis"] {
        let w = by_name(name).unwrap();
        let ff = measure(w.as_ref(), Variant::FeedForward { depth: 1 }, scale, cfg).unwrap();
        match measure(w.as_ref(), Variant::Vectorized { width: 4, depth: 1 }, scale, cfg) {
            Ok(m) => t.row(vec![name.into(), fx(ff.seconds / m.seconds)]),
            Err(e) => t.row(vec![name.into(), format!("n/a ({e})")]),
        };
    }
    t
}

// ---------------------------------------------------------------------------
// E7 — headline numbers
// ---------------------------------------------------------------------------

pub struct Headline {
    pub max_ff_speedup: f64,
    pub avg_ff_speedup_gainers: f64,
    pub max_total_speedup: f64,
}

/// "up to 65x, ~20x average across gainers, up to 86x with M2C2".
pub fn headline(scale: Scale, cfg: &DeviceConfig) -> Headline {
    let rows = table2_rows(scale, cfg);
    let speedups: Vec<(String, f64)> = rows
        .iter()
        .map(|r| (r.base.workload.clone(), r.base.seconds / r.ff.seconds))
        .collect();
    let max_ff = speedups.iter().map(|(_, s)| *s).fold(0.0, f64::max);
    let gainers: Vec<f64> = speedups.iter().map(|(_, s)| *s).filter(|s| *s > 2.0).collect();
    let avg = gainers.iter().sum::<f64>() / gainers.len().max(1) as f64;
    // best total = FF x M2C2 on the biggest gainer
    let best = speedups
        .iter()
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .map(|(n, _)| n.clone())
        .unwrap();
    let w = by_name(&best).unwrap();
    let base = measure(w.as_ref(), Variant::Baseline, scale, cfg).unwrap();
    let total = match measure(w.as_ref(), Variant::MxCx { parts: 2, depth: 1 }, scale, cfg) {
        Ok(m2) => base.seconds / m2.seconds,
        Err(_) => max_ff,
    };
    Headline { max_ff_speedup: max_ff, avg_ff_speedup_gainers: avg, max_total_speedup: total }
}
