//! Durable, content-addressed measurement store (the PR-2 tentpole).
//!
//! PR 1's memoization layer is process-local: every `pipefwd` invocation
//! and every CI run re-simulates the whole grid. This module persists each
//! `(transformed-IR hash, DeviceConfig, ExecOptions) → CellResult` record
//! as one canonical-JSON file under a results directory (default
//! `.pipefwd-cache/`), so shards and successive runs share work:
//!
//! * **One file per entry** — `entries/<16-hex-key>.json`, written with a
//!   temp-file + rename so concurrent writers (shard processes, parallel
//!   engines on one store) never expose torn bytes; the last writer wins
//!   with identical content because measurements are deterministic.
//! * **Corruption tolerance** — a truncated, garbled, or
//!   wrong-schema-version entry is a cache *miss*, never a crash: the
//!   engine just re-simulates and rewrites it.
//! * **Stable keys** — entries outlive the process, so the content address
//!   is FNV-1a over a canonical signature string, not `DefaultHasher`
//!   (whose output is unspecified across Rust releases). The key shape
//!   (see `engine::content_signature`) is
//!   `workload \n scale \n DeviceConfig \n profile/des flags \n
//!   per-launch-unit transformed IR`, hashed to 64 bits — pipe depth and
//!   replication factor are part of the IR text, so every probe of the
//!   PR-3 tuner's depth×replication product space (`coordinator::tune`)
//!   lands under this same key shape, and a warm store replays an entire
//!   search with zero simulations. (PR 3 still bumps [`STORE_SCHEMA`] to
//!   v2: the *record* format changed — error strings gained class
//!   prefixes — not the key.)
//! * **Manifest** — `MANIFEST.json` lists every key in sorted order for
//!   fast external enumeration (CI, tooling). The directory scan remains
//!   the source of truth; the manifest is advisory and rewritten after
//!   each run and merge.
//! * **Trace tier (v3)** — execution traces (the functional interpreter's
//!   per-launch profiles, `workloads::ExecTrace`) persist under
//!   `traces/<16-hex-key>.json` beside the measurement entries, keyed by
//!   the *depth-invariant* `engine::trace_key`. A warm store answers a
//!   whole depth ladder from one trace file; `merge_from` carries traces
//!   across shards like any other entry.
//! * **Per-launch profile pool (v4)** — a trace file no longer inlines
//!   its `KernelProfile`s: each launch records a list of *refs* into a
//!   content-addressed pool, `profiles/<16-hex-fnv>.json`, one canonical
//!   compact file per distinct profile (FNV-1a over
//!   `KernelProfile::canonical_compact`). Convergence-loop workloads
//!   (pagerank/bfs/mis iterations) re-launch byte-identical kernels
//!   dozens of times per trace, and the same profiles recur across
//!   traces, configs and shards — the pool stores each distinct profile
//!   once, globally. A missing, truncated, or hash-mismatched pool file
//!   degrades only the *referencing* trace to a miss (the engine
//!   re-interprets); `merge_from` unions the pool before the traces so a
//!   merged store never holds a dangling ref.
//! * **GC** — [`Store::gc`] deletes every entry/trace whose key is not in
//!   a caller-supplied reachable set (computed by `coordinator::gc` from
//!   the current experiment grids + tuner ladders, exactly like `merge`
//!   replays the grid) and every pooled profile no surviving trace
//!   references, then rewrites the manifest. [`Store::stats`] reports
//!   per-tier counts/bytes and the pool's dedup ratio.
//! * **Byte budget / LRU tier** — [`Store::with_max_bytes`] arms a hard
//!   byte budget (`--max-bytes` / `PIPEFWD_MAX_BYTES`) over the three
//!   governed tiers (entries + traces + profiles; `journal/` intents and
//!   `.tmp-` droppings are bookkeeping, not cache). Reads and writes
//!   refresh a batched, crash-tolerant last-access stamp
//!   (`STAMPS.json`; a lost stamp only *ages* a record, never corrupts
//!   it), and every put/push that lands over budget plans a
//!   coldest-first eviction batch: records under an open engine claim
//!   ([`Store::pin_guard`]) are never evicted, pooled profiles survive
//!   exactly as long as one surviving trace references them, and the
//!   whole batch is a journal intent (`op: "evict"`) healed
//!   idempotently at [`Store::open`] like an interrupted gc. A budget
//!   too tight to hold even the newest record degrades to
//!   write-through-skip (the result is still returned, just not
//!   persisted) counted in `store_budget_skips`, instead of thrashing
//!   the disk; evicted records count in `store_evictions`.

use super::engine::{CellResult, TraceResult};
use super::experiments::Measurement;
use crate::sim::profile::KernelProfile;
use crate::util::json::{self, Json};
use crate::workloads::{ExecTrace, LaunchRecord};
use std::collections::{HashMap, HashSet};
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Store layout/keying version. Bumping this orphans every existing entry
/// (old files parse but fail the schema check and read as misses), which is
/// exactly what a change to the key signature or record format requires.
/// CI keys its shared cache on this string. v2: error records carry a
/// class prefix (`validation: ` / `infeasible: `) that `best_ff` and the
/// PR-3 tuner dispatch on — v1 stores hold unprefixed error strings that
/// would be misclassified as fatal, so they must read as misses. v3: the
/// two-tier measurement pipeline — execution traces persist under
/// `traces/` beside the measurement entries, and the interpreter moved to
/// chunked pipe transfers, which can change results for depth-*sensitive*
/// workloads (NW past its safe depth) — v2 measurement entries must
/// therefore read as misses, not be served beside v3 ones. v4: the
/// per-launch profile pool — trace records hold refs into
/// `profiles/<fnv>.json` instead of inline profiles (a v3 trace never
/// referenced the pool), and the bfs benign-race vouch changes bfs's
/// trace key *and* its interpreter pipe mode (chunked instead of exact).
/// color/pagerank also gained vouches, but their split units already
/// passed the syntactic depth-invariance check, so their keys and pipe
/// mode are unchanged — the record format alone forces the bump. v5: the
/// device zoo — the content signature gained a `device=<name>` line for
/// every profile *except* `arria10` (whose keys are byte-identical to
/// v4's, by the frozen-`Debug` contract in `sim::device`), so the key
/// *space* grew without moving any existing key. Uniquely among bumps,
/// v5 therefore accepted v4 records on read: every v4 record is an
/// `arria10` record by construction and its key, format, and meaning are
/// unchanged. New writes always carry the current version. v6: the
/// launch-graph overlap axis — the content signature can now carry a
/// trailing `overlap=on` line for overlap-keyed measurements
/// (`engine::content_key_with`). Exactly like the v5 device bump, the
/// key space grew without moving any existing key: overlap-off keys are
/// byte-identical to v5's, and trace keys never see the axis at all. v6
/// therefore reads [`STORE_SCHEMA_COMPAT`] (v5) and
/// [`STORE_SCHEMA_COMPAT_V4`] (v4) records as warm hits — both are
/// overlap-off by construction with unchanged format and meaning — while
/// overlap-keyed lookups against an old store simply miss (their keys
/// never existed there).
pub const STORE_SCHEMA: &str = "pipefwd-store-v6";

/// The immediately prior schema version v6 still reads (see the v6 note
/// on [`STORE_SCHEMA`]): v5 records are overlap-off and key-compatible,
/// so orphaning them would force a full pointless re-simulation of every
/// pre-overlap store.
pub const STORE_SCHEMA_COMPAT: &str = "pipefwd-store-v5";

/// The oldest schema version still read (the v5→v4 compat window carried
/// forward: v4 records are `arria10`-only, overlap-off, and
/// key-compatible). Earlier versions (v1–v3) remain misses.
pub const STORE_SCHEMA_COMPAT_V4: &str = "pipefwd-store-v4";

/// Default results directory (overridable via `--cache-dir` /
/// `PIPEFWD_CACHE_DIR`).
pub const DEFAULT_DIR: &str = ".pipefwd-cache";

/// Schema tag of `journal/` intent records (see [`Store::open`]'s
/// healing pass). An intent is written *before* a multi-file operation
/// (`put_trace`, `gc`, `evict`) and removed after it completes, so an
/// intent on disk at open time marks an interrupted operation to roll
/// forward or discard. Single-file writes need no intent — temp-file +
/// rename is already atomic.
pub const JOURNAL_SCHEMA: &str = "pipefwd-journal-v1";

/// Last-access stamp file at the store root (beside `MANIFEST.json`).
/// Purely advisory LRU metadata: a missing or torn stamp file only makes
/// records look *older* (stampless records evict first), so it is loaded
/// leniently and flushed in batches without a journal intent.
pub const STAMPS_FILE: &str = "STAMPS.json";

/// Schema tag of [`STAMPS_FILE`].
pub const STAMPS_SCHEMA: &str = "pipefwd-stamps-v1";

/// Dirty stamp updates buffered before a batched flush. Batching keeps
/// hot read paths from rewriting a file per hit; anything buffered at
/// crash time is lost, which only ages the touched records.
const STAMP_FLUSH_EVERY: u64 = 16;

/// FNV-1a 64-bit: tiny, dependency-free, and — unlike `DefaultHasher` —
/// specified, so persisted keys stay valid across toolchains.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fixed-width file-name form of a key.
pub fn key_hex(key: u64) -> String {
    format!("{key:016x}")
}

/// Parse a byte-budget string: plain bytes, or binary `k`/`m`/`g`
/// suffixes (case-insensitive). Zero is rejected — a zero budget can
/// hold nothing and is always a mistyped flag, not an intent.
pub fn parse_byte_budget(s: &str) -> Result<u64, String> {
    let t = s.trim().to_ascii_lowercase();
    let (digits, mult) = match t.as_bytes().last() {
        Some(b'k') => (&t[..t.len() - 1], 1u64 << 10),
        Some(b'm') => (&t[..t.len() - 1], 1u64 << 20),
        Some(b'g') => (&t[..t.len() - 1], 1u64 << 30),
        _ => (t.as_str(), 1),
    };
    digits
        .trim()
        .parse::<u64>()
        .ok()
        .filter(|n| *n > 0)
        .and_then(|n| n.checked_mul(mult))
        .ok_or_else(|| format!("invalid byte budget {s:?} (want e.g. 65536, 64k, 8m, 1g)"))
}

/// In-memory view of [`STAMPS_FILE`]: a logical access clock (monotonic
/// per store handle, persisted so it survives reopens) and the last
/// clock tick each entry/trace key was read or written at. Wall time is
/// deliberately not used — logical ticks keep eviction order a pure
/// function of the access sequence, so seeded runs evict identically.
#[derive(Default)]
struct Stamps {
    clock: u64,
    entries: HashMap<u64, u64>,
    traces: HashMap<u64, u64>,
    dirty: u64,
}

/// Durable measurement store rooted at one directory.
pub struct Store {
    root: PathBuf,
    /// Read-only fallback: set when the cache directory turns
    /// unwritable (real ENOSPC, vanished mount, permissions). Reads
    /// keep serving warm hits; writes are silently skipped and counted
    /// in `degraded_writes` — the engine keeps computing.
    degraded: AtomicBool,
    degraded_writes: AtomicU64,
    /// Interrupted `put_trace`/`gc`/`evict` operations rolled forward
    /// or discarded by [`Store::open`]'s healing pass.
    journal_replays: AtomicU64,
    /// Byte budget over the governed tiers (entries + traces +
    /// profiles); `None` = unbounded, today's behavior.
    max_bytes: Option<u64>,
    /// Records removed by budget eviction (counters `store_evictions`).
    evictions: AtomicU64,
    /// Writes skipped because even a full eviction pass could not fit
    /// the new record (counters `store_budget_skips`).
    budget_skips: AtomicU64,
    /// Set when the budget proved too tight for the newest record:
    /// subsequent writes short-circuit to write-through-skip until
    /// room for a record of the size that failed (`tight_floor`)
    /// exists again (hysteresis — without it every put would write +
    /// evict-self, thrashing the disk).
    tight: AtomicBool,
    /// Size of the record that could not fit when `tight` latched.
    tight_floor: AtomicU64,
    stamps: std::sync::Mutex<Stamps>,
    /// Keys under an open engine claim, refcounted: eviction never
    /// removes a pinned entry/trace (see [`Store::pin_guard`]).
    pins: std::sync::Mutex<HashMap<u64, usize>>,
}

impl Store {
    fn at(root: PathBuf) -> Store {
        Store {
            root,
            degraded: AtomicBool::new(false),
            degraded_writes: AtomicU64::new(0),
            journal_replays: AtomicU64::new(0),
            max_bytes: None,
            evictions: AtomicU64::new(0),
            budget_skips: AtomicU64::new(0),
            tight: AtomicBool::new(false),
            tight_floor: AtomicU64::new(0),
            stamps: std::sync::Mutex::new(Stamps::default()),
            pins: std::sync::Mutex::new(HashMap::new()),
        }
    }

    /// Open (creating if needed) a store rooted at `root`, then heal:
    /// stale temp droppings from crashed writers are swept and every
    /// `journal/` intent left by an interrupted multi-file operation is
    /// rolled forward or discarded (see [`Store::heal`]).
    pub fn open(root: impl Into<PathBuf>) -> io::Result<Store> {
        let root = root.into();
        std::fs::create_dir_all(root.join("entries"))?;
        std::fs::create_dir_all(root.join("traces"))?;
        std::fs::create_dir_all(root.join("profiles"))?;
        std::fs::create_dir_all(root.join("journal"))?;
        let store = Store::at(root);
        let replays = store.heal();
        store.journal_replays.store(replays, Ordering::Relaxed);
        store.load_stamps();
        Ok(store)
    }

    /// Open an existing store, erroring if `root` is not one — the
    /// read side (`merge <dir>...`, `store gc`, `store stats`), where
    /// silently fabricating an empty store would turn a typo or a missing
    /// CI artifact into a misleading "shard incomplete" failure later.
    /// Deliberately creates nothing (a source store may live on a
    /// read-only mount, and `store gc --dry-run` promises to touch
    /// nothing): every read path tolerates absent subdirectories, and
    /// write destinations go through [`Store::open`], which creates them.
    pub fn open_existing(root: impl Into<PathBuf>) -> io::Result<Store> {
        let root = root.into();
        if !root.join("entries").is_dir() {
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("{} is not a measurement store (no entries/ directory)", root.display()),
            ));
        }
        Ok(Store::at(root))
    }

    /// The store directory configured for this process: `--cache-dir` wins,
    /// then `PIPEFWD_CACHE_DIR`, then [`DEFAULT_DIR`].
    pub fn resolve_dir(flag: Option<&str>) -> PathBuf {
        match flag {
            Some(d) => PathBuf::from(d),
            None => std::env::var("PIPEFWD_CACHE_DIR")
                .map(PathBuf::from)
                .unwrap_or_else(|_| PathBuf::from(DEFAULT_DIR)),
        }
    }

    /// The store byte budget configured for this process: `--max-bytes`
    /// wins, then `PIPEFWD_MAX_BYTES`, then unbounded. Accepts plain
    /// bytes or a `k`/`m`/`g` suffix (binary units); zero and garbage
    /// are errors, not silent unboundedness.
    pub fn resolve_max_bytes(flag: Option<&str>) -> Result<Option<u64>, String> {
        let src = match flag {
            Some(s) => Some(s.to_string()),
            None => std::env::var("PIPEFWD_MAX_BYTES").ok(),
        };
        match src {
            None => Ok(None),
            Some(s) => parse_byte_budget(&s).map(Some),
        }
    }

    /// Arm (or disarm, with `None`) the byte budget, then run one
    /// enforcement pass so a store opened over budget starts within it.
    /// Builder-style: call between [`Store::open`] and first use.
    pub fn with_max_bytes(mut self, max: Option<u64>) -> Store {
        self.max_bytes = max;
        if max.is_some() {
            if let Err(e) = self.enforce_budget(None) {
                eprintln!("store: initial budget enforcement failed: {e} (healed at next open)");
            }
        }
        self
    }

    /// The armed byte budget, if any.
    pub fn max_bytes(&self) -> Option<u64> {
        self.max_bytes
    }

    /// Records removed by budget eviction so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Writes skipped by the over-tight-budget degraded mode so far.
    pub fn budget_skips(&self) -> u64 {
        self.budget_skips.load(Ordering::Relaxed)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn entry_path(&self, key: u64) -> PathBuf {
        self.root.join("entries").join(format!("{}.json", key_hex(key)))
    }

    fn trace_path(&self, key: u64) -> PathBuf {
        self.root.join("traces").join(format!("{}.json", key_hex(key)))
    }

    fn profile_path(&self, fnv: u64) -> PathBuf {
        self.root.join("profiles").join(format!("{}.json", key_hex(fnv)))
    }

    fn journal_dir(&self) -> PathBuf {
        self.root.join("journal")
    }

    fn journal_path(&self, op: &str, key: u64) -> PathBuf {
        self.journal_dir().join(format!("{op}-{}.json", key_hex(key)))
    }

    /// Intents currently on disk (0 after every cleanly completed
    /// operation — the chaos-smoke CI gate asserts exactly this).
    pub fn journal_len(&self) -> usize {
        match std::fs::read_dir(self.journal_dir()) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
                .count(),
            Err(_) => 0,
        }
    }

    /// Intents healed by [`Store::open`] (counters-v3 `journal_replays`).
    pub fn journal_replays(&self) -> u64 {
        self.journal_replays.load(Ordering::Relaxed)
    }

    /// Is the store in read-only degraded mode?
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Writes skipped or lost to an unwritable cache directory
    /// (counters-v3 `store_degraded`). Nonzero means warm reruns will
    /// recompute whatever failed to persist — results are unaffected.
    pub fn degraded_count(&self) -> u64 {
        self.degraded_writes.load(Ordering::Relaxed)
    }

    /// After a write failure, decide whether the directory itself has
    /// turned unwritable (degrade) or the failure was one bad write
    /// (stay up — healing and reruns cover it). The probe bypasses
    /// `util::json`, so injected `store.write` faults never degrade.
    fn note_write_failure(&self, failed: &Path) {
        if self.is_degraded() {
            return;
        }
        let dir = failed.parent().unwrap_or(&self.root);
        let probe = dir.join(format!(".probe-{}", std::process::id()));
        let writable = std::fs::write(&probe, b"probe").is_ok();
        let _ = std::fs::remove_file(&probe);
        if !writable {
            self.degraded.store(true, Ordering::Relaxed);
            eprintln!(
                "store: {} is unwritable — degrading to read-only (results unaffected; \
                 further writes are skipped and counted)",
                dir.display()
            );
        }
    }

    /// Count a write suppressed by degraded mode. Returns `true` when
    /// degraded (caller skips the write and reports success — the
    /// engine keeps computing).
    fn skip_if_degraded(&self) -> bool {
        if self.is_degraded() {
            self.degraded_writes.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Count a write suppressed by the over-tight-budget mode. Returns
    /// `true` when the budget has proved too small for even one fresh
    /// record and room for a record of that size (`tight_floor`) still
    /// does not exist — the hysteresis that turns per-put thrash into
    /// one cheap probe per put. An external shrink — gc, manual
    /// deletion — that frees enough room is noticed here and re-enables
    /// writes.
    fn skip_if_budget_tight(&self) -> bool {
        let Some(max) = self.max_bytes else { return false };
        if !self.tight.load(Ordering::Relaxed) {
            return false;
        }
        let floor = self.tight_floor.load(Ordering::Relaxed);
        if self.governed_bytes().saturating_add(floor) <= max {
            self.tight.store(false, Ordering::Relaxed);
            return false;
        }
        self.budget_skips.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Pin `key` (both tiers — entry and trace keys share the space but
    /// never collide in practice) against budget eviction. Refcounted:
    /// concurrent claims on the same key stack.
    pub fn pin(&self, key: u64) {
        *self.pins.lock().unwrap().entry(key).or_insert(0) += 1;
    }

    /// Release one pin on `key`.
    pub fn unpin(&self, key: u64) {
        let mut pins = self.pins.lock().unwrap();
        if let Some(n) = pins.get_mut(&key) {
            *n -= 1;
            if *n == 0 {
                pins.remove(&key);
            }
        }
    }

    /// RAII pin: the engine holds one over a key for the whole span of
    /// an open claim (compute + persist), so eviction can never delete
    /// the record a worker is about to write or has just written but
    /// not yet fulfilled. Unpins on drop, including unwind — a worker
    /// panicking under claim releases its pin like it abandons its
    /// claim.
    pub fn pin_guard(&self, key: u64) -> PinGuard<'_> {
        self.pin(key);
        PinGuard { store: self, key }
    }

    fn is_pinned(&self, key: u64) -> bool {
        self.pins.lock().unwrap().contains_key(&key)
    }

    /// Record an access to an entry (`b'e'`) or trace (`b't'`) key.
    /// No-op without a budget — an unbudgeted store stays byte-for-byte
    /// identical on disk to every prior release. Flushes are batched
    /// ([`STAMP_FLUSH_EVERY`]) and failures ignored: stamps are
    /// advisory (see [`STAMPS_FILE`]).
    fn touch(&self, tier: u8, key: u64) {
        if self.max_bytes.is_none() || self.is_degraded() {
            return;
        }
        let mut st = self.stamps.lock().unwrap();
        st.clock += 1;
        let now = st.clock;
        match tier {
            b'e' => st.entries.insert(key, now),
            _ => st.traces.insert(key, now),
        };
        st.dirty += 1;
        if st.dirty >= STAMP_FLUSH_EVERY {
            self.flush_stamps_locked(&mut st);
        }
    }

    /// Write the stamp file (best-effort, no intent — see
    /// [`STAMPS_FILE`]). Caller holds the stamps lock.
    fn flush_stamps_locked(&self, st: &mut Stamps) {
        st.dirty = 0;
        let map = |m: &HashMap<u64, u64>| {
            let mut pairs: Vec<(u64, u64)> = m.iter().map(|(k, v)| (*k, *v)).collect();
            pairs.sort_unstable();
            Json::Obj(
                pairs.into_iter().map(|(k, v)| (key_hex(k), Json::Num(v as f64))).collect(),
            )
        };
        let doc = Json::obj(vec![
            ("schema", Json::Str(STAMPS_SCHEMA.into())),
            ("clock", Json::Num(st.clock as f64)),
            ("entries", map(&st.entries)),
            ("traces", map(&st.traces)),
        ]);
        let _ = json::write_file_atomic_compact(&self.root.join(STAMPS_FILE), &doc);
    }

    /// Load [`STAMPS_FILE`] leniently: a missing, torn, or
    /// foreign-schema file reads as "no stamps" (everything equally
    /// cold) — never an error.
    fn load_stamps(&self) {
        let Ok(doc) = json::read_file(&self.root.join(STAMPS_FILE)) else { return };
        if doc.get("schema").and_then(Json::as_str) != Some(STAMPS_SCHEMA) {
            return;
        }
        let read_map = |field: &str| -> HashMap<u64, u64> {
            let mut out = HashMap::new();
            if let Some(Json::Obj(pairs)) = doc.get(field) {
                for (hex, v) in pairs {
                    if let (Ok(k), Some(n)) = (u64::from_str_radix(hex, 16), v.as_u64()) {
                        out.insert(k, n);
                    }
                }
            }
            out
        };
        let mut st = self.stamps.lock().unwrap();
        st.clock = doc.get("clock").and_then(Json::as_u64).unwrap_or(0);
        st.entries = read_map("entries");
        st.traces = read_map("traces");
    }

    /// Bytes currently under budget governance: the entries, traces,
    /// and profiles tiers. `journal/` intents, `.tmp-` droppings,
    /// `MANIFEST.json`, and [`STAMPS_FILE`] are bookkeeping, not cache,
    /// and are deliberately outside the governed total (and outside
    /// eviction's reach). Fresh directory scan — the same source of
    /// truth [`Store::stats`] uses.
    pub fn governed_bytes(&self) -> u64 {
        let mut total = 0u64;
        for dir in ["entries", "traces", "profiles"] {
            if let Ok(rd) = std::fs::read_dir(self.root.join(dir)) {
                for e in rd.filter_map(|e| e.ok()) {
                    if e.path().extension().is_some_and(|x| x == "json") {
                        total += e.metadata().map(|m| m.len()).unwrap_or(0);
                    }
                }
            }
        }
        total
    }

    /// Bring governed bytes back under the budget, evicting
    /// coldest-first. `protect` names the record whose write triggered
    /// this pass — it is evicted only as a last resort (see below).
    ///
    /// The plan walks entry + trace candidates ordered by (stamp, tier,
    /// key) — stampless records first, then logical access order; the
    /// trailing key makes the order total and deterministic. Pinned
    /// keys (open engine claims) and the protected key are skipped.
    /// Evicting a trace frees the pooled profiles only *surviving*
    /// traces no longer reference, exactly like gc. The whole batch —
    /// deletes + freed profiles — is one `evict` journal intent written
    /// before the first delete, so a crash (or an injected
    /// `store.evict` fault) anywhere in the sequence is healed
    /// idempotently at the next open.
    ///
    /// If evicting every eligible candidate still cannot fit the
    /// protected record, the budget is simply too small for the
    /// workload's newest record: the protected record itself is
    /// evicted, `store_budget_skips` counts it, and the `tight` latch
    /// flips writes to write-through-skip until pressure halves — the
    /// invariant `governed_bytes ≤ max_bytes` holds either way.
    fn enforce_budget(&self, protect: Option<(u8, u64)>) -> io::Result<()> {
        let Some(max) = self.max_bytes else { return Ok(()) };
        // cheap size-only scan first: the common under-budget put must
        // not pay the trace-document ref walk below
        if self.governed_bytes() <= max {
            return Ok(());
        }
        let fsize = |p: &Path| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
        // Snapshot the governed tiers: sizes, live pool refcounts.
        let entry_keys = self.keys();
        let trace_keys = self.trace_keys();
        let mut trace_refs: HashMap<u64, Vec<u64>> = HashMap::new();
        let mut ref_count: HashMap<u64, usize> = HashMap::new();
        for &k in &trace_keys {
            let refs: Vec<u64> = self
                .trace_profile_refs(k)
                .unwrap_or_default()
                .into_iter()
                .collect::<HashSet<u64>>() // distinct per trace
                .into_iter()
                .collect();
            for &f in &refs {
                *ref_count.entry(f).or_insert(0) += 1;
            }
            trace_refs.insert(k, refs);
        }
        let profile_size: HashMap<u64, u64> =
            self.profile_keys().into_iter().map(|f| (f, fsize(&self.profile_path(f)))).collect();
        let mut bytes = entry_keys.iter().map(|&k| fsize(&self.entry_path(k))).sum::<u64>()
            + trace_keys.iter().map(|&k| fsize(&self.trace_path(k))).sum::<u64>()
            + profile_size.values().sum::<u64>();
        if bytes <= max {
            return Ok(());
        }
        // Coldest-first candidate order. Missing stamp = 0 = coldest.
        let (stamp_e, stamp_t) = {
            let st = self.stamps.lock().unwrap();
            (st.entries.clone(), st.traces.clone())
        };
        let mut cands: Vec<(u64, u8, u64)> = vec![]; // (stamp, tier, key)
        for &k in &entry_keys {
            if !self.is_pinned(k) && protect != Some((b'e', k)) {
                cands.push((stamp_e.get(&k).copied().unwrap_or(0), b'e', k));
            }
        }
        for &k in &trace_keys {
            if !self.is_pinned(k) && protect != Some((b't', k)) {
                cands.push((stamp_t.get(&k).copied().unwrap_or(0), b't', k));
            }
        }
        cands.sort_unstable();
        let mut doomed: Vec<PathBuf> = vec![];
        let mut doomed_keys: Vec<(u8, u64)> = vec![];
        // evict a trace → drop its refs → profiles at refcount 0 die too
        let mut free_profiles = |refs: &[u64], doomed: &mut Vec<PathBuf>, bytes: &mut u64| {
            for f in refs {
                let n = ref_count.entry(*f).or_insert(0);
                if *n > 0 {
                    *n -= 1;
                    if *n == 0 {
                        *bytes = bytes.saturating_sub(profile_size.get(f).copied().unwrap_or(0));
                        doomed.push(self.profile_path(*f));
                    }
                }
            }
        };
        for (_, tier, key) in cands {
            if bytes <= max {
                break;
            }
            match tier {
                b'e' => {
                    bytes = bytes.saturating_sub(fsize(&self.entry_path(key)));
                    doomed.push(self.entry_path(key));
                }
                _ => {
                    bytes = bytes.saturating_sub(fsize(&self.trace_path(key)));
                    doomed.push(self.trace_path(key));
                    if let Some(refs) = trace_refs.get(&key) {
                        free_profiles(refs, &mut doomed, &mut bytes);
                    }
                }
            }
            doomed_keys.push((tier, key));
        }
        let mut skipped_protect = false;
        if bytes > max {
            // Every eligible record is gone and we are still over: the
            // newest record itself cannot fit. Take it too (unless it
            // is only pinned bulk keeping us over, in which case there
            // is nothing legal left to delete). Its size becomes the
            // `tight` floor: writes stay skipped until that much room
            // exists, so an over-tight budget costs one probe per put,
            // not a write + self-evict churn.
            if let Some((tier, key)) = protect {
                skipped_protect = true;
                let path = match tier {
                    b'e' => self.entry_path(key),
                    _ => self.trace_path(key),
                };
                self.tight_floor.store(fsize(&path), Ordering::Relaxed);
                doomed.push(path);
                if tier == b't' {
                    if let Some(refs) = trace_refs.get(&key) {
                        free_profiles(refs, &mut doomed, &mut bytes);
                    }
                }
                doomed_keys.push((tier, key));
            }
        }
        if doomed.is_empty() {
            return Ok(());
        }
        // Journaled batch, gc-style: intent first, idempotent deletes,
        // manifest, intent removal. An injected `store.evict` fault (or
        // a crash) leaves the intent for the next open's healing pass.
        let batch = fnv1a64(
            doomed.iter().map(|p| p.to_string_lossy()).collect::<Vec<_>>().join("\n").as_bytes(),
        );
        let intent = self.write_intent("evict", batch, &doomed)?;
        for path in &doomed {
            crate::util::fault::maybe_io_error("store.evict")?;
            match std::fs::remove_file(path) {
                Ok(()) => {}
                Err(e) if e.kind() == io::ErrorKind::NotFound => {}
                Err(e) => return Err(e),
            }
        }
        self.write_manifest()?;
        let _ = std::fs::remove_file(intent);
        let evicted = doomed_keys.len() as u64 - u64::from(skipped_protect);
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        if skipped_protect {
            self.budget_skips.fetch_add(1, Ordering::Relaxed);
        }
        self.tight.store(skipped_protect, Ordering::Relaxed);
        {
            // drop stamps for dead keys and persist the survivors, so a
            // reopened store does not order live records by ghosts
            let mut st = self.stamps.lock().unwrap();
            for (tier, key) in &doomed_keys {
                match tier {
                    b'e' => st.entries.remove(key),
                    _ => st.traces.remove(key),
                };
            }
            self.flush_stamps_locked(&mut st);
        }
        Ok(())
    }

    /// Write a `journal/` intent naming every file the operation will
    /// touch (paths relative to the store root), before touching any.
    fn write_intent(&self, op: &str, key: u64, files: &[PathBuf]) -> io::Result<PathBuf> {
        std::fs::create_dir_all(self.journal_dir())?;
        let rels: Vec<Json> = files
            .iter()
            .map(|p| {
                let rel = p.strip_prefix(&self.root).unwrap_or(p);
                Json::Str(rel.to_string_lossy().replace('\\', "/"))
            })
            .collect();
        let doc = Json::obj(vec![
            ("schema", Json::Str(JOURNAL_SCHEMA.into())),
            ("op", Json::Str(op.into())),
            ("key", Json::Str(key_hex(key))),
            ("files", Json::Arr(rels)),
        ]);
        let path = self.journal_path(op, key);
        json::write_file_atomic_compact(&path, &doc)?;
        Ok(path)
    }

    /// Crash-consistency healing, run by [`Store::open`]: sweep stale
    /// `.tmp-` droppings (a torn write never renamed over its
    /// destination), then resolve every pending intent —
    ///
    /// * `put_trace`: if the trace document resolves (doc + every pool
    ///   ref valid) the operation in fact completed — roll forward by
    ///   dropping the intent. Otherwise discard: remove the partial
    ///   trace document (orphaned-but-valid pool files are harmless —
    ///   content-addressed, reclaimed by the next `gc`).
    /// * `gc` / `evict`: deletion is idempotent — roll forward by
    ///   re-deleting every listed file and rewriting the manifest. An
    ///   eviction batch lists every freed pool file alongside its
    ///   traces, so replaying it can never leave a dangling pool ref.
    ///
    /// Unreadable intents are themselves crash debris and are dropped.
    /// Returns the number of intents resolved.
    fn heal(&self) -> u64 {
        for dir in ["entries", "traces", "profiles", "journal"] {
            if let Ok(rd) = std::fs::read_dir(self.root.join(dir)) {
                for e in rd.filter_map(|e| e.ok()) {
                    if e.file_name().to_string_lossy().contains(".tmp-") {
                        let _ = std::fs::remove_file(e.path());
                    }
                }
            }
        }
        let mut replays = 0u64;
        let Ok(rd) = std::fs::read_dir(self.journal_dir()) else { return 0 };
        let mut memo = HashMap::new();
        for e in rd.filter_map(|e| e.ok()) {
            let path = e.path();
            if !path.extension().is_some_and(|x| x == "json") {
                continue;
            }
            replays += 1;
            if let Ok(doc) = json::read_file(&path) {
                self.replay_intent(&doc, &mut memo);
            }
            let _ = std::fs::remove_file(&path);
        }
        replays
    }

    fn replay_intent(&self, doc: &Json, memo: &mut HashMap<u64, KernelProfile>) {
        let valid = doc.get("schema").and_then(Json::as_str) == Some(JOURNAL_SCHEMA);
        let op = doc.get("op").and_then(Json::as_str).unwrap_or("");
        let key = doc
            .get("key")
            .and_then(Json::as_str)
            .and_then(|h| u64::from_str_radix(h, 16).ok());
        match (valid, op, key) {
            (true, "put_trace", Some(key)) => {
                let tpath = self.trace_path(key);
                let complete = json::read_file(&tpath)
                    .is_ok_and(|tdoc| self.trace_resolves(&tdoc, key, memo));
                if !complete {
                    let _ = std::fs::remove_file(&tpath);
                    eprintln!(
                        "store: discarded interrupted trace write {} (will re-interpret)",
                        key_hex(key)
                    );
                }
            }
            (true, op @ ("gc" | "evict"), _) => {
                if let Some(files) = doc.get("files").and_then(Json::as_array) {
                    for f in files.iter().filter_map(Json::as_str) {
                        let _ = std::fs::remove_file(self.root.join(f));
                    }
                }
                let _ = self.write_manifest();
                eprintln!("store: rolled forward an interrupted {op}");
            }
            _ => {} // unreadable/foreign intent: dropped by the caller
        }
    }

    /// Look an entry up. Any defect — missing file, truncated or garbled
    /// JSON, schema-version mismatch, key mismatch, malformed record — is a
    /// miss, not an error: the caller re-simulates and overwrites.
    pub fn get(&self, key: u64) -> Option<CellResult> {
        let doc = json::read_file(&self.entry_path(key)).ok()?;
        let r = decode_entry(&doc, key)?;
        self.touch(b'e', key);
        Some(r)
    }

    /// Persist an entry (atomic temp-file + rename; see `util::json`).
    /// `des` records which estimator produced the measurement — advisory
    /// metadata for filtered rendering; the content key already separates
    /// DES from analytic entries.
    pub fn put(&self, key: u64, result: &CellResult, des: bool) -> io::Result<()> {
        if self.skip_if_degraded() || self.skip_if_budget_tight() {
            return Ok(());
        }
        let path = self.entry_path(key);
        json::write_file_atomic(&path, &encode_entry(key, result, des))
            .inspect_err(|_| self.note_write_failure(&path))?;
        self.touch(b'e', key);
        // The record is durable; a failed eviction pass (injected
        // `store.evict` fault, crash) leaves its intent for the next
        // open to heal, so it must not fail the put.
        if let Err(e) = self.enforce_budget(Some((b'e', key))) {
            eprintln!("store: budget enforcement failed: {e} (healed at next open)");
        }
        Ok(())
    }

    /// Look a trace up (the measurement pipeline's first tier). Same
    /// corruption contract as [`Store::get`]: any defect — in the trace
    /// document itself *or* in any pooled profile it references (missing
    /// file, truncated JSON, content that no longer hashes to its own
    /// name) — is a miss, never a panic: the engine re-runs the
    /// interpreter and rewrites both the trace and its pool files. A bad
    /// pool file only fails the traces that reference it; every other
    /// trace resolves independently.
    pub fn get_trace(&self, key: u64) -> Option<TraceResult> {
        let doc = json::read_file(&self.trace_path(key)).ok()?;
        let r = self.decode_trace_doc(&doc, key)?;
        self.touch(b't', key);
        Some(r)
    }

    /// Persist a trace-tier entry (atomic temp-file + rename;
    /// [`Store::open`] created `traces/` and `profiles/`). The launch
    /// profiles go to the content-addressed pool first — each distinct
    /// `KernelProfile` is written once, under the FNV-1a of its canonical
    /// compact bytes — and the trace document records only the refs, so a
    /// reader never sees a trace whose pool files are not yet on disk.
    /// Convergence-loop workloads whose launches repeat byte-identically
    /// across iterations (pagerank/bfs/mis) collapse to a handful of pool
    /// files regardless of launch count.
    pub fn put_trace(&self, key: u64, result: &TraceResult) -> io::Result<()> {
        if self.skip_if_degraded() || self.skip_if_budget_tight() {
            return Ok(());
        }
        // Serialize everything first (pure), so the journal intent can
        // name every file *before* any of them is touched.
        let (doc, pool) = match result {
            Ok(trace) => {
                // one pool write per *distinct* profile in this trace —
                // convergence loops repeat launches byte-identically, so
                // `written` collapses dozens of refs to one file. The
                // write is unconditional (not guarded on `exists`) so
                // persisting a freshly re-acquired trace also heals a
                // garbled pool file under the same key; concurrent
                // writers land identical canonical bytes via the atomic
                // rename.
                let mut written: HashSet<u64> = HashSet::new();
                let mut pool: Vec<(u64, String)> = vec![];
                let mut launches = vec![];
                for rec in &trace.launches {
                    let mut refs = vec![];
                    for prof in &rec.profiles {
                        let text = prof.canonical_compact();
                        let fnv = fnv1a64(text.as_bytes());
                        if written.insert(fnv) {
                            pool.push((fnv, text));
                        }
                        refs.push(Json::Str(key_hex(fnv)));
                    }
                    launches.push(Json::Obj(vec![
                        ("unit".into(), Json::Str(rec.unit.clone())),
                        ("kernels".into(), Json::Arr(refs)),
                    ]));
                }
                (encode_trace_doc(key, Ok(Json::Arr(launches))), pool)
            }
            Err(e) => (encode_trace_doc(key, Err(e)), vec![]),
        };
        // Multi-file sequence under a journal intent: if any write (or
        // the process) dies mid-way, `Store::open`'s healing pass rolls
        // the operation forward or discards the partial trace.
        let mut files: Vec<PathBuf> = pool.iter().map(|(fnv, _)| self.profile_path(*fnv)).collect();
        files.push(self.trace_path(key));
        let intent = self.write_intent("put_trace", key, &files)?;
        let write_all = || -> io::Result<()> {
            for (fnv, text) in &pool {
                let path = self.profile_path(*fnv);
                json::write_text_atomic(&path, text)
                    .inspect_err(|_| self.note_write_failure(&path))?;
            }
            let tpath = self.trace_path(key);
            json::write_file_atomic_compact(&tpath, &doc)
                .inspect_err(|_| self.note_write_failure(&tpath))
        };
        // the intent stays on disk when a write fails — the next open
        // heals the partial state exactly like a crash
        write_all()?;
        let _ = std::fs::remove_file(intent);
        self.touch(b't', key);
        if let Err(e) = self.enforce_budget(Some((b't', key))) {
            eprintln!("store: budget enforcement failed: {e} (healed at next open)");
        }
        Ok(())
    }

    /// Resolve one pooled profile. `memo` collapses repeated refs within
    /// one trace resolution (a convergence trace references the same
    /// profile dozens of times). Any defect — unreadable file, malformed
    /// JSON, or content whose canonical bytes no longer hash to `fnv` —
    /// is `None`: the caller degrades the referencing trace to a miss.
    fn pool_get(&self, fnv: u64, memo: &mut HashMap<u64, KernelProfile>) -> Option<KernelProfile> {
        if let Some(p) = memo.get(&fnv) {
            return Some(p.clone());
        }
        let doc = json::read_file(&self.profile_path(fnv)).ok()?;
        let prof = KernelProfile::from_json(&doc)?;
        if fnv1a64(prof.canonical_compact().as_bytes()) != fnv {
            return None; // content/name mismatch: corrupt or misfiled
        }
        memo.insert(fnv, prof.clone());
        Some(prof)
    }

    fn decode_trace_doc(&self, doc: &Json, key: u64) -> Option<TraceResult> {
        check_trace_header(doc, key)?;
        match doc.get("status")?.as_str()? {
            "err" => Some(Err(doc.get("error")?.as_str()?.to_string())),
            "ok" => {
                let mut memo = HashMap::new();
                let mut launches = vec![];
                for rec in doc.get("launches")?.as_array()? {
                    let unit = rec.get("unit")?.as_str()?.to_string();
                    let mut profiles = vec![];
                    for r in rec.get("kernels")?.as_array()? {
                        let fnv = u64::from_str_radix(r.as_str()?, 16).ok()?;
                        profiles.push(self.pool_get(fnv, &mut memo)?);
                    }
                    launches.push(LaunchRecord { unit, profiles });
                }
                Some(Ok(ExecTrace { launches }))
            }
            _ => None,
        }
    }

    /// The pool refs a trace document records, without resolving them —
    /// what GC and `store stats` walk. `None` if the document itself is
    /// missing/corrupt/stale (its refs then hold nothing live); an error
    /// trace yields an empty list.
    pub fn trace_profile_refs(&self, key: u64) -> Option<Vec<u64>> {
        let doc = json::read_file(&self.trace_path(key)).ok()?;
        trace_doc_refs(&doc, key)
    }

    /// Every key present on disk (directory scan — the source of truth).
    pub fn keys(&self) -> Vec<u64> {
        Self::scan_keys(self.root.join("entries"))
    }

    /// Every trace-tier key present on disk.
    pub fn trace_keys(&self) -> Vec<u64> {
        Self::scan_keys(self.root.join("traces"))
    }

    /// Every pooled-profile key present on disk.
    pub fn profile_keys(&self) -> Vec<u64> {
        Self::scan_keys(self.root.join("profiles"))
    }

    fn scan_keys(dir: PathBuf) -> Vec<u64> {
        let mut keys: Vec<u64> = match std::fs::read_dir(dir) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter_map(|e| {
                    let name = e.file_name().to_string_lossy().to_string();
                    let hex = name.strip_suffix(".json")?;
                    u64::from_str_radix(hex, 16).ok()
                })
                .collect(),
            Err(_) => vec![],
        };
        keys.sort_unstable();
        keys
    }

    pub fn len(&self) -> usize {
        self.keys().len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys().is_empty()
    }

    /// Every *valid* entry on disk (corrupt files are skipped).
    pub fn entries(&self) -> Vec<(u64, CellResult)> {
        self.keys().into_iter().filter_map(|k| self.get(k).map(|r| (k, r))).collect()
    }

    /// Every successful measurement, in the canonical (workload, variant,
    /// scale) order the results sink uses.
    pub fn measurements(&self) -> Vec<Measurement> {
        let mut ms: Vec<Measurement> =
            self.entries().into_iter().filter_map(|(_, r)| r.ok()).collect();
        super::experiments::canonical_sort(&mut ms);
        ms
    }

    /// [`Store::measurements`] restricted to one dataset scale and one
    /// estimator — a store accumulates entries across scales and `--des`
    /// runs, and mixing them in one rendering would show duplicate
    /// configurations with divergent times.
    pub fn measurements_filtered(&self, scale: &str, des: bool) -> Vec<Measurement> {
        let mut ms: Vec<Measurement> = self
            .keys()
            .into_iter()
            .filter_map(|key| {
                let doc = json::read_file(&self.entry_path(key)).ok()?;
                if doc.get("des")?.as_bool()? != des {
                    return None;
                }
                match decode_entry(&doc, key)? {
                    Ok(m) if m.scale == scale => Some(m),
                    _ => None,
                }
            })
            .collect();
        super::experiments::canonical_sort(&mut ms);
        ms
    }

    /// Copy every record of `other` that this store lacks (raw document
    /// copy, preserving all metadata) — measurement entries, traces, and
    /// the profile pool, which is unioned *first* so an imported trace
    /// never references a profile that has not landed yet. Returns how
    /// many records (across all three tiers) were imported. Corrupt
    /// source records are skipped; a corrupt local record is replaced by
    /// a valid imported one.
    pub fn merge_from(&self, other: &Store) -> io::Result<usize> {
        let mut imported = 0;
        // profile pool first: content-addressed, so "missing locally" is
        // the only question — identical keys are identical bytes. Each
        // source file is read once, validated (parse + re-hash to its own
        // name), and its canonical bytes rewritten locally; `local_pool`
        // memoizes validated profiles so the trace validation below never
        // re-parses a pool file.
        let mut local_pool: HashMap<u64, KernelProfile> = HashMap::new();
        for fnv in other.profile_keys() {
            if self.pool_get(fnv, &mut local_pool).is_some() {
                continue;
            }
            let Ok(doc) = json::read_file(&other.profile_path(fnv)) else { continue };
            let Some(prof) = KernelProfile::from_json(&doc) else { continue };
            let canonical = prof.canonical_compact();
            if fnv1a64(canonical.as_bytes()) != fnv {
                continue; // corrupt in the source: skip, don't propagate
            }
            // write the *canonical* bytes, not a copy of the source doc:
            // a hash-valid but non-canonical source file must not break
            // the one-canonical-file-per-profile invariant downstream
            json::write_text_atomic(&self.profile_path(fnv), &canonical)?;
            local_pool.insert(fnv, prof);
            imported += 1;
        }
        // one trace validation for both sides: structurally sound and
        // every ref resolves in the (just-unioned) local pool — all pool
        // reads go through `local_pool`, so shared profiles parse once
        // across the whole merge, not once per referencing trace
        for key in other.trace_keys() {
            if let Ok(local) = json::read_file(&self.trace_path(key)) {
                if self.trace_resolves(&local, key, &mut local_pool) {
                    continue; // present and valid locally: keep ours
                }
            }
            let Ok(doc) = json::read_file(&other.trace_path(key)) else { continue };
            // a ref whose profile was corrupt at the source was not
            // imported above, so its trace is skipped exactly as if it
            // failed to resolve there
            if !self.trace_resolves(&doc, key, &mut local_pool) {
                continue;
            }
            json::write_file_atomic_compact(&self.trace_path(key), &doc)?;
            imported += 1;
        }
        for key in other.keys() {
            if self.get(key).is_some() {
                continue;
            }
            let Ok(doc) = json::read_file(&other.entry_path(key)) else { continue };
            if decode_entry(&doc, key).is_none() {
                continue;
            }
            json::write_file_atomic(&self.entry_path(key), &doc)?;
            imported += 1;
        }
        Ok(imported)
    }

    /// Rewrite `MANIFEST.json`: schema + sorted key list.
    pub fn write_manifest(&self) -> io::Result<PathBuf> {
        let doc = Json::Obj(vec![
            ("schema".into(), Json::Str(STORE_SCHEMA.into())),
            (
                "keys".into(),
                Json::Arr(self.keys().into_iter().map(|k| Json::Str(key_hex(k))).collect()),
            ),
        ]);
        let path = self.root.join("MANIFEST.json");
        json::write_file_atomic(&path, &doc)?;
        Ok(path)
    }

    /// The manifest's key list, if present and valid for this schema.
    /// Advisory: may lag the directory (e.g. after a crashed run).
    pub fn load_manifest(&self) -> Option<Vec<u64>> {
        let doc = json::read_file(&self.root.join("MANIFEST.json")).ok()?;
        if doc.get("schema")?.as_str()? != STORE_SCHEMA {
            return None;
        }
        doc.get("keys")?
            .as_array()?
            .iter()
            .map(|k| u64::from_str_radix(k.as_str()?, 16).ok())
            .collect()
    }

    /// Garbage-collect the store against a reachable-key set (computed by
    /// `coordinator::gc::reachable_keys` from the current experiment
    /// grids and tuner ladders — the same replay `merge` performs):
    ///
    /// 1. measurement entries whose key is unreachable are deleted;
    /// 2. traces whose key is unreachable are deleted;
    /// 3. pooled profiles referenced by **no surviving trace** are
    ///    deleted — a reachable-but-corrupt trace document contributes no
    ///    refs (it already reads as a miss and will be rewritten by the
    ///    next run);
    /// 4. `MANIFEST.json` is rewritten.
    ///
    /// With `dry_run` the same report is computed and *nothing* is
    /// touched — not even the manifest.
    pub fn gc(
        &self,
        reachable_entries: &HashSet<u64>,
        reachable_traces: &HashSet<u64>,
        dry_run: bool,
    ) -> io::Result<GcReport> {
        let mut report = GcReport { dry_run, ..GcReport::default() };
        // plan the full removal set first, deleting nothing: the journal
        // intent below must name every doomed file before any dies
        let mut doomed: Vec<PathBuf> = vec![];
        for key in self.keys() {
            if reachable_entries.contains(&key) {
                report.kept_entries += 1;
            } else {
                report.removed_entries += 1;
                doomed.push(self.entry_path(key));
            }
        }
        let mut live_profiles: HashSet<u64> = HashSet::new();
        for key in self.trace_keys() {
            if reachable_traces.contains(&key) {
                report.kept_traces += 1;
                if let Some(refs) = self.trace_profile_refs(key) {
                    live_profiles.extend(refs);
                }
            } else {
                report.removed_traces += 1;
                doomed.push(self.trace_path(key));
            }
        }
        for fnv in self.profile_keys() {
            if live_profiles.contains(&fnv) {
                report.kept_profiles += 1;
            } else {
                report.removed_profiles += 1;
                doomed.push(self.profile_path(fnv));
            }
        }
        if !dry_run {
            // deletion is idempotent, so an interrupted gc is always
            // rolled *forward* by the healing pass (finish the deletes,
            // rewrite the manifest)
            let intent = self.write_intent("gc", 0, &doomed)?;
            for path in &doomed {
                match std::fs::remove_file(path) {
                    Ok(()) => {}
                    Err(e) if e.kind() == io::ErrorKind::NotFound => {}
                    Err(e) => return Err(e),
                }
            }
            self.write_manifest()?;
            let _ = std::fs::remove_file(intent);
        }
        Ok(report)
    }

    /// Is this trace document structurally sound with every pool ref
    /// resolving locally? Shared by [`Store::merge_from`] and
    /// [`Store::import_records`]; `memo` collapses repeated profile
    /// parses across many traces.
    fn trace_resolves(
        &self,
        doc: &Json,
        key: u64,
        memo: &mut HashMap<u64, KernelProfile>,
    ) -> bool {
        trace_doc_refs(doc, key)
            .is_some_and(|refs| refs.iter().all(|f| self.pool_get(*f, memo).is_some()))
    }

    /// Every valid record as a raw wire document, in import-safe order —
    /// profiles, then traces, then entries, mirroring [`Store::merge_from`]'s
    /// union order so a receiver applying them in sequence never holds a
    /// trace whose pool files have not landed. Corrupt records are skipped
    /// (they would not import anywhere either). This is what the daemon
    /// streams for a `store_pull` exchange.
    pub fn export_records(&self) -> Vec<ExportRecord> {
        let mut out = vec![];
        for fnv in self.profile_keys() {
            let Ok(doc) = json::read_file(&self.profile_path(fnv)) else { continue };
            let Some(prof) = KernelProfile::from_json(&doc) else { continue };
            if fnv1a64(prof.canonical_compact().as_bytes()) != fnv {
                continue;
            }
            out.push(ExportRecord { tier: Tier::Profiles, key: fnv, doc });
        }
        for key in self.trace_keys() {
            let Ok(doc) = json::read_file(&self.trace_path(key)) else { continue };
            if trace_doc_refs(&doc, key).is_none() {
                continue;
            }
            out.push(ExportRecord { tier: Tier::Traces, key, doc });
        }
        for key in self.keys() {
            let Ok(doc) = json::read_file(&self.entry_path(key)) else { continue };
            if decode_entry(&doc, key).is_none() {
                continue;
            }
            out.push(ExportRecord { tier: Tier::Entries, key, doc });
        }
        out
    }

    /// [`Store::merge_from`] over a wire-record list instead of a sibling
    /// directory — the receiving half of a store exchange (`store_push`
    /// on the daemon, `client store-pull` locally). Same validation and
    /// precedence: pooled profiles are re-hashed against their own name
    /// and written canonically, traces must resolve every ref against
    /// the (just-unioned) local pool, entries must decode under the
    /// current schema, and existing valid local records win. A record
    /// failing validation is **rejected** — counted, skipped, and unable
    /// to poison the rest of the batch; a record the store already holds
    /// is neither imported nor rejected. The batch is admitted through
    /// the byte budget: one enforcement pass runs after the writes, and
    /// its failure (injected `store.evict` fault) is the caller's to
    /// retry — unlike `put`, a push reply must not claim a budget it
    /// did not enforce.
    pub fn import_records(&self, records: &[ExportRecord]) -> io::Result<ImportReport> {
        let mut report = ImportReport::default();
        if self.skip_if_budget_tight() {
            // write-through-skip applies to pushes like any other
            // write: the records are validated nowhere cheaper than at
            // the (still-responding) client, so just decline the batch
            return Ok(report);
        }
        let mut local_pool: HashMap<u64, KernelProfile> = HashMap::new();
        for r in records.iter().filter(|r| r.tier == Tier::Profiles) {
            if self.pool_get(r.key, &mut local_pool).is_some() {
                continue;
            }
            let Some(prof) = KernelProfile::from_json(&r.doc) else {
                report.rejected += 1;
                continue;
            };
            let canonical = prof.canonical_compact();
            if fnv1a64(canonical.as_bytes()) != r.key {
                report.rejected += 1; // mis-hashed in transit or at source
                continue;
            }
            json::write_text_atomic(&self.profile_path(r.key), &canonical)?;
            local_pool.insert(r.key, prof);
            report.imported += 1;
        }
        for r in records.iter().filter(|r| r.tier == Tier::Traces) {
            if let Ok(local) = json::read_file(&self.trace_path(r.key)) {
                if self.trace_resolves(&local, r.key, &mut local_pool) {
                    continue;
                }
            }
            // a ref whose pushed profile was rejected above fails to
            // resolve here, so the trace is rejected with it
            if !self.trace_resolves(&r.doc, r.key, &mut local_pool) {
                report.rejected += 1;
                continue;
            }
            json::write_file_atomic_compact(&self.trace_path(r.key), &r.doc)?;
            self.touch(b't', r.key);
            report.imported += 1;
        }
        for r in records.iter().filter(|r| r.tier == Tier::Entries) {
            if self.get(r.key).is_some() {
                continue;
            }
            if decode_entry(&r.doc, r.key).is_none() {
                report.rejected += 1;
                continue;
            }
            json::write_file_atomic(&self.entry_path(r.key), &r.doc)?;
            self.touch(b'e', r.key);
            report.imported += 1;
        }
        self.enforce_budget(None)?;
        Ok(report)
    }

    /// Per-tier counts and on-disk bytes, plus the profile pool's dedup
    /// leverage: `profile_refs` counts every ref every valid trace
    /// document holds (what an inline-profile store would have written),
    /// against `profiles.count` distinct pooled files. The `journal`
    /// tier is bookkeeping overhead — `journal/` intents plus any
    /// `.tmp-` droppings torn writers left in *any* tier directory —
    /// reported separately and excluded from the budget-governed total
    /// ([`StoreStats::governed_bytes`]).
    pub fn stats(&self) -> StoreStats {
        let tier = |dir: &str| {
            let mut t = TierStats::default();
            if let Ok(rd) = std::fs::read_dir(self.root.join(dir)) {
                for e in rd.filter_map(|e| e.ok()) {
                    if e.path().extension().is_some_and(|x| x == "json") {
                        t.count += 1;
                        t.bytes += e.metadata().map(|m| m.len()).unwrap_or(0);
                    }
                }
            }
            t
        };
        let mut journal = TierStats::default();
        for dir in ["entries", "traces", "profiles", "journal"] {
            if let Ok(rd) = std::fs::read_dir(self.root.join(dir)) {
                for e in rd.filter_map(|e| e.ok()) {
                    let name = e.file_name().to_string_lossy().to_string();
                    let is_intent = dir == "journal" && name.ends_with(".json");
                    if is_intent || name.contains(".tmp-") {
                        journal.count += 1;
                        journal.bytes += e.metadata().map(|m| m.len()).unwrap_or(0);
                    }
                }
            }
        }
        let mut refs = 0u64;
        for key in self.trace_keys() {
            if let Some(r) = self.trace_profile_refs(key) {
                refs += r.len() as u64;
            }
        }
        StoreStats {
            entries: tier("entries"),
            traces: tier("traces"),
            profiles: tier("profiles"),
            journal,
            profile_refs: refs,
            max_bytes: self.max_bytes,
        }
    }
}

/// RAII handle from [`Store::pin_guard`]: holds one eviction pin on a
/// key for the span of an engine claim.
pub struct PinGuard<'a> {
    store: &'a Store,
    key: u64,
}

impl Drop for PinGuard<'_> {
    fn drop(&mut self) {
        self.store.unpin(self.key);
    }
}

/// What [`Store::gc`] kept and removed, per tier.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GcReport {
    pub dry_run: bool,
    pub kept_entries: usize,
    pub removed_entries: usize,
    pub kept_traces: usize,
    pub removed_traces: usize,
    pub kept_profiles: usize,
    pub removed_profiles: usize,
}

impl GcReport {
    pub fn removed_total(&self) -> usize {
        self.removed_entries + self.removed_traces + self.removed_profiles
    }

    /// The `pipefwd-api-v1` `store_gc` reply body.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dry_run", Json::Bool(self.dry_run)),
            ("kept_entries", Json::Num(self.kept_entries as f64)),
            ("removed_entries", Json::Num(self.removed_entries as f64)),
            ("kept_traces", Json::Num(self.kept_traces as f64)),
            ("removed_traces", Json::Num(self.removed_traces as f64)),
            ("kept_profiles", Json::Num(self.kept_profiles as f64)),
            ("removed_profiles", Json::Num(self.removed_profiles as f64)),
            ("removed_total", Json::Num(self.removed_total() as f64)),
        ])
    }

    /// Inverse of [`GcReport::to_json`] (the client renders the daemon's
    /// reply with the same table code the local CLI path uses).
    pub fn from_json(v: &Json) -> Option<GcReport> {
        Some(GcReport {
            dry_run: v.get("dry_run")?.as_bool()?,
            kept_entries: v.get("kept_entries")?.as_usize()?,
            removed_entries: v.get("removed_entries")?.as_usize()?,
            kept_traces: v.get("kept_traces")?.as_usize()?,
            removed_traces: v.get("removed_traces")?.as_usize()?,
            kept_profiles: v.get("kept_profiles")?.as_usize()?,
            removed_profiles: v.get("removed_profiles")?.as_usize()?,
        })
    }
}

/// Which store tier a wire-exchange record belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    Entries,
    Traces,
    Profiles,
}

impl Tier {
    pub fn label(self) -> &'static str {
        match self {
            Tier::Entries => "entries",
            Tier::Traces => "traces",
            Tier::Profiles => "profiles",
        }
    }

    pub fn parse(s: &str) -> Option<Tier> {
        match s {
            "entries" => Some(Tier::Entries),
            "traces" => Some(Tier::Traces),
            "profiles" => Some(Tier::Profiles),
            _ => None,
        }
    }
}

/// One store record in wire form: the raw on-disk document plus its tier
/// and key. Produced by [`Store::export_records`], consumed by
/// [`Store::import_records`]; `coordinator::service` maps these to and
/// from `pipefwd-api-v1` record lines.
#[derive(Debug, Clone, PartialEq)]
pub struct ExportRecord {
    pub tier: Tier,
    pub key: u64,
    pub doc: Json,
}

/// One tier's footprint as [`Store::stats`] reports it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    pub count: usize,
    pub bytes: u64,
}

/// What [`Store::import_records`] did with a pushed batch: `imported`
/// records written locally, `rejected` records that failed validation
/// (mis-hashed pool file, unresolvable trace, undecodable entry).
/// Records the store already held validly count as neither.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ImportReport {
    pub imported: usize,
    pub rejected: usize,
}

/// Per-tier footprint + pool dedup ratio (`pipefwd store stats`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StoreStats {
    pub entries: TierStats,
    pub traces: TierStats,
    pub profiles: TierStats,
    /// Bookkeeping overhead: `journal/` intents + `.tmp-` droppings
    /// across every tier directory. Zero after any cleanly completed
    /// run; excluded from [`StoreStats::governed_bytes`].
    pub journal: TierStats,
    /// Profile refs across all valid trace documents — the number of
    /// profile records an inline (pre-v4) trace tier would store.
    pub profile_refs: u64,
    /// The byte budget the reporting store had armed, if any.
    pub max_bytes: Option<u64>,
}

impl StoreStats {
    /// refs ÷ distinct pooled profiles (1.0 = no repetition; convergence
    /// workloads typically read well above 1).
    pub fn dedup_ratio(&self) -> f64 {
        if self.profiles.count == 0 {
            return 1.0;
        }
        self.profile_refs as f64 / self.profiles.count as f64
    }

    /// Bytes the `--max-bytes` budget governs: the three cache tiers,
    /// never the journal/droppings overhead.
    pub fn governed_bytes(&self) -> u64 {
        self.entries.bytes + self.traces.bytes + self.profiles.bytes
    }

    /// The `store stats --format json` document. The `journal`,
    /// `governed_bytes`, and `max_bytes` keys are additive over the
    /// original v1 shape — existing consumers (the CI store-growth
    /// report) read the keys they know.
    pub fn to_json(&self) -> Json {
        let tier = |t: &TierStats| {
            Json::Obj(vec![
                ("count".into(), Json::Num(t.count as f64)),
                ("bytes".into(), Json::Num(t.bytes as f64)),
            ])
        };
        Json::Obj(vec![
            ("schema".into(), Json::Str("pipefwd-store-stats-v1".into())),
            ("store_schema".into(), Json::Str(STORE_SCHEMA.into())),
            ("entries".into(), tier(&self.entries)),
            ("traces".into(), tier(&self.traces)),
            ("profiles".into(), tier(&self.profiles)),
            ("journal".into(), tier(&self.journal)),
            ("profile_refs".into(), Json::Num(self.profile_refs as f64)),
            ("dedup_ratio".into(), Json::Num(self.dedup_ratio())),
            ("governed_bytes".into(), Json::Num(self.governed_bytes() as f64)),
            (
                "max_bytes".into(),
                match self.max_bytes {
                    Some(m) => Json::Num(m as f64),
                    None => Json::Null,
                },
            ),
        ])
    }
}

fn encode_entry(key: u64, result: &CellResult, des: bool) -> Json {
    let mut fields = vec![
        ("schema".into(), Json::Str(STORE_SCHEMA.into())),
        ("key".into(), Json::Str(key_hex(key))),
        ("des".into(), Json::Bool(des)),
    ];
    match result {
        Ok(m) => {
            fields.push(("status".into(), Json::Str("ok".into())));
            fields.push(("measurement".into(), m.to_json()));
        }
        Err(e) => {
            fields.push(("status".into(), Json::Str("err".into())));
            fields.push(("error".into(), Json::Str(e.clone())));
        }
    }
    Json::Obj(fields)
}

/// Crate-visible so the daemon's `store_push` handler can decode a
/// pushed entry once more to fulfil an outstanding in-memory claim.
pub(crate) fn decode_entry(doc: &Json, key: u64) -> Option<CellResult> {
    let schema = doc.get("schema")?.as_str()?;
    // v5/v4 read-compat: pre-overlap (and pre-device-zoo) records are
    // overlap-off records with unchanged keys and format (see
    // STORE_SCHEMA_COMPAT / STORE_SCHEMA_COMPAT_V4).
    if schema != STORE_SCHEMA && schema != STORE_SCHEMA_COMPAT && schema != STORE_SCHEMA_COMPAT_V4
    {
        return None;
    }
    if doc.get("key")?.as_str()? != key_hex(key) {
        return None;
    }
    match doc.get("status")?.as_str()? {
        "ok" => Measurement::from_json(doc.get("measurement")?).map(Ok),
        "err" => Some(Err(doc.get("error")?.as_str()?.to_string())),
        _ => None,
    }
}

/// The v4 trace document envelope: `launches` holds pool refs (built by
/// [`Store::put_trace`]), never inline profiles.
fn encode_trace_doc(key: u64, body: Result<Json, &String>) -> Json {
    let mut fields = vec![
        ("schema".into(), Json::Str(STORE_SCHEMA.into())),
        ("kind".into(), Json::Str("trace".into())),
        ("key".into(), Json::Str(key_hex(key))),
    ];
    match body {
        Ok(launches) => {
            fields.push(("status".into(), Json::Str("ok".into())));
            fields.push(("launches".into(), launches));
        }
        Err(e) => {
            fields.push(("status".into(), Json::Str("err".into())));
            fields.push(("error".into(), Json::Str(e.clone())));
        }
    }
    Json::Obj(fields)
}

/// Structural walk of a trace document without pool resolution: every
/// launch record must carry a `unit` string and well-formed hex refs.
/// `None` = corrupt/stale/misfiled document; an error trace is `Some`
/// with no refs. Shared by [`Store::trace_profile_refs`] (GC, stats) and
/// the merge import validation.
fn trace_doc_refs(doc: &Json, key: u64) -> Option<Vec<u64>> {
    check_trace_header(doc, key)?;
    match doc.get("status")?.as_str()? {
        "err" => {
            doc.get("error")?.as_str()?;
            Some(vec![])
        }
        "ok" => {
            let mut refs = vec![];
            for rec in doc.get("launches")?.as_array()? {
                rec.get("unit")?.as_str()?;
                for r in rec.get("kernels")?.as_array()? {
                    refs.push(u64::from_str_radix(r.as_str()?, 16).ok()?);
                }
            }
            Some(refs)
        }
        _ => None,
    }
}

/// Schema/kind/key validation shared by trace resolution and the
/// refs-only walk. `None` = stale or misfiled document (a miss).
fn check_trace_header(doc: &Json, key: u64) -> Option<()> {
    let schema = doc.get("schema")?.as_str()?;
    // v5/v4 read-compat, as for measurement entries: trace keys are
    // device- and overlap-free and the record format is unchanged.
    if schema != STORE_SCHEMA && schema != STORE_SCHEMA_COMPAT && schema != STORE_SCHEMA_COMPAT_V4
    {
        return None;
    }
    if doc.get("kind")?.as_str()? != "trace" {
        return None;
    }
    if doc.get("key")?.as_str()? != key_hex(key) {
        return None;
    }
    Some(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::LaunchRecord;

    fn tmp_store(name: &str) -> Store {
        let dir = std::env::temp_dir()
            .join(format!("pipefwd-store-unit-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Store::open(dir).unwrap()
    }

    fn sample_measurement() -> Measurement {
        Measurement {
            workload: "fw".into(),
            variant: "ff(d1)".into(),
            scale: "tiny".into(),
            seconds: 0.125,
            cycles: 3.0e7,
            logic_pct: 17.5,
            brams: 412,
            max_ii: 285,
            max_bw: 7.34e9,
            launches: 3,
        }
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // published FNV-1a test vectors — the persisted keys depend on them
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn open_existing_rejects_non_stores() {
        let dir = std::env::temp_dir()
            .join(format!("pipefwd-store-unit-{}-absent", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(Store::open_existing(&dir).is_err(), "absent dir must not open");
        Store::open(&dir).unwrap();
        assert!(Store::open_existing(&dir).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn roundtrips_ok_and_err_entries() {
        let s = tmp_store("roundtrip");
        let m = sample_measurement();
        s.put(1, &Ok(m.clone()), false).unwrap();
        s.put(2, &Err("replication unsupported".into()), false).unwrap();
        assert_eq!(s.get(1), Some(Ok(m)));
        assert_eq!(s.get(2), Some(Err("replication unsupported".into())));
        assert_eq!(s.get(3), None);
        assert_eq!(s.keys(), vec![1, 2]);
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn corrupt_truncated_and_mismatched_entries_are_misses() {
        let s = tmp_store("corrupt");
        let m = sample_measurement();
        s.put(7, &Ok(m.clone()), false).unwrap();
        let path = s.root().join("entries").join(format!("{}.json", key_hex(7)));

        // truncated mid-document
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert_eq!(s.get(7), None, "truncated entry must be a miss");

        // outright garbage
        std::fs::write(&path, "not json at all \u{0}\u{1}").unwrap();
        assert_eq!(s.get(7), None, "garbled entry must be a miss");

        // valid JSON, wrong schema version (a schema bump invalidates)
        let stale = full.replace(STORE_SCHEMA, "pipefwd-store-v0");
        std::fs::write(&path, &stale).unwrap();
        assert_eq!(s.get(7), None, "old-schema entry must be a miss");

        // valid JSON under the wrong key (e.g. a mis-copied file)
        s.put(8, &Ok(m), false).unwrap();
        std::fs::copy(s.root().join("entries").join(format!("{}.json", key_hex(8))), &path)
            .unwrap();
        assert_eq!(s.get(7), None, "key-mismatched entry must be a miss");
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// The read-compat window: records whose schema field says v5 (every
    /// record written before the overlap axis) or v4 (before the device
    /// zoo) must be warm *hits*, for both tiers — their keys, format,
    /// and meaning are unchanged under v6, so orphaning them would
    /// re-simulate every pre-existing store for nothing. Anything older
    /// stays a miss.
    #[test]
    fn v5_and_v4_schema_records_read_as_hits_under_v6() {
        let s = tmp_store("compat-window");
        let m = sample_measurement();
        s.put(7, &Ok(m.clone()), false).unwrap();
        let epath = s.root().join("entries").join(format!("{}.json", key_hex(7)));
        let full = std::fs::read_to_string(&epath).unwrap();
        assert!(full.contains(STORE_SCHEMA), "new writes carry v6");
        for old in [STORE_SCHEMA_COMPAT, STORE_SCHEMA_COMPAT_V4] {
            std::fs::write(&epath, full.replace(STORE_SCHEMA, old)).unwrap();
            assert_eq!(s.get(7), Some(Ok(m.clone())), "{old} entry must stay a warm hit");
        }
        std::fs::write(&epath, full.replace(STORE_SCHEMA, "pipefwd-store-v3")).unwrap();
        assert_eq!(s.get(7), None, "v3 entry must stay a miss");

        s.put_trace(9, &Ok(sample_trace())).unwrap();
        let tpath = s.root().join("traces").join(format!("{}.json", key_hex(9)));
        let tfull = std::fs::read_to_string(&tpath).unwrap();
        for old in [STORE_SCHEMA_COMPAT, STORE_SCHEMA_COMPAT_V4] {
            std::fs::write(&tpath, tfull.replace(STORE_SCHEMA, old)).unwrap();
            assert_eq!(
                s.get_trace(9),
                Some(Ok(sample_trace())),
                "{old} trace must stay a warm hit"
            );
        }
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn manifest_roundtrips_and_rejects_other_schemas() {
        let s = tmp_store("manifest");
        s.put(5, &Err("e".into()), false).unwrap();
        s.put(3, &Err("e".into()), false).unwrap();
        s.write_manifest().unwrap();
        assert_eq!(s.load_manifest(), Some(vec![3, 5]));
        let text = std::fs::read_to_string(s.root().join("MANIFEST.json"))
            .unwrap()
            .replace(STORE_SCHEMA, "pipefwd-store-v0");
        std::fs::write(s.root().join("MANIFEST.json"), text).unwrap();
        assert_eq!(s.load_manifest(), None);
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn measurements_filter_by_scale_and_estimator() {
        let s = tmp_store("filter");
        let analytic_tiny = sample_measurement();
        let mut des_tiny = sample_measurement();
        des_tiny.seconds = 0.25; // DES estimate of the same configuration
        let mut analytic_small = sample_measurement();
        analytic_small.scale = "small".into();
        s.put(1, &Ok(analytic_tiny.clone()), false).unwrap();
        s.put(2, &Ok(des_tiny.clone()), true).unwrap();
        s.put(3, &Ok(analytic_small), false).unwrap();
        s.put(4, &Err("infeasible".into()), false).unwrap();
        assert_eq!(s.measurements_filtered("tiny", false), vec![analytic_tiny]);
        assert_eq!(s.measurements_filtered("tiny", true), vec![des_tiny]);
        assert_eq!(s.measurements().len(), 3, "unfiltered view keeps everything");
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// Tuner probes persist like any other measurement: product-space
    /// variants (deep pipes, replication at depth) round-trip and sort
    /// canonically next to the classic grid entries.
    #[test]
    fn tuner_product_space_entries_roundtrip_and_sort() {
        let s = tmp_store("tune-space");
        let mk = |variant: &str| {
            let mut m = sample_measurement();
            m.variant = variant.into();
            m
        };
        s.put(1, &Ok(mk("m3c3(d16)")), false).unwrap();
        s.put(2, &Ok(mk("ff(d512)")), false).unwrap();
        s.put(3, &Ok(mk("ff(d1)")), false).unwrap();
        let ms = s.measurements_filtered("tiny", false);
        let variants: Vec<&str> = ms.iter().map(|m| m.variant.as_str()).collect();
        assert_eq!(variants, vec!["ff(d1)", "ff(d512)", "m3c3(d16)"]);
        let _ = std::fs::remove_dir_all(s.root());
    }

    fn sample_trace() -> ExecTrace {
        let mut prof = crate::sim::profile::KernelProfile::new("fw_mem", 3);
        for a in 0..50i64 {
            prof.sites[0].record(a);
            prof.sites[1].record(a * 7 % 13);
        }
        prof.loops.insert(crate::ir::LoopId(0), crate::sim::profile::LoopStats {
            invocations: 1,
            iters: 50,
        });
        prof.pipe_writes = 100;
        ExecTrace {
            launches: vec![
                LaunchRecord { unit: "fw_kernel".into(), profiles: vec![prof.clone()] },
                LaunchRecord { unit: "fw_kernel".into(), profiles: vec![prof] },
            ],
        }
    }

    #[test]
    fn trace_entries_roundtrip_ok_and_err() {
        let s = tmp_store("trace-roundtrip");
        let t = sample_trace();
        s.put_trace(11, &Ok(t.clone())).unwrap();
        s.put_trace(12, &Err("validation: nw: m[9] = 1, want 2".into())).unwrap();
        assert_eq!(s.get_trace(11), Some(Ok(t)));
        assert_eq!(s.get_trace(12), Some(Err("validation: nw: m[9] = 1, want 2".into())));
        assert_eq!(s.get_trace(13), None);
        assert_eq!(s.trace_keys(), vec![11, 12]);
        // both launches carry the identical profile: the pool holds it once
        assert_eq!(s.profile_keys().len(), 1, "identical launches must share one pool file");
        assert_eq!(s.trace_profile_refs(11), Some(vec![s.profile_keys()[0]; 2]));
        assert_eq!(s.trace_profile_refs(12), Some(vec![]), "error traces hold no refs");
        // the two tiers are separate namespaces: no measurement entry
        // exists under a trace key
        assert_eq!(s.get(11), None);
        assert_eq!(s.len(), 0, "traces must not count as measurement entries");
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// The pool is global: a second trace (different key, overlapping
    /// launches) reuses the existing profile files instead of rewriting
    /// its own copies.
    #[test]
    fn profile_pool_dedups_across_traces() {
        let s = tmp_store("pool-dedup");
        s.put_trace(31, &Ok(sample_trace())).unwrap();
        let mut longer = sample_trace();
        let extra = longer.launches[0].clone();
        longer.launches.push(extra); // 3 identical launches now
        s.put_trace(32, &Ok(longer.clone())).unwrap();
        assert_eq!(s.profile_keys().len(), 1, "one distinct profile across both traces");
        assert_eq!(s.get_trace(32), Some(Ok(longer)));
        let stats = s.stats();
        assert_eq!(stats.profiles.count, 1);
        assert_eq!(stats.profile_refs, 5, "2 + 3 refs against one pooled profile");
        assert_eq!(stats.dedup_ratio(), 5.0);
        assert_eq!(stats.traces.count, 2);
        assert!(stats.profiles.bytes > 0 && stats.traces.bytes > 0);
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// A defective pool file (missing, garbled, or content that no longer
    /// hashes to its name) fails exactly the traces that reference it —
    /// never a panic, never an unrelated trace.
    #[test]
    fn corrupt_pool_files_degrade_only_referencing_traces() {
        let s = tmp_store("pool-corrupt");
        s.put_trace(41, &Ok(sample_trace())).unwrap();
        // an unrelated trace with a distinct profile
        let mut other = sample_trace();
        other.launches.truncate(1);
        other.launches[0].profiles[0].pipe_writes = 999; // distinct content
        s.put_trace(42, &Ok(other.clone())).unwrap();
        assert_eq!(s.profile_keys().len(), 2);
        let victim = s.trace_profile_refs(41).unwrap()[0];
        let path = s.root().join("profiles").join(format!("{}.json", key_hex(victim)));

        // valid JSON profile, but the content no longer matches the name
        let swapped = other.launches[0].profiles[0].canonical_compact();
        std::fs::write(&path, &swapped).unwrap();
        assert_eq!(s.get_trace(41), None, "hash-mismatched pool file must be a miss");
        assert_eq!(s.get_trace(42), Some(Ok(other.clone())), "other traces unaffected");

        // garbled
        std::fs::write(&path, "not json \u{0}").unwrap();
        assert_eq!(s.get_trace(41), None, "garbled pool file must be a miss");

        // missing entirely
        std::fs::remove_file(&path).unwrap();
        assert_eq!(s.get_trace(41), None, "dangling ref must be a miss");
        assert_eq!(s.get_trace(42), Some(Ok(other)), "other traces still resolve");

        // rewriting the trace heals the pool
        s.put_trace(41, &Ok(sample_trace())).unwrap();
        assert_eq!(s.get_trace(41), Some(Ok(sample_trace())));
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// GC against explicit reachable sets: unreachable entries and traces
    /// go, pooled profiles survive exactly as long as one surviving trace
    /// references them, and the manifest is rewritten (unless dry-run).
    #[test]
    fn gc_removes_unreachable_records_and_orphan_profiles() {
        let s = tmp_store("gc-unit");
        let m = sample_measurement();
        s.put(1, &Ok(m.clone()), false).unwrap();
        s.put(2, &Ok(m), false).unwrap();
        s.put_trace(11, &Ok(sample_trace())).unwrap();
        let mut other = sample_trace();
        other.launches[0].profiles[0].pipe_writes = 777; // distinct profile
        other.launches.truncate(1);
        s.put_trace(12, &Ok(other)).unwrap();
        assert_eq!(s.profile_keys().len(), 2);

        let entries: HashSet<u64> = [1].into_iter().collect();
        let traces: HashSet<u64> = [11].into_iter().collect();

        // dry run: full report, zero deletion, manifest untouched
        let dry = s.gc(&entries, &traces, true).unwrap();
        assert!(dry.dry_run);
        assert_eq!((dry.kept_entries, dry.removed_entries), (1, 1));
        assert_eq!((dry.kept_traces, dry.removed_traces), (1, 1));
        assert_eq!((dry.kept_profiles, dry.removed_profiles), (1, 1));
        assert_eq!(s.keys(), vec![1, 2], "dry run must not delete");
        assert_eq!(s.trace_keys(), vec![11, 12]);
        assert!(!s.root().join("MANIFEST.json").exists(), "dry run must not write");

        let real = s.gc(&entries, &traces, false).unwrap();
        assert_eq!(real, GcReport { dry_run: false, ..dry });
        assert_eq!(s.keys(), vec![1]);
        assert_eq!(s.trace_keys(), vec![11]);
        assert_eq!(s.profile_keys().len(), 1);
        assert_eq!(s.get_trace(11), Some(Ok(sample_trace())), "kept trace still resolves");
        assert_eq!(s.load_manifest(), Some(vec![1]), "manifest rewritten post-gc");
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn corrupt_or_stale_trace_entries_are_misses() {
        let s = tmp_store("trace-corrupt");
        s.put_trace(7, &Ok(sample_trace())).unwrap();
        let path = s.root().join("traces").join(format!("{}.json", key_hex(7)));
        let full = std::fs::read_to_string(&path).unwrap();

        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert_eq!(s.get_trace(7), None, "truncated trace must be a miss");

        // a previous schema version (the inline-profile trace format):
        // stale — its launches never referenced the pool, and v3 is
        // outside the v5/v4 read-compat window
        let stale = full.replace(STORE_SCHEMA, "pipefwd-store-v3");
        std::fs::write(&path, &stale).unwrap();
        assert_eq!(s.get_trace(7), None, "v3 trace must be a miss under v5");

        // a measurement entry misfiled under a trace path (wrong kind)
        s.put(7, &Ok(sample_measurement()), false).unwrap();
        std::fs::copy(s.root().join("entries").join(format!("{}.json", key_hex(7))), &path)
            .unwrap();
        assert_eq!(s.get_trace(7), None, "kind mismatch must be a miss");
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn merge_from_carries_traces_and_unions_the_pool() {
        let a = tmp_store("trace-merge-a");
        let b = tmp_store("trace-merge-b");
        let t = sample_trace();
        b.put_trace(21, &Ok(t.clone())).unwrap();
        b.put(22, &Ok(sample_measurement()), false).unwrap();
        assert_eq!(
            a.merge_from(&b).unwrap(),
            3,
            "one pooled profile + one trace + one measurement"
        );
        assert_eq!(a.profile_keys(), b.profile_keys(), "pool must be unioned");
        assert_eq!(a.get_trace(21), Some(Ok(t)), "imported trace resolves against local pool");
        assert!(a.get(22).is_some());
        // idempotent: nothing new on a second merge
        assert_eq!(a.merge_from(&b).unwrap(), 0);
        let _ = std::fs::remove_dir_all(a.root());
        let _ = std::fs::remove_dir_all(b.root());
    }

    /// A trace whose pool file is corrupt in the source store is skipped
    /// by merge (it would not resolve there either); valid records still
    /// import.
    #[test]
    fn merge_skips_traces_with_corrupt_source_pools() {
        let a = tmp_store("pool-merge-a");
        let b = tmp_store("pool-merge-b");
        b.put_trace(51, &Ok(sample_trace())).unwrap();
        let victim = b.profile_keys()[0];
        std::fs::write(
            b.root().join("profiles").join(format!("{}.json", key_hex(victim))),
            "garbage",
        )
        .unwrap();
        b.put(52, &Ok(sample_measurement()), false).unwrap();
        assert_eq!(a.merge_from(&b).unwrap(), 1, "only the measurement imports");
        assert_eq!(a.get_trace(51), None);
        assert!(a.get(52).is_some());
        let _ = std::fs::remove_dir_all(a.root());
        let _ = std::fs::remove_dir_all(b.root());
    }

    #[test]
    fn merge_from_imports_only_missing_entries() {
        let a = tmp_store("merge-a");
        let b = tmp_store("merge-b");
        let m = sample_measurement();
        a.put(1, &Ok(m.clone()), false).unwrap();
        b.put(1, &Err("divergent (must not overwrite)".into()), false).unwrap();
        b.put(2, &Ok(m.clone()), false).unwrap();
        assert_eq!(a.merge_from(&b).unwrap(), 1);
        assert_eq!(a.get(1), Some(Ok(m.clone())), "existing entries are kept");
        assert_eq!(a.get(2), Some(Ok(m)));
        let _ = std::fs::remove_dir_all(a.root());
        let _ = std::fs::remove_dir_all(b.root());
    }

    /// `export_records` → `import_records` is `merge_from` over the wire:
    /// all three tiers round-trip in import-safe order and the exchange
    /// is idempotent.
    #[test]
    fn export_import_records_roundtrip_all_tiers() {
        let a = tmp_store("export-a");
        let b = tmp_store("export-b");
        a.put_trace(61, &Ok(sample_trace())).unwrap();
        a.put(62, &Ok(sample_measurement()), false).unwrap();
        a.put(63, &Err("validation: nw: m[9] = 1, want 2".into()), false).unwrap();
        let records = a.export_records();
        let tiers: Vec<Tier> = records.iter().map(|r| r.tier).collect();
        assert_eq!(
            tiers,
            vec![Tier::Profiles, Tier::Traces, Tier::Entries, Tier::Entries],
            "pool must precede the traces that reference it"
        );
        assert_eq!(
            b.import_records(&records).unwrap(),
            ImportReport { imported: 4, rejected: 0 }
        );
        assert_eq!(b.get_trace(61), Some(Ok(sample_trace())));
        assert_eq!(b.get(62), Some(Ok(sample_measurement())));
        assert_eq!(b.get(63), Some(Err("validation: nw: m[9] = 1, want 2".into())));
        assert_eq!(
            b.import_records(&records).unwrap(),
            ImportReport::default(),
            "exchange is idempotent: already-held records are neither imported nor rejected"
        );
        let _ = std::fs::remove_dir_all(a.root());
        let _ = std::fs::remove_dir_all(b.root());
    }

    /// A record set missing the pool a trace references imports nothing
    /// for that trace (same contract as a corrupt source pool in
    /// `merge_from`); hash-mismatched pooled profiles are dropped too.
    #[test]
    fn import_records_skips_unresolvable_and_corrupt_records() {
        let src = tmp_store("import-src");
        src.put_trace(71, &Ok(sample_trace())).unwrap();
        src.put(72, &Ok(sample_measurement()), false).unwrap();
        let records = src.export_records();

        // strip the pool: the trace must not import, the entry still does
        let no_pool: Vec<ExportRecord> =
            records.iter().filter(|r| r.tier != Tier::Profiles).cloned().collect();
        let dst = tmp_store("import-nopool");
        assert_eq!(
            dst.import_records(&no_pool).unwrap(),
            ImportReport { imported: 1, rejected: 1 },
            "only the entry lands; the unresolvable trace is rejected"
        );
        assert_eq!(dst.get_trace(71), None);
        assert!(dst.get(72).is_some());

        // mis-key a profile: re-hash validation drops it and its trace
        let mut bad = records.clone();
        for r in &mut bad {
            if r.tier == Tier::Profiles {
                r.key ^= 1;
            }
        }
        let dst2 = tmp_store("import-badpool");
        assert_eq!(
            dst2.import_records(&bad).unwrap(),
            ImportReport { imported: 1, rejected: 2 },
            "only the entry lands; the mis-hashed profile and its trace are rejected"
        );
        assert_eq!(dst2.get_trace(71), None);
        let _ = std::fs::remove_dir_all(src.root());
        let _ = std::fs::remove_dir_all(dst.root());
        let _ = std::fs::remove_dir_all(dst2.root());
    }

    #[test]
    fn tier_labels_roundtrip_and_gc_report_json_roundtrips() {
        for t in [Tier::Entries, Tier::Traces, Tier::Profiles] {
            assert_eq!(Tier::parse(t.label()), Some(t));
        }
        assert_eq!(Tier::parse("pool"), None);
        let r = GcReport {
            dry_run: true,
            kept_entries: 1,
            removed_entries: 2,
            kept_traces: 3,
            removed_traces: 4,
            kept_profiles: 5,
            removed_profiles: 6,
        };
        assert_eq!(GcReport::from_json(&r.to_json()), Some(r));
    }

    #[test]
    fn concurrent_writers_lose_no_records() {
        let s = tmp_store("concurrent");
        let m = sample_measurement();
        std::thread::scope(|sc| {
            for t in 0..8u64 {
                let s = &s;
                let m = &m;
                sc.spawn(move || {
                    for k in 0..16u64 {
                        // half the keys contended by every thread, half private
                        let key = if k % 2 == 0 { k } else { t * 100 + k };
                        s.put(key, &Ok(m.clone()), false).unwrap();
                        assert!(s.get(key).is_some(), "entry must be readable after put");
                    }
                });
            }
        });
        // all contended + all private keys present and valid
        for k in (0..16u64).filter(|k| k % 2 == 0) {
            assert_eq!(s.get(k), Some(Ok(m.clone())));
        }
        for t in 0..8u64 {
            for k in (0..16u64).filter(|k| k % 2 == 1) {
                assert_eq!(s.get(t * 100 + k), Some(Ok(m.clone())));
            }
        }
        assert_eq!(s.len(), 8 + 8 * 8);
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// Fabricate the on-disk state of a crash: an intent in `journal/`
    /// (exactly as `put_trace` writes it) plus whatever partial files
    /// the test wants. Reopening must resolve it.
    fn fake_intent(s: &Store, op: &str, key: u64, files: Vec<&str>) {
        let doc = Json::obj(vec![
            ("schema", Json::Str(JOURNAL_SCHEMA.into())),
            ("op", Json::Str(op.into())),
            ("key", Json::Str(key_hex(key))),
            ("files", Json::Arr(files.into_iter().map(|f| Json::Str(f.into())).collect())),
        ]);
        let path = s.root().join("journal").join(format!("{op}-{}.json", key_hex(key)));
        json::write_file_atomic_compact(&path, &doc).unwrap();
    }

    /// Completed multi-file operations leave no intent behind; an
    /// intent whose trace is torn is *discarded* on open (partial doc
    /// removed, re-interpreted later); an intent whose trace resolves
    /// is *rolled forward* (the write in fact completed — keep it).
    #[test]
    fn open_heals_interrupted_put_trace() {
        let s = tmp_store("journal-put");
        s.put_trace(11, &Ok(sample_trace())).unwrap();
        assert_eq!(s.journal_len(), 0, "completed put_trace must clear its intent");
        assert_eq!(s.journal_replays(), 0);

        // crash A: intent present, trace document torn mid-write
        let tpath = s.root().join("traces").join(format!("{}.json", key_hex(11)));
        let full = std::fs::read_to_string(&tpath).unwrap();
        std::fs::write(&tpath, &full[..full.len() / 2]).unwrap();
        fake_intent(&s, "put_trace", 11, vec![]);
        let root = s.root().to_path_buf();
        let s = Store::open(&root).unwrap();
        assert_eq!(s.journal_replays(), 1, "one intent resolved at open");
        assert_eq!(s.journal_len(), 0, "no leaked intents after healing");
        assert!(!tpath.exists(), "partial trace document must be discarded");
        assert_eq!(s.get_trace(11), None);

        // crash B: intent present but every write landed (died between
        // the last rename and the intent removal) — rolled forward
        s.put_trace(11, &Ok(sample_trace())).unwrap();
        fake_intent(&s, "put_trace", 11, vec![]);
        let s = Store::open(&root).unwrap();
        assert_eq!(s.journal_replays(), 1);
        assert_eq!(s.get_trace(11), Some(Ok(sample_trace())), "completed write must survive");
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// An interrupted gc rolls *forward*: the healing pass finishes the
    /// recorded deletions and rewrites the manifest. Stale `.tmp-`
    /// droppings (torn atomic writes) are swept too.
    #[test]
    fn open_rolls_forward_interrupted_gc_and_sweeps_droppings() {
        let s = tmp_store("journal-gc");
        let m = sample_measurement();
        s.put(1, &Ok(m.clone()), false).unwrap();
        s.put(2, &Ok(m), false).unwrap();
        // a gc that "died" after deleting nothing: both doomed files listed
        let doomed = format!("entries/{}.json", key_hex(2));
        fake_intent(&s, "gc", 0, vec![&doomed]);
        // plus a torn temp file a crashed writer left behind
        let dropping = s.root().join("entries").join(".dead.json.tmp-999-0");
        std::fs::write(&dropping, "{ torn").unwrap();
        let root = s.root().to_path_buf();
        let s = Store::open(&root).unwrap();
        assert_eq!(s.journal_replays(), 1);
        assert_eq!(s.keys(), vec![1], "gc deletions must be completed");
        assert_eq!(s.load_manifest(), Some(vec![1]), "manifest rewritten by roll-forward");
        assert!(!dropping.exists(), "torn temp files must be swept");
        assert_eq!(s.journal_len(), 0);
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// A cleanly-completed gc leaves no intent behind, and corrupt
    /// intents are dropped (counted, never fatal).
    #[test]
    fn gc_clears_its_intent_and_corrupt_intents_are_dropped() {
        let s = tmp_store("journal-clean");
        s.put(1, &Ok(sample_measurement()), false).unwrap();
        let reach: HashSet<u64> = HashSet::new();
        s.gc(&reach, &reach, false).unwrap();
        assert_eq!(s.journal_len(), 0, "completed gc must clear its intent");
        std::fs::write(s.root().join("journal").join("garbage.json"), "not json").unwrap();
        let root = s.root().to_path_buf();
        let s = Store::open(&root).unwrap();
        assert_eq!(s.journal_replays(), 1, "corrupt intent still counts as resolved");
        assert_eq!(s.journal_len(), 0);
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// When the cache directory itself turns unwritable the store
    /// degrades to read-only: writes are skipped and counted, reads
    /// keep serving, and nothing errors — the engine keeps computing.
    #[test]
    fn unwritable_dir_degrades_to_read_only() {
        let s = tmp_store("degraded");
        let m = sample_measurement();
        s.put(1, &Ok(m.clone()), false).unwrap();
        assert!(!s.is_degraded());
        // make the entries tier unwritable in a way that defeats even
        // root (permission bits don't): replace the directory by a file
        std::fs::remove_dir_all(s.root().join("entries")).unwrap();
        std::fs::write(s.root().join("entries"), "not a directory").unwrap();
        assert!(s.put(2, &Ok(m.clone()), false).is_err(), "the failing write surfaces once");
        assert!(s.is_degraded(), "an unwritable dir must flip degraded mode");
        // subsequent writes are skipped silently and counted
        assert!(s.put(3, &Ok(m.clone()), false).is_ok());
        assert!(s.put_trace(4, &Ok(sample_trace())).is_ok());
        assert_eq!(s.degraded_count(), 2);
        assert_eq!(s.journal_len(), 0, "skipped writes must not journal");
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// A transient single-write failure (injected torn write, flaky
    /// NFS) must NOT degrade the store while the directory stays
    /// writable — the next write goes through.
    #[test]
    fn transient_write_failure_does_not_degrade() {
        let s = tmp_store("transient");
        // simulate: a write failed but the dir is fine — note_write_failure
        // probes and finds it writable
        s.note_write_failure(&s.entry_path(9));
        assert!(!s.is_degraded());
        s.put(9, &Ok(sample_measurement()), false).unwrap();
        assert!(s.get(9).is_some());
        assert_eq!(s.degraded_count(), 0);
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn parse_byte_budget_accepts_units_and_rejects_garbage() {
        assert_eq!(parse_byte_budget("65536"), Ok(65536));
        assert_eq!(parse_byte_budget("64k"), Ok(64 << 10));
        assert_eq!(parse_byte_budget("8M"), Ok(8 << 20));
        assert_eq!(parse_byte_budget(" 1g "), Ok(1 << 30));
        assert!(parse_byte_budget("0").is_err(), "a zero budget is a mistyped flag");
        assert!(parse_byte_budget("").is_err());
        assert!(parse_byte_budget("lots").is_err());
        assert!(parse_byte_budget("-4k").is_err());
    }

    /// The LRU contract: when a put lands over budget, the coldest
    /// record dies first — stampless before stamped, logical access
    /// order among stamped — never the freshly written (protected)
    /// record, and the `governed_bytes ≤ max_bytes` invariant holds
    /// after the put. The eviction batch journals like a gc, so no
    /// intent survives a clean pass.
    #[test]
    fn budget_evicts_coldest_first_and_keeps_invariant() {
        let s = tmp_store("budget-lru");
        let m = sample_measurement();
        for k in 1..=4u64 {
            s.put(k, &Ok(m.clone()), false).unwrap();
        }
        let esize = s.governed_bytes() / 4;
        assert!(esize > 0);
        let root = s.root().to_path_buf();
        // room for four records and change — the fifth put must evict one
        let s = Store::open(&root).unwrap().with_max_bytes(Some(esize * 4 + esize / 2));
        assert_eq!(s.evictions(), 0, "opening under budget evicts nothing");
        // warm key 1: without stamps it would die first (lowest key)
        assert!(s.get(1).is_some());
        s.put(5, &Ok(m.clone()), false).unwrap();
        assert!(s.governed_bytes() <= s.max_bytes().unwrap(), "invariant after the put");
        assert_eq!(s.evictions(), 1);
        assert_eq!(s.budget_skips(), 0);
        assert!(s.get(1).is_some(), "warm record survives");
        assert!(s.get(2).is_none(), "coldest (stampless, lowest key) record evicted");
        assert!(s.get(5).is_some(), "the record that triggered eviction is protected");
        assert_eq!(s.journal_len(), 0, "a clean eviction batch clears its intent");
        assert!(root.join(STAMPS_FILE).exists(), "eviction flushes the stamp file");
        let _ = std::fs::remove_dir_all(&root);
    }

    /// Evicting a trace frees its pooled profiles only when no
    /// *surviving* trace still references them — the gc liveness rule,
    /// applied incrementally.
    #[test]
    fn eviction_keeps_pool_files_shared_with_surviving_traces() {
        let s = tmp_store("budget-pool");
        s.put_trace(21, &Ok(sample_trace())).unwrap();
        s.put_trace(22, &Ok(sample_trace())).unwrap();
        let st = s.stats();
        assert_eq!(st.profiles.count, 1, "both traces share one pooled profile");
        let (tsize, psize) = (st.traces.bytes / 2, st.profiles.bytes);
        let root = s.root().to_path_buf();
        // room for one trace + the pool: opening must evict exactly one
        let s = Store::open(&root).unwrap().with_max_bytes(Some(tsize + psize + tsize / 2));
        assert_eq!(s.evictions(), 1);
        assert_eq!(s.trace_keys(), vec![22], "lower key (equally cold) evicted first");
        assert_eq!(
            s.get_trace(22),
            Some(Ok(sample_trace())),
            "surviving trace still resolves — its shared pool file must not die with 21"
        );
        // now nothing fits: the second trace goes, and the orphaned pool file with it
        let s = Store::open(&root).unwrap().with_max_bytes(Some(psize.max(64)));
        assert!(s.trace_keys().is_empty());
        assert!(s.profile_keys().is_empty(), "orphaned pool file evicted with its last trace");
        assert!(s.governed_bytes() <= s.max_bytes().unwrap());
        assert_eq!(s.journal_len(), 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    /// A pinned key (open engine claim) is never evicted, whatever its
    /// stamp; the pin is refcounted and released by the guard.
    #[test]
    fn pinned_keys_survive_eviction() {
        let s = tmp_store("budget-pin");
        let m = sample_measurement();
        for k in 1..=4u64 {
            s.put(k, &Ok(m.clone()), false).unwrap();
        }
        let esize = s.governed_bytes() / 4;
        let root = s.root().to_path_buf();
        let s = Store::open(&root).unwrap().with_max_bytes(Some(esize * 4 + esize / 2));
        {
            let _pin = s.pin_guard(1); // coldest key, would die first
            s.put(5, &Ok(m.clone()), false).unwrap();
            assert!(s.get(1).is_some(), "pinned key survives");
            assert!(s.get(2).is_none(), "eviction moved to the next-coldest");
        }
        assert!(!s.is_pinned(1), "guard releases its pin on drop");
        let _ = std::fs::remove_dir_all(&root);
    }

    /// A budget smaller than a single record degrades to
    /// write-through-skip: the first put latches `tight` (one write +
    /// self-evict, counted), subsequent puts skip the write entirely —
    /// no thrash, invariant intact, results unaffected.
    #[test]
    fn over_tight_budget_degrades_to_write_through_skip() {
        let s = tmp_store("budget-tight");
        let m = sample_measurement();
        s.put(1, &Ok(m.clone()), false).unwrap();
        let esize = s.governed_bytes();
        let root = s.root().to_path_buf();
        let s = Store::open(&root).unwrap().with_max_bytes(Some(esize / 2));
        assert!(s.keys().is_empty(), "opening over an un-fittable budget clears the store");
        s.put(2, &Ok(m.clone()), false).unwrap();
        let skips_after_first = s.budget_skips();
        assert!(skips_after_first >= 1, "the un-fittable record counts a budget skip");
        s.put(3, &Ok(m.clone()), false).unwrap();
        assert!(s.budget_skips() > skips_after_first, "later puts skip without writing");
        assert!(s.keys().is_empty());
        assert!(s.governed_bytes() <= s.max_bytes().unwrap());
        assert_eq!(s.journal_len(), 0);
        let _ = std::fs::remove_dir_all(&root);
    }

    /// The satellite heal test: an eviction batch killed between its
    /// deletes and the manifest rewrite rolls *forward* at open —
    /// every listed file re-deleted idempotently, manifest rewritten,
    /// no dangling pool refs, no leaked intent.
    #[test]
    fn open_rolls_forward_interrupted_eviction() {
        let s = tmp_store("journal-evict");
        let m = sample_measurement();
        s.put(1, &Ok(m.clone()), false).unwrap();
        s.put(2, &Ok(m), false).unwrap();
        s.put_trace(31, &Ok(sample_trace())).unwrap();
        let pool = s.profile_keys();
        assert_eq!(pool.len(), 1);
        // the batch doomed entry 2, trace 31, and its (now orphaned)
        // pool file; "death" struck after deleting only the entry
        let doomed_entry = format!("entries/{}.json", key_hex(2));
        let doomed_trace = format!("traces/{}.json", key_hex(31));
        let doomed_prof = format!("profiles/{}.json", key_hex(pool[0]));
        std::fs::remove_file(s.root().join(&doomed_entry)).unwrap();
        fake_intent(&s, "evict", 9, vec![&doomed_entry, &doomed_trace, &doomed_prof]);
        let root = s.root().to_path_buf();
        let s = Store::open(&root).unwrap();
        assert_eq!(s.journal_replays(), 1);
        assert_eq!(s.journal_len(), 0, "no leaked intent after healing");
        assert_eq!(s.keys(), vec![1], "interrupted deletes completed (idempotently)");
        assert!(s.trace_keys().is_empty());
        assert!(s.profile_keys().is_empty(), "no dangling pool files");
        assert_eq!(s.load_manifest(), Some(vec![1]), "manifest rewritten by roll-forward");
        let _ = std::fs::remove_dir_all(&root);
    }

    /// The stats satellite: journal intents and `.tmp-` droppings are a
    /// visible tier of their own, excluded from the governed total.
    #[test]
    fn stats_reports_journal_overhead_outside_the_governed_total() {
        let s = tmp_store("stats-journal");
        s.put(1, &Ok(sample_measurement()), false).unwrap();
        let clean = s.stats();
        assert_eq!(clean.journal, TierStats::default());
        fake_intent(&s, "gc", 0, vec![]);
        std::fs::write(s.root().join("entries").join(".dead.json.tmp-999-0"), "{ torn").unwrap();
        let st = s.stats();
        assert_eq!(st.journal.count, 2, "one intent + one dropping");
        assert!(st.journal.bytes > 0);
        assert_eq!(
            st.governed_bytes(),
            clean.governed_bytes(),
            "bookkeeping overhead must not move the budget-governed total"
        );
        assert_eq!(st.entries, clean.entries, "droppings are not entries");
        let doc = st.to_json();
        assert!(doc.get("journal").is_some());
        assert_eq!(doc.get("governed_bytes").and_then(Json::as_u64), Some(st.governed_bytes()));
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// Access stamps survive a reopen (STAMPS.json), so LRU order
    /// reflects history across daemon restarts; a torn stamp file only
    /// makes records equally cold, never errors.
    #[test]
    fn stamps_persist_across_reopen_and_tolerate_corruption() {
        let s = tmp_store("stamps");
        let m = sample_measurement();
        for k in 1..=4u64 {
            s.put(k, &Ok(m.clone()), false).unwrap();
        }
        let esize = s.governed_bytes() / 4;
        let root = s.root().to_path_buf();
        let budget = esize * 4 + esize / 2;
        let s = Store::open(&root).unwrap().with_max_bytes(Some(budget));
        // warm key 1 enough times to force a batched flush
        for _ in 0..STAMP_FLUSH_EVERY {
            assert!(s.get(1).is_some());
        }
        drop(s);
        let s = Store::open(&root).unwrap().with_max_bytes(Some(budget));
        s.put(5, &Ok(m.clone()), false).unwrap();
        assert!(s.get(1).is_some(), "stamp from the previous process protects the warm key");
        assert!(s.get(2).is_none());
        // a torn stamp file is "no stamps", never an error
        std::fs::write(root.join(STAMPS_FILE), "{ torn").unwrap();
        let s = Store::open(&root).unwrap().with_max_bytes(Some(budget));
        assert!(s.get(5).is_some(), "store opens and serves despite the torn stamp file");
        let _ = std::fs::remove_dir_all(&root);
    }
}
