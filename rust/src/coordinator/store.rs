//! Durable, content-addressed measurement store (the PR-2 tentpole).
//!
//! PR 1's memoization layer is process-local: every `pipefwd` invocation
//! and every CI run re-simulates the whole grid. This module persists each
//! `(transformed-IR hash, DeviceConfig, ExecOptions) → CellResult` record
//! as one canonical-JSON file under a results directory (default
//! `.pipefwd-cache/`), so shards and successive runs share work:
//!
//! * **One file per entry** — `entries/<16-hex-key>.json`, written with a
//!   temp-file + rename so concurrent writers (shard processes, parallel
//!   engines on one store) never expose torn bytes; the last writer wins
//!   with identical content because measurements are deterministic.
//! * **Corruption tolerance** — a truncated, garbled, or
//!   wrong-schema-version entry is a cache *miss*, never a crash: the
//!   engine just re-simulates and rewrites it.
//! * **Stable keys** — entries outlive the process, so the content address
//!   is FNV-1a over a canonical signature string, not `DefaultHasher`
//!   (whose output is unspecified across Rust releases). The key shape
//!   (see `engine::content_signature`) is
//!   `workload \n scale \n DeviceConfig \n profile/des flags \n
//!   per-launch-unit transformed IR`, hashed to 64 bits — pipe depth and
//!   replication factor are part of the IR text, so every probe of the
//!   PR-3 tuner's depth×replication product space (`coordinator::tune`)
//!   lands under this same key shape, and a warm store replays an entire
//!   search with zero simulations. (PR 3 still bumps [`STORE_SCHEMA`] to
//!   v2: the *record* format changed — error strings gained class
//!   prefixes — not the key.)
//! * **Manifest** — `MANIFEST.json` lists every key in sorted order for
//!   fast external enumeration (CI, tooling). The directory scan remains
//!   the source of truth; the manifest is advisory and rewritten after
//!   each run and merge.
//! * **Trace tier (v3)** — execution traces (the functional interpreter's
//!   per-launch profiles, `workloads::ExecTrace`) persist under
//!   `traces/<16-hex-key>.json` beside the measurement entries, keyed by
//!   the *depth-invariant* `engine::trace_key`. A warm store answers a
//!   whole depth ladder from one trace file; `merge_from` carries traces
//!   across shards like any other entry.

use super::engine::{CellResult, TraceResult};
use super::experiments::Measurement;
use crate::util::json::{self, Json};
use crate::workloads::ExecTrace;
use std::io;
use std::path::{Path, PathBuf};

/// Store layout/keying version. Bumping this orphans every existing entry
/// (old files parse but fail the schema check and read as misses), which is
/// exactly what a change to the key signature or record format requires.
/// CI keys its shared cache on this string. v2: error records carry a
/// class prefix (`validation: ` / `infeasible: `) that `best_ff` and the
/// PR-3 tuner dispatch on — v1 stores hold unprefixed error strings that
/// would be misclassified as fatal, so they must read as misses. v3: the
/// two-tier measurement pipeline — execution traces persist under
/// `traces/` beside the measurement entries, and the interpreter moved to
/// chunked pipe transfers, which can change results for depth-*sensitive*
/// workloads (NW past its safe depth) — v2 measurement entries must
/// therefore read as misses, not be served beside v3 ones.
pub const STORE_SCHEMA: &str = "pipefwd-store-v3";

/// Default results directory (overridable via `--cache-dir` /
/// `PIPEFWD_CACHE_DIR`).
pub const DEFAULT_DIR: &str = ".pipefwd-cache";

/// FNV-1a 64-bit: tiny, dependency-free, and — unlike `DefaultHasher` —
/// specified, so persisted keys stay valid across toolchains.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fixed-width file-name form of a key.
pub fn key_hex(key: u64) -> String {
    format!("{key:016x}")
}

/// Durable measurement store rooted at one directory.
pub struct Store {
    root: PathBuf,
}

impl Store {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> io::Result<Store> {
        let root = root.into();
        std::fs::create_dir_all(root.join("entries"))?;
        std::fs::create_dir_all(root.join("traces"))?;
        Ok(Store { root })
    }

    /// Open an existing store, erroring if `root` is not one — the
    /// read side (`merge <dir>...`), where silently fabricating an empty
    /// store would turn a typo or a missing CI artifact into a misleading
    /// "shard incomplete" failure later.
    pub fn open_existing(root: impl Into<PathBuf>) -> io::Result<Store> {
        let root = root.into();
        if !root.join("entries").is_dir() {
            return Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("{} is not a measurement store (no entries/ directory)", root.display()),
            ));
        }
        Ok(Store { root })
    }

    /// The store directory configured for this process: `--cache-dir` wins,
    /// then `PIPEFWD_CACHE_DIR`, then [`DEFAULT_DIR`].
    pub fn resolve_dir(flag: Option<&str>) -> PathBuf {
        match flag {
            Some(d) => PathBuf::from(d),
            None => std::env::var("PIPEFWD_CACHE_DIR")
                .map(PathBuf::from)
                .unwrap_or_else(|_| PathBuf::from(DEFAULT_DIR)),
        }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn entry_path(&self, key: u64) -> PathBuf {
        self.root.join("entries").join(format!("{}.json", key_hex(key)))
    }

    fn trace_path(&self, key: u64) -> PathBuf {
        self.root.join("traces").join(format!("{}.json", key_hex(key)))
    }

    /// Look an entry up. Any defect — missing file, truncated or garbled
    /// JSON, schema-version mismatch, key mismatch, malformed record — is a
    /// miss, not an error: the caller re-simulates and overwrites.
    pub fn get(&self, key: u64) -> Option<CellResult> {
        let doc = json::read_file(&self.entry_path(key)).ok()?;
        decode_entry(&doc, key)
    }

    /// Persist an entry (atomic temp-file + rename; see `util::json`).
    /// `des` records which estimator produced the measurement — advisory
    /// metadata for filtered rendering; the content key already separates
    /// DES from analytic entries.
    pub fn put(&self, key: u64, result: &CellResult, des: bool) -> io::Result<()> {
        json::write_file_atomic(&self.entry_path(key), &encode_entry(key, result, des))
    }

    /// Look a trace up (the measurement pipeline's first tier). Same
    /// corruption contract as [`Store::get`]: any defect is a miss — the
    /// engine re-runs the interpreter and rewrites the entry.
    pub fn get_trace(&self, key: u64) -> Option<TraceResult> {
        let doc = json::read_file(&self.trace_path(key)).ok()?;
        decode_trace(&doc, key)
    }

    /// Persist a trace-tier entry (atomic temp-file + rename;
    /// [`Store::open`] created `traces/`). Traces are written compact —
    /// one record per host launch, they dominate the store's disk
    /// footprint.
    pub fn put_trace(&self, key: u64, result: &TraceResult) -> io::Result<()> {
        json::write_file_atomic_compact(&self.trace_path(key), &encode_trace(key, result))
    }

    /// Every key present on disk (directory scan — the source of truth).
    pub fn keys(&self) -> Vec<u64> {
        Self::scan_keys(self.root.join("entries"))
    }

    /// Every trace-tier key present on disk.
    pub fn trace_keys(&self) -> Vec<u64> {
        Self::scan_keys(self.root.join("traces"))
    }

    fn scan_keys(dir: PathBuf) -> Vec<u64> {
        let mut keys: Vec<u64> = match std::fs::read_dir(dir) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter_map(|e| {
                    let name = e.file_name().to_string_lossy().to_string();
                    let hex = name.strip_suffix(".json")?;
                    u64::from_str_radix(hex, 16).ok()
                })
                .collect(),
            Err(_) => vec![],
        };
        keys.sort_unstable();
        keys
    }

    pub fn len(&self) -> usize {
        self.keys().len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys().is_empty()
    }

    /// Every *valid* entry on disk (corrupt files are skipped).
    pub fn entries(&self) -> Vec<(u64, CellResult)> {
        self.keys().into_iter().filter_map(|k| self.get(k).map(|r| (k, r))).collect()
    }

    /// Every successful measurement, in the canonical (workload, variant,
    /// scale) order the results sink uses.
    pub fn measurements(&self) -> Vec<Measurement> {
        let mut ms: Vec<Measurement> =
            self.entries().into_iter().filter_map(|(_, r)| r.ok()).collect();
        super::experiments::canonical_sort(&mut ms);
        ms
    }

    /// [`Store::measurements`] restricted to one dataset scale and one
    /// estimator — a store accumulates entries across scales and `--des`
    /// runs, and mixing them in one rendering would show duplicate
    /// configurations with divergent times.
    pub fn measurements_filtered(&self, scale: &str, des: bool) -> Vec<Measurement> {
        let mut ms: Vec<Measurement> = self
            .keys()
            .into_iter()
            .filter_map(|key| {
                let doc = json::read_file(&self.entry_path(key)).ok()?;
                if doc.get("des")?.as_bool()? != des {
                    return None;
                }
                match decode_entry(&doc, key)? {
                    Ok(m) if m.scale == scale => Some(m),
                    _ => None,
                }
            })
            .collect();
        super::experiments::canonical_sort(&mut ms);
        ms
    }

    /// Copy every entry of `other` that this store lacks (raw document
    /// copy, preserving all metadata), measurement and trace tiers both.
    /// Returns how many entries were imported. Corrupt source entries are
    /// skipped; a corrupt local entry is replaced by a valid imported one.
    pub fn merge_from(&self, other: &Store) -> io::Result<usize> {
        let mut imported = 0;
        for key in other.keys() {
            if self.get(key).is_some() {
                continue;
            }
            let Ok(doc) = json::read_file(&other.entry_path(key)) else { continue };
            if decode_entry(&doc, key).is_none() {
                continue;
            }
            json::write_file_atomic(&self.entry_path(key), &doc)?;
            imported += 1;
        }
        for key in other.trace_keys() {
            if self.get_trace(key).is_some() {
                continue;
            }
            let Ok(doc) = json::read_file(&other.trace_path(key)) else { continue };
            if decode_trace(&doc, key).is_none() {
                continue;
            }
            json::write_file_atomic_compact(&self.trace_path(key), &doc)?;
            imported += 1;
        }
        Ok(imported)
    }

    /// Rewrite `MANIFEST.json`: schema + sorted key list.
    pub fn write_manifest(&self) -> io::Result<PathBuf> {
        let doc = Json::Obj(vec![
            ("schema".into(), Json::Str(STORE_SCHEMA.into())),
            (
                "keys".into(),
                Json::Arr(self.keys().into_iter().map(|k| Json::Str(key_hex(k))).collect()),
            ),
        ]);
        let path = self.root.join("MANIFEST.json");
        json::write_file_atomic(&path, &doc)?;
        Ok(path)
    }

    /// The manifest's key list, if present and valid for this schema.
    /// Advisory: may lag the directory (e.g. after a crashed run).
    pub fn load_manifest(&self) -> Option<Vec<u64>> {
        let doc = json::read_file(&self.root.join("MANIFEST.json")).ok()?;
        if doc.get("schema")?.as_str()? != STORE_SCHEMA {
            return None;
        }
        doc.get("keys")?
            .as_array()?
            .iter()
            .map(|k| u64::from_str_radix(k.as_str()?, 16).ok())
            .collect()
    }
}

fn encode_entry(key: u64, result: &CellResult, des: bool) -> Json {
    let mut fields = vec![
        ("schema".into(), Json::Str(STORE_SCHEMA.into())),
        ("key".into(), Json::Str(key_hex(key))),
        ("des".into(), Json::Bool(des)),
    ];
    match result {
        Ok(m) => {
            fields.push(("status".into(), Json::Str("ok".into())));
            fields.push(("measurement".into(), m.to_json()));
        }
        Err(e) => {
            fields.push(("status".into(), Json::Str("err".into())));
            fields.push(("error".into(), Json::Str(e.clone())));
        }
    }
    Json::Obj(fields)
}

fn decode_entry(doc: &Json, key: u64) -> Option<CellResult> {
    if doc.get("schema")?.as_str()? != STORE_SCHEMA {
        return None;
    }
    if doc.get("key")?.as_str()? != key_hex(key) {
        return None;
    }
    match doc.get("status")?.as_str()? {
        "ok" => Measurement::from_json(doc.get("measurement")?).map(Ok),
        "err" => Some(Err(doc.get("error")?.as_str()?.to_string())),
        _ => None,
    }
}

fn encode_trace(key: u64, result: &TraceResult) -> Json {
    let mut fields = vec![
        ("schema".into(), Json::Str(STORE_SCHEMA.into())),
        ("kind".into(), Json::Str("trace".into())),
        ("key".into(), Json::Str(key_hex(key))),
    ];
    match result {
        Ok(trace) => {
            fields.push(("status".into(), Json::Str("ok".into())));
            fields.push(("launches".into(), trace.to_json()));
        }
        Err(e) => {
            fields.push(("status".into(), Json::Str("err".into())));
            fields.push(("error".into(), Json::Str(e.clone())));
        }
    }
    Json::Obj(fields)
}

fn decode_trace(doc: &Json, key: u64) -> Option<TraceResult> {
    if doc.get("schema")?.as_str()? != STORE_SCHEMA {
        return None;
    }
    if doc.get("kind")?.as_str()? != "trace" {
        return None;
    }
    if doc.get("key")?.as_str()? != key_hex(key) {
        return None;
    }
    match doc.get("status")?.as_str()? {
        "ok" => ExecTrace::from_json(doc.get("launches")?).map(Ok),
        "err" => Some(Err(doc.get("error")?.as_str()?.to_string())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::LaunchRecord;

    fn tmp_store(name: &str) -> Store {
        let dir = std::env::temp_dir()
            .join(format!("pipefwd-store-unit-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Store::open(dir).unwrap()
    }

    fn sample_measurement() -> Measurement {
        Measurement {
            workload: "fw".into(),
            variant: "ff(d1)".into(),
            scale: "tiny".into(),
            seconds: 0.125,
            cycles: 3.0e7,
            logic_pct: 17.5,
            brams: 412,
            max_ii: 285,
            max_bw: 7.34e9,
            launches: 3,
        }
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // published FNV-1a test vectors — the persisted keys depend on them
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn open_existing_rejects_non_stores() {
        let dir = std::env::temp_dir()
            .join(format!("pipefwd-store-unit-{}-absent", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(Store::open_existing(&dir).is_err(), "absent dir must not open");
        Store::open(&dir).unwrap();
        assert!(Store::open_existing(&dir).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn roundtrips_ok_and_err_entries() {
        let s = tmp_store("roundtrip");
        let m = sample_measurement();
        s.put(1, &Ok(m.clone()), false).unwrap();
        s.put(2, &Err("replication unsupported".into()), false).unwrap();
        assert_eq!(s.get(1), Some(Ok(m)));
        assert_eq!(s.get(2), Some(Err("replication unsupported".into())));
        assert_eq!(s.get(3), None);
        assert_eq!(s.keys(), vec![1, 2]);
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn corrupt_truncated_and_mismatched_entries_are_misses() {
        let s = tmp_store("corrupt");
        let m = sample_measurement();
        s.put(7, &Ok(m.clone()), false).unwrap();
        let path = s.root().join("entries").join(format!("{}.json", key_hex(7)));

        // truncated mid-document
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert_eq!(s.get(7), None, "truncated entry must be a miss");

        // outright garbage
        std::fs::write(&path, "not json at all \u{0}\u{1}").unwrap();
        assert_eq!(s.get(7), None, "garbled entry must be a miss");

        // valid JSON, wrong schema version (a schema bump invalidates)
        let stale = full.replace(STORE_SCHEMA, "pipefwd-store-v0");
        std::fs::write(&path, &stale).unwrap();
        assert_eq!(s.get(7), None, "old-schema entry must be a miss");

        // valid JSON under the wrong key (e.g. a mis-copied file)
        s.put(8, &Ok(m), false).unwrap();
        std::fs::copy(s.root().join("entries").join(format!("{}.json", key_hex(8))), &path)
            .unwrap();
        assert_eq!(s.get(7), None, "key-mismatched entry must be a miss");
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn manifest_roundtrips_and_rejects_other_schemas() {
        let s = tmp_store("manifest");
        s.put(5, &Err("e".into()), false).unwrap();
        s.put(3, &Err("e".into()), false).unwrap();
        s.write_manifest().unwrap();
        assert_eq!(s.load_manifest(), Some(vec![3, 5]));
        let text = std::fs::read_to_string(s.root().join("MANIFEST.json"))
            .unwrap()
            .replace(STORE_SCHEMA, "pipefwd-store-v0");
        std::fs::write(s.root().join("MANIFEST.json"), text).unwrap();
        assert_eq!(s.load_manifest(), None);
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn measurements_filter_by_scale_and_estimator() {
        let s = tmp_store("filter");
        let analytic_tiny = sample_measurement();
        let mut des_tiny = sample_measurement();
        des_tiny.seconds = 0.25; // DES estimate of the same configuration
        let mut analytic_small = sample_measurement();
        analytic_small.scale = "small".into();
        s.put(1, &Ok(analytic_tiny.clone()), false).unwrap();
        s.put(2, &Ok(des_tiny.clone()), true).unwrap();
        s.put(3, &Ok(analytic_small), false).unwrap();
        s.put(4, &Err("infeasible".into()), false).unwrap();
        assert_eq!(s.measurements_filtered("tiny", false), vec![analytic_tiny]);
        assert_eq!(s.measurements_filtered("tiny", true), vec![des_tiny]);
        assert_eq!(s.measurements().len(), 3, "unfiltered view keeps everything");
        let _ = std::fs::remove_dir_all(s.root());
    }

    /// Tuner probes persist like any other measurement: product-space
    /// variants (deep pipes, replication at depth) round-trip and sort
    /// canonically next to the classic grid entries.
    #[test]
    fn tuner_product_space_entries_roundtrip_and_sort() {
        let s = tmp_store("tune-space");
        let mk = |variant: &str| {
            let mut m = sample_measurement();
            m.variant = variant.into();
            m
        };
        s.put(1, &Ok(mk("m3c3(d16)")), false).unwrap();
        s.put(2, &Ok(mk("ff(d512)")), false).unwrap();
        s.put(3, &Ok(mk("ff(d1)")), false).unwrap();
        let ms = s.measurements_filtered("tiny", false);
        let variants: Vec<&str> = ms.iter().map(|m| m.variant.as_str()).collect();
        assert_eq!(variants, vec!["ff(d1)", "ff(d512)", "m3c3(d16)"]);
        let _ = std::fs::remove_dir_all(s.root());
    }

    fn sample_trace() -> ExecTrace {
        let mut prof = crate::sim::profile::KernelProfile::new("fw_mem", 3);
        for a in 0..50i64 {
            prof.sites[0].record(a);
            prof.sites[1].record(a * 7 % 13);
        }
        prof.loops.insert(crate::ir::LoopId(0), crate::sim::profile::LoopStats {
            invocations: 1,
            iters: 50,
        });
        prof.pipe_writes = 100;
        ExecTrace {
            launches: vec![
                LaunchRecord { unit: "fw_kernel".into(), profiles: vec![prof.clone()] },
                LaunchRecord { unit: "fw_kernel".into(), profiles: vec![prof] },
            ],
        }
    }

    #[test]
    fn trace_entries_roundtrip_ok_and_err() {
        let s = tmp_store("trace-roundtrip");
        let t = sample_trace();
        s.put_trace(11, &Ok(t.clone())).unwrap();
        s.put_trace(12, &Err("validation: nw: m[9] = 1, want 2".into())).unwrap();
        assert_eq!(s.get_trace(11), Some(Ok(t)));
        assert_eq!(s.get_trace(12), Some(Err("validation: nw: m[9] = 1, want 2".into())));
        assert_eq!(s.get_trace(13), None);
        assert_eq!(s.trace_keys(), vec![11, 12]);
        // the two tiers are separate namespaces: no measurement entry
        // exists under a trace key
        assert_eq!(s.get(11), None);
        assert_eq!(s.len(), 0, "traces must not count as measurement entries");
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn corrupt_or_stale_trace_entries_are_misses() {
        let s = tmp_store("trace-corrupt");
        s.put_trace(7, &Ok(sample_trace())).unwrap();
        let path = s.root().join("traces").join(format!("{}.json", key_hex(7)));
        let full = std::fs::read_to_string(&path).unwrap();

        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert_eq!(s.get_trace(7), None, "truncated trace must be a miss");

        // a previous schema version (the chunked-interpreter bump): stale
        let stale = full.replace(STORE_SCHEMA, "pipefwd-store-v2");
        std::fs::write(&path, &stale).unwrap();
        assert_eq!(s.get_trace(7), None, "v2 trace must be a miss under v3");

        // a measurement entry misfiled under a trace path (wrong kind)
        s.put(7, &Ok(sample_measurement()), false).unwrap();
        std::fs::copy(s.root().join("entries").join(format!("{}.json", key_hex(7))), &path)
            .unwrap();
        assert_eq!(s.get_trace(7), None, "kind mismatch must be a miss");
        let _ = std::fs::remove_dir_all(s.root());
    }

    #[test]
    fn merge_from_carries_traces_across_stores() {
        let a = tmp_store("trace-merge-a");
        let b = tmp_store("trace-merge-b");
        let t = sample_trace();
        b.put_trace(21, &Ok(t.clone())).unwrap();
        b.put(22, &Ok(sample_measurement()), false).unwrap();
        assert_eq!(a.merge_from(&b).unwrap(), 2, "one trace + one measurement");
        assert_eq!(a.get_trace(21), Some(Ok(t)));
        assert!(a.get(22).is_some());
        let _ = std::fs::remove_dir_all(a.root());
        let _ = std::fs::remove_dir_all(b.root());
    }

    #[test]
    fn merge_from_imports_only_missing_entries() {
        let a = tmp_store("merge-a");
        let b = tmp_store("merge-b");
        let m = sample_measurement();
        a.put(1, &Ok(m.clone()), false).unwrap();
        b.put(1, &Err("divergent (must not overwrite)".into()), false).unwrap();
        b.put(2, &Ok(m.clone()), false).unwrap();
        assert_eq!(a.merge_from(&b).unwrap(), 1);
        assert_eq!(a.get(1), Some(Ok(m.clone())), "existing entries are kept");
        assert_eq!(a.get(2), Some(Ok(m)));
        let _ = std::fs::remove_dir_all(a.root());
        let _ = std::fs::remove_dir_all(b.root());
    }

    #[test]
    fn concurrent_writers_lose_no_records() {
        let s = tmp_store("concurrent");
        let m = sample_measurement();
        std::thread::scope(|sc| {
            for t in 0..8u64 {
                let s = &s;
                let m = &m;
                sc.spawn(move || {
                    for k in 0..16u64 {
                        // half the keys contended by every thread, half private
                        let key = if k % 2 == 0 { k } else { t * 100 + k };
                        s.put(key, &Ok(m.clone()), false).unwrap();
                        assert!(s.get(key).is_some(), "entry must be readable after put");
                    }
                });
            }
        });
        // all contended + all private keys present and valid
        for k in (0..16u64).filter(|k| k % 2 == 0) {
            assert_eq!(s.get(k), Some(Ok(m.clone())));
        }
        for t in 0..8u64 {
            for k in (0..16u64).filter(|k| k % 2 == 1) {
                assert_eq!(s.get(t * 100 + k), Some(Ok(m.clone())));
            }
        }
        assert_eq!(s.len(), 8 + 8 * 8);
        let _ = std::fs::remove_dir_all(s.root());
    }
}
