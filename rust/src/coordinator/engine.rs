//! Parallel, cache-aware experiment engine (the PR-1 tentpole).
//!
//! Three pieces:
//!
//! * **Grid fan-out** — every experiment E1–E7 is described as a grid of
//!   [`Cell`]s (workload × variant × scale). [`Engine::run_cells`] fans a
//!   grid out across a std-thread worker pool (rayon is unavailable in
//!   this offline image; `std::thread::scope` plus an atomic work index is
//!   the same work-stealing shape).
//! * **Content-addressed memoization** — measurements are keyed on the
//!   hash of the *transformed kernel IR* (pretty-printed launch units:
//!   pipes, depths, replication — everything the variant decides) plus the
//!   [`DeviceConfig`] and [`ExecOptions`]. Experiments overlap heavily
//!   (every table re-measures the feed-forward baseline), so each unique
//!   configuration is simulated exactly once per engine, even under
//!   concurrency: the cache has claim/fulfil semantics and other workers
//!   block on in-flight entries instead of recomputing them.
//! * **Structured results sink** — every cached measurement serializes to
//!   `BENCH_PR1.json` in a canonical sort order, so the serial and
//!   parallel engines produce byte-identical files (proved by
//!   `tests/integration_engine.rs`).
//!
//! PR 2 layers a durable tier beneath the memo table: an attached
//! [`Store`] is consulted on every memo miss and written behind every
//! simulation, so shards ([`shard_cells`]) and successive processes share
//! work; [`merge_bench_json`] reassembles shard stores into the same
//! canonical sink bytes. Keys are stable FNV-1a content addresses
//! ([`content_key`]) because they now outlive the process.
//!
//! PR 4 splits the measurement itself into **two content-addressed
//! tiers**, mirroring the paper's core move of letting each part of a
//! pipeline run at its natural rate:
//!
//! 1. **Trace acquisition** — the functional interpreter run producing
//!    [`crate::workloads::ExecTrace`], keyed by [`trace_key`]: the full
//!    signature with pipe depths *masked to 1* wherever the trace is
//!    provably (or vouchedly) depth-invariant, and with `DeviceConfig` /
//!    the estimator flag dropped entirely (the interpreter sees neither).
//!    This is by far the most expensive stage, and it is exactly the one
//!    a depth ladder repeats needlessly: with the tier in place, a sweep
//!    over D depths runs the interpreter once per (workload, scale).
//! 2. **Modelling** — the analytic `PerfModel` (or the DES under
//!    `--des`), replayed from the trace against the *actual* probed
//!    configuration, keyed by the existing full [`content_key`].
//!
//! Both tiers persist in the attached [`Store`] (measurement entries +
//! trace entries whose per-launch profiles live in a content-addressed
//! pool) and are counted separately:
//! [`Engine::trace_runs`] (interpreter executions) and
//! [`Engine::trace_hits`] (trace-tier answers) next to
//! [`Engine::store_hits`] / [`Engine::simulations`].
//!
//! # The device axis and the key shape
//!
//! An engine is bound to exactly one device profile
//! (`DeviceConfig::by_name` / the CLI `--device` flag); `--device all`
//! fans out one engine per registry profile and stitches their E8
//! portability rows together with [`cross_device_table`].
//! The two tiers split cleanly across devices:
//!
//! * **Measurement keys** ([`content_key`]) are per-device. The signature
//!   embeds the frozen `Debug` of the 32 classic `DeviceConfig` fields
//!   and, for every device *except* `arria10`, an extra
//!   `device=<name>` line carrying the registry name (which also stands
//!   in for the device's `MemModel` calibration). `arria10` omits the
//!   line so its keys — and therefore every store record written before
//!   the device zoo existed (schema <= v4, accepted by the v5 store) —
//!   hash identically to today's.
//! * **Trace keys** ([`trace_key`]) carry no device at all: the
//!   functional interpreter never consults a `DeviceConfig`, so all
//!   registry profiles share one trace per (workload, scale) — a full
//!   cross-device sweep pays the interpreter cost once, then replays the
//!   model per device. The depth-invariance vouch contract is unchanged:
//!   pipe depths are masked to 1 in the trace key wherever
//!   [`unit_depth_invariant`] proves (or the workload's
//!   `benign_cross_kernel_races` vouch asserts) the interpreter's
//!   observable trace cannot depend on channel capacity; depth-sensitive
//!   units (NW) keep their real depths. Vouches are claims about the
//!   *interpreter*, not the model — modelled time may (and on HBM-class
//!   profiles does) depend on depth even for vouched workloads.
//!
//! # The launch-graph axis (overlap)
//!
//! The scheduling unit used to be one launch; it is now a launch *graph*.
//! With overlap on ([`Engine::with_overlap`] / `run --overlap`), the
//! modelling tier replays the recorded launch trace as a dependence DAG
//! (`analysis::deps`) and co-schedules mutually unordered launches
//! through the graph DES (`sim::des::simulate_graph`) — MKPipe-style
//! multi-kernel overlap. Key shape follows the `device=` precedent:
//! overlap-on measurements get a dedicated trailing `overlap=on`
//! signature line that is **omitted when off**, so every overlap-off key
//! is byte for byte the pre-overlap key and existing stores stay warm.
//! Overlapped rows carry a `+ov` variant-label suffix in the results
//! sink (sequential and overlapped measurements of one cell must sort
//! apart in [`experiments::canonical_sort`]), and their `launches` field
//! reports DAG wavefronts — the scheduling unit under overlap. The trace
//! tier is untouched: both legs of E9 share one interpreter run.

use super::experiments::{self, Measurement, DEPTHS};
use super::scale_label;
use super::store::{fnv1a64, Store};
use super::tune::{self, TuneSpec};
use crate::report::{fx, mbps, ms, Table};
use crate::sim::device::DeviceConfig;
use crate::sim::exec::ExecOptions;
use crate::transform::Variant;
use crate::util::json::Json;
use crate::workloads::micro::{Micro, MicroSpec};
use crate::workloads::{
    by_name, is_validation_error, replay_built_workload, replay_built_workload_overlapped,
    run_built_workload_recorded, suite, unit_depth_invariant, ExecTrace, Scale, Workload,
};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Benchmarks used by the paper's sweep experiments (E4c/E4d).
pub const SWEEP_TRIO: [&str; 3] = ["fw", "hotspot", "mis"];
/// Benchmarks quoted in the paper's in-text II/bandwidth numbers (E4a/b).
pub const INTEXT_NAMES: [&str; 6] = ["fw", "backprop", "mis", "bfs", "nw", "hotspot"];
/// Benchmarks of the vector-type case study (E4e).
pub const VECTOR_NAMES: [&str; 2] = ["fw", "mis"];
/// Multi-launch graph workloads of the overlap study (E9): each drives a
/// host loop launching several kernels per iteration, so the launch
/// dependence DAG has real width for the scheduler to exploit.
pub const GRAPH_TRIO: [&str; 3] = ["bfs", "color", "pagerank"];

// ---------------------------------------------------------------------------
// Experiment index
// ---------------------------------------------------------------------------

/// The paper's experiment index (see DESIGN.md): one id per table/figure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExperimentId {
    /// Table 2: feed-forward vs single work-item baseline.
    E1,
    /// Figure 4: M2C2 speedup + resource overhead.
    E2,
    /// Table 3: microbenchmarks.
    E3,
    /// In-text numbers and sweeps (II/bandwidth, depth, producer/consumer,
    /// vector types).
    E4,
    /// Extended microbenchmark family (the paper's future-work sweep).
    E5,
    /// Table 1: benchmark characterisation (no simulation).
    E6,
    /// Headline speedup claims.
    E7,
    /// Cross-device portability grid: the pipe win and best channel depth
    /// per device (one device per engine; `--device all` stitches the
    /// registry's rows together via [`cross_device_table`]). Its cells
    /// are a subset of E4's, so it adds no new reachable store keys.
    E8,
    /// Launch-graph overlap study: sequential vs overlapped modelled
    /// time on the multi-launch graph workloads ([`GRAPH_TRIO`]). Both
    /// legs are DES-modelled over one shared trace, so the delta
    /// isolates scheduling — the dependence DAG's width — not estimator
    /// choice.
    E9,
}

impl ExperimentId {
    pub fn parse(s: &str) -> Option<ExperimentId> {
        match s.to_ascii_uppercase().as_str() {
            "E1" => Some(ExperimentId::E1),
            "E2" => Some(ExperimentId::E2),
            "E3" => Some(ExperimentId::E3),
            "E4" => Some(ExperimentId::E4),
            "E5" => Some(ExperimentId::E5),
            "E6" => Some(ExperimentId::E6),
            "E7" => Some(ExperimentId::E7),
            "E8" => Some(ExperimentId::E8),
            "E9" => Some(ExperimentId::E9),
            _ => None,
        }
    }

    pub fn all() -> [ExperimentId; 9] {
        [
            ExperimentId::E1,
            ExperimentId::E2,
            ExperimentId::E3,
            ExperimentId::E4,
            ExperimentId::E5,
            ExperimentId::E6,
            ExperimentId::E7,
            ExperimentId::E8,
            ExperimentId::E9,
        ]
    }

    pub fn label(self) -> &'static str {
        match self {
            ExperimentId::E1 => "E1",
            ExperimentId::E2 => "E2",
            ExperimentId::E3 => "E3",
            ExperimentId::E4 => "E4",
            ExperimentId::E5 => "E5",
            ExperimentId::E6 => "E6",
            ExperimentId::E7 => "E7",
            ExperimentId::E8 => "E8",
            ExperimentId::E9 => "E9",
        }
    }
}

/// One point of an experiment grid.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub workload: String,
    pub variant: Variant,
    pub scale: Scale,
}

impl Cell {
    pub fn new(workload: &str, variant: Variant, scale: Scale) -> Cell {
        Cell { workload: workload.to_string(), variant, scale }
    }
}

/// Resolve a workload by name: the Table-1 suite first, then the
/// auto-generated microbenchmarks (Table 3 + family).
pub fn resolve_workload(name: &str) -> Option<Box<dyn Workload>> {
    if let Some(w) = by_name(name) {
        return Some(w);
    }
    MicroSpec::table3()
        .into_iter()
        .chain(MicroSpec::family())
        .find(|spec| spec.label() == name)
        .map(|spec| Box::new(Micro::new(spec)) as Box<dyn Workload>)
}

/// Drop duplicate cells, keeping first-occurrence order. Experiments
/// overlap heavily (every table re-measures the feed-forward baselines);
/// sharding must partition *unique* cells or two shards would each
/// simulate the shared ones. O(n) via a seen-set (`run --experiment all`
/// concatenates seven overlapping grids).
pub fn dedup_cells(cells: &[Cell]) -> Vec<Cell> {
    let mut seen = std::collections::HashSet::new();
    let mut out: Vec<Cell> = vec![];
    for c in cells {
        if seen.insert(format!("{}\u{1f}{:?}\u{1f}{:?}", c.workload, c.variant, c.scale)) {
            out.push(c.clone());
        }
    }
    out
}

/// Deterministic disjoint partition of a cell grid for `run --shard I/N`
/// (1-based `index`): unique cell `j` belongs to shard `j % count + 1`.
/// Grid construction is deterministic, so independent processes given the
/// same experiments and scale agree on the partition with no coordination.
/// Dedups internally (idempotent and O(n), so already-unique input from
/// [`grid_for`] costs one cheap extra pass). Out-of-range indices are a
/// clean `Err`, never a panic — `--shard 0/3` is user input.
pub fn shard_cells(cells: &[Cell], index: usize, count: usize) -> Result<Vec<Cell>, String> {
    if count == 0 || !(1..=count).contains(&index) {
        return Err(format!("bad shard {index}/{count} (expected I/N with 1 <= I <= N)"));
    }
    Ok(dedup_cells(cells)
        .into_iter()
        .enumerate()
        .filter(|(j, _)| j % count == index - 1)
        .map(|(_, c)| c)
        .collect())
}

/// Sort + dedup a user-supplied depth list: `--depths 100,100,1` must
/// render the same sweep table (and sink) as `--depths 1,100` — duplicate
/// columns and order-dependent output would break the byte-identical
/// guarantees downstream.
pub fn normalize_depths(mut depths: Vec<usize>) -> Vec<usize> {
    depths.sort_unstable();
    depths.dedup();
    depths
}

/// The full (deduplicated) grid of a set of experiments at one scale —
/// what `run` simulates, what shards partition, and what `merge` replays
/// against the persistent stores.
pub fn grid_for(exps: &[ExperimentId], scale: Scale) -> Vec<Cell> {
    let all: Vec<Cell> = exps.iter().flat_map(|e| grid(*e, scale)).collect();
    dedup_cells(&all)
}

/// The simulation grid of one experiment at one scale (the cells the
/// engine prewarms in parallel before the serial table renderers run).
pub fn grid(exp: ExperimentId, scale: Scale) -> Vec<Cell> {
    let names: Vec<String> = suite().iter().map(|w| w.name().to_string()).collect();
    let mut cells = vec![];
    match exp {
        ExperimentId::E1 | ExperimentId::E7 => {
            for name in &names {
                cells.push(Cell::new(name, Variant::Baseline, scale));
                for d in DEPTHS {
                    cells.push(Cell::new(name, Variant::FeedForward { depth: d }, scale));
                }
            }
            if exp == ExperimentId::E7 {
                for name in &names {
                    cells.push(Cell::new(name, Variant::MxCx { parts: 2, depth: 1 }, scale));
                }
            }
        }
        ExperimentId::E2 => {
            for name in &names {
                cells.push(Cell::new(name, Variant::FeedForward { depth: 1 }, scale));
                cells.push(Cell::new(name, Variant::MxCx { parts: 2, depth: 1 }, scale));
            }
        }
        ExperimentId::E3 => {
            for spec in MicroSpec::table3() {
                cells.push(Cell::new(&spec.label(), Variant::Baseline, scale));
                cells.push(Cell::new(&spec.label(), Variant::MxCx { parts: 2, depth: 1 }, scale));
            }
        }
        ExperimentId::E4 => {
            for name in INTEXT_NAMES {
                cells.push(Cell::new(name, Variant::Baseline, scale));
                cells.push(Cell::new(name, Variant::FeedForward { depth: 1 }, scale));
            }
            for name in SWEEP_TRIO {
                for d in DEPTHS {
                    cells.push(Cell::new(name, Variant::FeedForward { depth: d }, scale));
                }
                for parts in [2usize, 3, 4] {
                    cells.push(Cell::new(name, Variant::MxCx { parts, depth: 1 }, scale));
                }
                cells.push(Cell::new(name, Variant::M1Cx { consumers: 2, depth: 1 }, scale));
            }
            for name in VECTOR_NAMES {
                cells.push(Cell::new(name, Variant::Vectorized { width: 4, depth: 1 }, scale));
            }
        }
        ExperimentId::E5 => {
            for spec in MicroSpec::family() {
                cells.push(Cell::new(&spec.label(), Variant::Baseline, scale));
                cells.push(Cell::new(&spec.label(), Variant::FeedForward { depth: 1 }, scale));
                cells.push(Cell::new(&spec.label(), Variant::MxCx { parts: 2, depth: 1 }, scale));
            }
        }
        ExperimentId::E6 => {} // Table 1 is static characterisation
        ExperimentId::E8 => {
            // Strict subset of E4's grid: the portability table only needs
            // the baseline plus the feed-forward depth ladder per trio
            // benchmark, so running E8 after E4 costs zero new simulations
            // and `gc` reachability gains no new keys.
            for name in SWEEP_TRIO {
                cells.push(Cell::new(name, Variant::Baseline, scale));
                for d in DEPTHS {
                    cells.push(Cell::new(name, Variant::FeedForward { depth: d }, scale));
                }
            }
        }
        ExperimentId::E9 => {
            // Both legs of the overlap study replay these cells' shared
            // traces; the overlapped leg is keyed separately (trailing
            // `overlap=on` signature line) and measured by the renderer
            // itself — grid cells can only express (workload, variant,
            // scale).
            for name in GRAPH_TRIO {
                cells.push(Cell::new(name, Variant::FeedForward { depth: 1 }, scale));
            }
        }
    }
    cells
}

// ---------------------------------------------------------------------------
// Content addressing
// ---------------------------------------------------------------------------

/// The canonical signature a measurement is addressed by: workload + scale
/// + device config + exec options + the transformed-IR text of every launch
/// unit (pipes, depths, replication — everything the variant decides).
/// Hashed with FNV-1a (not `DefaultHasher`) because keys persist on disk
/// across processes and toolchains; any change to this format requires a
/// `store::STORE_SCHEMA` bump.
///
/// The device axis rides on a dedicated `device=<name>` line that is
/// **omitted for `arria10`**: the default device's signatures are byte
/// for byte what they were before the device zoo, so every record in
/// every pre-existing store stays a warm hit. Non-default devices get
/// distinct keys via the name line even where their 32 classic `Debug`
/// fields happen to match, because the name also keys the `MemModel`
/// calibration (deliberately excluded from the frozen `Debug` — see
/// `sim::device`).
pub fn content_signature(
    workload: &str,
    app: &crate::workloads::App,
    scale: Scale,
    cfg: &DeviceConfig,
    use_des: bool,
) -> String {
    let mut sig = String::new();
    sig.push_str(workload);
    sig.push('\n');
    sig.push_str(scale_label(scale));
    sig.push('\n');
    sig.push_str(&format!("{cfg:?}"));
    sig.push('\n');
    if cfg.name != "arria10" {
        sig.push_str(&format!("device={}\n", cfg.name));
    }
    sig.push_str(&format!(
        "profile={} des={use_des}\n",
        ExecOptions::default().profile
    ));
    for unit in &app.units {
        sig.push_str(&crate::ir::pretty::program_to_string(unit));
        sig.push('\n');
    }
    sig
}

/// [`content_signature`] hashed down to the store's 64-bit key.
pub fn content_key(
    workload: &str,
    app: &crate::workloads::App,
    scale: Scale,
    cfg: &DeviceConfig,
    use_des: bool,
) -> u64 {
    fnv1a64(content_signature(workload, app, scale, cfg, use_des).as_bytes())
}

/// [`content_signature`] extended with the launch-graph axis. Follows the
/// `device=` precedent exactly: overlap-on signatures carry a dedicated
/// trailing `overlap=on` line, overlap-off signatures are byte for byte
/// the 5-argument form — so every record written before the overlap axis
/// existed stays a warm hit, and the 5-argument [`content_key`] remains
/// the canonical overlap-off address (`merge`, `gc`, and the store views
/// keep calling it directly).
pub fn content_signature_with(
    workload: &str,
    app: &crate::workloads::App,
    scale: Scale,
    cfg: &DeviceConfig,
    use_des: bool,
    overlap: bool,
) -> String {
    let mut sig = content_signature(workload, app, scale, cfg, use_des);
    if overlap {
        sig.push_str("overlap=on\n");
    }
    sig
}

/// [`content_signature_with`] hashed down to the store's 64-bit key.
pub fn content_key_with(
    workload: &str,
    app: &crate::workloads::App,
    scale: Scale,
    cfg: &DeviceConfig,
    use_des: bool,
    overlap: bool,
) -> u64 {
    fnv1a64(content_signature_with(workload, app, scale, cfg, use_des, overlap).as_bytes())
}

/// The trace tier's content signature: what the *functional interpreter*
/// run depends on, and nothing more. Differences from
/// [`content_signature`]:
///
/// * no `DeviceConfig` and no estimator flag — the interpreter consults
///   neither, so analytic and DES engines (and any device config) share
///   one trace;
/// * pipe depths are **masked to 1** in every launch unit whose trace is
///   depth-invariant ([`unit_depth_invariant`], or the workload's
///   [`Workload::benign_cross_kernel_races`] vouch), so every rung of a
///   depth ladder lands on the same trace key. Units where depth can
///   leak into values read (NW) keep their real depths — conservative,
///   never wrong.
///
/// Replication, vectorization and privatization all change the kernel
/// text itself, so they address distinct traces automatically. Any change
/// to this format requires a `store::STORE_SCHEMA` bump.
pub fn trace_signature(
    workload: &str,
    benign_races: bool,
    app: &crate::workloads::App,
    scale: Scale,
) -> String {
    let mut sig = String::from("trace\n");
    sig.push_str(workload);
    sig.push('\n');
    sig.push_str(scale_label(scale));
    sig.push('\n');
    sig.push_str(&format!("profile={}\n", ExecOptions::default().profile));
    for unit in &app.units {
        if benign_races || unit_depth_invariant(unit) {
            let masked = unit.clone().with_pipe_depth(1);
            sig.push_str(&crate::ir::pretty::program_to_string(&masked));
        } else {
            sig.push_str(&crate::ir::pretty::program_to_string(unit));
        }
        sig.push('\n');
    }
    sig
}

/// [`trace_signature`] hashed down to the store's 64-bit key.
pub fn trace_key(
    workload: &str,
    benign_races: bool,
    app: &crate::workloads::App,
    scale: Scale,
) -> u64 {
    fnv1a64(trace_signature(workload, benign_races, app, scale).as_bytes())
}

// ---------------------------------------------------------------------------
// Memoization layer
// ---------------------------------------------------------------------------

/// Outcome of one cell: the measurement, or the feasibility/validation
/// error string (matching the serial path's reporting).
pub type CellResult = Result<Measurement, String>;

/// Outcome of one trace acquisition: the recorded trace, or the
/// execution/validation error string. Shared behind an `Arc` — traces can
/// be large (one record per host launch) and are read by many probes.
pub type TraceResult = Result<ExecTrace, String>;

/// Snapshot of the engine's tier counters ([`Engine::counters`]): one
/// value instead of six accessor calls, so the `Service` facade and the
/// daemon's stats endpoint report a single coherent reading.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineCounters {
    /// Unique configurations entered into the memo table (claimed keys).
    pub cache_len: u64,
    pub cache_hits: u64,
    pub store_hits: u64,
    pub store_errors: u64,
    pub simulations: u64,
    pub trace_hits: u64,
    pub trace_runs: u64,
    /// Interrupted store operations healed when the attached store
    /// opened (counters v3; 0 with no store attached).
    pub journal_replays: u64,
    /// Store writes skipped because the cache dir turned unwritable —
    /// the store degraded to read-only and the engine kept computing
    /// (counters v3; 0 with no store attached).
    pub store_degraded: u64,
    /// Records removed by `--max-bytes` budget eviction (0 with no
    /// store attached or no budget armed).
    pub store_evictions: u64,
    /// Writes skipped by the over-tight-budget write-through-skip mode
    /// (0 with no store attached or no budget armed).
    pub store_budget_skips: u64,
}

enum Slot<V> {
    InFlight,
    Done(V),
}

/// Claim/fulfil memo table: at most one worker computes a key; concurrent
/// requesters for the same key block until it is fulfilled. Generic over
/// the value so the measurement tier ([`CellResult`]) and the trace tier
/// (`Arc<TraceResult>`) share one implementation.
struct ClaimCache<V: Clone> {
    slots: Mutex<HashMap<u64, Slot<V>>>,
    ready: Condvar,
    hits: AtomicU64,
}

impl<V: Clone> ClaimCache<V> {
    fn new() -> ClaimCache<V> {
        ClaimCache {
            slots: Mutex::new(HashMap::new()),
            ready: Condvar::new(),
            hits: AtomicU64::new(0),
        }
    }

    /// `Some(value)` if the key is (or becomes) computed; `None` if the
    /// caller claimed the slot and must compute + [`ClaimCache::fulfil`].
    fn get_or_claim(&self, key: u64) -> Option<V> {
        let mut slots = self.slots.lock().unwrap();
        loop {
            match slots.get(&key) {
                None => {
                    slots.insert(key, Slot::InFlight);
                    return None;
                }
                Some(Slot::Done(v)) => {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Some(v.clone());
                }
                Some(Slot::InFlight) => {
                    slots = self.ready.wait(slots).unwrap();
                }
            }
        }
    }

    fn fulfil(&self, key: u64, value: V) {
        let mut slots = self.slots.lock().unwrap();
        slots.insert(key, Slot::Done(value));
        self.ready.notify_all();
    }

    /// Fulfil `key` only if a claim is currently in flight — an
    /// *external* result (a `store_push` landing the record a worker is
    /// computing) may unblock waiters early, but must never overwrite a
    /// completed slot or fabricate one nobody asked for. Returns whether
    /// it fulfilled. The claiming worker's own later fulfil just
    /// rewrites the identical (content-addressed) value.
    fn fulfil_if_claimed(&self, key: u64, value: V) -> bool {
        let mut slots = self.slots.lock().unwrap();
        if matches!(slots.get(&key), Some(Slot::InFlight)) {
            slots.insert(key, Slot::Done(value));
            self.ready.notify_all();
            return true;
        }
        false
    }

    /// Release an in-flight claim without a result (the computation
    /// panicked): the slot is removed and every waiter is woken — the
    /// next one through [`ClaimCache::get_or_claim`] re-claims and
    /// recomputes. Crucially, no sentinel value is ever stored: a
    /// "panicked" placeholder served to a waiter holding a *different*
    /// claim could be written through to the persistent store and make a
    /// transient panic durable.
    fn abandon(&self, key: u64) {
        let mut slots = self.slots.lock().unwrap();
        if matches!(slots.get(&key), Some(Slot::InFlight)) {
            slots.remove(&key);
        }
        self.ready.notify_all();
    }

    /// Claim a key for computation, returning a guard that abandons the
    /// claim if the computation panics before [`ClaimGuard::fulfil`] runs
    /// — otherwise waiters in [`ClaimCache::get_or_claim`] would block on
    /// the Condvar forever.
    fn claim_guard(&self, key: u64) -> ClaimGuard<'_, V> {
        ClaimGuard { cache: self, key, done: false }
    }

    fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    fn done_values(&self) -> Vec<V> {
        self.slots
            .lock()
            .unwrap()
            .values()
            .filter_map(|s| match s {
                Slot::Done(v) => Some(v.clone()),
                Slot::InFlight => None,
            })
            .collect()
    }
}

struct ClaimGuard<'a, V: Clone> {
    cache: &'a ClaimCache<V>,
    key: u64,
    done: bool,
}

impl<V: Clone> ClaimGuard<'_, V> {
    fn fulfil(mut self, value: V) {
        self.done = true;
        self.cache.fulfil(self.key, value);
    }
}

impl<V: Clone> Drop for ClaimGuard<'_, V> {
    fn drop(&mut self) {
        if !self.done {
            // unwound mid-computation: release the claim so waiters
            // re-claim and recompute while this thread's panic propagates
            self.cache.abandon(self.key);
        }
    }
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

pub struct Engine {
    pub cfg: DeviceConfig,
    /// Worker threads for grid fan-out (1 = serial).
    pub jobs: usize,
    /// Estimate with the discrete-event simulator instead of the analytic
    /// model (`run --des`). Part of the content address, so both estimates
    /// cache side by side.
    pub use_des: bool,
    /// Model launch traces as dependence DAGs and co-schedule unordered
    /// launches through the graph DES (`run --overlap`). Part of the
    /// content address (trailing `overlap=on` signature line), so
    /// sequential and overlapped measurements cache side by side.
    pub overlap: bool,
    cache: ClaimCache<CellResult>,
    /// Trace-tier memo table (depth-invariant keys — see [`trace_key`]):
    /// the in-process layer that lets a cold depth sweep run the
    /// interpreter once per (workload, scale) even with no store attached.
    traces: ClaimCache<Arc<TraceResult>>,
    /// Durable read-through/write-behind tier beneath the in-memory memo
    /// table (`coordinator::store`). `None` = process-local only (PR-1
    /// behavior).
    store: Option<Store>,
    /// When set, [`Engine::best_ff`] searches the depth ladder through
    /// `coordinator::tune` instead of sweeping the exhaustive `DEPTHS`
    /// grid, and [`Engine::depth_sweep`] annotates the tuned choice.
    tuner: Option<TuneSpec>,
    store_hits: AtomicU64,
    store_errors: AtomicU64,
    simulations: AtomicU64,
    trace_hits: AtomicU64,
    trace_runs: AtomicU64,
}

impl Engine {
    pub fn new(cfg: DeviceConfig, jobs: usize) -> Engine {
        Engine {
            cfg,
            jobs: jobs.max(1),
            use_des: false,
            overlap: false,
            cache: ClaimCache::new(),
            traces: ClaimCache::new(),
            store: None,
            tuner: None,
            store_hits: AtomicU64::new(0),
            store_errors: AtomicU64::new(0),
            simulations: AtomicU64::new(0),
            trace_hits: AtomicU64::new(0),
            trace_runs: AtomicU64::new(0),
        }
    }

    /// Attach a persistent measurement store: cache misses consult it
    /// before simulating, and fresh results are written behind it.
    pub fn with_store(mut self, store: Store) -> Engine {
        self.store = Some(store);
        self
    }

    /// Switch the estimator to the discrete-event simulator.
    pub fn with_des(mut self, use_des: bool) -> Engine {
        self.use_des = use_des;
        self
    }

    /// Switch the scheduler to launch-graph overlap: measurements model
    /// the recorded trace as a dependence DAG and co-schedule unordered
    /// launches in wavefronts (always through the graph DES — overlap is
    /// a property of the event-driven scheduler, so the analytic model
    /// cannot express it and `use_des` only keys the cache here).
    pub fn with_overlap(mut self, overlap: bool) -> Engine {
        self.overlap = overlap;
        self
    }

    /// Attach a depth autotuner: `best_ff` searches instead of sweeping,
    /// and the depth-sweep table reports the tuned choice per benchmark.
    pub fn with_tuner(mut self, spec: TuneSpec) -> Engine {
        self.tuner = Some(spec);
        self
    }

    pub fn tuner(&self) -> Option<TuneSpec> {
        self.tuner
    }

    pub fn store(&self) -> Option<&Store> {
        self.store.as_ref()
    }

    /// A single-worker engine (still cached — the serial reference path).
    pub fn serial(cfg: DeviceConfig) -> Engine {
        Engine::new(cfg, 1)
    }

    /// An engine sized to the host (one worker per available core).
    pub fn host_parallel(cfg: DeviceConfig) -> Engine {
        let jobs = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Engine::new(cfg, jobs)
    }

    /// Unique configurations simulated so far.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Measurements served from the memo table instead of re-simulated.
    pub fn cache_hits(&self) -> u64 {
        self.cache.hits.load(Ordering::Relaxed)
    }

    /// Measurements served from the persistent store instead of simulated.
    pub fn store_hits(&self) -> u64 {
        self.store_hits.load(Ordering::Relaxed)
    }

    /// Failed store writes (results computed but not persisted). Shard
    /// runs, whose only product is the store, must treat nonzero as fatal.
    pub fn store_errors(&self) -> u64 {
        self.store_errors.load(Ordering::Relaxed)
    }

    /// Actual simulations performed by this engine (neither memo table nor
    /// store could answer). A warm-store rerun of the same grid reads 0.
    pub fn simulations(&self) -> u64 {
        self.simulations.load(Ordering::Relaxed)
    }

    /// Measurements answered by replaying a cached execution trace
    /// through the model instead of re-running the interpreter (memo or
    /// store tier). On a cold depth sweep over D depths this reads D-1
    /// per depth-invariant (workload, scale).
    pub fn trace_hits(&self) -> u64 {
        self.trace_hits.load(Ordering::Relaxed)
    }

    /// Functional interpreter executions — the expensive tier. A cold
    /// depth sweep reads 1 per depth-invariant (workload, scale); a
    /// warm-store rerun reads 0.
    pub fn trace_runs(&self) -> u64 {
        self.trace_runs.load(Ordering::Relaxed)
    }

    /// One consistent-enough snapshot of every tier counter — what the
    /// `Service` facade reports through `--counters` documents and the
    /// daemon's `GET /stats`. Individual loads are relaxed (exactly like
    /// the accessors above); a snapshot taken while workers are mid-cell
    /// may be skewed by in-flight increments, which the counters gates
    /// never race against (they read quiesced engines).
    pub fn counters(&self) -> EngineCounters {
        EngineCounters {
            cache_len: self.cache.len() as u64,
            cache_hits: self.cache_hits(),
            store_hits: self.store_hits(),
            store_errors: self.store_errors(),
            simulations: self.simulations(),
            trace_hits: self.trace_hits(),
            trace_runs: self.trace_runs(),
            journal_replays: self.store.as_ref().map(|s| s.journal_replays()).unwrap_or(0),
            store_degraded: self.store.as_ref().map(|s| s.degraded_count()).unwrap_or(0),
            store_evictions: self.store.as_ref().map(|s| s.evictions()).unwrap_or(0),
            store_budget_skips: self.store.as_ref().map(|s| s.budget_skips()).unwrap_or(0),
        }
    }

    /// Fulfil an outstanding in-flight measurement claim with an
    /// externally supplied result — the daemon's `store_push` handler
    /// calls this after validating a pushed entry, so a worker (or
    /// waiting client) computing the same key is answered by the push
    /// instead of finishing the simulation alone. Never overwrites a
    /// completed slot and never inserts a slot nobody claimed (keys are
    /// content-addressed, so a racing worker's own fulfil writes the
    /// identical value). Returns whether a claim was fulfilled.
    pub fn fulfil_external(&self, key: u64, result: &CellResult) -> bool {
        self.cache.fulfil_if_claimed(key, result.clone())
    }

    /// Run one (workload, variant, scale) through the memo table and the
    /// persistent store: the feed-forward split runs here (it defines the
    /// content address), but interpretation, the performance model and
    /// validation run at most once per unique configuration — across
    /// processes, when a store is attached. On a full-key miss the work
    /// splits into the two tiers: trace acquisition (interpreter, keyed
    /// depth-invariantly by [`trace_key`]) and modelling (replay through
    /// `PerfModel`/DES at the actual configuration).
    pub fn measure(
        &self,
        w: &dyn Workload,
        variant: Variant,
        scale: Scale,
    ) -> Result<Measurement, String> {
        self.measure_opts(w, variant, scale, self.use_des, self.overlap)
    }

    /// [`Engine::measure`] under explicit estimator/scheduler options,
    /// independent of the engine's defaults. The E9 renderer uses this to
    /// measure both legs of the overlap study through one engine (shared
    /// memo cache, shared trace tier, one store).
    pub fn measure_opts(
        &self,
        w: &dyn Workload,
        variant: Variant,
        scale: Scale,
        use_des: bool,
        overlap: bool,
    ) -> Result<Measurement, String> {
        let app = match w.build(variant) {
            Ok(app) => app,
            // feasibility-class: searches may skip these like validation
            // failures (see workloads::INFEASIBLE_PREFIX)
            Err(e) => return Err(format!("{}{e}", crate::workloads::INFEASIBLE_PREFIX)),
        };
        let key = content_key_with(w.name(), &app, scale, &self.cfg, use_des, overlap);
        if let Some(r) = self.cache.get_or_claim(key) {
            return r;
        }
        let guard = self.cache.claim_guard(key);
        // Pin the key against budget eviction for the whole claim span
        // (read + compute + persist): eviction must never delete the
        // record a worker is serving or has just written but not yet
        // fulfilled. Released on drop, including the panic unwind.
        let _pin = self.store.as_ref().map(|s| s.pin_guard(key));
        if let Some(store) = &self.store {
            if let Some(r) = store.get(key) {
                self.store_hits.fetch_add(1, Ordering::Relaxed);
                guard.fulfil(r.clone());
                return r;
            }
        }
        self.simulations.fetch_add(1, Ordering::Relaxed);
        // `engine.panic` injection site: a worker dies *holding the
        // claim*. The claim guard's unwind path releases the slot so a
        // concurrent (or retried) request recomputes instead of
        // deadlocking; the daemon's worker pool catches the unwind and
        // answers 500, which the client's retry policy recovers.
        crate::util::fault::maybe_panic("engine.panic");
        let result = self.compute_measurement(w, &app, variant, scale, use_des, overlap);
        if let Some(store) = &self.store {
            if let Err(e) = store.put(key, &result, use_des) {
                self.store_errors.fetch_add(1, Ordering::Relaxed);
                eprintln!("store: persisting {} failed: {e}", super::store::key_hex(key));
            }
        }
        guard.fulfil(result.clone());
        result
    }

    /// Full-key miss path: answer from the trace tier (replay) when a
    /// trace exists, else run the interpreter once — recording the trace
    /// for every other configuration that shares it.
    fn compute_measurement(
        &self,
        w: &dyn Workload,
        app: &crate::workloads::App,
        variant: Variant,
        scale: Scale,
        use_des: bool,
        overlap: bool,
    ) -> CellResult {
        let tkey = trace_key(w.name(), w.benign_cross_kernel_races(), app, scale);

        // in-process trace memo (claims the slot on a miss)
        if let Some(tr) = self.traces.get_or_claim(tkey) {
            if let Some(r) = self.result_from_trace(w, app, variant, scale, use_des, overlap, &tr)
            {
                // a hit only once the replay actually answered — same
                // accounting as the store tier below
                self.trace_hits.fetch_add(1, Ordering::Relaxed);
                return r;
            }
            // corrupt/stale memoized trace (should not happen in-process):
            // re-acquire and overwrite the slot
            return self
                .acquire_trace_and_measure(w, app, variant, scale, use_des, overlap, tkey, None);
        }
        let tguard = self.traces.claim_guard(tkey);

        // durable trace tier
        if let Some(store) = &self.store {
            if let Some(tr) = store.get_trace(tkey) {
                let tr = Arc::new(tr);
                if let Some(r) =
                    self.result_from_trace(w, app, variant, scale, use_des, overlap, &tr)
                {
                    self.trace_hits.fetch_add(1, Ordering::Relaxed);
                    tguard.fulfil(tr);
                    return r;
                }
                // a persisted trace that no longer replays (program drift
                // without a schema bump, disk corruption the JSON layer
                // could not catch): fall through and re-acquire
                eprintln!(
                    "store: trace {} does not replay against {}; re-running the interpreter",
                    super::store::key_hex(tkey),
                    app.name
                );
            }
        }
        self.acquire_trace_and_measure(w, app, variant, scale, use_des, overlap, tkey, Some(tguard))
    }

    /// Replay a cached trace through the modelling tier. `None` = the
    /// trace does not fit this app (caller re-acquires). With `overlap`
    /// the trace replays as a dependence DAG through the graph DES; the
    /// resulting row carries the `+ov` variant suffix and reports
    /// wavefronts in place of launches ([`Measurement::overlapped`]).
    #[allow(clippy::too_many_arguments)]
    fn result_from_trace(
        &self,
        w: &dyn Workload,
        app: &crate::workloads::App,
        variant: Variant,
        scale: Scale,
        use_des: bool,
        overlap: bool,
        tr: &TraceResult,
    ) -> Option<CellResult> {
        match tr {
            // the recorded run failed (execution or validation error) —
            // depth-invariant like the trace itself, so it IS the result
            Err(e) => Some(Err(e.clone())),
            Ok(trace) if overlap => {
                match replay_built_workload_overlapped(
                    app,
                    &self.cfg,
                    w.benign_cross_kernel_races(),
                    trace,
                ) {
                    Ok((h, waves)) => {
                        Some(Ok(Measurement::overlapped(w, variant, scale, &h, waves)))
                    }
                    Err(_) => None,
                }
            }
            Ok(trace) => match replay_built_workload(app, &self.cfg, use_des, trace) {
                Ok(h) => Some(Ok(Measurement::from_harness(w, variant, scale, &h))),
                Err(_) => None,
            },
        }
    }

    /// The expensive tier: one recorded interpreter run. Persists the
    /// trace (write-behind; failures only warn — the measurement result
    /// itself is persisted separately) and fulfils the memo slot.
    #[allow(clippy::too_many_arguments)]
    fn acquire_trace_and_measure(
        &self,
        w: &dyn Workload,
        app: &crate::workloads::App,
        variant: Variant,
        scale: Scale,
        use_des: bool,
        overlap: bool,
        tkey: u64,
        guard: Option<ClaimGuard<'_, Arc<TraceResult>>>,
    ) -> CellResult {
        self.trace_runs.fetch_add(1, Ordering::Relaxed);
        // pin the trace key like measure_opts pins the entry key: the
        // freshly persisted trace must survive until the claim fulfils
        let _pin = self.store.as_ref().map(|s| s.pin_guard(tkey));
        let outcome = run_built_workload_recorded(w, app, scale, &self.cfg, use_des);
        let (tres, result) = match outcome {
            Ok((h, trace)) => {
                let r = if overlap {
                    replay_built_workload_overlapped(
                        app,
                        &self.cfg,
                        w.benign_cross_kernel_races(),
                        &trace,
                    )
                    .map(|(oh, waves)| Measurement::overlapped(w, variant, scale, &oh, waves))
                } else {
                    Ok(Measurement::from_harness(w, variant, scale, &h))
                };
                (Ok(trace), r)
            }
            Err(e) => (Err(e.clone()), Err(e)),
        };
        let tres = Arc::new(tres);
        if let Some(store) = &self.store {
            if let Err(e) = store.put_trace(tkey, &tres) {
                eprintln!(
                    "store: persisting trace {} failed: {e} (warm reruns will re-interpret)",
                    super::store::key_hex(tkey)
                );
            }
        }
        match guard {
            Some(g) => g.fulfil(tres),
            None => self.traces.fulfil(tkey, tres),
        }
        result
    }

    /// Best feed-forward measurement across the paper's depth sweep —
    /// or, when a tuner is attached ([`Engine::with_tuner`]), across a
    /// budgeted search of the depth ladder instead of the exhaustive
    /// grid.
    ///
    /// Validation-class failures are skipped, exactly as a paper author
    /// drops an invalid configuration (NW is only safe below the row
    /// width — see `workloads::nw`); any *other* error class is a real
    /// defect and propagates immediately. If no depth yields a valid
    /// measurement the collected per-depth failures come back as one
    /// `Err` instead of the historical `Ok(best.unwrap())` panic.
    pub fn best_ff(&self, w: &dyn Workload, scale: Scale) -> Result<Measurement, String> {
        if let Some(spec) = self.tuner {
            return tune::best_ff_tuned(self, w, scale, spec);
        }
        let mut best: Option<Measurement> = None;
        let mut failures: Vec<String> = vec![];
        for d in DEPTHS {
            match self.measure(w, Variant::FeedForward { depth: d }, scale) {
                Ok(m) => {
                    if best.as_ref().map(|b| m.seconds < b.seconds).unwrap_or(true) {
                        best = Some(m);
                    }
                }
                Err(e) if is_validation_error(&e) => failures.push(format!("depth {d}: {e}")),
                Err(e) => return Err(format!("{} ff depth {d}: {e}", w.name())),
            }
        }
        best.ok_or_else(|| {
            format!(
                "{}: no feed-forward depth in {DEPTHS:?} produced a valid measurement:\n  {}",
                w.name(),
                failures.join("\n  ")
            )
        })
    }

    /// Fan a grid of cells out across the worker pool. Results come back
    /// in cell order, so the output is independent of scheduling; cache
    /// claim/fulfil guarantees each unique configuration runs once.
    pub fn run_cells(&self, cells: &[Cell]) -> Vec<Result<Measurement, String>> {
        let n = cells.len();
        let results: Vec<Mutex<Option<CellResult>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let workers = self.jobs.min(n.max(1));
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let cell = &cells[i];
                    let r = match resolve_workload(&cell.workload) {
                        Some(w) => self.measure(w.as_ref(), cell.variant, cell.scale),
                        None => Err(format!("unknown workload `{}`", cell.workload)),
                    };
                    *results[i].lock().unwrap() = Some(r);
                });
            }
        });
        results
            .into_iter()
            .map(|slot| slot.into_inner().unwrap().expect("worker filled every claimed cell"))
            .collect()
    }

    /// Prewarm the memo table with an experiment's full grid (parallel);
    /// the serial renderers afterwards only take cache hits.
    pub fn prewarm(&self, exp: ExperimentId, scale: Scale) {
        let cells = grid(exp, scale);
        let _ = self.run_cells(&cells);
    }

    /// Run one experiment end to end: parallel prewarm, then render its
    /// tables (byte-identical to the serial path by construction).
    pub fn run_experiment(&self, exp: ExperimentId, scale: Scale) -> Vec<Table> {
        self.prewarm(exp, scale);
        match exp {
            ExperimentId::E1 => vec![self.table2(scale)],
            ExperimentId::E2 => vec![self.figure4(scale)],
            ExperimentId::E3 => vec![self.table3(scale)],
            ExperimentId::E4 => vec![
                self.intext(scale),
                self.depth_sweep(&SWEEP_TRIO, scale, &DEPTHS),
                self.pc_sweep(&SWEEP_TRIO, scale),
                self.vector_study(scale),
            ],
            ExperimentId::E5 => vec![self.micro_family(scale)],
            ExperimentId::E6 => vec![experiments::table1(scale)],
            ExperimentId::E7 => vec![self.headline_table(scale)],
            ExperimentId::E8 => vec![self.portability(scale)],
            ExperimentId::E9 => vec![self.overlap_study(scale)],
        }
    }

    // -- table renderers (serial; all measurements go through the cache) ----

    pub fn table2_rows(&self, scale: Scale) -> Vec<experiments::Table2Row> {
        let mut rows = vec![];
        for w in suite() {
            let base = self.measure(w.as_ref(), Variant::Baseline, scale).expect("baseline runs");
            // best_ff now errors (instead of panicking) when every depth
            // fails; report and drop the row rather than killing the
            // whole table
            let ff = match self.best_ff(w.as_ref(), scale) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("table2: skipping {}: {e}", w.name());
                    continue;
                }
            };
            rows.push(experiments::Table2Row { base, ff });
        }
        rows
    }

    pub fn table2(&self, scale: Scale) -> Table {
        let mut t = Table::new(
            "Table 2: feed-forward design vs single work-item baseline",
            &[
                "Benchmark",
                "Baseline time (ms)",
                "FF speedup",
                "Baseline logic (%)",
                "FF logic (%)",
                "Baseline BRAM",
                "FF BRAM",
            ],
        );
        for r in self.table2_rows(scale) {
            t.row(vec![
                r.base.workload.clone(),
                ms(r.base.seconds),
                fx(r.base.seconds / r.ff.seconds),
                format!("{:.2}", r.base.logic_pct),
                format!("{:.2}", r.ff.logic_pct),
                r.base.brams.to_string(),
                r.ff.brams.to_string(),
            ]);
        }
        t
    }

    pub fn figure4(&self, scale: Scale) -> Table {
        let mut t = Table::new(
            "Figure 4: M2C2 speedup and resource overhead vs feed-forward baseline",
            &["Benchmark", "M2C2 speedup", "Logic overhead (%)", "BRAM overhead (%)"],
        );
        let mut speedups = vec![];
        for w in suite() {
            let ff = match self.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, scale) {
                Ok(m) => m,
                Err(_) => continue,
            };
            let m2 = match self.measure(w.as_ref(), Variant::MxCx { parts: 2, depth: 1 }, scale) {
                Ok(m) => m,
                Err(e) => {
                    t.row(vec![w.name().into(), format!("n/a ({e})"), "-".into(), "-".into()]);
                    continue;
                }
            };
            let s = ff.seconds / m2.seconds;
            speedups.push(s);
            t.row(vec![
                w.name().into(),
                fx(s),
                format!("{:+.1}", (m2.logic_pct / ff.logic_pct - 1.0) * 100.0),
                format!("{:+.1}", (m2.brams as f64 / ff.brams as f64 - 1.0) * 100.0),
            ]);
        }
        let avg = speedups.iter().sum::<f64>() / speedups.len().max(1) as f64;
        t.row(vec!["(average)".into(), fx(avg), "-".into(), "-".into()]);
        t
    }

    pub fn table3(&self, scale: Scale) -> Table {
        let mut t = Table::new(
            "Table 3: microbenchmark speedup (M2C2 over baseline) and area",
            &[
                "Benchmark",
                "Baseline time (ms)",
                "Speedup",
                "Logic base (%)",
                "Logic M2C2 (%)",
                "BRAM base",
                "BRAM M2C2",
            ],
        );
        for spec in MicroSpec::table3() {
            let w = Micro::new(spec);
            let base = self.measure(&w, Variant::Baseline, scale).expect("micro baseline");
            let m2 =
                self.measure(&w, Variant::MxCx { parts: 2, depth: 1 }, scale).expect("micro m2c2");
            t.row(vec![
                spec.label(),
                ms(base.seconds),
                format!("{}x", fx(base.seconds / m2.seconds)),
                format!("{:.2}", base.logic_pct),
                format!("{:.2}", m2.logic_pct),
                base.brams.to_string(),
                m2.brams.to_string(),
            ]);
        }
        t
    }

    /// Extended microbenchmark family (the paper's future-work sweep).
    pub fn micro_family(&self, scale: Scale) -> Table {
        let mut t = Table::new(
            "Microbenchmark family: AI x pattern x divergence",
            &["Benchmark", "FF speedup", "M2C2 speedup (over FF)"],
        );
        for spec in MicroSpec::family() {
            let w = Micro::new(spec);
            let base = self.measure(&w, Variant::Baseline, scale).expect("family baseline");
            let ff =
                self.measure(&w, Variant::FeedForward { depth: 1 }, scale).expect("family ff");
            let m2 =
                self.measure(&w, Variant::MxCx { parts: 2, depth: 1 }, scale).expect("family m2c2");
            t.row(vec![
                spec.label(),
                fx(base.seconds / ff.seconds),
                fx(ff.seconds / m2.seconds),
            ]);
        }
        t
    }

    pub fn intext(&self, scale: Scale) -> Table {
        let mut t = Table::new(
            "In-text metrics: II and max bandwidth, baseline vs feed-forward",
            &["Benchmark", "Baseline II", "FF II", "Baseline max BW (MB/s)", "FF max BW (MB/s)"],
        );
        for name in INTEXT_NAMES {
            let w = by_name(name).unwrap();
            let base = self.measure(w.as_ref(), Variant::Baseline, scale).expect("baseline");
            let ff =
                self.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, scale).expect("ff");
            t.row(vec![
                name.into(),
                base.max_ii.to_string(),
                ff.max_ii.to_string(),
                mbps(base.max_bw),
                mbps(ff.max_bw),
            ]);
        }
        t
    }

    /// Hotspot M2C2 bandwidth claim (§3: 7340 -> 13660 MB/s).
    pub fn hotspot_m2c2_bw(&self, scale: Scale) -> (f64, f64) {
        let w = by_name("hotspot").unwrap();
        let ff = self.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, scale).unwrap();
        let m2 = self.measure(w.as_ref(), Variant::MxCx { parts: 2, depth: 1 }, scale).unwrap();
        (ff.max_bw, m2.max_bw)
    }

    /// Channel-depth sweep over an arbitrary depth list (paper: no
    /// significant effect at 1/100/1000). With a tuner attached, a final
    /// column reports the config the budgeted search picked for each
    /// benchmark — the E4 sweep consuming tuner output.
    pub fn depth_sweep(&self, names: &[&str], scale: Scale, depths: &[usize]) -> Table {
        let mut header: Vec<String> = vec!["Benchmark".to_string()];
        for d in depths {
            header.push(format!("depth {d}"));
        }
        if self.tuner.is_some() {
            header.push("tuned best".to_string());
        }
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new("Channel-depth sweep (feed-forward, seconds)", &header_refs);
        for name in names {
            let mut cells = vec![name.to_string()];
            match resolve_workload(name) {
                Some(w) => {
                    for &d in depths {
                        match self.measure(w.as_ref(), Variant::FeedForward { depth: d }, scale) {
                            Ok(m) => cells.push(format!("{:.4}", m.seconds)),
                            Err(_) => cells.push("invalid".into()),
                        }
                    }
                    if self.tuner.is_some() {
                        match self.best_ff(w.as_ref(), scale) {
                            Ok(m) => cells.push(m.variant.clone()),
                            Err(_) => cells.push("n/a".into()),
                        }
                    }
                }
                None => {
                    cells.extend(depths.iter().map(|_| "unknown".to_string()));
                    if self.tuner.is_some() {
                        cells.push("unknown".into());
                    }
                }
            }
            t.row(cells);
        }
        t
    }

    /// Producer/consumer count sweep incl. the 1-producer shape (paper:
    /// plateau at 2x2; M1CN worse than MNCN).
    pub fn pc_sweep(&self, names: &[&str], scale: Scale) -> Table {
        let mut t = Table::new(
            "Producer/consumer sweep (speedup over feed-forward baseline)",
            &["Benchmark", "m1c1", "m2c2", "m3c3", "m4c4", "m1c2"],
        );
        for name in names {
            let Some(w) = resolve_workload(name) else {
                t.row(vec![
                    name.to_string(),
                    "unknown".into(),
                    "unknown".into(),
                    "unknown".into(),
                    "unknown".into(),
                    "unknown".into(),
                ]);
                continue;
            };
            let ff = self.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, scale).unwrap();
            let mut cells = vec![name.to_string(), "1.00".into()];
            for parts in [2usize, 3, 4] {
                match self.measure(w.as_ref(), Variant::MxCx { parts, depth: 1 }, scale) {
                    Ok(m) => cells.push(fx(ff.seconds / m.seconds)),
                    Err(_) => cells.push("n/a".into()),
                }
            }
            match self.measure(w.as_ref(), Variant::M1Cx { consumers: 2, depth: 1 }, scale) {
                Ok(m) => cells.push(fx(ff.seconds / m.seconds)),
                Err(_) => cells.push("n/a".into()),
            }
            t.row(cells);
        }
        t
    }

    /// Vector-type case study (paper: FW ~3x further, MIS degrades; their
    /// SDK crashed on pipes+vectors — our substrate completes it).
    pub fn vector_study(&self, scale: Scale) -> Table {
        let mut t = Table::new(
            "Vector-type case study (speedup of vec4 feed-forward over feed-forward)",
            &["Benchmark", "ff_v4 vs ff"],
        );
        for name in VECTOR_NAMES {
            let w = by_name(name).unwrap();
            let ff = self.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, scale).unwrap();
            match self.measure(w.as_ref(), Variant::Vectorized { width: 4, depth: 1 }, scale) {
                Ok(m) => t.row(vec![name.into(), fx(ff.seconds / m.seconds)]),
                Err(e) => t.row(vec![name.into(), format!("n/a ({e})")]),
            };
        }
        t
    }

    /// "up to 65x, ~20x average across gainers, up to 86x with M2C2".
    pub fn headline(&self, scale: Scale) -> experiments::Headline {
        let rows = self.table2_rows(scale);
        let speedups: Vec<(String, f64)> = rows
            .iter()
            .map(|r| (r.base.workload.clone(), r.base.seconds / r.ff.seconds))
            .collect();
        let max_ff = speedups.iter().map(|(_, s)| *s).fold(0.0, f64::max);
        let gainers: Vec<f64> = speedups.iter().map(|(_, s)| *s).filter(|s| *s > 2.0).collect();
        let avg = gainers.iter().sum::<f64>() / gainers.len().max(1) as f64;
        // best total = FF x M2C2 on the biggest gainer
        let best = speedups
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(n, _)| n.clone())
            .unwrap();
        let w = by_name(&best).unwrap();
        let base = self.measure(w.as_ref(), Variant::Baseline, scale).unwrap();
        let total = match self.measure(w.as_ref(), Variant::MxCx { parts: 2, depth: 1 }, scale) {
            Ok(m2) => base.seconds / m2.seconds,
            Err(_) => max_ff,
        };
        experiments::Headline {
            max_ff_speedup: max_ff,
            avg_ff_speedup_gainers: avg,
            max_total_speedup: total,
        }
    }

    fn headline_table(&self, scale: Scale) -> Table {
        let h = self.headline(scale);
        let mut t = Table::new("E7: headline speedup claims", &["Metric", "Measured", "Paper"]);
        t.row(vec![
            "max feed-forward speedup".into(),
            format!("{:.1}x", h.max_ff_speedup),
            "up to 65x".into(),
        ]);
        t.row(vec![
            "avg speedup (gainers)".into(),
            format!("{:.1}x", h.avg_ff_speedup_gainers),
            "~20x average".into(),
        ]);
        t.row(vec![
            "max with M2C2".into(),
            format!("{:.1}x", h.max_total_speedup),
            "up to 86x".into(),
        ]);
        t
    }

    /// E8: the single-device slice of the portability grid — baseline vs
    /// best feed-forward pipe design on *this* engine's device, with the
    /// winning channel depth spelled out in the variant label. The depth
    /// column is the point of the experiment: on `arria10` the fill cost
    /// is zero so every depth ties and the sweep keeps depth 1, while on
    /// `stratix10-hbm` deep channels amortise the 24-cycle fill and the
    /// deepest depth wins. Stitch several engines' slices into one table
    /// with [`cross_device_table`].
    pub fn portability(&self, scale: Scale) -> Table {
        let mut t = Table::new(
            &format!("E8: pipe-win portability ({})", self.cfg.name),
            &["Benchmark", "Baseline (ms)", "Best FF", "FF (ms)", "Pipe win"],
        );
        for name in SWEEP_TRIO {
            t.row(self.portability_cells(name, scale));
        }
        t
    }

    /// One benchmark's portability row, minus any device column: label,
    /// baseline ms, winning feed-forward variant, its ms, and the win.
    fn portability_cells(&self, name: &str, scale: Scale) -> Vec<String> {
        let Some(w) = resolve_workload(name) else {
            return vec![name.to_string(), "unknown".into(), "-".into(), "-".into(), "-".into()];
        };
        let base = match self.measure(w.as_ref(), Variant::Baseline, scale) {
            Ok(m) => m,
            Err(e) => {
                return vec![name.to_string(), format!("n/a ({e})"), "-".into(), "-".into(), "-".into()]
            }
        };
        match self.best_ff(w.as_ref(), scale) {
            Ok(ff) => vec![
                name.to_string(),
                ms(base.seconds),
                ff.variant.clone(),
                ms(ff.seconds),
                fx(base.seconds / ff.seconds),
            ],
            Err(e) => vec![name.to_string(), ms(base.seconds), format!("n/a ({e})"), "-".into(), "-".into()],
        }
    }

    /// E9: the launch-graph overlap study. Each graph workload is
    /// measured twice over the *same* recorded trace: once launch-at-a-
    /// time (the chain the host issued) and once overlapped into DAG
    /// wavefronts. Both legs are DES-modelled regardless of the engine's
    /// `--des` flag, so the win column isolates scheduling — the width
    /// `analysis::deps` proved safe — rather than estimator choice. The
    /// launches-vs-wavefronts pair is the dependence layer's output made
    /// visible: equal numbers mean the DAG is a chain and overlap is
    /// refused (NW's shape), a wavefront count of 2 on pagerank is the
    /// ping-pong collapse.
    pub fn overlap_study(&self, scale: Scale) -> Table {
        let mut t = Table::new(
            "E9: launch-graph overlap (sequential vs overlapped, DES-modelled)",
            &[
                "Benchmark",
                "Launches",
                "Wavefronts",
                "Sequential (ms)",
                "Overlapped (ms)",
                "Overlap win",
            ],
        );
        for name in GRAPH_TRIO {
            let Some(w) = resolve_workload(name) else {
                t.row(vec![
                    name.to_string(),
                    "unknown".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            };
            let v = Variant::FeedForward { depth: 1 };
            let seq = match self.measure_opts(w.as_ref(), v, scale, true, false) {
                Ok(m) => m,
                Err(e) => {
                    t.row(vec![
                        name.to_string(),
                        format!("n/a ({e})"),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                    continue;
                }
            };
            let ov = match self.measure_opts(w.as_ref(), v, scale, true, true) {
                Ok(m) => m,
                Err(e) => {
                    t.row(vec![
                        name.to_string(),
                        seq.launches.to_string(),
                        format!("n/a ({e})"),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                    continue;
                }
            };
            t.row(vec![
                name.to_string(),
                seq.launches.to_string(),
                ov.launches.to_string(),
                ms(seq.seconds),
                ms(ov.seconds),
                fx(seq.seconds / ov.seconds),
            ]);
        }
        t
    }

    // -- structured results sink --------------------------------------------

    /// Every successful measurement in canonical order (workload, variant,
    /// scale) — identical between serial and parallel engines.
    pub fn measurements(&self) -> Vec<Measurement> {
        let mut ms: Vec<Measurement> =
            self.cache.done_values().into_iter().filter_map(|r| r.ok()).collect();
        experiments::canonical_sort(&mut ms);
        ms
    }

    /// The BENCH_PR1.json document (deterministic bytes).
    pub fn bench_json(&self, scale: Scale, experiments: &[ExperimentId]) -> String {
        bench_doc(scale, experiments, &self.measurements())
    }

    /// Write the results sink to disk (default file name: BENCH_PR1.json).
    pub fn write_bench_json(
        &self,
        path: &std::path::Path,
        scale: Scale,
        experiments: &[ExperimentId],
    ) -> std::io::Result<()> {
        std::fs::write(path, self.bench_json(scale, experiments))
    }
}

/// Render the BENCH_PR1.json document from canonically sorted
/// measurements. Shared by [`Engine::bench_json`] and [`merge_bench_json`]
/// so a merged sharded run is byte-identical to the serial path.
pub fn bench_doc(scale: Scale, experiments: &[ExperimentId], measurements: &[Measurement]) -> String {
    let doc = Json::Obj(vec![
        ("schema".into(), Json::Str("pipefwd-bench-v1".into())),
        ("scale".into(), Json::Str(scale_label(scale).into())),
        (
            "experiments".into(),
            Json::Arr(experiments.iter().map(|e| Json::Str(e.label().into())).collect()),
        ),
        (
            "measurements".into(),
            Json::Arr(measurements.iter().map(Measurement::to_json).collect()),
        ),
    ]);
    doc.to_pretty()
}

/// Union a set of shard stores into the serial path's results sink: replay
/// the experiment grid (IR transforms only — zero simulation), look every
/// cell's content address up across the stores, and render the canonical
/// document. Errors if any feasible cell is missing from every store
/// (i.e. the shards did not cover the grid).
pub fn merge_bench_json(
    stores: &[Store],
    exps: &[ExperimentId],
    scale: Scale,
    cfg: &DeviceConfig,
    use_des: bool,
) -> Result<String, String> {
    let mut seen = std::collections::HashSet::new();
    let mut ms: Vec<Measurement> = vec![];
    let mut missing: Vec<String> = vec![];
    for cell in grid_for(exps, scale) {
        let Some(w) = resolve_workload(&cell.workload) else {
            missing.push(format!("unknown workload `{}`", cell.workload));
            continue;
        };
        // infeasible variants never enter the serial sink either
        let Ok(app) = w.build(cell.variant) else { continue };
        let key = content_key(&cell.workload, &app, cell.scale, cfg, use_des);
        if !seen.insert(key) {
            continue;
        }
        match stores.iter().find_map(|s| s.get(key)) {
            Some(Ok(m)) => ms.push(m),
            // simulated but failed (e.g. validation): excluded, like serial
            Some(Err(_)) => {}
            None => missing.push(format!(
                "{} {} {} ({})",
                cell.workload,
                cell.variant.label(),
                scale_label(cell.scale),
                super::store::key_hex(key)
            )),
        }
    }
    if !missing.is_empty() {
        return Err(format!(
            "merge: {} grid cell(s) missing from the given stores — did every shard run?\n  {}",
            missing.len(),
            missing.join("\n  ")
        ));
    }
    experiments::canonical_sort(&mut ms);
    Ok(bench_doc(scale, exps, &ms))
}

/// Stitch one E8 portability slice per engine into a single cross-device
/// comparison table: one row per (benchmark, device), benchmark-major so
/// the devices of one workload read as a block. This is the `--device all`
/// output — the repo's answer to "does the pipe win travel?". Each engine
/// carries its own device config, store, and memo cache; the trace tier is
/// device-free, so a multi-engine sweep sharing a store directory pays the
/// interpreter once per (workload, scale) no matter how many devices run.
pub fn cross_device_table(engines: &[&Engine], scale: Scale) -> Table {
    let mut t = Table::new(
        "E8: cross-device pipe-win portability",
        &["Benchmark", "Device", "Baseline (ms)", "Best FF", "FF (ms)", "Pipe win"],
    );
    for name in SWEEP_TRIO {
        for e in engines {
            let cells = e.portability_cells(name, scale);
            let mut row = vec![cells[0].clone(), e.cfg.name.to_string()];
            row.extend(cells.into_iter().skip(1));
            t.row(row);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn experiment_ids_roundtrip() {
        for exp in ExperimentId::all() {
            assert_eq!(ExperimentId::parse(exp.label()), Some(exp));
            assert_eq!(ExperimentId::parse(&exp.label().to_lowercase()), Some(exp));
        }
        assert_eq!(ExperimentId::parse("E10"), None);
    }

    #[test]
    fn grids_are_nonempty_for_simulated_experiments() {
        for exp in ExperimentId::all() {
            let g = grid(exp, Scale::Tiny);
            if exp == ExperimentId::E6 {
                assert!(g.is_empty());
            } else {
                assert!(!g.is_empty(), "empty grid for {exp:?}");
            }
        }
    }

    #[test]
    fn resolve_finds_suite_and_micro_workloads() {
        assert!(resolve_workload("fw").is_some());
        let micro = MicroSpec::table3()[0].label();
        assert!(resolve_workload(&micro).is_some(), "micro {micro} not resolvable");
        assert!(resolve_workload("nope").is_none());
    }

    #[test]
    fn cache_memoizes_identical_configurations() {
        let e = Engine::serial(DeviceConfig::pac_a10());
        let w = by_name("fw").unwrap();
        let a = e.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny).unwrap();
        assert_eq!(e.cache_len(), 1);
        assert_eq!(e.cache_hits(), 0);
        let b = e.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny).unwrap();
        assert_eq!(e.cache_len(), 1, "second identical measure must not re-simulate");
        assert_eq!(e.cache_hits(), 1);
        assert_eq!(a, b);
        // a different depth is a different content address
        let _ = e.measure(w.as_ref(), Variant::FeedForward { depth: 100 }, Scale::Tiny).unwrap();
        assert_eq!(e.cache_len(), 2);
    }

    #[test]
    fn shards_partition_unique_cells_disjointly() {
        let cells = {
            // duplicate the grid so dedup has real work to do
            let mut g = grid(ExperimentId::E2, Scale::Tiny);
            g.extend(grid(ExperimentId::E2, Scale::Tiny));
            g
        };
        let unique = dedup_cells(&cells);
        assert_eq!(unique.len(), grid(ExperimentId::E2, Scale::Tiny).len());
        for n in [1usize, 3, 4] {
            let shards: Vec<Vec<Cell>> =
                (1..=n).map(|i| shard_cells(&cells, i, n).unwrap()).collect();
            let total: usize = shards.iter().map(|s| s.len()).sum();
            assert_eq!(total, unique.len(), "shards must cover the unique grid exactly");
            for (i, s) in shards.iter().enumerate() {
                for c in s {
                    for (j, other) in shards.iter().enumerate() {
                        if i != j {
                            assert!(!other.contains(c), "cell in shards {i} and {j}");
                        }
                    }
                }
            }
            // deterministic across calls
            assert_eq!(shards[0], shard_cells(&cells, 1, n).unwrap());
        }
    }

    /// `--shard 0/3`, `4/3`, and `1/0` are user input: a clean `Err`,
    /// never an assert backtrace.
    #[test]
    fn shard_bounds_are_rejected_not_asserted() {
        let cells = grid(ExperimentId::E2, Scale::Tiny);
        for (i, n) in [(0usize, 3usize), (4, 3), (1, 0), (0, 0)] {
            let err = shard_cells(&cells, i, n).unwrap_err();
            assert!(err.contains(&format!("{i}/{n}")), "error must quote the input: {err}");
        }
        assert!(shard_cells(&cells, 3, 3).is_ok());
    }

    #[test]
    fn normalize_depths_sorts_and_dedups() {
        assert_eq!(normalize_depths(vec![100, 100, 1]), vec![1, 100]);
        assert_eq!(normalize_depths(vec![1000, 1, 100]), vec![1, 100, 1000]);
        assert_eq!(normalize_depths(vec![]), Vec::<usize>::new());
    }

    /// `--depths 100,100,1` must render the same sweep table as
    /// `--depths 1,100`: one column per unique depth, ascending.
    #[test]
    fn duplicate_depth_sweep_is_deterministic() {
        let e = Engine::serial(DeviceConfig::pac_a10());
        let a = e.depth_sweep(&["fw"], Scale::Tiny, &normalize_depths(vec![100, 100, 1]));
        let b = e.depth_sweep(&["fw"], Scale::Tiny, &[1, 100]);
        assert_eq!(a.to_markdown(), b.to_markdown());
        assert_eq!(a.header.len(), 3, "Benchmark + one column per unique depth");
    }

    /// A workload whose output never matches the reference: every depth
    /// fails validation, and `best_ff` must collect the per-depth
    /// failures into one `Err` instead of panicking on `best.unwrap()`.
    struct AlwaysInvalid;

    impl crate::workloads::Workload for AlwaysInvalid {
        fn name(&self) -> &'static str {
            "always_invalid"
        }
        fn suite(&self) -> &'static str {
            "test"
        }
        fn dwarf(&self) -> &'static str {
            "-"
        }
        fn pattern(&self) -> &'static str {
            "-"
        }
        fn dataset_desc(&self, _scale: Scale) -> String {
            "-".into()
        }
        fn dominant(&self) -> &'static str {
            "mis1"
        }
        fn kernels(&self) -> Vec<crate::ir::Kernel> {
            vec![crate::transform::examples::fig2_kernel()]
        }
        fn image(&self, _scale: Scale) -> crate::sim::mem::MemoryImage {
            crate::sim::mem::MemoryImage::new()
        }
        fn run(
            &self,
            _app: &crate::workloads::App,
            _img: &mut crate::sim::mem::MemoryImage,
            _h: &mut crate::workloads::Harness,
        ) -> Result<(), crate::sim::exec::ExecError> {
            Ok(())
        }
        fn validate(
            &self,
            _img: &crate::sim::mem::MemoryImage,
            _scale: Scale,
        ) -> Result<(), String> {
            Err("forced mismatch".into())
        }
    }

    #[test]
    fn best_ff_collects_failures_instead_of_panicking() {
        let e = Engine::serial(DeviceConfig::pac_a10());
        let err = e.best_ff(&AlwaysInvalid, Scale::Tiny).unwrap_err();
        assert!(err.contains("no feed-forward depth"), "{err}");
        for d in DEPTHS {
            assert!(err.contains(&format!("depth {d}")), "missing depth {d} in: {err}");
        }
        assert!(err.contains("validation"), "{err}");
    }

    /// NW: deep pipes break validation (past the safe row width) and are
    /// skipped; depth 1 succeeds and wins.
    #[test]
    fn best_ff_skips_validation_failures_and_still_succeeds() {
        let e = Engine::serial(DeviceConfig::pac_a10());
        let m = e.best_ff(by_name("nw").unwrap().as_ref(), Scale::Tiny).unwrap();
        assert_eq!(m.variant, "ff(d1)");
    }

    /// The tentpole acceptance shape in miniature: a cold depth ladder
    /// over a depth-invariant workload runs the interpreter exactly once;
    /// every other rung replays the shared trace through the model.
    #[test]
    fn depth_sweep_shares_one_trace_per_workload() {
        let e = Engine::serial(DeviceConfig::pac_a10());
        let w = by_name("fw").unwrap();
        for d in DEPTHS {
            e.measure(w.as_ref(), Variant::FeedForward { depth: d }, Scale::Tiny).unwrap();
        }
        assert_eq!(e.simulations(), 3, "each depth is still a distinct measurement");
        assert_eq!(e.trace_runs(), 1, "one interpreter run for the whole ladder");
        assert_eq!(e.trace_hits(), 2);
    }

    /// Replayed rungs must equal what an independent cold engine computes
    /// at that depth — the byte-identity guarantee of the results sink
    /// rests on this.
    #[test]
    fn replayed_depths_match_independent_cold_runs() {
        let sweep = Engine::serial(DeviceConfig::pac_a10());
        let w = by_name("fw").unwrap();
        for d in DEPTHS {
            let replayed =
                sweep.measure(w.as_ref(), Variant::FeedForward { depth: d }, Scale::Tiny).unwrap();
            let cold = Engine::serial(DeviceConfig::pac_a10())
                .measure(w.as_ref(), Variant::FeedForward { depth: d }, Scale::Tiny)
                .unwrap();
            assert_eq!(replayed, cold, "depth {d}: replay diverged from a cold run");
        }
        assert_eq!(sweep.trace_runs(), 1);
    }

    /// NW's trace is depth-sensitive (shared read-write buffer, no
    /// vouch): every depth must acquire its own trace.
    #[test]
    fn depth_sensitive_workloads_do_not_share_traces() {
        let e = Engine::serial(DeviceConfig::pac_a10());
        let w = by_name("nw").unwrap();
        let _ = e.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny);
        let _ = e.measure(w.as_ref(), Variant::FeedForward { depth: 100 }, Scale::Tiny);
        assert_eq!(e.trace_runs(), 2, "NW depths must not share a trace");
        assert_eq!(e.trace_hits(), 0);
    }

    #[test]
    fn trace_key_masks_depth_only_where_invariant() {
        let fw = by_name("fw").unwrap();
        let a1 = fw.build(Variant::FeedForward { depth: 1 }).unwrap();
        let a100 = fw.build(Variant::FeedForward { depth: 100 }).unwrap();
        assert_eq!(
            trace_key("fw", true, &a1, Scale::Tiny),
            trace_key("fw", true, &a100, Scale::Tiny),
            "vouched workload: depth masked"
        );
        let nw = by_name("nw").unwrap();
        let n1 = nw.build(Variant::FeedForward { depth: 1 }).unwrap();
        let n100 = nw.build(Variant::FeedForward { depth: 100 }).unwrap();
        assert_ne!(
            trace_key("nw", false, &n1, Scale::Tiny),
            trace_key("nw", false, &n100, Scale::Tiny),
            "depth-sensitive unit keeps its real depth"
        );
        // replication changes the kernel text: distinct trace even vouched
        let m2 = fw.build(Variant::MxCx { parts: 2, depth: 1 }).unwrap();
        assert_ne!(
            trace_key("fw", true, &a1, Scale::Tiny),
            trace_key("fw", true, &m2, Scale::Tiny)
        );
        // scale is part of the trace address
        assert_ne!(
            trace_key("fw", true, &a1, Scale::Tiny),
            trace_key("fw", true, &a1, Scale::Small)
        );
        // stable across calls (persisted keys depend on it)
        assert_eq!(
            trace_key("fw", true, &a1, Scale::Tiny),
            trace_key("fw", true, &a1, Scale::Tiny)
        );
    }

    #[test]
    fn content_key_separates_des_from_analytic() {
        let cfg = DeviceConfig::pac_a10();
        let w = by_name("fw").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let analytic = content_key("fw", &app, Scale::Tiny, &cfg, false);
        let des = content_key("fw", &app, Scale::Tiny, &cfg, true);
        assert_ne!(analytic, des, "DES and analytic estimates must cache side by side");
        // stable across calls (persisted keys depend on it)
        assert_eq!(analytic, content_key("fw", &app, Scale::Tiny, &cfg, false));
    }

    #[test]
    fn infeasible_variants_surface_errors() {
        let e = Engine::serial(DeviceConfig::pac_a10());
        let w = by_name("nw").unwrap();
        // NW opts out of replication; the engine reports, not panics.
        let r = e.measure(w.as_ref(), Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny);
        assert!(r.is_err());
    }

    /// The store-compat contract: the default device's signature is byte
    /// for byte the pre-zoo signature (no `device=` line), so every
    /// record written before the device axis existed stays a warm hit.
    /// Every other profile gets its name on a dedicated line.
    #[test]
    fn arria10_signature_has_no_device_line_but_others_do() {
        let w = by_name("fw").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let a10 = content_signature("fw", &app, Scale::Tiny, &DeviceConfig::pac_a10(), false);
        assert!(!a10.contains("device="), "default device must keep pre-zoo key bytes");
        let hbm =
            content_signature("fw", &app, Scale::Tiny, &DeviceConfig::stratix10_hbm(), false);
        assert!(hbm.contains("device=stratix10-hbm\n"));
    }

    /// Devices separate at the measurement tier but share the trace tier:
    /// a cross-device sweep re-estimates per device yet pays the
    /// interpreter exactly once per (workload, scale).
    #[test]
    fn content_keys_differ_across_devices_but_trace_keys_do_not() {
        let w = by_name("fw").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let mut keys = vec![];
        for cfg in crate::sim::device::DeviceRegistry::all() {
            keys.push(content_key("fw", &app, Scale::Tiny, &cfg, false));
        }
        let distinct: std::collections::HashSet<u64> = keys.iter().copied().collect();
        assert_eq!(distinct.len(), keys.len(), "every device needs its own measurement key");
        // the trace address never mentions the device
        assert_eq!(
            trace_key("fw", true, &app, Scale::Tiny),
            trace_key("fw", true, &app, Scale::Tiny)
        );
    }

    /// The acceptance-criterion divergence, provable from the model: on
    /// arria10 the channel fill cost is zero, so every feed-forward depth
    /// estimates identical seconds and the strict `<` sweep keeps depth 1;
    /// on stratix10-hbm deep channels amortise the 24-cycle fill, so the
    /// deepest depth strictly wins. The best pipe depth is a property of
    /// the device, not the kernel — the point of the portability grid.
    #[test]
    fn best_depth_diverges_between_arria10_and_hbm() {
        let a10 = Engine::serial(DeviceConfig::pac_a10());
        let hbm = Engine::serial(DeviceConfig::stratix10_hbm());
        let w = by_name("fw").unwrap();
        let d = |e: &Engine, depth| {
            e.measure(w.as_ref(), Variant::FeedForward { depth }, Scale::Tiny).unwrap().seconds
        };
        assert_eq!(d(&a10, 1), d(&a10, 1000), "identity fill: depth cannot matter on arria10");
        assert!(d(&hbm, 1000) < d(&hbm, 1), "HBM fill latency must reward deep channels");
        assert_eq!(a10.best_ff(w.as_ref(), Scale::Tiny).unwrap().variant, "ff(d1)");
        assert_eq!(hbm.best_ff(w.as_ref(), Scale::Tiny).unwrap().variant, "ff(d1000)");
    }

    /// The store-compat contract for the overlap axis, mirroring the
    /// `device=` line: overlap-off signatures are byte for byte the
    /// pre-overlap signatures (no `overlap` substring anywhere), so
    /// every record written before the launch-graph axis existed stays
    /// a warm hit; overlap-on gets a dedicated trailing line and a
    /// distinct key.
    #[test]
    fn overlap_off_signature_keeps_pre_overlap_bytes() {
        let w = by_name("bfs").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let cfg = DeviceConfig::pac_a10();
        let off = content_signature_with("bfs", &app, Scale::Tiny, &cfg, false, false);
        assert_eq!(off, content_signature("bfs", &app, Scale::Tiny, &cfg, false));
        assert!(!off.contains("overlap"), "overlap-off keys must not mention the axis");
        let on = content_signature_with("bfs", &app, Scale::Tiny, &cfg, false, true);
        assert!(on.ends_with("overlap=on\n"));
        assert_ne!(
            content_key_with("bfs", &app, Scale::Tiny, &cfg, false, true),
            content_key("bfs", &app, Scale::Tiny, &cfg, false),
            "sequential and overlapped measurements must cache side by side"
        );
    }

    /// The E9 acceptance criterion: overlapped modelled time is strictly
    /// lower than the sequential chain on bfs and pagerank (the DAG has
    /// real width there), ties the chain *exactly* on single-launch NW
    /// (a one-node graph runs through the identical heap loop), and both
    /// legs of every workload share one interpreter run.
    #[test]
    fn overlap_strictly_wins_on_graph_workloads_and_ties_single_launch() {
        let e = Engine::serial(DeviceConfig::pac_a10());
        let v = Variant::FeedForward { depth: 1 };
        for name in ["bfs", "pagerank"] {
            let w = by_name(name).unwrap();
            let seq = e.measure_opts(w.as_ref(), v, Scale::Tiny, true, false).unwrap();
            let ov = e.measure_opts(w.as_ref(), v, Scale::Tiny, true, true).unwrap();
            assert!(
                ov.seconds < seq.seconds,
                "{name}: overlap must strictly win ({} vs {})",
                ov.seconds,
                seq.seconds
            );
            assert!(ov.launches < seq.launches, "{name}: fewer wavefronts than launches");
            assert_eq!(ov.variant, "ff(d1)+ov", "{name}: overlapped rows must sort apart");
            assert_eq!(seq.variant, "ff(d1)");
        }
        let nw = by_name("nw").unwrap();
        let seq = e.measure_opts(nw.as_ref(), v, Scale::Tiny, true, false).unwrap();
        let ov = e.measure_opts(nw.as_ref(), v, Scale::Tiny, true, true).unwrap();
        assert_eq!(ov.cycles, seq.cycles, "one launch: graph DES must be bit-identical");
        assert_eq!(ov.launches, 1, "one launch is one wavefront");
        // the trace tier never saw the overlap axis: one interpreter run
        // per workload, the second leg replays
        assert_eq!(e.trace_runs(), 3);
        assert_eq!(e.trace_hits(), 3);
    }

    /// `with_overlap` routes the plain `measure` path: an overlap engine
    /// and an explicit `measure_opts(.., true)` call agree exactly.
    #[test]
    fn overlap_engine_defaults_match_explicit_opts() {
        let v = Variant::FeedForward { depth: 1 };
        let w = by_name("pagerank").unwrap();
        let ove = Engine::serial(DeviceConfig::pac_a10()).with_des(true).with_overlap(true);
        let a = ove.measure(w.as_ref(), v, Scale::Tiny).unwrap();
        let b = Engine::serial(DeviceConfig::pac_a10())
            .measure_opts(w.as_ref(), v, Scale::Tiny, true, true)
            .unwrap();
        assert_eq!(a, b);
    }

    /// `--device all` output shape: benchmark-major rows, one per
    /// (benchmark, device), with the device column spelling out whose
    /// numbers each row carries.
    #[test]
    fn cross_device_table_stitches_one_row_per_device() {
        let engines = vec![
            Engine::serial(DeviceConfig::pac_a10()),
            Engine::serial(DeviceConfig::stratix10_hbm()),
        ];
        let refs: Vec<&Engine> = engines.iter().collect();
        let t = cross_device_table(&refs, Scale::Tiny);
        assert_eq!(t.rows.len(), SWEEP_TRIO.len() * engines.len());
        assert_eq!(t.rows[0][0], t.rows[1][0], "devices of one benchmark read as a block");
        assert_eq!(t.rows[0][1], "arria10");
        assert_eq!(t.rows[1][1], "stratix10-hbm");
    }
}
