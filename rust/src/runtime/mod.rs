//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! from the Rust side (the `xla` crate over xla_extension's PJRT C API).
//!
//! HLO *text* is the interchange format — see python/compile/aot.py and
//! /opt/xla-example/README.md: jax >= 0.5 serialized protos carry 64-bit
//! instruction ids that this XLA rejects; the text parser reassigns them.
//!
//! Python never runs here: `Runtime` only needs `artifacts/manifest.txt`
//! and the `.hlo.txt` files produced once by `make artifacts`.
//!
//! The PJRT backend is gated behind the off-by-default `pjrt` cargo
//! feature: the `xla` crate links a native xla_extension library that the
//! offline image does not ship. Without the feature, manifest parsing and
//! the artifact specs still work, but `Runtime::new` reports the backend
//! as unavailable — every caller already treats that as "skip golden
//! validation", so the rest of the system is unaffected.

pub mod golden;

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};
#[cfg(feature = "pjrt")]
use std::cell::RefCell;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `f32[64,64]`-style shape spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    fn parse(s: &str) -> Result<TensorSpec> {
        let (dtype, rest) = s
            .split_once('[')
            .ok_or_else(|| anyhow!("bad tensor spec: {s}"))?;
        let dims = rest
            .trim_end_matches(']')
            .split(',')
            .filter(|d| !d.is_empty())
            .map(|d| d.trim().parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { dtype: dtype.to_string(), dims })
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One manifest entry: `name;in=f32[..],f32[..];out=f32[..]`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut out = vec![];
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split(';');
        let name = parts.next().ok_or_else(|| anyhow!("empty manifest line"))?.to_string();
        let ins = parts
            .next()
            .and_then(|p| p.strip_prefix("in="))
            .ok_or_else(|| anyhow!("manifest line missing in=: {line}"))?;
        let outs = parts
            .next()
            .and_then(|p| p.strip_prefix("out="))
            .ok_or_else(|| anyhow!("manifest line missing out=: {line}"))?;
        let inputs = split_specs(ins)
            .into_iter()
            .map(|s| TensorSpec::parse(&s))
            .collect::<Result<Vec<_>>>()?;
        out.push(ArtifactSpec { name, inputs, output: TensorSpec::parse(outs)? });
    }
    Ok(out)
}

/// Split `f32[64,64],f32[1,8]` at top-level commas (commas inside [] kept).
fn split_specs(s: &str) -> Vec<String> {
    let mut out = vec![];
    let mut depth = 0;
    let mut cur = String::new();
    for ch in s.chars() {
        match ch {
            '[' => {
                depth += 1;
                cur.push(ch);
            }
            ']' => {
                depth -= 1;
                cur.push(ch);
            }
            ',' if depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(ch),
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// The PJRT runtime: one CPU client, lazily compiled executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

/// Stub runtime (no `pjrt` feature): construction always fails with a
/// clear message; callers skip golden validation, as they do when the
/// artifacts are missing.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    specs: Vec<ArtifactSpec>,
}

impl Runtime {
    /// Default artifact directory: `$PIPEFWD_ARTIFACTS` or `artifacts/`
    /// next to the current directory (falling back to the crate root).
    pub fn artifact_dir() -> PathBuf {
        if let Ok(d) = std::env::var("PIPEFWD_ARTIFACTS") {
            return PathBuf::from(d);
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.txt").exists() {
                return p;
            }
        }
        // CARGO_MANIFEST_DIR works for tests/benches run via cargo
        let mut p = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        p.push("artifacts");
        p
    }

    /// Open the default artifact directory.
    pub fn open_default() -> Result<Runtime> {
        Runtime::new(&Runtime::artifact_dir())
    }

    pub fn names(&self) -> Vec<&str> {
        self.specs.iter().map(|s| s.name.as_str()).collect()
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.iter().find(|s| s.name == name)
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt (run `make artifacts`)", dir.display()))?;
        let specs = parse_manifest(&manifest)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir: dir.to_path_buf(), specs, exes: RefCell::new(HashMap::new()) })
    }

    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` on f32 inputs (shapes per the manifest);
    /// returns the flattened f32 output.
    pub fn run_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let spec = self
            .spec(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
            .clone();
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        self.ensure_compiled(name)?;
        let mut literals = vec![];
        for (data, ts) in inputs.iter().zip(&spec.inputs) {
            if data.len() != ts.elements() {
                bail!("{name}: input size {} != {:?}", data.len(), ts.dims);
            }
            let dims: Vec<i64> = ts.dims.iter().map(|d| *d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            literals.push(lit);
        }
        let exes = self.exes.borrow();
        let exe = exes.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec {name}: {e:?}"))
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt (run `make artifacts`)", dir.display()))?;
        let _specs = parse_manifest(&manifest)?;
        bail!(
            "PJRT backend not compiled in (artifacts found at {}); \
             rebuild with `--features pjrt` and the xla crate available",
            dir.display()
        )
    }

    /// Unreachable without the `pjrt` feature: `new` never hands out a
    /// `Runtime`, so this only exists to keep callers compiling.
    pub fn run_f32(&self, name: &str, _inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        bail!("PJRT backend not compiled in; cannot execute artifact `{name}`")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let m = "hotspot;in=float32[64,64],float32[64,64];out=float32[64,64]\n\
                 knn;in=float32[1024,8],float32[1,8];out=float32[1024,1]\n";
        let specs = parse_manifest(m).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "hotspot");
        assert_eq!(specs[0].inputs.len(), 2);
        assert_eq!(specs[0].inputs[0].dims, vec![64, 64]);
        assert_eq!(specs[1].output.dims, vec![1024, 1]);
        assert_eq!(specs[1].inputs[1].elements(), 8);
    }

    #[test]
    fn split_specs_respects_brackets() {
        assert_eq!(
            split_specs("f32[64,64],f32[1,8]"),
            vec!["f32[64,64]".to_string(), "f32[1,8]".to_string()]
        );
    }

    #[test]
    fn bad_manifest_rejected() {
        assert!(parse_manifest("name-without-fields").is_err());
        assert!(parse_manifest("x;nope;out=f32[1]").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_backend_unavailable() {
        let dir = std::env::temp_dir().join("pipefwd_stub_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "knn;in=f32[1,8];out=f32[1,1]\n").unwrap();
        let err = match Runtime::new(&dir) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("stub runtime must not construct"),
        };
        assert!(err.contains("PJRT backend not compiled in"), "err: {err}");
    }
}
