//! PJRT golden-reference validation: the IR interpreter's numeric
//! benchmarks are cross-checked at Tiny scale against the AOT-compiled
//! JAX/Pallas artifacts — an *independent* implementation of the same
//! math, executed through a completely different stack (L1/L2 vs L3).

use super::Runtime;
use crate::ir::Val;
use crate::sim::exec::{run_group, ExecOptions};
use crate::transform::Variant;
use crate::bail;
use crate::util::error::Result;
use crate::workloads::{Scale, Workload};

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Hotspot: one stencil step on the Tiny grid, full-grid comparison
/// (the Pallas kernel's edge-replicated halo matches the host-patched
/// boundary of the IR kernel).
pub fn check_hotspot(rt: &Runtime) -> Result<f32> {
    use crate::workloads::hotspot::Hotspot;
    let w = Hotspot;
    let app = w.build(Variant::Baseline).unwrap();
    let mut img = w.image(Scale::Tiny);
    let mut h = crate::workloads::Harness::new(&app, &crate::sim::device::DeviceConfig::pac_a10());
    w.run(&app, &mut img, &mut h)?;
    let got = img.buf("temp").unwrap().to_f32s(); // after swap

    let (temp, power) = crate::workloads::datagen::hotspot_grids(64, 64, crate::workloads::hotspot::SEED);
    let want = rt.run_f32("hotspot", &[temp, power])?;
    let d = max_abs_diff(&got, &want);
    if d > 1e-3 {
        bail!("hotspot vs PJRT golden: max |diff| = {d}");
    }
    Ok(d)
}

/// Floyd–Warshall: the full Tiny run vs the jitted fori_loop artifact.
pub fn check_fw(rt: &Runtime) -> Result<f32> {
    use crate::workloads::fw::{Fw, SEED};
    let w = Fw;
    let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
    let mut img = w.image(Scale::Tiny);
    let mut h = crate::workloads::Harness::new(&app, &crate::sim::device::DeviceConfig::pac_a10());
    w.run(&app, &mut img, &mut h)?;
    let got = img.buf("dist").unwrap().to_f32s();

    let dist0 = crate::workloads::datagen::distance_matrix(64, SEED);
    let want = rt.run_f32("fw", &[dist0])?;
    let d = max_abs_diff(&got, &want);
    if d > 1e-2 {
        bail!("fw vs PJRT golden: max |diff| = {d}");
    }
    Ok(d)
}

/// KNN distances on the Tiny point set.
pub fn check_knn(rt: &Runtime) -> Result<f32> {
    use crate::workloads::knn::{Knn, DIMS, SEED};
    let w = Knn;
    let app = w.build(Variant::Baseline).unwrap();
    let mut img = w.image(Scale::Tiny);
    let mut h = crate::workloads::Harness::new(&app, &crate::sim::device::DeviceConfig::pac_a10());
    w.run(&app, &mut img, &mut h)?;
    let got = img.buf("dist").unwrap().to_f32s();

    let pts = crate::workloads::datagen::matrix(1024, DIMS, 1.0, SEED);
    let q = crate::workloads::datagen::matrix(1, DIMS, 1.0, SEED ^ 1);
    let want = rt.run_f32("knn", &[pts, q])?;
    let d = max_abs_diff(&got, &want);
    if d > 1e-3 {
        bail!("knn vs PJRT golden: max |diff| = {d}");
    }
    Ok(d)
}

/// PageRank: 10 power iterations; the artifact is a dense-matvec step, so
/// the CSR graph is densified into the column-normalized matrix.
pub fn check_pagerank(rt: &Runtime) -> Result<f32> {
    use crate::workloads::pagerank::{graph, PageRank, ROUNDS};
    let w = PageRank;
    let app = w.build(Variant::Baseline).unwrap();
    let mut img = w.image(Scale::Tiny);
    let mut h = crate::workloads::Harness::new(&app, &crate::sim::device::DeviceConfig::pac_a10());
    w.run(&app, &mut img, &mut h)?;
    let got = img.buf("pr").unwrap().to_f32s();

    let g = graph(Scale::Tiny);
    let n = g.n;
    let mut a = vec![0.0f32; n * n];
    for u in 0..n {
        let deg = g.degree(u).max(1) as f32;
        for &v in g.neighbors(u) {
            // pull formulation: pr_next[v] += pr[u]/deg(u)
            a[(v as usize) * n + u] = 1.0 / deg;
        }
    }
    let mut pr = vec![1.0f32 / n as f32; n];
    for _ in 0..ROUNDS {
        pr = rt.run_f32("pagerank", &[a.clone(), pr])?;
    }
    let d = max_abs_diff(&got, &pr);
    if d > 1e-4 {
        bail!("pagerank vs PJRT golden: max |diff| = {d}");
    }
    Ok(d)
}

/// MIS neighbour-min (the paper's Fig. 2 reduction): first-round
/// `min_array` vs the Pallas masked-min artifact on the densified graph.
pub fn check_mis_neighbor_min(rt: &Runtime) -> Result<f32> {
    use crate::workloads::mis::{graph, Mis, BIG, SEED};
    let w = Mis;
    let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
    let mut img = w.image(Scale::Tiny);
    // one reset + one gather launch only (round 0, everything active)
    img.set_scalar("round", Val::I(0));
    run_group(app.unit("mis_reset"), &img, &ExecOptions::default())?;
    run_group(app.unit("mis_kernel"), &img, &ExecOptions::default())?;
    let got = img.buf("min_array").unwrap().to_f32s();

    let g = graph(Scale::Tiny);
    let n = g.n;
    let values = crate::workloads::datagen::node_values(n, SEED ^ 1);
    let mut adj = vec![0.0f32; n * n];
    for v in 0..n {
        for &u in g.neighbors(v) {
            adj[v * n + u as usize] = 1.0;
        }
    }
    let vals_row: Vec<f32> = values.clone();
    let active = vec![1.0f32; n];
    let want = rt.run_f32("mis_neighbor_min", &[adj, vals_row, active])?;
    // isolated nodes: both sides produce BIG
    let d = got
        .iter()
        .zip(&want)
        .map(|(a, b)| if *a >= BIG && *b >= BIG { 0.0 } else { (a - b).abs() })
        .fold(0.0, f32::max);
    if d > 1e-3 {
        bail!("mis neighbour-min vs PJRT golden: max |diff| = {d}");
    }
    Ok(d)
}

/// Run every golden check; returns (name, max-abs-diff) pairs.
pub fn check_all(rt: &Runtime) -> Result<Vec<(&'static str, f32)>> {
    Ok(vec![
        ("hotspot", check_hotspot(rt)?),
        ("fw", check_fw(rt)?),
        ("knn", check_knn(rt)?),
        ("pagerank", check_pagerank(rt)?),
        ("mis_neighbor_min", check_mis_neighbor_min(rt)?),
    ])
}
