//! # pipefwd
//!
//! A reproduction of *"Enabling the Feed-Forward Design Model in OpenCL
//! Using Pipes"* (Eghbali Zarch & Becchi; camera-ready title: *"Improving
//! the Efficiency of OpenCL Kernels through Pipes"*) as a three-layer
//! Rust + JAX + Pallas system. See DESIGN.md for the architecture and the
//! substitution table (the FPGA substrate is simulated).
pub mod analysis;
pub mod coordinator;
pub mod util;
pub mod ir;
pub mod transform;
pub mod workloads;
pub mod report;
pub mod runtime;
pub mod sim;
