//! Discrete-event performance simulator.
//!
//! Cross-checks the analytic model (`sim::perf`) by actually *playing out*
//! the launch: every kernel is a process consuming its outer-iteration
//! token stream; pipes impose producer->consumer data dependencies plus
//! depth-bounded backpressure; the DRAM controller is an epoch-bucketed
//! byte ledger that stalls whoever overdraws it. Captures what the
//! steady-state solver abstracts away — pipeline fill skew, channel-depth
//! slack, congestion transients — and is used by the `simulator` bench as
//! an ablation (analytic vs DES) and by `prop_sim` for consistency
//! properties (DES >= either bound, depth insensitivity, monotonicity).

use super::device::DeviceConfig;
use super::perf::PerfModel;
use super::profile::KernelProfile;
use crate::ir::{Program, Stmt};

/// DRAM epoch length in cycles (granularity of the bandwidth ledger).
const EPOCH: f64 = 256.0;

#[derive(Debug, Clone)]
pub struct DesResult {
    pub cycles: f64,
    pub seconds: f64,
    /// Per-kernel finish times (cycles).
    pub finish: Vec<(String, f64)>,
}

struct Proc {
    /// steady per-token cost (cycles), from the same per-loop accounting
    /// the analytic model uses
    cost: f64,
    /// DRAM-occupancy bytes consumed per token
    bytes: f64,
    /// tokens to process
    tokens: u64,
    /// index of upstream producer (pipe dependency), if any
    upstream: Option<usize>,
    /// channel depth toward this consumer (backpressure bound on producer)
    depth: usize,
    /// simulation state
    t: f64,
    done: u64,
    /// finish time of each of the last `depth` tokens of the *consumer*
    /// is tracked on the producer side via the consumer's `done`/times.
    recent: std::collections::VecDeque<f64>,
}

/// DRAM ledger: bytes available per epoch.
struct Dram {
    capacity_per_epoch: f64,
    used: Vec<f64>,
}

impl Dram {
    fn new(bytes_per_cycle: f64) -> Dram {
        Dram { capacity_per_epoch: bytes_per_cycle * EPOCH, used: vec![] }
    }

    /// Consume `bytes` starting at time `t`; returns the time the transfer
    /// completes (stalls into later epochs when the ledger is exhausted).
    fn consume(&mut self, t: f64, mut bytes: f64) -> f64 {
        let mut e = (t / EPOCH) as usize;
        loop {
            if self.used.len() <= e {
                self.used.resize(e + 1, 0.0);
            }
            let free = self.capacity_per_epoch - self.used[e];
            if bytes <= free {
                self.used[e] += bytes;
                let frac = self.used[e] / self.capacity_per_epoch;
                return (((e as f64) + frac.min(1.0)) * EPOCH).max(t);
            }
            bytes -= free;
            self.used[e] = self.capacity_per_epoch;
            e += 1;
        }
    }
}

/// Run the DES for one launch. `chunk` tokens are advanced per scheduling
/// decision (1 = exact, larger = faster with bounded error).
pub fn simulate(
    prog: &Program,
    model: &PerfModel,
    profiles: &[KernelProfile],
    cfg: &DeviceConfig,
    chunk: u64,
) -> DesResult {
    let analytic = model.estimate(profiles);
    let fmax = analytic.fmax_hz;

    // Outer-token count: iterations of each kernel's first top-level loop.
    let mut procs: Vec<Proc> = vec![];
    for ((k, kr), prof) in prog.kernels.iter().zip(&model.report.kernels).zip(profiles) {
        let outer = k
            .body
            .iter()
            .find_map(|s| match s {
                Stmt::For { id, .. } => Some(prof.loop_stats(*id).iters),
                _ => None,
            })
            .unwrap_or(1)
            .max(1);
        // steady per-token cost & bytes from the analytic per-kernel totals
        let cb = analytic
            .per_kernel
            .iter()
            .find(|(n, _)| n == &kr.name)
            .map(|(_, c)| *c)
            .unwrap_or(0.0);
        let bytes: f64 = kr
            .sites
            .iter()
            .map(|s| {
                let st = &prof.sites[s.site];
                st.count as f64 * model.access_cost(kr, s.site, st.seq_frac())
            })
            .sum();
        procs.push(Proc {
            cost: cb / outer as f64,
            bytes: bytes / outer as f64,
            tokens: outer,
            upstream: None,
            depth: 1,
            t: 0.0,
            done: 0,
            recent: Default::default(),
        });
    }

    // Pipe topology: consumer's upstream = producer index; depth = min depth
    // of the connecting pipes.
    for pd in &prog.pipes {
        let mut producer = None;
        let mut consumer = None;
        for (ki, k) in prog.kernels.iter().enumerate() {
            crate::ir::stmt::visit_body(&k.body, &mut |s| match s {
                Stmt::PipeWrite { pipe, .. } if pipe == &pd.name => producer = Some(ki),
                Stmt::PipeRead { pipe, .. } if pipe == &pd.name => consumer = Some(ki),
                _ => {}
            });
        }
        if let (Some(p), Some(c)) = (producer, consumer) {
            procs[c].upstream = Some(p);
            let d = procs[c].depth.max(pd.depth.max(1));
            procs[c].depth = d;
        }
    }

    let mut dram = Dram::new(cfg.dram_bytes_per_cycle(fmax));

    // Round-based co-simulation: advance the least-advanced runnable proc.
    loop {
        // pick unfinished process with smallest virtual time whose
        // dependencies allow progress
        let mut pick: Option<usize> = None;
        for (i, p) in procs.iter().enumerate() {
            if p.done >= p.tokens {
                continue;
            }
            if pick.map(|j| procs[j].t > p.t).unwrap_or(true) {
                pick = Some(i);
            }
        }
        let i = match pick {
            Some(i) => i,
            None => break,
        };

        let n = chunk.min(procs[i].tokens - procs[i].done);
        // data dependency: token `done + n` needs upstream to have produced
        // at least that many (channel latency added)
        let mut start = procs[i].t;
        if let Some(u) = procs[i].upstream {
            let need = procs[i].done + n;
            if procs[u].done < need {
                // upstream not there yet: advance upstream first by
                // retrying (set our clock to upstream's and loop)
                if procs[u].done < procs[u].tokens {
                    // move this proc's clock to upstream's to deprioritize
                    procs[i].t = procs[i].t.max(procs[u].t + cfg.channel_latency as f64);
                    continue;
                }
            }
            start = start.max(procs[u].t + cfg.channel_latency as f64);
            // backpressure on producer handled implicitly by consumer lag:
            // producer may run ahead at most depth tokens
            let _ = procs[i].depth;
        }

        let compute_end = start + procs[i].cost * n as f64;
        let end = if procs[i].bytes > 0.0 {
            dram.consume(start, procs[i].bytes * n as f64).max(compute_end)
        } else {
            compute_end
        };
        let p = &mut procs[i];
        p.t = end;
        p.done += n;
        p.recent.push_back(end);
        if p.recent.len() > p.depth {
            p.recent.pop_front();
        }

        // backpressure: if this proc is a producer, cap how far it runs
        // ahead of its consumer by depth tokens
        for j in 0..procs.len() {
            if procs[j].upstream == Some(i) {
                let lead = procs[i].done as i64 - procs[j].done as i64;
                let max_lead = procs[j].depth as i64 + chunk as i64;
                if lead > max_lead {
                    // producer stalls until consumer catches up: approximate
                    // by setting producer clock to consumer clock
                    let tj = procs[j].t;
                    if tj > procs[i].t {
                        procs[i].t = tj;
                    }
                }
            }
        }
    }

    let cycles = procs.iter().map(|p| p.t).fold(0.0, f64::max);
    DesResult {
        cycles,
        seconds: cycles / fmax,
        finish: prog
            .kernels
            .iter()
            .zip(&procs)
            .map(|(k, p)| (k.name.clone(), p.t))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, Program, Ty};
    use crate::sim::exec::{run_group, ExecOptions};
    use crate::sim::mem::MemoryImage;

    fn setup(n: usize) -> (Program, MemoryImage) {
        let k = KernelBuilder::new("s", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("a", v("i")) * f(2.0))],
            )])
            .finish();
        let ff = crate::transform::feedforward(&k, 4).unwrap();
        let mut m = MemoryImage::new();
        m.add_f32s("a", &vec![1.0; n]).add_zeros("o", Ty::F32, n).set_i("n", n as i64);
        (ff, m)
    }

    #[test]
    fn des_close_to_analytic_on_stream_pair() {
        let cfg = DeviceConfig::pac_a10();
        let (prog, img) = setup(50_000);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let model = PerfModel::new(&prog, &cfg);
        let a = model.estimate(&run.profiles);
        let d = simulate(&prog, &model, &run.profiles, &cfg, 64);
        let ratio = d.cycles / a.cycles;
        assert!(ratio > 0.8 && ratio < 2.0, "DES/analytic = {ratio}");
    }

    #[test]
    fn des_depth_insensitive() {
        // E4c shape: channel depth does not matter much.
        let cfg = DeviceConfig::pac_a10();
        let mut times = vec![];
        for depth in [1usize, 100, 1000] {
            let (prog, img) = setup(20_000);
            let prog = prog.with_pipe_depth(depth);
            let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
            let model = PerfModel::new(&prog, &cfg);
            let d = simulate(&prog, &model, &run.profiles, &cfg, 64);
            times.push(d.cycles);
        }
        let max = times.iter().cloned().fold(0.0, f64::max);
        let min = times.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min < 1.15, "depth sweep spread too large: {times:?}");
    }
}
