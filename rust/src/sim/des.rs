//! Discrete-event performance simulator.
//!
//! Cross-checks the analytic model (`sim::perf`) by actually *playing out*
//! the launch: every kernel is a process consuming its outer-iteration
//! token stream; pipes impose producer->consumer data dependencies plus
//! depth-bounded backpressure; the DRAM controller is an epoch-bucketed
//! byte ledger that stalls whoever overdraws it. The ledger's capacity is
//! derated by the device's bank-level parallelism (`sim::mem::MemModel`),
//! so the DES and the analytic estimator tell the same per-device story
//! (exact identity on `arria10`). Captures what the
//! steady-state solver abstracts away — pipeline fill skew, channel-depth
//! slack, congestion transients — and is used by the `simulator` and
//! `interp` benches as an ablation (analytic vs DES) and by `prop_sim`
//! for consistency properties (DES >= either bound, depth insensitivity,
//! monotonicity).
//!
//! § Perf — two data-structure upgrades over the original implementation
//! (kept as [`simulate_reference`] for the equivalence tests and the
//! `interp` bench ablation):
//!
//! * **Heap scheduler** — picking the least-advanced runnable process was
//!   an O(P) scan per scheduling decision; it is now a [`BinaryHeap`]
//!   keyed on `(virtual time, process index)`. Only the popped process's
//!   clock ever moves, so entries never go stale and the pop order is
//!   exactly the scan's pick order (first index among minimal times) —
//!   `DesResult::cycles` is bit-identical by construction, proved by
//!   `heap_scheduler_matches_reference_exactly`.
//! * **Epoch-ring DRAM ledger** — [`Dram`] used to keep one `f64` per
//!   epoch since time zero in an ever-growing `Vec`, so long simulations
//!   resized the ledger forever. Scheduled times are non-decreasing
//!   (each pop is the global minimum and clocks only move forward), so
//!   epochs before the current pick are final: the ledger is now a ring
//!   (`VecDeque` + base epoch) that retires dead epochs as the pick time
//!   advances — O(1) amortized per consume, memory bounded by the active
//!   congestion window instead of total simulated time.

use super::device::DeviceConfig;
use super::perf::PerfModel;
use super::profile::KernelProfile;
use crate::ir::{Program, Stmt};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// DRAM epoch length in cycles (granularity of the bandwidth ledger).
const EPOCH: f64 = 256.0;

#[derive(Debug, Clone)]
pub struct DesResult {
    pub cycles: f64,
    pub seconds: f64,
    /// Per-kernel finish times (cycles).
    pub finish: Vec<(String, f64)>,
    /// High-water mark of live DRAM-ledger epochs (ring occupancy); the
    /// reference implementation reports its full ledger length here.
    pub dram_window: usize,
}

struct Proc {
    /// steady per-token cost (cycles), from the same per-loop accounting
    /// the analytic model uses
    cost: f64,
    /// DRAM-occupancy bytes consumed per token
    bytes: f64,
    /// tokens to process
    tokens: u64,
    /// index of upstream producer (pipe dependency), if any
    upstream: Option<usize>,
    /// channel depth toward this consumer (backpressure bound on producer)
    depth: usize,
    /// simulation state
    t: f64,
    done: u64,
}

/// DRAM ledger: bytes available per epoch, stored as a ring over the
/// active window. `base` is the epoch index of `ring[0]`; epochs before
/// `base` are retired (final) and epochs past the back are implicitly
/// empty until first touched.
struct Dram {
    capacity_per_epoch: f64,
    base: usize,
    ring: VecDeque<f64>,
    peak_window: usize,
}

impl Dram {
    fn new(bytes_per_cycle: f64) -> Dram {
        Dram {
            capacity_per_epoch: bytes_per_cycle * EPOCH,
            base: 0,
            ring: VecDeque::new(),
            peak_window: 0,
        }
    }

    /// Retire every epoch strictly before `t`'s. Sound because the
    /// scheduler's pick times are non-decreasing and every transfer
    /// starts at or after its pick time — a retired epoch can never be
    /// written again.
    fn retire(&mut self, t: f64) {
        let e = (t / EPOCH) as usize;
        while self.base < e && self.ring.pop_front().is_some() {
            self.base += 1;
        }
        if self.ring.is_empty() && self.base < e {
            self.base = e;
        }
    }

    /// Consume `bytes` starting at time `t`; returns the time the transfer
    /// completes (stalls into later epochs when the ledger is exhausted).
    /// Same arithmetic as the historical `Vec` ledger — only the storage
    /// of live epochs changed.
    fn consume(&mut self, t: f64, mut bytes: f64) -> f64 {
        let mut e = (t / EPOCH) as usize;
        debug_assert!(e >= self.base, "transfer into a retired epoch ({e} < {})", self.base);
        e = e.max(self.base);
        loop {
            while self.ring.len() <= e - self.base {
                self.ring.push_back(0.0);
            }
            self.peak_window = self.peak_window.max(self.ring.len());
            let slot = &mut self.ring[e - self.base];
            let free = self.capacity_per_epoch - *slot;
            if bytes <= free {
                *slot += bytes;
                let frac = *slot / self.capacity_per_epoch;
                return (((e as f64) + frac.min(1.0)) * EPOCH).max(t);
            }
            bytes -= free;
            *slot = self.capacity_per_epoch;
            e += 1;
        }
    }
}

/// Outer-token processes + pipe topology shared by [`simulate`] and
/// [`simulate_reference`] (cost model identical between the two).
/// Returns the processes plus the design fmax from the analytic estimate.
fn build_procs(
    prog: &Program,
    model: &PerfModel,
    profiles: &[KernelProfile],
) -> (Vec<Proc>, f64) {
    let analytic = model.estimate(profiles);

    // Outer-token count: iterations of each kernel's first top-level loop.
    let mut procs: Vec<Proc> = vec![];
    for ((k, kr), prof) in prog.kernels.iter().zip(&model.report.kernels).zip(profiles) {
        let outer = k
            .body
            .iter()
            .find_map(|s| match s {
                Stmt::For { id, .. } => Some(prof.loop_stats(*id).iters),
                _ => None,
            })
            .unwrap_or(1)
            .max(1);
        // steady per-token cost & bytes from the analytic per-kernel totals
        let cb = analytic
            .per_kernel
            .iter()
            .find(|(n, _)| n == &kr.name)
            .map(|(_, c)| *c)
            .unwrap_or(0.0);
        let bytes: f64 = kr
            .sites
            .iter()
            .map(|s| {
                let st = &prof.sites[s.site];
                st.count as f64 * model.access_cost(kr, s.site, st.seq_frac())
            })
            .sum();
        procs.push(Proc {
            cost: cb / outer as f64,
            bytes: bytes / outer as f64,
            tokens: outer,
            upstream: None,
            depth: 1,
            t: 0.0,
            done: 0,
        });
    }

    // Pipe topology: consumer's upstream = producer index; depth = the
    // deepest connecting pipe (the historical, deliberately loose
    // backpressure bound — kept bit-compatible with simulate_reference).
    for pd in &prog.pipes {
        let mut producer = None;
        let mut consumer = None;
        for (ki, k) in prog.kernels.iter().enumerate() {
            crate::ir::stmt::visit_body(&k.body, &mut |s| match s {
                Stmt::PipeWrite { pipe, .. } if pipe == &pd.name => producer = Some(ki),
                Stmt::PipeRead { pipe, .. } if pipe == &pd.name => consumer = Some(ki),
                _ => {}
            });
        }
        if let (Some(p), Some(c)) = (producer, consumer) {
            procs[c].upstream = Some(p);
            let d = procs[c].depth.max(pd.depth.max(1));
            procs[c].depth = d;
        }
    }
    (procs, analytic.fmax_hz)
}

/// Min-heap key: `(virtual time, process index)` — lexicographic order
/// reproduces the linear scan's pick exactly (first index among the
/// minimal times).
#[derive(PartialEq)]
struct SchedKey {
    t: f64,
    i: usize,
}

impl Eq for SchedKey {}

impl Ord for SchedKey {
    fn cmp(&self, other: &SchedKey) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.i.cmp(&other.i))
    }
}

impl PartialOrd for SchedKey {
    fn partial_cmp(&self, other: &SchedKey) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The heap co-simulation loop shared by [`simulate`] (one launch) and
/// [`simulate_graph`] (one merged wavefront of launches): pop the
/// least-advanced unfinished proc and advance it by up to `chunk` tokens.
/// Only the popped proc's clock moves, so each proc has exactly one live
/// heap entry and entries never go stale. Extracted verbatim from the
/// historical `simulate` body — `heap_scheduler_matches_reference_exactly`
/// still pins it against [`simulate_reference`].
fn run_heap(procs: &mut [Proc], dram: &mut Dram, cfg: &DeviceConfig, chunk: u64) {
    // Reverse adjacency for the backpressure pass: consumers of each proc.
    let mut downstream: Vec<Vec<usize>> = vec![vec![]; procs.len()];
    for (j, p) in procs.iter().enumerate() {
        if let Some(u) = p.upstream {
            downstream[u].push(j);
        }
    }

    let mut heap: BinaryHeap<Reverse<SchedKey>> = procs
        .iter()
        .enumerate()
        .map(|(i, p)| Reverse(SchedKey { t: p.t, i }))
        .collect();
    while let Some(Reverse(SchedKey { t, i })) = heap.pop() {
        debug_assert_eq!(t, procs[i].t, "stale heap entry for proc {i}");
        if procs[i].done >= procs[i].tokens {
            continue;
        }
        // the pick time is the global minimum and clocks only advance:
        // epochs before it are final
        dram.retire(t);

        let n = chunk.min(procs[i].tokens - procs[i].done);
        // data dependency: token `done + n` needs upstream to have produced
        // at least that many (channel latency added)
        let mut start = procs[i].t;
        if let Some(u) = procs[i].upstream {
            let need = procs[i].done + n;
            if procs[u].done < need && procs[u].done < procs[u].tokens {
                // upstream not there yet: move this proc's clock to
                // upstream's to deprioritize, and retry later
                procs[i].t = procs[i].t.max(procs[u].t + cfg.channel_latency as f64);
                heap.push(Reverse(SchedKey { t: procs[i].t, i }));
                continue;
            }
            start = start.max(procs[u].t + cfg.channel_latency as f64);
        }

        let compute_end = start + procs[i].cost * n as f64;
        let end = if procs[i].bytes > 0.0 {
            dram.consume(start, procs[i].bytes * n as f64).max(compute_end)
        } else {
            compute_end
        };
        procs[i].t = end;
        procs[i].done += n;

        // backpressure: if this proc is a producer, cap how far it runs
        // ahead of its consumers by depth tokens
        for &j in &downstream[i] {
            let lead = procs[i].done as i64 - procs[j].done as i64;
            let max_lead = procs[j].depth as i64 + chunk as i64;
            if lead > max_lead {
                // producer stalls until consumer catches up: approximate
                // by setting producer clock to consumer clock
                let tj = procs[j].t;
                if tj > procs[i].t {
                    procs[i].t = tj;
                }
            }
        }
        if procs[i].done < procs[i].tokens {
            heap.push(Reverse(SchedKey { t: procs[i].t, i }));
        }
    }
}

/// Run the DES for one launch. `chunk` tokens are advanced per scheduling
/// decision (1 = exact, larger = faster with bounded error).
pub fn simulate(
    prog: &Program,
    model: &PerfModel,
    profiles: &[KernelProfile],
    cfg: &DeviceConfig,
    chunk: u64,
) -> DesResult {
    let (mut procs, fmax) = build_procs(prog, model, profiles);
    // The ledger sees the same bank-parallelism-derated capacity as the
    // analytic model: kernels that move DRAM bytes are the requesters
    // (exact x1.0 on arria10, so historical cycle counts are unchanged).
    let requesters = procs.iter().filter(|p| p.bytes > 0.0).count();
    let mut dram =
        Dram::new(cfg.dram_bytes_per_cycle(fmax) * cfg.mem.bank_parallel_efficiency(requesters));

    run_heap(&mut procs, &mut dram, cfg, chunk);

    finish(prog, &procs, fmax, dram.peak_window)
}

/// One launch of a co-scheduled wavefront, as [`simulate_graph`] consumes
/// it: the launch unit, its per-unit performance model (sharing the
/// design fmax), and the profiles its trace recorded.
pub struct GraphLaunch<'a> {
    pub unit: &'a Program,
    pub model: &'a PerfModel,
    pub profiles: &'a [KernelProfile],
}

/// Result of a launch-graph simulation.
#[derive(Debug, Clone)]
pub struct GraphDesResult {
    /// Total modelled cycles: the sum of wavefront spans.
    pub cycles: f64,
    pub seconds: f64,
    /// Per-wavefront spans (cycles), in execution order.
    pub wave_cycles: Vec<f64>,
    /// High-water mark of the DRAM ledger's live window over all waves.
    pub dram_window: usize,
}

/// Co-schedule a launch *graph* through the DES: launches with equal
/// `levels[i]` (the [`crate::analysis::LaunchDag`] wavefront assignment)
/// are merged into one proc set sharing a single DRAM ledger, and
/// wavefronts execute in level order with a barrier between them — a
/// conservative rendering of the DAG (a launch may in principle start as
/// soon as its *predecessors* finish; the wavefront barrier only ever
/// rounds the overlap *down*, never models an illegal one).
///
/// Two model properties anchor the E9 comparison:
///
/// * **Single-member waves are exact**: a wavefront containing one launch
///   builds the same procs, requester count, and ledger capacity as
///   [`simulate`], and runs the identical [`run_heap`] loop — so a full
///   chain (every level distinct, e.g. NW) sums to exactly the
///   sequential per-launch cycles. Proved by
///   `graph_single_launch_is_bit_identical_to_simulate`.
/// * **Merging never slows the model down**: the merged ledger capacity
///   uses `bank_parallel_efficiency(requesters)`, which is nondecreasing
///   in the requester count (capped at 1.0), and a wave's span is bounded
///   by what its members would cost back to back on the weaker ledger.
pub fn simulate_graph(
    launches: &[GraphLaunch],
    levels: &[usize],
    cfg: &DeviceConfig,
    chunk: u64,
) -> GraphDesResult {
    assert_eq!(launches.len(), levels.len(), "one level per launch");
    let mut fmax = 0.0f64;
    let mut wave_cycles = vec![];
    let mut dram_window = 0usize;
    let max_level = levels.iter().copied().max();
    if let Some(max_level) = max_level {
        for lvl in 0..=max_level {
            // merge every launch of this wavefront into one proc set,
            // offsetting pipe-upstream indices per launch
            let mut procs: Vec<Proc> = vec![];
            for (gl, _) in launches.iter().zip(levels).filter(|(_, l)| **l == lvl) {
                let (mut ps, f) = build_procs(gl.unit, gl.model, gl.profiles);
                let off = procs.len();
                for p in &mut ps {
                    if let Some(u) = &mut p.upstream {
                        *u += off;
                    }
                }
                procs.extend(ps);
                fmax = f; // whole-design clock: identical across units
            }
            if procs.is_empty() {
                continue;
            }
            let requesters = procs.iter().filter(|p| p.bytes > 0.0).count();
            let mut dram = Dram::new(
                cfg.dram_bytes_per_cycle(fmax) * cfg.mem.bank_parallel_efficiency(requesters),
            );
            run_heap(&mut procs, &mut dram, cfg, chunk);
            wave_cycles.push(procs.iter().map(|p| p.t).fold(0.0, f64::max));
            dram_window = dram_window.max(dram.peak_window);
        }
    }
    let cycles = wave_cycles.iter().sum::<f64>();
    GraphDesResult {
        cycles,
        seconds: if fmax > 0.0 { cycles / fmax } else { 0.0 },
        wave_cycles,
        dram_window,
    }
}

/// The historical O(P)-scan scheduler with the ever-growing `Vec` DRAM
/// ledger, kept verbatim as the equivalence baseline for the heap/ring
/// implementation (`heap_scheduler_matches_reference_exactly`) and as the
/// "before" leg of the `interp` bench ablation. Do not use in production
/// paths: its ledger memory grows with simulated time.
#[doc(hidden)]
pub fn simulate_reference(
    prog: &Program,
    model: &PerfModel,
    profiles: &[KernelProfile],
    cfg: &DeviceConfig,
    chunk: u64,
) -> DesResult {
    struct DramVec {
        capacity_per_epoch: f64,
        used: Vec<f64>,
    }
    impl DramVec {
        fn consume(&mut self, t: f64, mut bytes: f64) -> f64 {
            let mut e = (t / EPOCH) as usize;
            loop {
                if self.used.len() <= e {
                    self.used.resize(e + 1, 0.0);
                }
                let free = self.capacity_per_epoch - self.used[e];
                if bytes <= free {
                    self.used[e] += bytes;
                    let frac = self.used[e] / self.capacity_per_epoch;
                    return (((e as f64) + frac.min(1.0)) * EPOCH).max(t);
                }
                bytes -= free;
                self.used[e] = self.capacity_per_epoch;
                e += 1;
            }
        }
    }

    let (mut procs, fmax) = build_procs(prog, model, profiles);
    let requesters = procs.iter().filter(|p| p.bytes > 0.0).count();
    let mut dram = DramVec {
        capacity_per_epoch: cfg.dram_bytes_per_cycle(fmax)
            * cfg.mem.bank_parallel_efficiency(requesters)
            * EPOCH,
        used: vec![],
    };

    loop {
        let mut pick: Option<usize> = None;
        for (i, p) in procs.iter().enumerate() {
            if p.done >= p.tokens {
                continue;
            }
            if pick.map(|j| procs[j].t > p.t).unwrap_or(true) {
                pick = Some(i);
            }
        }
        let i = match pick {
            Some(i) => i,
            None => break,
        };

        let n = chunk.min(procs[i].tokens - procs[i].done);
        let mut start = procs[i].t;
        if let Some(u) = procs[i].upstream {
            let need = procs[i].done + n;
            if procs[u].done < need && procs[u].done < procs[u].tokens {
                procs[i].t = procs[i].t.max(procs[u].t + cfg.channel_latency as f64);
                continue;
            }
            start = start.max(procs[u].t + cfg.channel_latency as f64);
        }

        let compute_end = start + procs[i].cost * n as f64;
        let end = if procs[i].bytes > 0.0 {
            dram.consume(start, procs[i].bytes * n as f64).max(compute_end)
        } else {
            compute_end
        };
        procs[i].t = end;
        procs[i].done += n;

        for j in 0..procs.len() {
            if procs[j].upstream == Some(i) {
                let lead = procs[i].done as i64 - procs[j].done as i64;
                let max_lead = procs[j].depth as i64 + chunk as i64;
                if lead > max_lead {
                    let tj = procs[j].t;
                    if tj > procs[i].t {
                        procs[i].t = tj;
                    }
                }
            }
        }
    }

    finish(prog, &procs, fmax, dram.used.len())
}

fn finish(prog: &Program, procs: &[Proc], fmax: f64, dram_window: usize) -> DesResult {
    let cycles = procs.iter().map(|p| p.t).fold(0.0, f64::max);
    DesResult {
        cycles,
        seconds: cycles / fmax,
        finish: prog
            .kernels
            .iter()
            .zip(procs)
            .map(|(k, p)| (k.name.clone(), p.t))
            .collect(),
        dram_window,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, Program, Ty};
    use crate::sim::exec::{run_group, ExecOptions};
    use crate::sim::mem::MemoryImage;

    fn setup(n: usize) -> (Program, MemoryImage) {
        let k = KernelBuilder::new("s", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("a", v("i")) * f(2.0))],
            )])
            .finish();
        let ff = crate::transform::feedforward(&k, 4).unwrap();
        let mut m = MemoryImage::new();
        m.add_f32s("a", &vec![1.0; n]).add_zeros("o", Ty::F32, n).set_i("n", n as i64);
        (ff, m)
    }

    #[test]
    fn des_close_to_analytic_on_stream_pair() {
        let cfg = DeviceConfig::pac_a10();
        let (prog, img) = setup(50_000);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let model = PerfModel::new(&prog, &cfg);
        let a = model.estimate(&run.profiles);
        let d = simulate(&prog, &model, &run.profiles, &cfg, 64);
        let ratio = d.cycles / a.cycles;
        assert!(ratio > 0.8 && ratio < 2.0, "DES/analytic = {ratio}");
        // the heap scheduler + epoch ring are storage changes only
        let r = simulate_reference(&prog, &model, &run.profiles, &cfg, 64);
        assert_eq!(d.cycles, r.cycles, "heap DES diverged from the reference scan");
    }

    #[test]
    fn des_depth_insensitive() {
        // E4c shape: channel depth does not matter much.
        let cfg = DeviceConfig::pac_a10();
        let mut times = vec![];
        for depth in [1usize, 100, 1000] {
            let (prog, img) = setup(20_000);
            let prog = prog.with_pipe_depth(depth);
            let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
            let model = PerfModel::new(&prog, &cfg);
            let d = simulate(&prog, &model, &run.profiles, &cfg, 64);
            let r = simulate_reference(&prog, &model, &run.profiles, &cfg, 64);
            assert_eq!(d.cycles, r.cycles, "depth {depth}: heap DES diverged from reference");
            times.push(d.cycles);
        }
        let max = times.iter().cloned().fold(0.0, f64::max);
        let min = times.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min < 1.15, "depth sweep spread too large: {times:?}");
    }

    /// Bit-exact equivalence on a topology that stresses the scheduler:
    /// a replicated producer/consumer program (4 processes, asymmetric
    /// token counts) at chunk 1 — the scheduling-heaviest configuration,
    /// where a tie-breaking or staleness bug in the heap would surface.
    #[test]
    fn heap_scheduler_matches_reference_exactly() {
        let cfg = DeviceConfig::pac_a10();
        let k = crate::transform::examples::fig2_kernel();
        let prog =
            crate::transform::apply_variant(&k, crate::transform::Variant::MxCx {
                parts: 2,
                depth: 1,
            })
            .unwrap();
        let row = vec![0i64, 2, 4, 5, 7];
        let col = vec![1i64, 2, 0, 3, 0, 1, 2];
        let mut img = MemoryImage::new();
        img.add_i64s("row", &row)
            .add_i64s("col", &col)
            .add_i64s("c_array", &[-1, -1, 3, -1])
            .add_f32s("node_value", &[0.3, 0.1, 0.9, 0.7])
            .add_zeros("min_array", Ty::F32, 4)
            .add_zeros("stop", Ty::I32, 1);
        img.set_i("num_nodes", 4).set_i("num_edges", 7);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let model = PerfModel::new(&prog, &cfg);
        for chunk in [1u64, 7, 64] {
            let d = simulate(&prog, &model, &run.profiles, &cfg, chunk);
            let r = simulate_reference(&prog, &model, &run.profiles, &cfg, chunk);
            assert_eq!(d.cycles, r.cycles, "chunk {chunk}: cycles diverged");
            assert_eq!(d.finish, r.finish, "chunk {chunk}: per-kernel finish times diverged");
        }
    }

    /// The epoch ring must retire dead epochs as simulated time advances:
    /// a long monotone consume stream keeps the live window small where
    /// the historical `Vec` ledger grew one slot per epoch forever.
    #[test]
    fn dram_ring_memory_stays_bounded() {
        let mut d = Dram::new(1.0); // 256 bytes per epoch
        let epochs = 100_000usize;
        for step in 0..epochs {
            let t = step as f64 * EPOCH;
            d.retire(t);
            // bursty but sustainable traffic (~74% average utilization):
            // every 10th step overdraws ~4 epochs ahead, the rest underfill
            let bytes = if step % 10 == 0 { 1000.0 } else { 100.0 };
            let end = d.consume(t, bytes);
            assert!(end >= t);
        }
        assert!(
            d.peak_window <= 16,
            "ring window {} epochs; a leaking ledger would hold ~{epochs}",
            d.peak_window
        );
        assert!(d.ring.len() <= 16);
        assert!(d.base > 0, "old epochs must actually retire");
    }

    /// The launch-graph scheduler's single-launch path is the old path:
    /// a graph whose levels are all distinct (a chain) must sum to
    /// exactly the per-launch `simulate` cycles, and a one-launch graph
    /// must be bit-identical to `simulate`. This is what keeps overlap-off
    /// BENCH keys and cycle counts stable across the refactor.
    #[test]
    fn graph_single_launch_is_bit_identical_to_simulate() {
        let cfg = DeviceConfig::pac_a10();
        let (prog, img) = setup(20_000);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let model = PerfModel::new(&prog, &cfg);
        let single = simulate(&prog, &model, &run.profiles, &cfg, 64);
        let gl = GraphLaunch { unit: &prog, model: &model, profiles: &run.profiles };
        let g1 = simulate_graph(std::slice::from_ref(&gl), &[0], &cfg, 64);
        assert_eq!(g1.cycles, single.cycles, "one-launch graph diverged from simulate");
        assert_eq!(g1.seconds, single.seconds);
        // a 3-launch chain = 3x the sequential cycles, exactly
        let chain = [
            GraphLaunch { unit: &prog, model: &model, profiles: &run.profiles },
            GraphLaunch { unit: &prog, model: &model, profiles: &run.profiles },
            GraphLaunch { unit: &prog, model: &model, profiles: &run.profiles },
        ];
        let gc = simulate_graph(&chain, &[0, 1, 2], &cfg, 64);
        assert_eq!(gc.wave_cycles, vec![single.cycles; 3]);
        assert_eq!(gc.cycles, single.cycles * 3.0);
    }

    /// Merging unordered launches into one wavefront never models more
    /// time than the sequential chain (bank-parallel efficiency is
    /// nondecreasing in requesters), and overlapping a compute-bound
    /// launch with a memory-bound one is strictly faster.
    #[test]
    fn graph_merged_wavefront_is_not_slower_than_chain() {
        let cfg = DeviceConfig::pac_a10();
        let (prog, img) = setup(20_000);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let model = PerfModel::new(&prog, &cfg);
        let mk = || GraphLaunch { unit: &prog, model: &model, profiles: &run.profiles };
        let launches = [mk(), mk(), mk(), mk()];
        let chain = simulate_graph(&launches, &[0, 1, 2, 3], &cfg, 64);
        let merged = simulate_graph(&launches, &[0, 0, 0, 0], &cfg, 64);
        assert!(
            merged.cycles <= chain.cycles,
            "merged wavefront slower than chain: {} > {}",
            merged.cycles,
            chain.cycles
        );
        assert_eq!(merged.wave_cycles.len(), 1);
        assert_eq!(chain.wave_cycles.len(), 4);
    }

    /// Ring-vs-Vec ledger equivalence on an adversarial pattern: starts
    /// jump ahead (upstream latency) and fall back to the pick time, with
    /// overdraw spilling several epochs forward.
    #[test]
    fn dram_ring_matches_vec_ledger_arithmetic() {
        let mut ring = Dram::new(0.5);
        let mut used: Vec<f64> = vec![]; // reference ledger
        let capacity = 0.5 * EPOCH;
        let mut reference_consume = |t: f64, mut bytes: f64| -> f64 {
            let mut e = (t / EPOCH) as usize;
            loop {
                if used.len() <= e {
                    used.resize(e + 1, 0.0);
                }
                let free = capacity - used[e];
                if bytes <= free {
                    used[e] += bytes;
                    let frac = used[e] / capacity;
                    return (((e as f64) + frac.min(1.0)) * EPOCH).max(t);
                }
                bytes -= free;
                used[e] = capacity;
                e += 1;
            }
        };
        let mut pick = 0.0f64;
        for step in 0..5_000 {
            pick += (step % 7) as f64 * 13.0; // non-decreasing pick times
            ring.retire(pick);
            // starts at or after the pick, sometimes far ahead
            let start = pick + (step % 11) as f64 * 97.0;
            let bytes = 1.0 + (step % 13) as f64 * 40.0;
            assert_eq!(
                ring.consume(start, bytes),
                reference_consume(start, bytes),
                "step {step}: ring and Vec ledgers diverged"
            );
        }
        assert!(ring.ring.len() < used.len(), "ring must hold fewer live epochs");
    }
}
