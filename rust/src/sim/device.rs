//! Device model: an Intel PAC (Arria 10 GX) -like board, §4.1 of the paper.
//!
//! All performance/area constants of the substrate live here so experiments
//! can sweep them (and so the calibration targets in DESIGN.md are in one
//! place).

/// Board + toolchain model parameters.
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    // ---- clocks -----------------------------------------------------------
    /// Nominal kernel clock (Hz). The paper reports no consistent fmax
    /// trend; we derate it slightly with design size (see `fmax_for_area`).
    pub fmax_hz: f64,
    /// Logic-utilization knee above which fmax starts to degrade.
    pub fmax_derate_knee: f64,
    /// Fractional fmax loss per logic-utilization point above the knee.
    pub fmax_derate_slope: f64,

    // ---- DRAM -------------------------------------------------------------
    /// Peak off-chip bandwidth (bytes/s) — 34.1 GB/s on the PAC board.
    pub dram_peak_bytes_per_s: f64,
    /// DRAM burst size in bytes (DDR4-64B).
    pub burst_bytes: u64,
    /// Efficiency of a prefetching LSU on a sequential stream.
    pub eff_seq_prefetch: f64,
    /// Efficiency of a burst-coalesced LSU on a sequential stream.
    pub eff_seq_burst: f64,
    /// Effective bytes consumed from DRAM per *random* 4-byte access
    /// (row activation + wasted burst): the memory-controller-wall number;
    /// 256 B/word reproduces the paper's ~200-600 MB/s random-access floor.
    pub random_access_cost_bytes: f64,
    /// Extra congestion per concurrent requester beyond this count.
    pub congestion_free_requesters: usize,
    /// Multiplicative efficiency loss per extra requester (regular streams).
    pub congestion_slope_regular: f64,
    /// Multiplicative efficiency loss per extra requester (irregular).
    pub congestion_slope_irregular: f64,

    // ---- pipeline ---------------------------------------------------------
    /// Depth of a kernel's compute pipeline (drain cost per loop
    /// invocation).
    pub pipeline_depth: u32,
    /// Number of serialized inner-loop instances the scheduler can keep in
    /// flight when the serialized loop is nested inside an outer loop
    /// (bounded loop-pipelining concurrency; 1 = no overlap).
    pub serialized_overlap: u32,
    /// Per-loop-invocation pipeline restart cost (cycles).
    pub loop_fill_cycles: f64,
    /// Peak bytes/cycle through one kernel's memory port (128-bit Avalon
    /// interface); a single kernel cannot saturate DRAM by itself — the
    /// headroom M2C2 exploits.
    pub kernel_port_bytes_per_cycle: f64,
    /// Per-iteration handshake overhead (cycles) added by each channel
    /// endpoint in a kernel's steady state.
    pub channel_overhead_cycles: f64,
    /// Latency through a channel (write -> readable), cycles.
    pub channel_latency: u32,

    // ---- area -------------------------------------------------------------
    /// Total ALMs on the device (Arria 10 GX 1150).
    pub total_alms: f64,
    /// Total M20K BRAM blocks.
    pub total_brams: u32,
    /// Total DSP blocks.
    pub total_dsps: u32,
    /// Board shell / BSP static logic fraction (0..1).
    pub shell_logic_frac: f64,
    /// Board shell BRAM blocks.
    pub shell_brams: u32,
    /// Per-kernel control overhead in ALMs.
    pub kernel_alms: f64,
    /// Per-kernel BRAM overhead.
    pub kernel_brams: u32,
    /// LSU areas (ALMs, BRAMs).
    pub lsu_burst_alms: f64,
    pub lsu_burst_brams: u32,
    pub lsu_prefetch_alms: f64,
    pub lsu_prefetch_brams: u32,
    pub lsu_pipelined_alms: f64,
    pub lsu_pipelined_brams: u32,
    /// Channel endpoint area; BRAM grows with depth (words / 512 per M20K).
    pub channel_alms: f64,
    pub channel_words_per_bram: usize,
}

impl DeviceConfig {
    /// The paper's testbed: Intel PAC with Arria 10 GX 1150, 2x4 GB DDR4.
    pub fn pac_a10() -> DeviceConfig {
        DeviceConfig {
            fmax_hz: 240e6,
            fmax_derate_knee: 0.20,
            fmax_derate_slope: 0.55,

            dram_peak_bytes_per_s: 34.1e9,
            burst_bytes: 64,
            eff_seq_prefetch: 0.86,
            eff_seq_burst: 0.74,
            random_access_cost_bytes: 256.0,
            congestion_free_requesters: 2,
            congestion_slope_regular: 0.06,
            congestion_slope_irregular: 0.05,

            pipeline_depth: 90,
            serialized_overlap: 4,
            loop_fill_cycles: 12.0,
            kernel_port_bytes_per_cycle: 64.0,
            channel_overhead_cycles: 0.035,
            channel_latency: 3,

            total_alms: 427_200.0,
            total_brams: 2_713,
            total_dsps: 3_036,
            shell_logic_frac: 0.1393,
            shell_brams: 380,
            kernel_alms: 1_500.0,
            kernel_brams: 9,
            lsu_burst_alms: 3_200.0,
            lsu_burst_brams: 14,
            lsu_prefetch_alms: 1_350.0,
            lsu_prefetch_brams: 9,
            lsu_pipelined_alms: 520.0,
            lsu_pipelined_brams: 0,
            channel_alms: 70.0,
            channel_words_per_bram: 512,
        }
    }

    /// DRAM capacity in bytes per kernel clock cycle.
    pub fn dram_bytes_per_cycle(&self, fmax: f64) -> f64 {
        self.dram_peak_bytes_per_s / fmax
    }

    /// fmax after derating for design size (deterministic, mild — the paper
    /// found no strong trend, only scatter).
    pub fn fmax_for_area(&self, logic_frac: f64) -> f64 {
        let over = (logic_frac - self.fmax_derate_knee).max(0.0);
        let derate = 1.0 - self.fmax_derate_slope * over;
        self.fmax_hz * derate.clamp(0.55, 1.0)
    }
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig::pac_a10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_cycle_budget_is_plausible() {
        let c = DeviceConfig::pac_a10();
        let bpc = c.dram_bytes_per_cycle(c.fmax_hz);
        // 34.1 GB/s at 240 MHz ~ 142 B/cycle
        assert!((bpc - 142.0).abs() < 2.0, "bpc={bpc}");
    }

    #[test]
    fn fmax_derates_monotonically() {
        let c = DeviceConfig::pac_a10();
        let f1 = c.fmax_for_area(0.16);
        let f2 = c.fmax_for_area(0.25);
        let f3 = c.fmax_for_area(0.40);
        assert_eq!(f1, c.fmax_hz); // below knee
        assert!(f2 < f1 && f3 < f2);
        assert!(f3 > 0.5 * c.fmax_hz);
    }
}
