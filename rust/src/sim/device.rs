//! Device zoo: named, calibrated board models behind one [`DeviceConfig`].
//!
//! The source paper measures one board (an Intel PAC with Arria 10 GX,
//! §4.1), but its framing is performance *portability*: pipes win because
//! FPGA external memory behaves unlike CPU/GPU memory. This module keeps
//! every performance/area constant of the modelled substrate in one place
//! and grows it into a registry of four calibrated profiles
//! ([`DeviceRegistry`]): `arria10` (the paper's testbed, numerically
//! unchanged so persistent-store keys and BENCH sinks stay byte-identical),
//! `stratix10-hbm`, `gpu-like`, and `cpu-like`. Per-profile provenance
//! lives on the constructors below and in `docs/DEVICES.md`.
//!
//! Two invariants the rest of the stack relies on:
//!
//! * **Frozen `Debug`.** `coordinator::engine` bakes `format!("{cfg:?}")`
//!   into every content-address key. The manual [`std::fmt::Debug`] impl
//!   reproduces the historical derived output over the original 32 fields
//!   *only* — the registry [`DeviceConfig::name`] and the
//!   [`MemModel`](crate::sim::mem::MemModel) are deliberately excluded, so
//!   `arria10` keys hash identically to every record written before the
//!   device zoo existed. Non-default devices are distinguished by a
//!   separate `device=<name>` line in the signature, not by `Debug`.
//! * **Identity memory model on `arria10`.** The default profile's
//!   [`MemModel`](crate::sim::mem::MemModel) hooks are exact no-ops
//!   (multipliers of 1.0, adders of 0.0), keeping `sim::perf` and
//!   `sim::des` arithmetic bit-identical to the pre-zoo code.
#![deny(missing_docs)]

use crate::sim::mem::MemModel;

/// Registry names, in presentation order. `DEVICE_NAMES[0]` is the
/// default device everywhere a device is optional.
pub const DEVICE_NAMES: [&str; 4] = ["arria10", "stratix10-hbm", "gpu-like", "cpu-like"];

/// Board + toolchain model parameters.
///
/// Construct via the named registry constructors ([`DeviceConfig::pac_a10`]
/// and friends) or [`DeviceConfig::by_name`]; the `Default` impl exists
/// only so historical tests keep compiling (see its deprecation note).
#[derive(Clone)]
pub struct DeviceConfig {
    /// Registry name of this profile (`"arria10"`, `"stratix10-hbm"`, ...).
    /// Joins the content-address key for every non-default device;
    /// intentionally *not* part of the frozen `Debug` output.
    pub name: &'static str,
    /// Memory-controller model (banking / interleave / stride-class
    /// efficiency); exact identity on `arria10`. Keyed by [`Self::name`]
    /// in the content address, not by value — see `sim::mem`.
    pub mem: MemModel,

    // ---- tuner defaults ---------------------------------------------------
    /// Default `tune` search policy when `--policy` is absent: `"golden"`
    /// or `"sh"` (parsed by `service::policy_from` at the use site —
    /// `sim` stays independent of `coordinator`). Like `name` and `mem`,
    /// deliberately excluded from the frozen `Debug`/store keys.
    pub tune_policy: &'static str,
    /// Default `tune` probe budget when `--budget` is absent. Devices
    /// with cheaper probes (deep memory-level parallelism, no area
    /// pressure) declare smaller budgets — the search converges in fewer
    /// probes on their smoother cost surfaces.
    pub tune_budget: usize,

    // ---- clocks -----------------------------------------------------------
    /// Nominal kernel clock (Hz). The paper reports no consistent fmax
    /// trend; we derate it slightly with design size (see `fmax_for_area`).
    pub fmax_hz: f64,
    /// Logic-utilization knee above which fmax starts to degrade.
    pub fmax_derate_knee: f64,
    /// Fractional fmax loss per logic-utilization point above the knee.
    pub fmax_derate_slope: f64,

    // ---- DRAM -------------------------------------------------------------
    /// Peak off-chip bandwidth (bytes/s) — 34.1 GB/s on the PAC board.
    pub dram_peak_bytes_per_s: f64,
    /// DRAM burst size in bytes (DDR4-64B).
    pub burst_bytes: u64,
    /// Efficiency of a prefetching LSU on a sequential stream.
    pub eff_seq_prefetch: f64,
    /// Efficiency of a burst-coalesced LSU on a sequential stream.
    pub eff_seq_burst: f64,
    /// Effective bytes consumed from DRAM per *random* 4-byte access
    /// (row activation + wasted burst): the memory-controller-wall number;
    /// 256 B/word reproduces the paper's ~200-600 MB/s random-access floor.
    pub random_access_cost_bytes: f64,
    /// Extra congestion per concurrent requester beyond this count.
    pub congestion_free_requesters: usize,
    /// Multiplicative efficiency loss per extra requester (regular streams).
    pub congestion_slope_regular: f64,
    /// Multiplicative efficiency loss per extra requester (irregular).
    pub congestion_slope_irregular: f64,

    // ---- pipeline ---------------------------------------------------------
    /// Depth of a kernel's compute pipeline (drain cost per loop
    /// invocation).
    pub pipeline_depth: u32,
    /// Number of serialized inner-loop instances the scheduler can keep in
    /// flight when the serialized loop is nested inside an outer loop
    /// (bounded loop-pipelining concurrency; 1 = no overlap).
    pub serialized_overlap: u32,
    /// Per-loop-invocation pipeline restart cost (cycles).
    pub loop_fill_cycles: f64,
    /// Peak bytes/cycle through one kernel's memory port (128-bit Avalon
    /// interface); a single kernel cannot saturate DRAM by itself — the
    /// headroom M2C2 exploits.
    pub kernel_port_bytes_per_cycle: f64,
    /// Per-iteration handshake overhead (cycles) added by each channel
    /// endpoint in a kernel's steady state.
    pub channel_overhead_cycles: f64,
    /// Latency through a channel (write -> readable), cycles.
    pub channel_latency: u32,

    // ---- area -------------------------------------------------------------
    /// Total ALMs on the device (Arria 10 GX 1150).
    pub total_alms: f64,
    /// Total M20K BRAM blocks.
    pub total_brams: u32,
    /// Total DSP blocks.
    pub total_dsps: u32,
    /// Board shell / BSP static logic fraction (0..1).
    pub shell_logic_frac: f64,
    /// Board shell BRAM blocks.
    pub shell_brams: u32,
    /// Per-kernel control overhead in ALMs.
    pub kernel_alms: f64,
    /// Per-kernel BRAM overhead.
    pub kernel_brams: u32,
    /// Burst-coalesced LSU area in ALMs.
    pub lsu_burst_alms: f64,
    /// Burst-coalesced LSU area in M20K blocks.
    pub lsu_burst_brams: u32,
    /// Prefetching LSU area in ALMs.
    pub lsu_prefetch_alms: f64,
    /// Prefetching LSU area in M20K blocks.
    pub lsu_prefetch_brams: u32,
    /// Pipelined LSU area in ALMs.
    pub lsu_pipelined_alms: f64,
    /// Pipelined LSU area in M20K blocks.
    pub lsu_pipelined_brams: u32,
    /// Channel endpoint area in ALMs; BRAM grows with depth
    /// (words / `channel_words_per_bram` per M20K).
    pub channel_alms: f64,
    /// Channel FIFO capacity per M20K block, in words.
    pub channel_words_per_bram: usize,
}

impl DeviceConfig {
    /// `arria10` — the paper's testbed: Intel PAC with Arria 10 GX 1150,
    /// 2x4 GB DDR4 at 34.1 GB/s peak.
    ///
    /// **Provenance:** every number is the original calibration against
    /// the source paper's §4 measurements (see DESIGN.md); the
    /// `random_access_cost_bytes = 256` floor and the 74-86% sequential
    /// LSU efficiencies are the effects *The Memory Controller Wall*
    /// (Zohouri & Matsuoka, arXiv:1910.06726) measures on the same
    /// DDR4-based Intel OpenCL memory interface. The memory model is the
    /// exact identity: one streaming LSU already saturates both DDR4
    /// banks (`bank_queue >= banks`), so banking adds nothing — which is
    /// why this profile reproduces the pre-device-zoo numbers bit for bit.
    pub fn pac_a10() -> DeviceConfig {
        DeviceConfig {
            name: "arria10",
            mem: MemModel::identity(2, 1024, 8),
            // the historical hardcoded CLI defaults, so `tune` with no
            // flags stays bit-identical to every pre-PR-10 invocation
            tune_policy: "golden",
            tune_budget: 40,

            fmax_hz: 240e6,
            fmax_derate_knee: 0.20,
            fmax_derate_slope: 0.55,

            dram_peak_bytes_per_s: 34.1e9,
            burst_bytes: 64,
            eff_seq_prefetch: 0.86,
            eff_seq_burst: 0.74,
            random_access_cost_bytes: 256.0,
            congestion_free_requesters: 2,
            congestion_slope_regular: 0.06,
            congestion_slope_irregular: 0.05,

            pipeline_depth: 90,
            serialized_overlap: 4,
            loop_fill_cycles: 12.0,
            kernel_port_bytes_per_cycle: 64.0,
            channel_overhead_cycles: 0.035,
            channel_latency: 3,

            total_alms: 427_200.0,
            total_brams: 2_713,
            total_dsps: 3_036,
            shell_logic_frac: 0.1393,
            shell_brams: 380,
            kernel_alms: 1_500.0,
            kernel_brams: 9,
            lsu_burst_alms: 3_200.0,
            lsu_burst_brams: 14,
            lsu_prefetch_alms: 1_350.0,
            lsu_prefetch_brams: 9,
            lsu_pipelined_alms: 520.0,
            lsu_pipelined_brams: 0,
            channel_alms: 70.0,
            channel_words_per_bram: 512,
        }
    }

    /// `stratix10-hbm` — an HBM2-attached Stratix 10 MX-class part: 32
    /// narrow pseudo-channels, ~410 GB/s aggregate, higher access latency.
    ///
    /// **Provenance:** *The Memory Controller Wall* (arXiv:1910.06726)
    /// motivates the shape: aggregate bandwidth is enormous but each
    /// 256-bit pseudo-channel needs its own deep request queue, so a
    /// single in-order OpenCL LSU strands most of the part's bandwidth —
    /// modelled as `banks = 32, bank_queue = 4` (one streamer reaches
    /// ~1/8 of peak; eight concurrent requesters saturate). Aggregate
    /// 409.6 GB/s and the 32x256-bit channel split are the public HBM2
    /// spec of the Stratix 10 MX 2100; the deeper `pipeline_depth` and
    /// higher nominal fmax reflect HyperFlex registering; the 24-cycle
    /// `channel_fill_cycles` models the longer load-to-use latency HBM
    /// exposes through a depth-1 pipe (deep pipes amortize it, which is
    /// why this device tunes to deeper channels than `arria10`).
    pub fn stratix10_hbm() -> DeviceConfig {
        DeviceConfig {
            name: "stratix10-hbm",
            mem: MemModel {
                banks: 32,
                interleave_bytes: 256,
                bank_queue: 4,
                channel_fill_cycles: 24.0,
                seq_scale: 1.0,
                strided_scale: 1.25,
                irregular_scale: 1.1,
            },
            // deeper pipes but a smoother cost surface: golden-section
            // converges faster, so fewer probes are declared
            tune_policy: "golden",
            tune_budget: 32,

            fmax_hz: 350e6,
            fmax_derate_knee: 0.25,
            fmax_derate_slope: 0.45,

            dram_peak_bytes_per_s: 409.6e9,
            burst_bytes: 32,
            eff_seq_prefetch: 0.82,
            eff_seq_burst: 0.70,
            random_access_cost_bytes: 160.0,
            congestion_free_requesters: 8,
            congestion_slope_regular: 0.02,
            congestion_slope_irregular: 0.03,

            pipeline_depth: 140,
            serialized_overlap: 4,
            loop_fill_cycles: 16.0,
            kernel_port_bytes_per_cycle: 32.0,
            channel_overhead_cycles: 0.035,
            channel_latency: 5,

            total_alms: 702_720.0,
            total_brams: 6_847,
            total_dsps: 3_960,
            shell_logic_frac: 0.11,
            shell_brams: 520,
            kernel_alms: 1_800.0,
            kernel_brams: 11,
            lsu_burst_alms: 3_600.0,
            lsu_burst_brams: 16,
            lsu_prefetch_alms: 1_500.0,
            lsu_prefetch_brams: 10,
            lsu_pipelined_alms: 560.0,
            lsu_pipelined_brams: 0,
            channel_alms: 80.0,
            channel_words_per_bram: 512,
        }
    }

    /// `gpu-like` — a discrete-GPU-shaped memory system: very high peak
    /// bandwidth, wide coalesced transactions, harsh penalties for
    /// uncoalesced strides, cheap on-chip queues with real per-token cost.
    ///
    /// **Provenance:** qualitative calibration against the GPU behavior
    /// *The Memory Controller Wall* contrasts FPGAs with: 128-byte
    /// coalesced transactions (`burst_bytes = 128`), ~90% of a 320 GB/s
    /// GDDR peak on streams, a 128 B effective cost per isolated 4 B
    /// gather (one 32 B sector fetched, mostly wasted, across 4 ideal
    /// accesses), and deep memory-level parallelism (`bank_queue = 16`)
    /// so even one kernel saturates the controller. Strided accesses
    /// serialize into multiple transactions (`strided_scale = 2.5` —
    /// the coalescing cliff). Pipes compile to on-chip queues that cost
    /// real instructions per token (`channel_overhead_cycles = 0.25`),
    /// so the pipe win shrinks relative to the FPGA profiles. Area is
    /// effectively unconstrained: fixed-function silicon, no fmax derate.
    pub fn gpu_like() -> DeviceConfig {
        DeviceConfig {
            name: "gpu-like",
            mem: MemModel {
                banks: 16,
                interleave_bytes: 256,
                bank_queue: 16,
                channel_fill_cycles: 6.0,
                seq_scale: 1.0,
                strided_scale: 2.5,
                irregular_scale: 1.3,
            },
            // pipe depth barely matters off the coalescing cliff — a
            // small golden-section budget finds the plateau
            tune_policy: "golden",
            tune_budget: 32,

            fmax_hz: 1.2e9,
            fmax_derate_knee: 1.0,
            fmax_derate_slope: 0.0,

            dram_peak_bytes_per_s: 320e9,
            burst_bytes: 128,
            eff_seq_prefetch: 0.92,
            eff_seq_burst: 0.88,
            random_access_cost_bytes: 128.0,
            congestion_free_requesters: 16,
            congestion_slope_regular: 0.01,
            congestion_slope_irregular: 0.02,

            pipeline_depth: 24,
            serialized_overlap: 6,
            loop_fill_cycles: 3.0,
            kernel_port_bytes_per_cycle: 128.0,
            channel_overhead_cycles: 0.25,
            channel_latency: 20,

            total_alms: 1.0e9,
            total_brams: 1_000_000,
            total_dsps: 1_000_000,
            shell_logic_frac: 0.0,
            shell_brams: 0,
            kernel_alms: 100.0,
            kernel_brams: 1,
            lsu_burst_alms: 100.0,
            lsu_burst_brams: 1,
            lsu_prefetch_alms: 100.0,
            lsu_prefetch_brams: 1,
            lsu_pipelined_alms: 50.0,
            lsu_pipelined_brams: 0,
            channel_alms: 10.0,
            channel_words_per_bram: 4096,
        }
    }

    /// `cpu-like` — a commodity multicore: low access latency, modest
    /// bandwidth, caches that forgive irregular access, and pipes that
    /// degrade into software queues.
    ///
    /// **Provenance:** dual-channel DDR4-3200 peak (51.2 GB/s) with
    /// hardware prefetchers near peak on streams (0.90-0.95 efficiency);
    /// the 16 B effective cost per irregular 4 B access plus
    /// `irregular_scale = 0.3` models last-level-cache absorption of
    /// gathers that would hit the controller wall on an FPGA — the
    /// contrast *The Memory Controller Wall* draws in its motivation.
    /// Pipes become shared-memory SPSC queues: ~1.5 cycles of real
    /// instructions per token (`channel_overhead_cycles`) and ~40 cycles
    /// of core-to-core latency, so the pipe transformation wins least
    /// here — the portability cliff the source paper's framing predicts.
    /// Area is unconstrained and fmax never derates (fixed silicon).
    pub fn cpu_like() -> DeviceConfig {
        DeviceConfig {
            name: "cpu-like",
            mem: MemModel {
                banks: 2,
                interleave_bytes: 4096,
                bank_queue: 10,
                channel_fill_cycles: 0.0,
                seq_scale: 1.0,
                strided_scale: 1.15,
                irregular_scale: 0.3,
            },
            // software queues make replication interactions noisier:
            // keep the full historical budget for the search
            tune_policy: "golden",
            tune_budget: 40,

            fmax_hz: 3.2e9,
            fmax_derate_knee: 1.0,
            fmax_derate_slope: 0.0,

            dram_peak_bytes_per_s: 51.2e9,
            burst_bytes: 64,
            eff_seq_prefetch: 0.95,
            eff_seq_burst: 0.90,
            random_access_cost_bytes: 16.0,
            congestion_free_requesters: 4,
            congestion_slope_regular: 0.03,
            congestion_slope_irregular: 0.04,

            pipeline_depth: 14,
            serialized_overlap: 8,
            loop_fill_cycles: 2.0,
            kernel_port_bytes_per_cycle: 32.0,
            channel_overhead_cycles: 1.5,
            channel_latency: 40,

            total_alms: 1.0e9,
            total_brams: 1_000_000,
            total_dsps: 1_000_000,
            shell_logic_frac: 0.0,
            shell_brams: 0,
            kernel_alms: 100.0,
            kernel_brams: 1,
            lsu_burst_alms: 100.0,
            lsu_burst_brams: 1,
            lsu_prefetch_alms: 100.0,
            lsu_prefetch_brams: 1,
            lsu_pipelined_alms: 50.0,
            lsu_pipelined_brams: 0,
            channel_alms: 10.0,
            channel_words_per_bram: 4096,
        }
    }

    /// Look up a registry profile by name (the `--device` axis).
    /// Returns `None` for unknown names; `"all"` is handled by the CLI,
    /// not here.
    pub fn by_name(name: &str) -> Option<DeviceConfig> {
        match name {
            "arria10" => Some(DeviceConfig::pac_a10()),
            "stratix10-hbm" => Some(DeviceConfig::stratix10_hbm()),
            "gpu-like" => Some(DeviceConfig::gpu_like()),
            "cpu-like" => Some(DeviceConfig::cpu_like()),
            _ => None,
        }
    }

    /// DRAM capacity in bytes per kernel clock cycle.
    pub fn dram_bytes_per_cycle(&self, fmax: f64) -> f64 {
        self.dram_peak_bytes_per_s / fmax
    }

    /// fmax after derating for design size (deterministic, mild — the paper
    /// found no strong trend, only scatter).
    pub fn fmax_for_area(&self, logic_frac: f64) -> f64 {
        let over = (logic_frac - self.fmax_derate_knee).max(0.0);
        let derate = 1.0 - self.fmax_derate_slope * over;
        self.fmax_hz * derate.clamp(0.55, 1.0)
    }
}

/// The named device registry behind the `--device` CLI axis.
pub struct DeviceRegistry;

impl DeviceRegistry {
    /// Registry names in presentation order (`arria10` first = default).
    pub fn names() -> &'static [&'static str] {
        &DEVICE_NAMES
    }

    /// All registry profiles, in [`DeviceRegistry::names`] order.
    pub fn all() -> Vec<DeviceConfig> {
        DEVICE_NAMES.iter().map(|n| DeviceConfig::by_name(n).expect("registry name")).collect()
    }

    /// Look up one profile by name.
    pub fn get(name: &str) -> Option<DeviceConfig> {
        DeviceConfig::by_name(name)
    }
}

/// Free-function form of [`DeviceConfig::by_name`], for callers (the CLI,
/// the service codec's `device_from`) that resolve a registry name
/// without wanting the config type in scope.
pub fn by_name(name: &str) -> Option<DeviceConfig> {
    DeviceConfig::by_name(name)
}

/// Frozen `Debug`: byte-identical to the historical `#[derive(Debug)]`
/// output over the original 32 fields, in declaration order, with
/// [`DeviceConfig::name`] and [`DeviceConfig::mem`] deliberately omitted.
/// `coordinator::engine::content_signature` feeds this string into every
/// persisted content-address key, so changing it orphans every store on
/// disk — non-default devices are keyed by a separate `device=<name>`
/// signature line instead. Pinned by `debug_format_is_frozen` below.
impl std::fmt::Debug for DeviceConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceConfig")
            .field("fmax_hz", &self.fmax_hz)
            .field("fmax_derate_knee", &self.fmax_derate_knee)
            .field("fmax_derate_slope", &self.fmax_derate_slope)
            .field("dram_peak_bytes_per_s", &self.dram_peak_bytes_per_s)
            .field("burst_bytes", &self.burst_bytes)
            .field("eff_seq_prefetch", &self.eff_seq_prefetch)
            .field("eff_seq_burst", &self.eff_seq_burst)
            .field("random_access_cost_bytes", &self.random_access_cost_bytes)
            .field("congestion_free_requesters", &self.congestion_free_requesters)
            .field("congestion_slope_regular", &self.congestion_slope_regular)
            .field("congestion_slope_irregular", &self.congestion_slope_irregular)
            .field("pipeline_depth", &self.pipeline_depth)
            .field("serialized_overlap", &self.serialized_overlap)
            .field("loop_fill_cycles", &self.loop_fill_cycles)
            .field("kernel_port_bytes_per_cycle", &self.kernel_port_bytes_per_cycle)
            .field("channel_overhead_cycles", &self.channel_overhead_cycles)
            .field("channel_latency", &self.channel_latency)
            .field("total_alms", &self.total_alms)
            .field("total_brams", &self.total_brams)
            .field("total_dsps", &self.total_dsps)
            .field("shell_logic_frac", &self.shell_logic_frac)
            .field("shell_brams", &self.shell_brams)
            .field("kernel_alms", &self.kernel_alms)
            .field("kernel_brams", &self.kernel_brams)
            .field("lsu_burst_alms", &self.lsu_burst_alms)
            .field("lsu_burst_brams", &self.lsu_burst_brams)
            .field("lsu_prefetch_alms", &self.lsu_prefetch_alms)
            .field("lsu_prefetch_brams", &self.lsu_prefetch_brams)
            .field("lsu_pipelined_alms", &self.lsu_pipelined_alms)
            .field("lsu_pipelined_brams", &self.lsu_pipelined_brams)
            .field("channel_alms", &self.channel_alms)
            .field("channel_words_per_bram", &self.channel_words_per_bram)
            .finish()
    }
}

/// Test-only convenience, kept for the pre-device-zoo test suite.
///
/// **Deprecation note:** with multiple devices in the registry, a silent
/// `Default` meaning `arria10` is a trap — production call sites must name
/// their device explicitly (`DeviceConfig::by_name` / the `--device` flag).
/// New code should not call this; it survives only so existing tests and
/// any `..Default::default()` struct updates keep compiling.
impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig::pac_a10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_cycle_budget_is_plausible() {
        let c = DeviceConfig::pac_a10();
        let bpc = c.dram_bytes_per_cycle(c.fmax_hz);
        // 34.1 GB/s at 240 MHz ~ 142 B/cycle
        assert!((bpc - 142.0).abs() < 2.0, "bpc={bpc}");
    }

    #[test]
    fn fmax_derates_monotonically() {
        let c = DeviceConfig::pac_a10();
        let f1 = c.fmax_for_area(0.16);
        let f2 = c.fmax_for_area(0.25);
        let f3 = c.fmax_for_area(0.40);
        assert_eq!(f1, c.fmax_hz); // below knee
        assert!(f2 < f1 && f3 < f2);
        assert!(f3 > 0.5 * c.fmax_hz);
    }

    /// The content-address contract: `Debug` must reproduce the historical
    /// derived output (32 original fields, no `name`, no `mem`), or every
    /// persisted `arria10` record on every machine goes stale. If this
    /// test fails you are changing store keys — bump the store schema.
    #[test]
    fn debug_format_is_frozen() {
        let s = format!("{:?}", DeviceConfig::pac_a10());
        assert_eq!(
            s,
            "DeviceConfig { fmax_hz: 240000000.0, fmax_derate_knee: 0.2, \
             fmax_derate_slope: 0.55, dram_peak_bytes_per_s: 34100000000.0, \
             burst_bytes: 64, eff_seq_prefetch: 0.86, eff_seq_burst: 0.74, \
             random_access_cost_bytes: 256.0, congestion_free_requesters: 2, \
             congestion_slope_regular: 0.06, congestion_slope_irregular: 0.05, \
             pipeline_depth: 90, serialized_overlap: 4, loop_fill_cycles: 12.0, \
             kernel_port_bytes_per_cycle: 64.0, channel_overhead_cycles: 0.035, \
             channel_latency: 3, total_alms: 427200.0, total_brams: 2713, \
             total_dsps: 3036, shell_logic_frac: 0.1393, shell_brams: 380, \
             kernel_alms: 1500.0, kernel_brams: 9, lsu_burst_alms: 3200.0, \
             lsu_burst_brams: 14, lsu_prefetch_alms: 1350.0, lsu_prefetch_brams: 9, \
             lsu_pipelined_alms: 520.0, lsu_pipelined_brams: 0, channel_alms: 70.0, \
             channel_words_per_bram: 512 }"
        );
        assert!(!s.contains("name"), "registry name must stay out of Debug/store keys");
        assert!(!s.contains("mem"), "mem model must stay out of Debug/store keys");
        assert!(!s.contains("tune_"), "tuner defaults must stay out of Debug/store keys");
    }

    /// Every profile declares a parseable tune policy and a positive
    /// budget, and `arria10` declares exactly the historical CLI
    /// defaults — `tune` with no flags stays bit-identical.
    #[test]
    fn tuner_defaults_are_declared_and_arria10_matches_history() {
        for d in DeviceRegistry::all() {
            assert!(
                matches!(d.tune_policy, "golden" | "sh"),
                "{}: unparseable tune_policy `{}`",
                d.name,
                d.tune_policy
            );
            assert!(d.tune_budget > 0, "{}: zero tune_budget", d.name);
        }
        let a10 = DeviceConfig::pac_a10();
        assert_eq!((a10.tune_policy, a10.tune_budget), ("golden", 40));
    }

    #[test]
    fn registry_resolves_every_name_and_rejects_unknowns() {
        for n in DeviceRegistry::names() {
            let d = DeviceConfig::by_name(n).expect("registry name resolves");
            assert_eq!(d.name, *n);
        }
        assert_eq!(DeviceRegistry::all().len(), DEVICE_NAMES.len());
        assert!(DeviceConfig::by_name("all").is_none(), "'all' is a CLI fan-out, not a device");
        assert!(DeviceConfig::by_name("arria-10").is_none());
        assert_eq!(DEVICE_NAMES[0], "arria10", "first registry entry is the default device");
    }

    #[test]
    fn default_device_has_the_identity_mem_model() {
        let c = DeviceConfig::pac_a10();
        assert_eq!(c.mem, crate::sim::mem::MemModel::identity(2, 1024, 8));
        // identity really means identity: queue covers both DDR banks
        assert!(c.mem.bank_queue >= c.mem.banks);
    }

    #[test]
    fn hbm_profile_rewards_concurrency_and_depth() {
        let h = DeviceConfig::stratix10_hbm();
        assert!(h.dram_peak_bytes_per_s > 10.0 * DeviceConfig::pac_a10().dram_peak_bytes_per_s);
        assert!(h.mem.bank_parallel_efficiency(1) < 0.2);
        assert_eq!(h.mem.bank_parallel_efficiency(8), 1.0);
        assert!(h.mem.pipe_fill_cost(1) > h.mem.pipe_fill_cost(1000));
    }
}
