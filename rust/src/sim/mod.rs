//! FPGA execution substrate: device model, global memory, the functional
//! concurrent interpreter, execution profiles and the performance models.
pub mod des;
pub mod device;
pub mod exec;
pub mod mem;
pub mod perf;
pub mod profile;

pub use device::DeviceConfig;
pub use perf::{LaunchMetrics, PerfModel};
pub use exec::{compile_kernel, launch, run_group, ExecError, ExecOptions, GroupRun};
pub use mem::{Buffer, MemoryImage};
pub use profile::{KernelProfile, LoopStats, SiteStats};
