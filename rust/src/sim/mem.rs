//! Global-memory image shared by concurrently executing kernels.
//!
//! Buffers are bit-encoded in `AtomicU64` cells with relaxed ordering —
//! plain loads/stores on x86, safely shareable across the kernel threads.
//! The feed-forward feasibility rules guarantee concurrent kernels never
//! race on the same element (no true MLCD; memory kernels only read).

use crate::ir::{Ty, Val};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One global buffer.
pub struct Buffer {
    pub ty: Ty,
    data: Vec<AtomicU64>,
}

impl Buffer {
    pub fn new(ty: Ty, len: usize) -> Buffer {
        let mut data = Vec::with_capacity(len);
        data.resize_with(len, || AtomicU64::new(Val::zero(ty).to_bits()));
        Buffer { ty, data }
    }

    pub fn from_i64s(vals: &[i64]) -> Buffer {
        let data = vals.iter().map(|v| AtomicU64::new(Val::I(*v).to_bits())).collect();
        Buffer { ty: Ty::I32, data }
    }

    pub fn from_f32s(vals: &[f32]) -> Buffer {
        let data = vals.iter().map(|v| AtomicU64::new(Val::F(*v).to_bits())).collect();
        Buffer { ty: Ty::F32, data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> Val {
        Val::from_bits(self.ty, self.data[i].load(Ordering::Relaxed))
    }

    #[inline]
    pub fn set(&self, i: usize, v: Val) {
        self.data[i].store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn to_i64s(&self) -> Vec<i64> {
        (0..self.len()).map(|i| self.get(i).as_i()).collect()
    }

    pub fn to_f32s(&self) -> Vec<f32> {
        (0..self.len()).map(|i| self.get(i).as_f()).collect()
    }

    /// Deep copy (snapshots for validation / ping-pong setup).
    pub fn duplicate(&self) -> Buffer {
        let data = self
            .data
            .iter()
            .map(|c| AtomicU64::new(c.load(Ordering::Relaxed)))
            .collect();
        Buffer { ty: self.ty, data }
    }
}

impl std::fmt::Debug for Buffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Buffer({:?} x{})", self.ty, self.len())
    }
}

/// The device-global memory image plus host-set scalar arguments.
#[derive(Debug, Default)]
pub struct MemoryImage {
    bufs: BTreeMap<String, Arc<Buffer>>,
    scalars: BTreeMap<String, Val>,
}

impl MemoryImage {
    pub fn new() -> MemoryImage {
        MemoryImage::default()
    }

    pub fn add_buf(&mut self, name: &str, buf: Buffer) -> &mut Self {
        self.bufs.insert(name.to_string(), Arc::new(buf));
        self
    }

    pub fn add_i64s(&mut self, name: &str, vals: &[i64]) -> &mut Self {
        self.add_buf(name, Buffer::from_i64s(vals))
    }

    pub fn add_f32s(&mut self, name: &str, vals: &[f32]) -> &mut Self {
        self.add_buf(name, Buffer::from_f32s(vals))
    }

    pub fn add_zeros(&mut self, name: &str, ty: Ty, len: usize) -> &mut Self {
        self.add_buf(name, Buffer::new(ty, len))
    }

    pub fn set_scalar(&mut self, name: &str, v: Val) -> &mut Self {
        self.scalars.insert(name.to_string(), v);
        self
    }

    pub fn set_i(&mut self, name: &str, v: i64) -> &mut Self {
        self.set_scalar(name, Val::I(v))
    }

    pub fn set_f(&mut self, name: &str, v: f32) -> &mut Self {
        self.set_scalar(name, Val::F(v))
    }

    pub fn buf(&self, name: &str) -> Option<&Arc<Buffer>> {
        self.bufs.get(name)
    }

    pub fn scalar(&self, name: &str) -> Option<Val> {
        self.scalars.get(name).copied()
    }

    pub fn buf_names(&self) -> impl Iterator<Item = &String> {
        self.bufs.keys()
    }

    /// Total bytes of all buffers (dataset-size metric).
    pub fn total_bytes(&self) -> u64 {
        self.bufs.values().map(|b| b.len() as u64 * 4).sum()
    }

    /// Ping-pong swap of two buffers (host-side buffer-object swap between
    /// launches, as OpenCL host code does with cl_mem arguments).
    pub fn swap_bufs(&mut self, a: &str, b: &str) {
        let ba = self.bufs.get(a).cloned().expect("swap_bufs: missing a");
        let bb = self.bufs.get(b).cloned().expect("swap_bufs: missing b");
        self.bufs.insert(a.to_string(), bb);
        self.bufs.insert(b.to_string(), ba);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_roundtrip() {
        let b = Buffer::from_f32s(&[1.5, -2.0]);
        assert_eq!(b.get(0), Val::F(1.5));
        b.set(1, Val::F(7.25));
        assert_eq!(b.to_f32s(), vec![1.5, 7.25]);
    }

    #[test]
    fn image_scalars_and_bufs() {
        let mut m = MemoryImage::new();
        m.add_i64s("row", &[0, 2, 5]).set_i("n", 3);
        assert_eq!(m.scalar("n"), Some(Val::I(3)));
        assert_eq!(m.buf("row").unwrap().to_i64s(), vec![0, 2, 5]);
        assert_eq!(m.total_bytes(), 12);
    }

    #[test]
    fn duplicate_is_deep() {
        let b = Buffer::from_i64s(&[1, 2]);
        let d = b.duplicate();
        b.set(0, Val::I(99));
        assert_eq!(d.get(0), Val::I(1));
    }
}
