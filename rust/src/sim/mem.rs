//! Global-memory image shared by concurrently executing kernels, plus the
//! per-device memory-controller model.
//!
//! Buffers are bit-encoded in `AtomicU64` cells with relaxed ordering —
//! plain loads/stores on x86, safely shareable across the kernel threads.
//! The feed-forward feasibility rules guarantee concurrent kernels never
//! race on the same element (no true MLCD; memory kernels only read).
//!
//! # Memory-controller model ([`MemModel`])
//!
//! *The Memory Controller Wall* (Zohouri & Matsuoka, arXiv:1910.06726)
//! shows the fraction of peak external bandwidth an OpenCL kernel actually
//! achieves depends on the memory system's *banking* as much as on the
//! access pattern: a single in-order load unit cannot keep enough requests
//! in flight to cover many narrow banks (HBM pseudo-channels), while a
//! 2-bank DDR board saturates with one streamer. [`MemModel`] captures
//! that per device with three orthogonal knobs, each an exact identity on
//! the default Arria-10 profile so its modelled numbers (and therefore the
//! persistent store's content keys and BENCH sinks) are bit-identical to
//! the pre-device-zoo code:
//!
//! * **Stride-class efficiency** — a multiplier on the DRAM-occupancy cost
//!   of each access, keyed by `analysis::pattern::AccessPattern`
//!   (sequential / strided / irregular). GPUs punish uncoalesced strides;
//!   CPU caches forgive irregular gathers.
//! * **Bank-level parallelism** — effective capacity is peak bandwidth
//!   scaled by `min(1, requesters * bank_queue / banks)`: with many narrow
//!   banks, few concurrent requesters leave most banks idle. Consumed by
//!   both `sim::perf`'s capacity term and `sim::des`'s DRAM ledger, so the
//!   analytic and event-driven estimators agree on the device story.
//! * **Channel fill latency** — a per-token pipe cost of
//!   `channel_fill_cycles / depth`: on high-latency memory systems a
//!   shallow pipe exposes the handshake latency every token, a deep pipe
//!   amortizes it away. This is what makes the best pipe depth
//!   *device-dependent* (the cross-device E8 grid).

use crate::analysis::AccessPattern;
use crate::ir::{Ty, Val};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Banking / interleaving / efficiency model of one device's memory
/// controller. Embedded in `sim::device::DeviceConfig`; see the module
/// docs for the calibration rationale and `docs/DEVICES.md` for the
/// per-device numbers.
///
/// Note: these parameters are keyed by the *device name* in the content
/// address (not by value) — recalibrating a profile without renaming it
/// requires a store-schema bump to invalidate stale records.
#[derive(Debug, Clone, PartialEq)]
pub struct MemModel {
    /// Independent banks / pseudo-channels the controller interleaves
    /// across (2 for a DDR4 board, 32 for HBM2 pseudo-channels).
    pub banks: usize,
    /// Address interleave granularity across banks, in bytes.
    pub interleave_bytes: u64,
    /// Outstanding requests one streaming load unit keeps in flight
    /// (the per-requester queue depth the controller can exploit).
    pub bank_queue: usize,
    /// Extra channel handshake latency (cycles) a pipe endpoint exposes
    /// per token before steady state; amortized by pipe depth via
    /// [`MemModel::pipe_fill_cost`]. 0.0 = latency fully hidden.
    pub channel_fill_cycles: f64,
    /// DRAM-occupancy cost multiplier for sequential / loop-invariant
    /// accesses (1.0 = the base LSU efficiencies already tell the story).
    pub seq_scale: f64,
    /// Cost multiplier for strided accesses (coalescing sensitivity).
    pub strided_scale: f64,
    /// Cost multiplier for irregular accesses (cache absorption < 1.0,
    /// uncoalesced-gather penalty > 1.0).
    pub irregular_scale: f64,
}

impl MemModel {
    /// The identity model: every hook returns an exact no-op factor, so a
    /// device using it reproduces the pre-device-zoo arithmetic bit for
    /// bit (x * 1.0 and x + 0.0 are exact for finite positive f64).
    pub fn identity(banks: usize, interleave_bytes: u64, bank_queue: usize) -> MemModel {
        MemModel {
            banks,
            interleave_bytes,
            bank_queue,
            channel_fill_cycles: 0.0,
            seq_scale: 1.0,
            strided_scale: 1.0,
            irregular_scale: 1.0,
        }
    }

    /// Cost multiplier for one access of the given stride class.
    pub fn stride_scale(&self, pattern: &AccessPattern) -> f64 {
        match pattern {
            AccessPattern::Sequential | AccessPattern::LoopInvariant => self.seq_scale,
            AccessPattern::Strided(_) => self.strided_scale,
            AccessPattern::Irregular => self.irregular_scale,
        }
    }

    /// Fraction of aggregate bandwidth `requesters` concurrent streaming
    /// kernels can actually draw: `min(1, requesters * bank_queue /
    /// banks)`. One streamer saturates a 2-bank DDR controller
    /// (queue >= banks) but strands most of 32 HBM pseudo-channels —
    /// the Memory Controller Wall effect that makes kernel replication
    /// (M2C2) and pipe fan-out *more* valuable on HBM-class parts.
    pub fn bank_parallel_efficiency(&self, requesters: usize) -> f64 {
        let in_flight = (requesters.max(1) * self.bank_queue.max(1)) as f64;
        (in_flight / self.banks.max(1) as f64).min(1.0)
    }

    /// Per-token pipe cost exposed by channel handshake latency at the
    /// given depth: `channel_fill_cycles / depth`. Deeper pipes hide the
    /// latency; depth 1 pays it on every token.
    pub fn pipe_fill_cost(&self, depth: usize) -> f64 {
        self.channel_fill_cycles / depth.max(1) as f64
    }
}

/// One global buffer.
pub struct Buffer {
    pub ty: Ty,
    data: Vec<AtomicU64>,
}

impl Buffer {
    pub fn new(ty: Ty, len: usize) -> Buffer {
        let mut data = Vec::with_capacity(len);
        data.resize_with(len, || AtomicU64::new(Val::zero(ty).to_bits()));
        Buffer { ty, data }
    }

    pub fn from_i64s(vals: &[i64]) -> Buffer {
        let data = vals.iter().map(|v| AtomicU64::new(Val::I(*v).to_bits())).collect();
        Buffer { ty: Ty::I32, data }
    }

    pub fn from_f32s(vals: &[f32]) -> Buffer {
        let data = vals.iter().map(|v| AtomicU64::new(Val::F(*v).to_bits())).collect();
        Buffer { ty: Ty::F32, data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> Val {
        Val::from_bits(self.ty, self.data[i].load(Ordering::Relaxed))
    }

    #[inline]
    pub fn set(&self, i: usize, v: Val) {
        self.data[i].store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn to_i64s(&self) -> Vec<i64> {
        (0..self.len()).map(|i| self.get(i).as_i()).collect()
    }

    pub fn to_f32s(&self) -> Vec<f32> {
        (0..self.len()).map(|i| self.get(i).as_f()).collect()
    }

    /// Deep copy (snapshots for validation / ping-pong setup).
    pub fn duplicate(&self) -> Buffer {
        let data = self
            .data
            .iter()
            .map(|c| AtomicU64::new(c.load(Ordering::Relaxed)))
            .collect();
        Buffer { ty: self.ty, data }
    }
}

impl std::fmt::Debug for Buffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Buffer({:?} x{})", self.ty, self.len())
    }
}

/// The device-global memory image plus host-set scalar arguments.
#[derive(Debug, Default)]
pub struct MemoryImage {
    bufs: BTreeMap<String, Arc<Buffer>>,
    scalars: BTreeMap<String, Val>,
}

impl MemoryImage {
    pub fn new() -> MemoryImage {
        MemoryImage::default()
    }

    pub fn add_buf(&mut self, name: &str, buf: Buffer) -> &mut Self {
        self.bufs.insert(name.to_string(), Arc::new(buf));
        self
    }

    pub fn add_i64s(&mut self, name: &str, vals: &[i64]) -> &mut Self {
        self.add_buf(name, Buffer::from_i64s(vals))
    }

    pub fn add_f32s(&mut self, name: &str, vals: &[f32]) -> &mut Self {
        self.add_buf(name, Buffer::from_f32s(vals))
    }

    pub fn add_zeros(&mut self, name: &str, ty: Ty, len: usize) -> &mut Self {
        self.add_buf(name, Buffer::new(ty, len))
    }

    pub fn set_scalar(&mut self, name: &str, v: Val) -> &mut Self {
        self.scalars.insert(name.to_string(), v);
        self
    }

    pub fn set_i(&mut self, name: &str, v: i64) -> &mut Self {
        self.set_scalar(name, Val::I(v))
    }

    pub fn set_f(&mut self, name: &str, v: f32) -> &mut Self {
        self.set_scalar(name, Val::F(v))
    }

    pub fn buf(&self, name: &str) -> Option<&Arc<Buffer>> {
        self.bufs.get(name)
    }

    pub fn scalar(&self, name: &str) -> Option<Val> {
        self.scalars.get(name).copied()
    }

    pub fn buf_names(&self) -> impl Iterator<Item = &String> {
        self.bufs.keys()
    }

    /// Total bytes of all buffers (dataset-size metric).
    pub fn total_bytes(&self) -> u64 {
        self.bufs.values().map(|b| b.len() as u64 * 4).sum()
    }

    /// Ping-pong swap of two buffers (host-side buffer-object swap between
    /// launches, as OpenCL host code does with cl_mem arguments).
    pub fn swap_bufs(&mut self, a: &str, b: &str) {
        let ba = self.bufs.get(a).cloned().expect("swap_bufs: missing a");
        let bb = self.bufs.get(b).cloned().expect("swap_bufs: missing b");
        self.bufs.insert(a.to_string(), bb);
        self.bufs.insert(b.to_string(), ba);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_roundtrip() {
        let b = Buffer::from_f32s(&[1.5, -2.0]);
        assert_eq!(b.get(0), Val::F(1.5));
        b.set(1, Val::F(7.25));
        assert_eq!(b.to_f32s(), vec![1.5, 7.25]);
    }

    #[test]
    fn image_scalars_and_bufs() {
        let mut m = MemoryImage::new();
        m.add_i64s("row", &[0, 2, 5]).set_i("n", 3);
        assert_eq!(m.scalar("n"), Some(Val::I(3)));
        assert_eq!(m.buf("row").unwrap().to_i64s(), vec![0, 2, 5]);
        assert_eq!(m.total_bytes(), 12);
    }

    #[test]
    fn duplicate_is_deep() {
        let b = Buffer::from_i64s(&[1, 2]);
        let d = b.duplicate();
        b.set(0, Val::I(99));
        assert_eq!(d.get(0), Val::I(1));
    }

    #[test]
    fn identity_model_is_an_exact_noop() {
        let m = MemModel::identity(2, 1024, 8);
        for p in [
            AccessPattern::Sequential,
            AccessPattern::Strided(7),
            AccessPattern::LoopInvariant,
            AccessPattern::Irregular,
        ] {
            assert_eq!(m.stride_scale(&p), 1.0);
        }
        for r in [0usize, 1, 2, 16] {
            assert_eq!(m.bank_parallel_efficiency(r), 1.0);
        }
        for d in [1usize, 100, 1000] {
            assert_eq!(m.pipe_fill_cost(d), 0.0);
        }
    }

    #[test]
    fn narrow_banks_starve_single_requesters() {
        // HBM-shaped: 32 pseudo-channels, 4 requests in flight per LSU.
        let m = MemModel { banks: 32, bank_queue: 4, ..MemModel::identity(32, 256, 4) };
        let one = m.bank_parallel_efficiency(1);
        let four = m.bank_parallel_efficiency(4);
        let many = m.bank_parallel_efficiency(16);
        assert!(one < 0.2, "one streamer should strand most HBM banks: {one}");
        assert!(four > one && four < 1.0);
        assert_eq!(many, 1.0, "enough requesters saturate the aggregate");
    }

    #[test]
    fn deep_pipes_amortize_fill_latency() {
        let m = MemModel { channel_fill_cycles: 24.0, ..MemModel::identity(32, 256, 4) };
        assert_eq!(m.pipe_fill_cost(1), 24.0);
        assert!(m.pipe_fill_cost(100) < 0.25);
        assert!(m.pipe_fill_cost(1000) < m.pipe_fill_cost(100));
        // depth 0 is normalized like PipeDecl depths are
        assert_eq!(m.pipe_fill_cost(0), 24.0);
    }
}
