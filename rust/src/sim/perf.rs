//! Trace-driven performance model (analytic steady-state solver).
//!
//! Consumes the static analysis (`analysis::report::KernelReport`: per-loop
//! II, per-site LSU + pattern) and the measured execution profile
//! (`sim::profile::KernelProfile`: per-loop trip counts, per-site address
//! stream summaries) and predicts the launch's execution time on the
//! modelled board.
//!
//! Per kernel `k` the *pipeline-bound* cycle count is
//!
//! ```text
//! CB_k = sum over loops l of max(iters_l * II_eff_l, bytes_l / PORT)
//!      + invocations_l * FILL + pipe_ops_k * CHAN + DEPTH
//! ```
//!
//! where `II_eff` divides a serialized loop's II by the bounded
//! outer-overlap factor when the loop is nested (the offline compiler keeps
//! a few instances of a serialized inner loop in flight), `PORT` is the
//! per-kernel memory-port width, and `bytes_l` charges each access its
//! DRAM-occupancy cost (sequential-prefetch ~4.7 B/word ... random ~256
//! B/word, blended by the *measured* sequential fraction for irregular
//! sites).
//!
//! The launch's makespan is `max(max_k CB_k, total_dram_bytes / CAP)` with
//! `CAP` derated by requester congestion — concurrently-streaming kernels
//! beyond `congestion_free_requesters` pay an arbitration penalty, more so
//! for irregular traffic (the effect that makes M2C2 plateau at two
//! producers, §4.2). A discrete-event cross-check lives in `sim::des`.
//!
//! The per-device memory-controller model (`sim::mem::MemModel`) hooks in
//! at three points: each access's DRAM-occupancy cost is scaled by its
//! stride class (`access_cost`), `CAP` is scaled by bank-level parallelism
//! (few requesters cannot cover many narrow banks), and each pipe token
//! pays `channel_fill_cycles / depth` on top of the handshake overhead
//! (deep pipes hide memory latency). All three are exact identities on
//! the default `arria10` profile — see `sim::device`.
//!
//! The model is **schedule-independent**: [`PerfModel::estimate`] prices
//! one launch in isolation, and its `per_kernel` pipeline bounds and
//! [`PerfModel::access_cost`] are exactly what the graph DES
//! (`sim::des::simulate_graph`) reuses when launch-graph overlap merges
//! several launches into one wavefront. The merge leans on one invariant
//! of the memory model: `MemModel::bank_parallel_efficiency` is monotone
//! nondecreasing in the requester count and capped at 1.0, so pooling
//! launches' requesters can only *grow* the shared DRAM capacity per
//! cycle — overlapped schedules can never model slower than the chain
//! (asserted below and in `sim::des`).

use super::device::DeviceConfig;
use super::profile::KernelProfile;
use crate::analysis::report::{CompilerReport, KernelReport};
use crate::analysis::{AccessPattern, LsuKind, MemSiteKind};
use crate::ir::Program;

/// Performance estimate for one launch group.
#[derive(Debug, Clone)]
pub struct LaunchMetrics {
    /// Modelled makespan in kernel-clock cycles.
    pub cycles: f64,
    /// Modelled wall time (s) at the design's fmax.
    pub seconds: f64,
    pub fmax_hz: f64,
    /// Payload bytes moved (4 B per access) — the numerator of the paper's
    /// "global memory bandwidth" numbers.
    pub payload_bytes: f64,
    /// DRAM-occupancy bytes (burst waste included).
    pub dram_bytes: f64,
    /// The DRAM-bound component of the makespan.
    pub dram_cycles: f64,
    /// Achieved global-memory bandwidth (payload bytes / seconds).
    pub bw_bytes_per_s: f64,
    /// Per-kernel pipeline-bound cycles.
    pub per_kernel: Vec<(String, f64)>,
}

impl LaunchMetrics {
    pub fn zero(fmax_hz: f64) -> LaunchMetrics {
        LaunchMetrics {
            cycles: 0.0,
            seconds: 0.0,
            fmax_hz,
            payload_bytes: 0.0,
            dram_bytes: 0.0,
            dram_cycles: 0.0,
            bw_bytes_per_s: 0.0,
            per_kernel: vec![],
        }
    }

    /// Accumulate a subsequent launch (host convergence loops).
    pub fn accumulate(&mut self, other: &LaunchMetrics) {
        self.cycles += other.cycles;
        self.seconds += other.seconds;
        self.payload_bytes += other.payload_bytes;
        self.dram_bytes += other.dram_bytes;
        self.dram_cycles += other.dram_cycles;
        // track the max achieved bandwidth over launches (paper reports max)
        self.bw_bytes_per_s = self.bw_bytes_per_s.max(other.bw_bytes_per_s);
        self.fmax_hz = other.fmax_hz;
    }
}

/// Reusable per-program model (static analysis done once).
pub struct PerfModel {
    pub report: CompilerReport,
    pub cfg: DeviceConfig,
    /// Per-token channel fill cost at this program's shallowest pipe
    /// depth (`mem.channel_fill_cycles / depth`); 0.0 when the device
    /// hides channel latency or the program has no pipes.
    pipe_fill: f64,
}

impl PerfModel {
    pub fn new(prog: &Program, cfg: &DeviceConfig) -> PerfModel {
        // The shallowest pipe bounds how well the whole chain hides the
        // device's channel fill latency (a deep pipe behind a depth-1 pipe
        // still stalls at the depth-1 handshake).
        let min_depth = prog.pipes.iter().map(|p| p.depth.max(1)).min().unwrap_or(1);
        PerfModel {
            report: crate::analysis::program_report(prog, cfg),
            cfg: cfg.clone(),
            pipe_fill: cfg.mem.pipe_fill_cost(min_depth),
        }
    }

    /// DRAM-occupancy bytes for one access of a site, scaled by the
    /// device's per-stride-class controller efficiency (identity on
    /// `arria10`).
    pub fn access_cost(&self, kr: &KernelReport, site_ix: usize, seq_frac: f64) -> f64 {
        let cfg = &self.cfg;
        let site = &kr.sites[site_ix];
        let seq_eff = match site.lsu {
            LsuKind::Prefetching => cfg.eff_seq_prefetch,
            _ => cfg.eff_seq_burst,
        };
        let base = match site.pattern {
            AccessPattern::Sequential => 4.0 / seq_eff,
            AccessPattern::Strided(c) => {
                // Unrolled/vectorized kernels produce W interleaved
                // strided-W sites; the burst-coalesced LSU merges their
                // same-cycle requests, so sub-burst strides behave like a
                // sequential stream. Beyond the burst size each access
                // opens its own line.
                if 4 * c.unsigned_abs() <= cfg.burst_bytes {
                    4.0 / cfg.eff_seq_burst
                } else {
                    cfg.burst_bytes as f64 / cfg.eff_seq_burst
                }
            }
            // Register-cached after the first read of an invocation.
            AccessPattern::LoopInvariant => 0.2,
            AccessPattern::Irregular => {
                seq_frac * (4.0 / cfg.eff_seq_burst)
                    + (1.0 - seq_frac) * cfg.random_access_cost_bytes
            }
        };
        base * cfg.mem.stride_scale(&site.pattern)
    }

    /// Model one launch from its measured profiles (one per kernel, in
    /// program order).
    pub fn estimate(&self, profiles: &[KernelProfile]) -> LaunchMetrics {
        let cfg = &self.cfg;
        let fmax = self.report.fmax_hz;
        assert_eq!(profiles.len(), self.report.kernels.len(), "one profile per kernel");

        let mut total_dram_bytes = 0.0;
        let mut irregular_bytes = 0.0;
        let mut payload_bytes = 0.0;
        let mut per_kernel = vec![];
        let mut requesters = 0usize;

        for (kr, prof) in self.report.kernels.iter().zip(profiles) {
            let mut kernel_mem_active = false;

            // Per-loop accounting: bytes per loop, II-bound cycles.
            let mut cb = 0.0;
            for l in &kr.loops {
                let ls = prof.loop_stats(l.loop_id);
                if ls.iters == 0 {
                    continue;
                }
                // A serialized loop still issues the *independent* parts of
                // the next few iterations (loads of i+1 during i's store
                // window) — the bounded-overlap factor the offline compiler
                // achieves in practice (FW: reported II 285, measured ~71
                // cycles/iteration).
                let overlap = if l.serialized_by.is_some() {
                    cfg.serialized_overlap.max(1) as f64
                } else {
                    1.0
                };
                let ii_eff = (l.ii as f64 / overlap).max(1.0);
                // bytes issued by sites whose innermost loop is this one
                let mut loop_payload = 0.0;
                for s in &kr.sites {
                    if s.loop_id == Some(l.loop_id) {
                        let st = &prof.sites[s.site];
                        if st.count > 0 {
                            let cost = self.access_cost(kr, s.site, st.seq_frac());
                            kernel_mem_active = true;
                            if s.pattern == AccessPattern::Irregular {
                                irregular_bytes += st.count as f64 * cost;
                            }
                            total_dram_bytes += st.count as f64 * cost;
                            loop_payload += st.count as f64 * 4.0;
                            payload_bytes += st.count as f64 * 4.0;
                            let _ = s.kind == MemSiteKind::Load;
                        }
                    }
                }
                let ii_cycles = ls.iters as f64 * ii_eff;
                // The kernel's memory port moves payload words; burst waste
                // is charged to the DRAM constraint below.
                let port_cycles = loop_payload / cfg.kernel_port_bytes_per_cycle;
                cb += ii_cycles.max(port_cycles);
                cb += ls.invocations as f64 * cfg.loop_fill_cycles;
            }
            // Sites outside any loop: one latency each.
            for s in &kr.sites {
                if s.loop_id.is_none() {
                    let st = &prof.sites[s.site];
                    if st.count > 0 {
                        cb += st.count as f64 * 4.0;
                        total_dram_bytes += st.count as f64 * 4.0 / cfg.eff_seq_burst;
                        payload_bytes += st.count as f64 * 4.0;
                        kernel_mem_active = true;
                    }
                }
            }
            // Each pipe token pays the steady-state handshake plus the
            // channel fill latency the program's shallowest pipe exposes
            // (0.0 on arria10; deep pipes amortize it on HBM-class parts).
            cb += (prof.pipe_writes + prof.pipe_reads) as f64
                * (cfg.channel_overhead_cycles + self.pipe_fill);
            cb += cfg.pipeline_depth as f64;
            if kernel_mem_active {
                requesters += 1;
            }
            per_kernel.push((kr.name.clone(), cb));
        }

        // DRAM capacity under congestion.
        let irr_share = if total_dram_bytes > 0.0 { irregular_bytes / total_dram_bytes } else { 0.0 };
        let slope = cfg.congestion_slope_regular * (1.0 - irr_share)
            + cfg.congestion_slope_irregular * irr_share;
        let extra = requesters.saturating_sub(cfg.congestion_free_requesters) as f64;
        let congestion = 1.0 + slope * extra;
        // Bank-level parallelism: few requesters cannot cover many narrow
        // banks (HBM pseudo-channels), so effective capacity scales with
        // the in-flight requests the launch actually sustains (exactly
        // 1.0 on arria10 — one streamer saturates both DDR4 banks).
        let bank_eff = cfg.mem.bank_parallel_efficiency(requesters);
        let capacity = cfg.dram_bytes_per_cycle(fmax) * bank_eff / congestion;
        let dram_cycles = total_dram_bytes / capacity;

        let cb_max = per_kernel.iter().map(|(_, c)| *c).fold(0.0, f64::max);
        let cycles = cb_max.max(dram_cycles);
        let seconds = cycles / fmax;
        LaunchMetrics {
            cycles,
            seconds,
            fmax_hz: fmax,
            payload_bytes,
            dram_bytes: total_dram_bytes,
            dram_cycles,
            bw_bytes_per_s: if seconds > 0.0 { payload_bytes / seconds } else { 0.0 },
            per_kernel,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, Program, Ty};
    use crate::sim::exec::{run_group, ExecOptions};
    use crate::sim::mem::MemoryImage;

    fn stream_kernel(n: &str) -> crate::ir::Kernel {
        KernelBuilder::new(n, KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("a", v("i")) * f(2.0))],
            )])
            .finish()
    }

    fn image(n: usize) -> MemoryImage {
        let mut m = MemoryImage::new();
        m.add_f32s("a", &vec![1.0; n]).add_zeros("o", Ty::F32, n).set_i("n", n as i64);
        m
    }

    #[test]
    fn pipelined_stream_is_about_one_cycle_per_iter() {
        let cfg = DeviceConfig::pac_a10();
        let prog = Program::single(stream_kernel("s"));
        let img = image(100_000);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let model = PerfModel::new(&prog, &cfg);
        let m = model.estimate(&run.profiles);
        let cpi = m.cycles / 100_000.0;
        assert!(cpi > 0.9 && cpi < 1.2, "cycles/iter = {cpi}");
        assert!(m.bw_bytes_per_s > 100e6, "bw = {}", m.bw_bytes_per_s);
    }

    #[test]
    fn serialized_kernel_is_tens_of_cycles_per_iter() {
        let cfg = DeviceConfig::pac_a10();
        // same-buffer update -> conservative MLCD on the (depth-0) loop
        let k = KernelBuilder::new("ser", KernelKind::SingleWorkItem)
            .buf_rw("a", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("a", v("i"), ld("a", v("i")) * f(2.0))],
            )])
            .finish();
        let prog = Program::single(k);
        let mut img = MemoryImage::new();
        img.add_f32s("a", &vec![1.0; 10_000]).set_i("n", 10_000);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let model = PerfModel::new(&prog, &cfg);
        let m = model.estimate(&run.profiles);
        // full II / bounded overlap: ~280/4 = ~70 achieved cycles per iter
        let cpi = m.cycles / 10_000.0;
        assert!(cpi > 40.0 && cpi < 120.0, "serialized cycles/iter = {cpi}");
    }

    #[test]
    fn feedforward_beats_serialized_baseline() {
        let cfg = DeviceConfig::pac_a10();
        let k = KernelBuilder::new("ser", KernelKind::SingleWorkItem)
            .buf_rw("a", Ty::F32)
            .buf_ro("b", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("a", v("i"), ld("a", v("i")) + ld("b", v("i")))],
            )])
            .finish();
        let n = 50_000usize;
        let base = Program::single(k.clone());
        let img1 = {
            let mut m = MemoryImage::new();
            m.add_f32s("a", &vec![1.0; n]).add_f32s("b", &vec![2.0; n]).set_i("n", n as i64);
            m
        };
        let r1 = run_group(&base, &img1, &ExecOptions::default()).unwrap();
        let t_base = PerfModel::new(&base, &cfg).estimate(&r1.profiles).seconds;

        let ff = crate::transform::feedforward(&k, 1).unwrap();
        let img2 = {
            let mut m = MemoryImage::new();
            m.add_f32s("a", &vec![1.0; n]).add_f32s("b", &vec![2.0; n]).set_i("n", n as i64);
            m
        };
        let r2 = run_group(&ff, &img2, &ExecOptions::default()).unwrap();
        let t_ff = PerfModel::new(&ff, &cfg).estimate(&r2.profiles).seconds;
        let speedup = t_base / t_ff;
        assert!(speedup > 20.0, "speedup = {speedup}");
    }

    #[test]
    fn irregular_traffic_is_dram_bound() {
        let cfg = DeviceConfig::pac_a10();
        let k = KernelBuilder::new("gather", KernelKind::SingleWorkItem)
            .buf_ro("idx", Ty::I32)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("a", ld("idx", v("i"))))],
            )])
            .finish();
        let n = 40_000usize;
        let prog = Program::single(k);
        let mut img = MemoryImage::new();
        // pseudo-random permutation indices
        let idx: Vec<i64> = (0..n).map(|i| ((i as i64).wrapping_mul(48271)) % n as i64).collect();
        img.add_i64s("idx", &idx)
            .add_f32s("a", &vec![1.0; n])
            .add_zeros("o", Ty::F32, n)
            .set_i("n", n as i64);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let m = PerfModel::new(&prog, &cfg).estimate(&run.profiles);
        // random gathers: DRAM-bound, low achieved bandwidth
        assert!(m.dram_cycles > 0.5 * m.cycles, "should be near DRAM bound");
        assert!(m.bw_bytes_per_s < 3e9, "bw = {}", m.bw_bytes_per_s);
    }

    /// The device axis at work: a depth ladder over the same pipe program
    /// is time-invariant on arria10 (channel fill latency fully hidden)
    /// but strictly improves with depth on the HBM profile, whose 24-cycle
    /// fill cost a depth-1 pipe exposes on every token.
    #[test]
    fn pipe_depth_matters_on_hbm_but_not_on_arria10() {
        let n = 20_000usize;
        let mut secs_a10 = vec![];
        let mut secs_hbm = vec![];
        for depth in [1usize, 1000] {
            let ff = crate::transform::feedforward(&stream_kernel("s"), 1)
                .unwrap()
                .with_pipe_depth(depth);
            let img = image(n);
            let run = run_group(&ff, &img, &ExecOptions::default()).unwrap();
            secs_a10
                .push(PerfModel::new(&ff, &DeviceConfig::pac_a10()).estimate(&run.profiles).seconds);
            secs_hbm.push(
                PerfModel::new(&ff, &DeviceConfig::stratix10_hbm())
                    .estimate(&run.profiles)
                    .seconds,
            );
        }
        assert_eq!(secs_a10[0], secs_a10[1], "arria10 must stay depth-invariant bit for bit");
        assert!(
            secs_hbm[1] < secs_hbm[0],
            "deep pipes must amortize HBM fill latency: {secs_hbm:?}"
        );
    }

    /// Bank-level parallelism gates a lone irregular requester on the
    /// HBM profile: the same gather gets strictly faster if the model is
    /// granted enough per-requester queue depth to cover all 32 banks.
    #[test]
    fn bank_parallelism_caps_a_lone_requester_on_hbm() {
        let k = KernelBuilder::new("gather", KernelKind::SingleWorkItem)
            .buf_ro("idx", Ty::I32)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("a", ld("idx", v("i"))))],
            )])
            .finish();
        let n = 40_000usize;
        let prog = Program::single(k);
        let mut img = MemoryImage::new();
        let idx: Vec<i64> = (0..n).map(|i| ((i as i64).wrapping_mul(48271)) % n as i64).collect();
        img.add_i64s("idx", &idx)
            .add_f32s("a", &vec![1.0; n])
            .add_zeros("o", Ty::F32, n)
            .set_i("n", n as i64);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();

        let starved = DeviceConfig::stratix10_hbm();
        let mut covered = DeviceConfig::stratix10_hbm();
        covered.mem.bank_queue = covered.mem.banks; // hypothetical deep-MLP LSU
        let t_starved = PerfModel::new(&prog, &starved).estimate(&run.profiles);
        let t_covered = PerfModel::new(&prog, &covered).estimate(&run.profiles);
        assert!(t_starved.dram_cycles > 2.0 * t_covered.dram_cycles);
        assert!(t_starved.cycles > t_covered.cycles);
    }

    /// The invariant launch-graph overlap rests on (see the module docs):
    /// on every registry device, bank-parallel efficiency is monotone
    /// nondecreasing in the requester count and never exceeds 1.0 — so
    /// merging two launches' requesters into one wavefront can only grow
    /// the shared DRAM capacity, never shrink it.
    #[test]
    fn bank_parallel_efficiency_is_monotone_and_capped() {
        for cfg in crate::sim::device::DeviceRegistry::all() {
            let mut prev = 0.0f64;
            for requesters in 0..=64usize {
                let eff = cfg.mem.bank_parallel_efficiency(requesters);
                assert!(
                    eff >= prev,
                    "{}: efficiency dropped at {requesters} requesters ({eff} < {prev})",
                    cfg.name
                );
                assert!(eff <= 1.0, "{}: efficiency above 1.0 at {requesters}", cfg.name);
                prev = eff;
            }
            assert_eq!(
                cfg.mem.bank_parallel_efficiency(0),
                cfg.mem.bank_parallel_efficiency(1),
                "{}: the zero-requester clamp must match a lone requester",
                cfg.name
            );
        }
    }
}
