//! Functional concurrent interpreter.
//!
//! Executes a device [`Program`] the way the board would: every kernel of
//! the launch group runs on its own thread (the paper's step 14 — all
//! kernels enqueued on separate queues), blocking pipes are bounded
//! `sync_channel`s with exactly the Intel-channel semantics (blocking
//! read/write, FIFO order, declared minimum depth), and global memory is
//! the shared [`MemoryImage`].
//!
//! Kernels are first *compiled*: variable names resolve to frame slots,
//! scalar parameters are baked to constants, buffers and pipes to dense
//! indices, and every global-memory access gets the same pre-order site id
//! that `analysis::lsu::select_lsus` assigns — the profiles this
//! interpreter emits line up 1:1 with the static analysis, which is what
//! makes the performance model trace-driven.

use super::mem::{Buffer, MemoryImage};
use super::profile::{KernelProfile, LoopStats};
use crate::ir::{BinOp, Expr, Kernel, KernelKind, LoopId, Program, Stmt, Ty, UnOp, Val};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;

#[derive(Debug, PartialEq)]
pub enum ExecError {
    OutOfBounds { kernel: String, buf: String, idx: i64, len: usize },
    PipeClosed { kernel: String, pipe: String },
    MissingBuffer { kernel: String, buf: String },
    MissingScalar { kernel: String, name: String },
    NdRange { kernel: String },
    Panic { kernel: String },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::OutOfBounds { kernel, buf, idx, len } => {
                write!(f, "kernel {kernel}: {buf}[{idx}] out of bounds (len {len})")
            }
            ExecError::PipeClosed { kernel, pipe } => write!(
                f,
                "kernel {kernel}: pipe {pipe} closed (trace mismatch between producer and consumer)"
            ),
            ExecError::MissingBuffer { kernel, buf } => {
                write!(f, "kernel {kernel}: missing buffer `{buf}` in memory image")
            }
            ExecError::MissingScalar { kernel, name } => {
                write!(f, "kernel {kernel}: missing scalar `{name}` in memory image")
            }
            ExecError::NdRange { kernel } => write!(
                f,
                "kernel {kernel}: NDRange kernels must be converted to single work-item first"
            ),
            ExecError::Panic { kernel } => write!(f, "kernel {kernel}: thread panicked"),
        }
    }
}

impl std::error::Error for ExecError {}

// ---------------------------------------------------------------------------
// Resolved IR
// ---------------------------------------------------------------------------

/// Index into the kernel's expression arena (§Perf: flattened from a
/// Box-tree — one contiguous Vec walks far better in cache and removes a
/// pointer dereference per node on the hottest path).
type EId = u32;

#[derive(Debug, Clone, Copy)]
enum RExpr {
    Const(Val),
    Var(u32),
    Load { buf: u32, site: u32, idx: EId },
    Bin(BinOp, EId, EId),
    Un(UnOp, EId),
    Select(EId, EId, EId),
}

#[derive(Debug, Clone)]
enum RStmt {
    Set { slot: u32, expr: EId },
    Store { buf: u32, site: u32, idx: EId, val: EId },
    If { cond: EId, then_b: Vec<RStmt>, else_b: Vec<RStmt> },
    For { lix: u32, slot: u32, lo: EId, hi: EId, body: Vec<RStmt> },
    PipeWrite { pipe: u32, val: EId },
    PipeRead { slot: u32, pipe: u32 },
}

/// A launch-ready kernel: names resolved, params baked.
pub struct CompiledKernel {
    pub name: String,
    nslots: u32,
    n_sites: u32,
    buf_names: Vec<String>,
    bufs: Vec<Arc<Buffer>>,
    exprs: Vec<RExpr>,
    /// dense loop index -> source LoopId (profiles report LoopIds)
    loop_ids: Vec<LoopId>,
    body: Vec<RStmt>,
}

struct Compiler<'a> {
    kernel: &'a Kernel,
    image: &'a MemoryImage,
    scopes: Vec<HashMap<String, u32>>,
    nslots: u32,
    bufs: Vec<(String, Arc<Buffer>)>,
    pipes: &'a HashMap<String, u32>,
    next_site: u32,
    exprs: Vec<RExpr>,
    loop_ids: Vec<LoopId>,
}

impl<'a> Compiler<'a> {
    fn lookup(&self, name: &str) -> Option<u32> {
        self.scopes.iter().rev().find_map(|s| s.get(name)).copied()
    }

    fn define(&mut self, name: &str) -> u32 {
        let slot = self.nslots;
        self.nslots += 1;
        self.scopes.last_mut().unwrap().insert(name.to_string(), slot);
        slot
    }

    fn buf_ix(&mut self, name: &str) -> Result<u32, ExecError> {
        if let Some(i) = self.bufs.iter().position(|(n, _)| n == name) {
            return Ok(i as u32);
        }
        let arc = self
            .image
            .buf(name)
            .ok_or_else(|| ExecError::MissingBuffer { kernel: self.kernel.name.clone(), buf: name.to_string() })?
            .clone();
        self.bufs.push((name.to_string(), arc));
        Ok((self.bufs.len() - 1) as u32)
    }

    fn push(&mut self, e: RExpr) -> EId {
        self.exprs.push(e);
        (self.exprs.len() - 1) as EId
    }

    fn expr(&mut self, e: &Expr) -> Result<EId, ExecError> {
        let node = match e {
            Expr::I(v) => RExpr::Const(Val::I(*v)),
            Expr::F(v) => RExpr::Const(Val::F(*v)),
            Expr::Var(n) => RExpr::Var(self.lookup(n).unwrap_or_else(|| {
                panic!("unresolved var {n} in kernel {} (validate first)", self.kernel.name)
            })),
            Expr::Param(n) => RExpr::Const(self.image.scalar(n).ok_or_else(|| {
                ExecError::MissingScalar { kernel: self.kernel.name.clone(), name: n.clone() }
            })?),
            Expr::GlobalId(_) => {
                return Err(ExecError::NdRange { kernel: self.kernel.name.clone() })
            }
            Expr::Load { buf, idx } => {
                // Pre-order site id: this load before any load in its index.
                let site = self.next_site;
                self.next_site += 1;
                let b = self.buf_ix(buf)?;
                let idx = self.expr(idx)?;
                RExpr::Load { buf: b, site, idx }
            }
            Expr::Bin(op, a, b) => {
                let a = self.expr(a)?;
                let b = self.expr(b)?;
                RExpr::Bin(*op, a, b)
            }
            Expr::Un(op, a) => {
                let a = self.expr(a)?;
                RExpr::Un(*op, a)
            }
            Expr::Select(c, t, f) => {
                let c = self.expr(c)?;
                let t = self.expr(t)?;
                let f = self.expr(f)?;
                RExpr::Select(c, t, f)
            }
        };
        Ok(self.push(node))
    }

    fn body(&mut self, body: &[Stmt]) -> Result<Vec<RStmt>, ExecError> {
        let mut out = vec![];
        for s in body {
            match s {
                Stmt::Let { var, expr, .. } => {
                    let e = self.expr(expr)?;
                    let slot = self.define(var);
                    out.push(RStmt::Set { slot, expr: e });
                }
                Stmt::Assign { var, expr } => {
                    let e = self.expr(expr)?;
                    let slot = self.lookup(var).expect("validated assign target");
                    out.push(RStmt::Set { slot, expr: e });
                }
                Stmt::Store { buf, idx, val } => {
                    let idx = self.expr(idx)?;
                    let val = self.expr(val)?;
                    let site = self.next_site;
                    self.next_site += 1;
                    let b = self.buf_ix(buf)?;
                    out.push(RStmt::Store { buf: b, site, idx, val });
                }
                Stmt::If { cond, then_b, else_b } => {
                    let cond = self.expr(cond)?;
                    self.scopes.push(HashMap::new());
                    let t = self.body(then_b)?;
                    self.scopes.pop();
                    self.scopes.push(HashMap::new());
                    let e = self.body(else_b)?;
                    self.scopes.pop();
                    out.push(RStmt::If { cond, then_b: t, else_b: e });
                }
                Stmt::For { id, var, lo, hi, body } => {
                    let lo = self.expr(lo)?;
                    let hi = self.expr(hi)?;
                    self.scopes.push(HashMap::new());
                    let slot = self.define(var);
                    let lix = self.loop_ids.len() as u32;
                    self.loop_ids.push(*id);
                    let b = self.body(body)?;
                    self.scopes.pop();
                    out.push(RStmt::For { lix, slot, lo, hi, body: b });
                }
                Stmt::PipeWrite { pipe, val } => {
                    let val = self.expr(val)?;
                    let pipe = *self.pipes.get(pipe).expect("validated pipe");
                    out.push(RStmt::PipeWrite { pipe, val });
                }
                Stmt::PipeRead { var, pipe, .. } => {
                    let pipe = *self.pipes.get(pipe).expect("validated pipe");
                    let slot = self.define(var);
                    out.push(RStmt::PipeRead { slot, pipe });
                }
            }
        }
        Ok(out)
    }
}

/// Compile one kernel against a memory image (params baked) and the
/// program's pipe numbering.
pub fn compile_kernel(
    kernel: &Kernel,
    image: &MemoryImage,
    pipes: &HashMap<String, u32>,
) -> Result<CompiledKernel, ExecError> {
    if kernel.kind == KernelKind::NDRange {
        return Err(ExecError::NdRange { kernel: kernel.name.clone() });
    }
    let mut c = Compiler {
        kernel,
        image,
        scopes: vec![HashMap::new()],
        nslots: 0,
        bufs: vec![],
        pipes,
        next_site: 0,
        exprs: vec![],
        loop_ids: vec![],
    };
    let body = c.body(&kernel.body)?;
    Ok(CompiledKernel {
        name: kernel.name.clone(),
        nslots: c.nslots,
        n_sites: c.next_site,
        buf_names: c.bufs.iter().map(|(n, _)| n.clone()).collect(),
        bufs: c.bufs.into_iter().map(|(_, b)| b).collect(),
        exprs: c.exprs,
        loop_ids: c.loop_ids,
        body,
    })
}

// ---------------------------------------------------------------------------
// Runtime
// ---------------------------------------------------------------------------

struct Runner<'k> {
    k: &'k CompiledKernel,
    slots: Vec<Val>,
    senders: Vec<Option<SyncSender<u64>>>,
    receivers: Vec<Option<Receiver<u64>>>,
    pipe_tys: Vec<Ty>,
    pipe_names: Vec<String>,
    profile: KernelProfile,
    /// dense per-loop counters, folded into `profile.loops` at the end
    loop_stats: Vec<LoopStats>,
    profiling: bool,
}

impl<'k> Runner<'k> {
    #[inline]
    fn eval(&mut self, e: EId) -> Result<Val, ExecError> {
        Ok(match self.k.exprs[e as usize] {
            RExpr::Const(v) => v,
            RExpr::Var(s) => self.slots[s as usize],
            RExpr::Load { buf, site, idx } => {
                let i = self.eval(idx)?.as_i();
                let b = &self.k.bufs[buf as usize];
                if i < 0 || i as usize >= b.len() {
                    return Err(ExecError::OutOfBounds {
                        kernel: self.k.name.clone(),
                        buf: self.k.buf_names[buf as usize].clone(),
                        idx: i,
                        len: b.len(),
                    });
                }
                if self.profiling {
                    self.profile.sites[site as usize].record(i);
                }
                b.get(i as usize)
            }
            RExpr::Bin(op, a, b) => {
                let x = self.eval(a)?;
                let y = self.eval(b)?;
                Expr::eval_bin(op, x, y)
            }
            RExpr::Un(op, a) => Expr::eval_un(op, self.eval(a)?),
            RExpr::Select(c, t, f) => {
                if self.eval(c)?.is_true() {
                    self.eval(t)?
                } else {
                    self.eval(f)?
                }
            }
        })
    }

    fn exec(&mut self, body: &[RStmt]) -> Result<(), ExecError> {
        for s in body {
            match s {
                RStmt::Set { slot, expr } => {
                    let v = self.eval(*expr)?;
                    self.slots[*slot as usize] = v;
                }
                RStmt::Store { buf, site, idx, val } => {
                    let i = self.eval(*idx)?.as_i();
                    let v = self.eval(*val)?;
                    let b = &self.k.bufs[*buf as usize];
                    if i < 0 || i as usize >= b.len() {
                        return Err(ExecError::OutOfBounds {
                            kernel: self.k.name.clone(),
                            buf: self.k.buf_names[*buf as usize].clone(),
                            idx: i,
                            len: b.len(),
                        });
                    }
                    // Match the buffer's element type (int stores into a
                    // float buffer keep C semantics via conversion).
                    let v = match b.ty {
                        Ty::I32 => Val::I(v.as_i()),
                        Ty::F32 => Val::F(v.as_f()),
                    };
                    if self.profiling {
                        self.profile.sites[*site as usize].record(i);
                    }
                    b.set(i as usize, v);
                }
                RStmt::If { cond, then_b, else_b } => {
                    if self.eval(*cond)?.is_true() {
                        self.exec(then_b)?;
                    } else {
                        self.exec(else_b)?;
                    }
                }
                RStmt::For { lix, slot, lo, hi, body } => {
                    let lo = self.eval(*lo)?.as_i();
                    let hi = self.eval(*hi)?.as_i();
                    if self.profiling {
                        let e = &mut self.loop_stats[*lix as usize];
                        e.invocations += 1;
                        e.iters += (hi - lo).max(0) as u64;
                    }
                    let mut i = lo;
                    while i < hi {
                        self.slots[*slot as usize] = Val::I(i);
                        self.exec(body)?;
                        i += 1;
                    }
                }
                RStmt::PipeWrite { pipe, val } => {
                    let v = self.eval(*val)?;
                    self.profile.pipe_writes += 1;
                    let tx = self.senders[*pipe as usize]
                        .as_ref()
                        .expect("kernel writes undeclared pipe endpoint");
                    tx.send(v.to_bits()).map_err(|_| ExecError::PipeClosed {
                        kernel: self.k.name.clone(),
                        pipe: self.pipe_names[*pipe as usize].clone(),
                    })?;
                }
                RStmt::PipeRead { slot, pipe } => {
                    let rx = self.receivers[*pipe as usize]
                        .as_ref()
                        .expect("kernel reads undeclared pipe endpoint");
                    let bits = rx.recv().map_err(|_| ExecError::PipeClosed {
                        kernel: self.k.name.clone(),
                        pipe: self.pipe_names[*pipe as usize].clone(),
                    })?;
                    self.profile.pipe_reads += 1;
                    self.slots[*slot as usize] = Val::from_bits(self.pipe_tys[*pipe as usize], bits);
                }
            }
        }
        Ok(())
    }
}

/// Options for a launch.
#[derive(Debug, Clone)]
pub struct ExecOptions {
    /// Collect site/loop profiles (small constant per-op cost).
    pub profile: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { profile: true }
    }
}

/// Result of one launch group (all kernels ran to completion).
#[derive(Debug)]
pub struct GroupRun {
    pub profiles: Vec<KernelProfile>,
}

/// Launch every kernel of `prog` concurrently against `image` and wait for
/// completion. This is one host-side `clEnqueue*` + `clFinish` round.
pub fn run_group(prog: &Program, image: &MemoryImage, opts: &ExecOptions) -> Result<GroupRun, ExecError> {
    // Pipe numbering and endpoints.
    let mut pipe_ix = HashMap::new();
    for (i, p) in prog.pipes.iter().enumerate() {
        pipe_ix.insert(p.name.clone(), i as u32);
    }
    let pipe_tys: Vec<Ty> = prog.pipes.iter().map(|p| p.ty).collect();
    let pipe_names: Vec<String> = prog.pipes.iter().map(|p| p.name.clone()).collect();

    let compiled: Vec<CompiledKernel> = prog
        .kernels
        .iter()
        .map(|k| compile_kernel(k, image, &pipe_ix))
        .collect::<Result<_, _>>()?;

    // Create channels; hand endpoints to the right kernels.
    let mut senders: Vec<Vec<Option<SyncSender<u64>>>> = (0..prog.kernels.len())
        .map(|_| (0..prog.pipes.len()).map(|_| None).collect())
        .collect();
    let mut receivers: Vec<Vec<Option<Receiver<u64>>>> = (0..prog.kernels.len())
        .map(|_| (0..prog.pipes.len()).map(|_| None).collect())
        .collect();
    for (pi, pd) in prog.pipes.iter().enumerate() {
        let (tx, rx) = sync_channel::<u64>(pd.depth.max(1));
        let mut tx = Some(tx);
        let mut rx = Some(rx);
        for (ki, k) in prog.kernels.iter().enumerate() {
            crate::ir::stmt::visit_body(&k.body, &mut |s| match s {
                Stmt::PipeWrite { pipe, .. } if pipe == &pd.name => {
                    if let Some(t) = tx.take() {
                        senders[ki][pi] = Some(t);
                    }
                }
                Stmt::PipeRead { pipe, .. } if pipe == &pd.name => {
                    if let Some(r) = rx.take() {
                        receivers[ki][pi] = Some(r);
                    }
                }
                _ => {}
            });
        }
    }

    let n = compiled.len();
    let mut results: Vec<Result<KernelProfile, ExecError>> =
        (0..n).map(|_| Err(ExecError::Panic { kernel: String::new() })).collect();

    std::thread::scope(|scope| {
        let mut handles = vec![];
        for ((ck, sends), recvs) in compiled.iter().zip(senders).zip(receivers) {
            let profiling = opts.profile;
            let pipe_tys = pipe_tys.clone();
            let pipe_names = pipe_names.clone();
            handles.push(scope.spawn(move || {
                let start = std::time::Instant::now();
                let mut r = Runner {
                    k: ck,
                    slots: vec![Val::I(0); ck.nslots as usize],
                    senders: sends,
                    receivers: recvs,
                    pipe_tys,
                    pipe_names,
                    profile: KernelProfile::new(&ck.name, ck.n_sites as usize),
                    loop_stats: vec![LoopStats::default(); ck.loop_ids.len()],
                    profiling,
                };
                let out = r.exec(&ck.body);
                // fold dense counters back into the LoopId-keyed profile
                for (lix, st) in r.loop_stats.iter().enumerate() {
                    if st.invocations > 0 {
                        let e = r.profile.loops.entry(ck.loop_ids[lix]).or_default();
                        e.invocations += st.invocations;
                        e.iters += st.iters;
                    }
                }
                r.profile.host_nanos = start.elapsed().as_nanos() as u64;
                out.map(|_| r.profile)
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            results[i] = match h.join() {
                Ok(res) => res,
                Err(_) => Err(ExecError::Panic { kernel: compiled[i].name.clone() }),
            };
        }
    });

    let mut profiles = vec![];
    for r in results {
        profiles.push(r?);
    }
    Ok(GroupRun { profiles })
}

/// Global counter of interpreted launches (used by benches/EXPERIMENTS).
pub static LAUNCHES: AtomicU64 = AtomicU64::new(0);

/// `run_group` + launch accounting.
pub fn launch(prog: &Program, image: &MemoryImage, opts: &ExecOptions) -> Result<GroupRun, ExecError> {
    LAUNCHES.fetch_add(1, Ordering::Relaxed);
    run_group(prog, image, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{PipeDecl, Program};
    use crate::transform::examples::fig2_kernel;

    fn saxpy() -> Kernel {
        KernelBuilder::new("saxpy", KernelKind::SingleWorkItem)
            .buf_ro("x", Ty::F32)
            .buf_ro("y", Ty::F32)
            .buf_wo("out", Ty::F32)
            .scalar("n", Ty::I32)
            .scalar("a", Ty::F32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("out", v("i"), p("a") * ld("x", v("i")) + ld("y", v("i")))],
            )])
            .finish()
    }

    fn saxpy_image(n: usize) -> MemoryImage {
        let mut m = MemoryImage::new();
        let xs: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let ys: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5).collect();
        m.add_f32s("x", &xs).add_f32s("y", &ys).add_zeros("out", Ty::F32, n);
        m.set_i("n", n as i64).set_f("a", 2.0);
        m
    }

    #[test]
    fn saxpy_single_kernel() {
        let img = saxpy_image(100);
        let prog = Program::single(saxpy());
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let out = img.buf("out").unwrap().to_f32s();
        for (i, o) in out.iter().enumerate() {
            assert_eq!(*o, 2.0 * i as f32 + 0.5 * i as f32);
        }
        // profile: 1 loop with 100 iters, 3 sites (2 loads + 1 store)
        let p = &run.profiles[0];
        assert_eq!(p.loop_stats(LoopId(0)).iters, 100);
        assert_eq!(p.sites.len(), 3);
        assert_eq!(p.sites[0].count, 100);
        assert!(p.sites[0].seq_frac() > 0.98);
    }

    #[test]
    fn feedforward_pair_produces_same_result() {
        let base = saxpy();
        let img1 = saxpy_image(256);
        let img2 = saxpy_image(256);
        run_group(&Program::single(base.clone()), &img1, &ExecOptions::default()).unwrap();
        let ff = crate::transform::feedforward(&base, 4).unwrap();
        let run = run_group(&ff, &img2, &ExecOptions::default()).unwrap();
        assert_eq!(img1.buf("out").unwrap().to_f32s(), img2.buf("out").unwrap().to_f32s());
        // token conservation
        let wr: u64 = run.profiles.iter().map(|p| p.pipe_writes).sum();
        let rd: u64 = run.profiles.iter().map(|p| p.pipe_reads).sum();
        assert_eq!(wr, rd);
        assert_eq!(wr, 512); // 2 loads x 256 iters
    }

    #[test]
    fn fig2_all_variants_agree() {
        use crate::transform::{apply_variant, Variant};
        // small CSR graph
        let row = vec![0i64, 2, 4, 5, 7];
        let col = vec![1i64, 2, 0, 3, 0, 1, 2];
        let car = vec![-1i64, -1, 3, -1];
        let nv = vec![0.3f32, 0.1, 0.9, 0.7];
        let image = || {
            let mut m = MemoryImage::new();
            m.add_i64s("row", &row)
                .add_i64s("col", &col)
                .add_i64s("c_array", &car)
                .add_f32s("node_value", &nv)
                .add_zeros("min_array", Ty::F32, 4)
                .add_zeros("stop", Ty::I32, 1);
            m.set_i("num_nodes", 4).set_i("num_edges", 7);
            m
        };
        let base_img = image();
        run_group(
            &Program::single(fig2_kernel()),
            &base_img,
            &ExecOptions::default(),
        )
        .unwrap();
        let want = base_img.buf("min_array").unwrap().to_f32s();
        assert_eq!(base_img.buf("stop").unwrap().get(0), Val::I(1));

        for variant in [
            Variant::FeedForward { depth: 1 },
            Variant::FeedForward { depth: 100 },
            Variant::MxCx { parts: 2, depth: 1 },
            Variant::M1Cx { consumers: 2, depth: 1 },
        ] {
            let prog = apply_variant(&fig2_kernel(), variant).unwrap();
            let img = image();
            run_group(&prog, &img, &ExecOptions::default()).unwrap();
            assert_eq!(
                img.buf("min_array").unwrap().to_f32s(),
                want,
                "variant {variant:?}"
            );
        }
    }

    #[test]
    fn oob_reports_kernel_and_buffer() {
        let k = KernelBuilder::new("bad", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_("i", i(0), p("n"), vec![store("o", v("i"), ld("a", v("i") + i(1)))])])
            .finish();
        let mut img = MemoryImage::new();
        img.add_f32s("a", &[1.0, 2.0]).add_zeros("o", Ty::F32, 2).set_i("n", 2);
        let err = run_group(&Program::single(k), &img, &ExecOptions::default()).unwrap_err();
        assert!(matches!(err, ExecError::OutOfBounds { ref buf, idx: 2, .. } if buf == "a"));
    }

    #[test]
    fn site_numbering_matches_analysis() {
        let k = saxpy();
        let sites = crate::analysis::select_lsus(&k);
        let img = saxpy_image(8);
        let prog = Program::single(k);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        assert_eq!(run.profiles[0].sites.len(), sites.len());
    }
}
