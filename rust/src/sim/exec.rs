//! Functional concurrent interpreter.
//!
//! Executes a device [`Program`] the way the board would: every kernel of
//! the launch group runs on its own thread (the paper's step 14 — all
//! kernels enqueued on separate queues), blocking pipes honour the
//! Intel-channel semantics (blocking read/write, FIFO order, declared
//! *minimum* depth), and global memory is the shared [`MemoryImage`].
//!
//! Kernels are first *compiled*: variable names resolve to frame slots,
//! scalar parameters are baked to constants, buffers and pipes to dense
//! indices, and every global-memory access gets the same pre-order site id
//! that `analysis::lsu::select_lsus` assigns — the profiles this
//! interpreter emits line up 1:1 with the static analysis, which is what
//! makes the performance model trace-driven.
//!
//! § Perf — chunked pipe transfers: tokens used to cross a
//! `sync_channel<u64>` one at a time, paying a full synchronization per
//! token on the hottest path. They now move in chunks of
//! `ceil(depth / 2)` tokens, capped at 1024 ([`chunk_for_depth`]),
//! through a `sync_channel<Vec<u64>>` holding [`chunks_in_flight`]
//! chunks, and spent chunk buffers are handed back to the producer over
//! a recycle channel so the steady state allocates nothing per outer
//! iteration. Capacity accounting: `chunk * (capacity + 1) >= depth + 1`,
//! so a producer always *completes* at least `depth` writes before
//! blocking — the `sync_channel(depth)` per-token contract (the declared
//! depth is a *minimum* the offline compiler may deepen, §3 — see
//! [`crate::ir::PipeDecl`]) — and holds at most `depth + 3 * chunk`
//! tokens transiently. Deadlock freedom with buffering: a producer flushes
//! every pending buffer before parking on a full channel, and a consumer
//! flushes its own pending *sends* before parking on an empty one —
//! conditional load sites fire at data-dependent rates, so one pipe's
//! tokens must never sit buffered while a peer starves on them.
//! Programs whose kernels share writable buffers opt out of chunking
//! entirely (`ExecOptions::exact_pipes`): they run per-token with
//! capacity exactly the declared depth, preserving the historical
//! producer-lead bound their semantics depend on.

use super::mem::{Buffer, MemoryImage};
use super::profile::{KernelProfile, LoopStats};
use crate::ir::{BinOp, Expr, Kernel, KernelKind, LoopId, Program, Stmt, Ty, UnOp, Val};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;

#[derive(Debug, PartialEq)]
pub enum ExecError {
    OutOfBounds { kernel: String, buf: String, idx: i64, len: usize },
    PipeClosed { kernel: String, pipe: String },
    MissingBuffer { kernel: String, buf: String },
    MissingScalar { kernel: String, name: String },
    NdRange { kernel: String },
    Panic { kernel: String },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::OutOfBounds { kernel, buf, idx, len } => {
                write!(f, "kernel {kernel}: {buf}[{idx}] out of bounds (len {len})")
            }
            ExecError::PipeClosed { kernel, pipe } => write!(
                f,
                "kernel {kernel}: pipe {pipe} closed (trace mismatch between producer and consumer)"
            ),
            ExecError::MissingBuffer { kernel, buf } => {
                write!(f, "kernel {kernel}: missing buffer `{buf}` in memory image")
            }
            ExecError::MissingScalar { kernel, name } => {
                write!(f, "kernel {kernel}: missing scalar `{name}` in memory image")
            }
            ExecError::NdRange { kernel } => write!(
                f,
                "kernel {kernel}: NDRange kernels must be converted to single work-item first"
            ),
            ExecError::Panic { kernel } => write!(f, "kernel {kernel}: thread panicked"),
        }
    }
}

impl std::error::Error for ExecError {}

// ---------------------------------------------------------------------------
// Resolved IR
// ---------------------------------------------------------------------------

/// Index into the kernel's expression arena (§Perf: flattened from a
/// Box-tree — one contiguous Vec walks far better in cache and removes a
/// pointer dereference per node on the hottest path).
type EId = u32;

#[derive(Debug, Clone, Copy)]
enum RExpr {
    Const(Val),
    Var(u32),
    Load { buf: u32, site: u32, idx: EId },
    Bin(BinOp, EId, EId),
    Un(UnOp, EId),
    Select(EId, EId, EId),
}

#[derive(Debug, Clone)]
enum RStmt {
    Set { slot: u32, expr: EId },
    Store { buf: u32, site: u32, idx: EId, val: EId },
    If { cond: EId, then_b: Vec<RStmt>, else_b: Vec<RStmt> },
    For { lix: u32, slot: u32, lo: EId, hi: EId, body: Vec<RStmt> },
    PipeWrite { pipe: u32, val: EId },
    PipeRead { slot: u32, pipe: u32 },
}

/// A launch-ready kernel: names resolved, params baked.
pub struct CompiledKernel {
    pub name: String,
    nslots: u32,
    n_sites: u32,
    buf_names: Vec<String>,
    bufs: Vec<Arc<Buffer>>,
    exprs: Vec<RExpr>,
    /// dense loop index -> source LoopId (profiles report LoopIds)
    loop_ids: Vec<LoopId>,
    body: Vec<RStmt>,
}

struct Compiler<'a> {
    kernel: &'a Kernel,
    image: &'a MemoryImage,
    scopes: Vec<HashMap<String, u32>>,
    nslots: u32,
    bufs: Vec<(String, Arc<Buffer>)>,
    pipes: &'a HashMap<String, u32>,
    next_site: u32,
    exprs: Vec<RExpr>,
    loop_ids: Vec<LoopId>,
}

impl<'a> Compiler<'a> {
    fn lookup(&self, name: &str) -> Option<u32> {
        self.scopes.iter().rev().find_map(|s| s.get(name)).copied()
    }

    fn define(&mut self, name: &str) -> u32 {
        let slot = self.nslots;
        self.nslots += 1;
        self.scopes.last_mut().unwrap().insert(name.to_string(), slot);
        slot
    }

    fn buf_ix(&mut self, name: &str) -> Result<u32, ExecError> {
        if let Some(i) = self.bufs.iter().position(|(n, _)| n == name) {
            return Ok(i as u32);
        }
        let arc = self
            .image
            .buf(name)
            .ok_or_else(|| ExecError::MissingBuffer { kernel: self.kernel.name.clone(), buf: name.to_string() })?
            .clone();
        self.bufs.push((name.to_string(), arc));
        Ok((self.bufs.len() - 1) as u32)
    }

    fn push(&mut self, e: RExpr) -> EId {
        self.exprs.push(e);
        (self.exprs.len() - 1) as EId
    }

    fn expr(&mut self, e: &Expr) -> Result<EId, ExecError> {
        let node = match e {
            Expr::I(v) => RExpr::Const(Val::I(*v)),
            Expr::F(v) => RExpr::Const(Val::F(*v)),
            Expr::Var(n) => RExpr::Var(self.lookup(n).unwrap_or_else(|| {
                panic!("unresolved var {n} in kernel {} (validate first)", self.kernel.name)
            })),
            Expr::Param(n) => RExpr::Const(self.image.scalar(n).ok_or_else(|| {
                ExecError::MissingScalar { kernel: self.kernel.name.clone(), name: n.clone() }
            })?),
            Expr::GlobalId(_) => {
                return Err(ExecError::NdRange { kernel: self.kernel.name.clone() })
            }
            Expr::Load { buf, idx } => {
                // Pre-order site id: this load before any load in its index.
                let site = self.next_site;
                self.next_site += 1;
                let b = self.buf_ix(buf)?;
                let idx = self.expr(idx)?;
                RExpr::Load { buf: b, site, idx }
            }
            Expr::Bin(op, a, b) => {
                let a = self.expr(a)?;
                let b = self.expr(b)?;
                RExpr::Bin(*op, a, b)
            }
            Expr::Un(op, a) => {
                let a = self.expr(a)?;
                RExpr::Un(*op, a)
            }
            Expr::Select(c, t, f) => {
                let c = self.expr(c)?;
                let t = self.expr(t)?;
                let f = self.expr(f)?;
                RExpr::Select(c, t, f)
            }
        };
        Ok(self.push(node))
    }

    fn body(&mut self, body: &[Stmt]) -> Result<Vec<RStmt>, ExecError> {
        let mut out = vec![];
        for s in body {
            match s {
                Stmt::Let { var, expr, .. } => {
                    let e = self.expr(expr)?;
                    let slot = self.define(var);
                    out.push(RStmt::Set { slot, expr: e });
                }
                Stmt::Assign { var, expr } => {
                    let e = self.expr(expr)?;
                    let slot = self.lookup(var).expect("validated assign target");
                    out.push(RStmt::Set { slot, expr: e });
                }
                Stmt::Store { buf, idx, val } => {
                    let idx = self.expr(idx)?;
                    let val = self.expr(val)?;
                    let site = self.next_site;
                    self.next_site += 1;
                    let b = self.buf_ix(buf)?;
                    out.push(RStmt::Store { buf: b, site, idx, val });
                }
                Stmt::If { cond, then_b, else_b } => {
                    let cond = self.expr(cond)?;
                    self.scopes.push(HashMap::new());
                    let t = self.body(then_b)?;
                    self.scopes.pop();
                    self.scopes.push(HashMap::new());
                    let e = self.body(else_b)?;
                    self.scopes.pop();
                    out.push(RStmt::If { cond, then_b: t, else_b: e });
                }
                Stmt::For { id, var, lo, hi, body } => {
                    let lo = self.expr(lo)?;
                    let hi = self.expr(hi)?;
                    self.scopes.push(HashMap::new());
                    let slot = self.define(var);
                    let lix = self.loop_ids.len() as u32;
                    self.loop_ids.push(*id);
                    let b = self.body(body)?;
                    self.scopes.pop();
                    out.push(RStmt::For { lix, slot, lo, hi, body: b });
                }
                Stmt::PipeWrite { pipe, val } => {
                    let val = self.expr(val)?;
                    let pipe = *self.pipes.get(pipe).expect("validated pipe");
                    out.push(RStmt::PipeWrite { pipe, val });
                }
                Stmt::PipeRead { var, pipe, .. } => {
                    let pipe = *self.pipes.get(pipe).expect("validated pipe");
                    let slot = self.define(var);
                    out.push(RStmt::PipeRead { slot, pipe });
                }
            }
        }
        Ok(out)
    }
}

/// Compile one kernel against a memory image (params baked) and the
/// program's pipe numbering.
pub fn compile_kernel(
    kernel: &Kernel,
    image: &MemoryImage,
    pipes: &HashMap<String, u32>,
) -> Result<CompiledKernel, ExecError> {
    if kernel.kind == KernelKind::NDRange {
        return Err(ExecError::NdRange { kernel: kernel.name.clone() });
    }
    let mut c = Compiler {
        kernel,
        image,
        scopes: vec![HashMap::new()],
        nslots: 0,
        bufs: vec![],
        pipes,
        next_site: 0,
        exprs: vec![],
        loop_ids: vec![],
    };
    let body = c.body(&kernel.body)?;
    Ok(CompiledKernel {
        name: kernel.name.clone(),
        nslots: c.nslots,
        n_sites: c.next_site,
        buf_names: c.bufs.iter().map(|(n, _)| n.clone()).collect(),
        bufs: c.bufs.into_iter().map(|(_, b)| b).collect(),
        exprs: c.exprs,
        loop_ids: c.loop_ids,
        body,
    })
}

// ---------------------------------------------------------------------------
// Runtime
// ---------------------------------------------------------------------------

/// Upper bound on tokens per transfer chunk (8 KiB of `u64` bits) — keeps
/// very deep pipes from buffering unboundedly large chunks.
const MAX_CHUNK: usize = 1024;

/// Tokens per chunk for a pipe of the given declared depth: `ceil(d/2)`,
/// capped at [`MAX_CHUNK`]. Paired with [`chunks_in_flight`] so that a
/// producer can always *complete* at least `depth` writes before
/// blocking (the declared minimum — see the invariant test
/// `chunk_sizes_honor_declared_minimum_depth`), while the transient
/// maximum (producer buffer + channel + consumer buffer) stays within
/// `depth + 3 * chunk`.
pub fn chunk_for_depth(depth: usize) -> usize {
    depth.max(1).div_ceil(2).min(MAX_CHUNK)
}

/// Channel capacity in chunks for a declared depth: the smallest count
/// such that `chunk * (capacity + 1) >= depth + 1`. Writes completed
/// with zero consumer progress = `capacity * chunk` delivered + `chunk -
/// 1` buffered below the flush threshold — the `depth + 1`-th write is
/// the first allowed to park, exactly the `sync_channel(depth)`
/// per-token contract. 1 or 2 slots for depths up to `2 * MAX_CHUNK`;
/// deeper pipes get proportionally more slots instead of bigger chunks.
pub fn chunks_in_flight(depth: usize) -> usize {
    let d = depth.max(1);
    (d + 1).div_ceil(chunk_for_depth(d)).saturating_sub(1).max(1)
}

/// Producer endpoint: a local chunk buffer in front of the channel, plus
/// the recycle lane returning spent chunk allocations from the consumer.
struct PipeTx {
    tx: SyncSender<Vec<u64>>,
    recycle: Receiver<Vec<u64>>,
    buf: Vec<u64>,
    chunk: usize,
    /// Declared pipe depth: how many unread tokens a consumer that
    /// exited may leave behind before the overrun is a trace mismatch.
    depth: u64,
    /// Tokens silently discarded because the consumer was gone — a real
    /// FIFO's unread contents at the end of the launch group.
    dropped: u64,
}

impl PipeTx {
    /// A cleared buffer for the next chunk — recycled from the consumer
    /// when one has come back, freshly allocated otherwise.
    fn next_buf(&mut self) -> Vec<u64> {
        match self.recycle.try_recv() {
            Ok(mut v) => {
                v.clear();
                v
            }
            Err(_) => Vec::with_capacity(self.chunk),
        }
    }

    /// Non-blocking flush: `Ok(true)` settled (delivered, nothing
    /// pending, or discarded within the dead-consumer tolerance),
    /// `Ok(false)` channel full (tokens stay buffered), `Err(())` the
    /// consumer is gone *and* more than the declared depth of tokens
    /// went undelivered — a token-trace mismatch, not teardown slack.
    ///
    /// The tolerance keeps the outcome schedule-independent: under the
    /// per-token channels, whether a trailing write to an exiting
    /// consumer returned Ok (delivered, dropped at Receiver teardown) or
    /// PipeClosed raced on thread timing. Here, up to `depth` unread
    /// tokens are always tolerated — what the declared FIFO could have
    /// absorbed — and a larger overrun always errors.
    fn try_flush(&mut self) -> Result<bool, ()> {
        if self.buf.is_empty() {
            return Ok(true);
        }
        let full = std::mem::take(&mut self.buf);
        match self.tx.try_send(full) {
            Ok(()) => {
                self.buf = self.next_buf();
                Ok(true)
            }
            Err(TrySendError::Full(full)) => {
                self.buf = full;
                Ok(false)
            }
            Err(TrySendError::Disconnected(full)) => {
                self.dropped += full.len() as u64;
                if self.dropped > self.depth {
                    Err(())
                } else {
                    Ok(true)
                }
            }
        }
    }

    /// Blocking flush with the same dead-consumer tolerance as
    /// [`PipeTx::try_flush`]. Only safe when this kernel holds no other
    /// pipe's tokens (see `Runner::flush_pipe`'s parking condition).
    fn flush_blocking(&mut self) -> Result<(), ()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let next = self.next_buf();
        let full = std::mem::replace(&mut self.buf, next);
        match self.tx.send(full) {
            Ok(()) => Ok(()),
            Err(std::sync::mpsc::SendError(full)) => {
                self.dropped += full.len() as u64;
                if self.dropped > self.depth {
                    Err(())
                } else {
                    Ok(())
                }
            }
        }
    }
}

/// Consumer endpoint: drains one received chunk at a time and returns the
/// spent allocation to the producer.
struct PipeRx {
    rx: Receiver<Vec<u64>>,
    recycle: Sender<Vec<u64>>,
    buf: Vec<u64>,
    pos: usize,
}

impl PipeRx {
    fn has_buffered(&self) -> bool {
        self.pos < self.buf.len()
    }

    /// Next token, blocking for the next chunk when the local one is
    /// drained. `Err(())` = producer gone with no tokens left.
    fn pop(&mut self) -> Result<u64, ()> {
        loop {
            if self.pos < self.buf.len() {
                let v = self.buf[self.pos];
                self.pos += 1;
                return Ok(v);
            }
            let spent = std::mem::take(&mut self.buf);
            self.pos = 0;
            if spent.capacity() > 0 {
                // producer may already be gone; reuse is best-effort
                let _ = self.recycle.send(spent);
            }
            self.buf = self.rx.recv().map_err(|_| ())?;
        }
    }
}

struct Runner<'k> {
    k: &'k CompiledKernel,
    slots: Vec<Val>,
    senders: Vec<Option<PipeTx>>,
    receivers: Vec<Option<PipeRx>>,
    pipe_tys: Vec<Ty>,
    pipe_names: Vec<String>,
    profile: KernelProfile,
    /// dense per-loop counters, folded into `profile.loops` at the end
    loop_stats: Vec<LoopStats>,
    profiling: bool,
}

impl<'k> Runner<'k> {
    fn closed(&self, pipe: usize) -> ExecError {
        ExecError::PipeClosed {
            kernel: self.k.name.clone(),
            pipe: self.pipe_names[pipe].clone(),
        }
    }

    /// Deliver pipe `p`'s buffered chunk. While the channel is full, every
    /// *other* pending buffer is re-offered on each retry: a peer starving
    /// on a different pipe (conditional sites fire at data-dependent
    /// rates) must always be able to drain tokens this kernel holds, or
    /// the group deadlocks where the per-token channels delivered every
    /// write immediately — and the peer may only *become* ready to drain
    /// them while we are already waiting, so a single pre-park pass is
    /// not enough. Once every other buffer is empty, nothing this kernel
    /// holds can starve anyone, and the wait downgrades to a native
    /// blocking send (zero CPU, immediate wake) instead of the poll loop.
    fn flush_pipe(&mut self, p: usize) -> Result<(), ExecError> {
        let mut spins = 0u32;
        loop {
            match self.senders[p].as_mut() {
                None => return Ok(()),
                Some(tx) => match tx.try_flush() {
                    Ok(true) => return Ok(()),
                    Ok(false) => {}
                    // beyond-depth overrun of a dead pipe
                    Err(()) => return Err(self.closed(p)),
                },
            }
            self.try_flush_all_sends()?;
            let others_empty = self
                .senders
                .iter()
                .enumerate()
                .all(|(q, s)| q == p || s.as_ref().is_none_or(|tx| tx.buf.is_empty()));
            if others_empty {
                // this kernel writes nothing while parked, so the
                // emptiness invariant holds for the whole wait
                let r = self.senders[p].as_mut().unwrap().flush_blocking();
                return match r {
                    Ok(()) => Ok(()),
                    Err(()) => Err(self.closed(p)),
                };
            }
            spins += 1;
            if spins < 64 {
                std::thread::yield_now();
            } else {
                // peers still hold undelivered tokens: poll with backoff
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
        }
    }

    /// Non-blocking delivery of every pending outgoing buffer — called
    /// before parking (on a read, or on another pipe's full channel) so
    /// tokens this kernel owes are visible first. A full channel is fine:
    /// the consumer already has a whole chunk to drain there; a consumer
    /// that exited within its pipe's depth tolerance is fine too (see
    /// [`PipeTx::try_flush`]). Only a beyond-depth overrun errors.
    fn try_flush_all_sends(&mut self) -> Result<(), ExecError> {
        for q in 0..self.senders.len() {
            let over = match self.senders[q].as_mut() {
                Some(tx) => tx.try_flush().is_err(),
                None => false,
            };
            if over {
                return Err(self.closed(q));
            }
        }
        Ok(())
    }

    /// End-of-kernel drain of every buffered partial chunk. Deadlock-free
    /// by the same argument as [`Runner::flush_pipe`] (which it reuses);
    /// dead consumers are tolerated up to their pipes' declared depths
    /// and error beyond (token-trace mismatch).
    fn flush_all_sends(&mut self) -> Result<(), ExecError> {
        for p in 0..self.senders.len() {
            self.flush_pipe(p)?;
        }
        Ok(())
    }

    #[inline]
    fn eval(&mut self, e: EId) -> Result<Val, ExecError> {
        Ok(match self.k.exprs[e as usize] {
            RExpr::Const(v) => v,
            RExpr::Var(s) => self.slots[s as usize],
            RExpr::Load { buf, site, idx } => {
                let i = self.eval(idx)?.as_i();
                let b = &self.k.bufs[buf as usize];
                if i < 0 || i as usize >= b.len() {
                    return Err(ExecError::OutOfBounds {
                        kernel: self.k.name.clone(),
                        buf: self.k.buf_names[buf as usize].clone(),
                        idx: i,
                        len: b.len(),
                    });
                }
                if self.profiling {
                    self.profile.sites[site as usize].record(i);
                }
                b.get(i as usize)
            }
            RExpr::Bin(op, a, b) => {
                let x = self.eval(a)?;
                let y = self.eval(b)?;
                Expr::eval_bin(op, x, y)
            }
            RExpr::Un(op, a) => Expr::eval_un(op, self.eval(a)?),
            RExpr::Select(c, t, f) => {
                if self.eval(c)?.is_true() {
                    self.eval(t)?
                } else {
                    self.eval(f)?
                }
            }
        })
    }

    fn exec(&mut self, body: &[RStmt]) -> Result<(), ExecError> {
        for s in body {
            match s {
                RStmt::Set { slot, expr } => {
                    let v = self.eval(*expr)?;
                    self.slots[*slot as usize] = v;
                }
                RStmt::Store { buf, site, idx, val } => {
                    let i = self.eval(*idx)?.as_i();
                    let v = self.eval(*val)?;
                    let b = &self.k.bufs[*buf as usize];
                    if i < 0 || i as usize >= b.len() {
                        return Err(ExecError::OutOfBounds {
                            kernel: self.k.name.clone(),
                            buf: self.k.buf_names[*buf as usize].clone(),
                            idx: i,
                            len: b.len(),
                        });
                    }
                    // Match the buffer's element type (int stores into a
                    // float buffer keep C semantics via conversion).
                    let v = match b.ty {
                        Ty::I32 => Val::I(v.as_i()),
                        Ty::F32 => Val::F(v.as_f()),
                    };
                    if self.profiling {
                        self.profile.sites[*site as usize].record(i);
                    }
                    b.set(i as usize, v);
                }
                RStmt::If { cond, then_b, else_b } => {
                    if self.eval(*cond)?.is_true() {
                        self.exec(then_b)?;
                    } else {
                        self.exec(else_b)?;
                    }
                }
                RStmt::For { lix, slot, lo, hi, body } => {
                    let lo = self.eval(*lo)?.as_i();
                    let hi = self.eval(*hi)?.as_i();
                    if self.profiling {
                        let e = &mut self.loop_stats[*lix as usize];
                        e.invocations += 1;
                        e.iters += (hi - lo).max(0) as u64;
                    }
                    let mut i = lo;
                    while i < hi {
                        self.slots[*slot as usize] = Val::I(i);
                        self.exec(body)?;
                        i += 1;
                    }
                }
                RStmt::PipeWrite { pipe, val } => {
                    let v = self.eval(*val)?;
                    self.profile.pipe_writes += 1;
                    let p = *pipe as usize;
                    let tx = self.senders[p]
                        .as_mut()
                        .expect("kernel writes undeclared pipe endpoint");
                    tx.buf.push(v.to_bits());
                    if tx.buf.len() >= tx.chunk {
                        self.flush_pipe(p)?;
                    }
                }
                RStmt::PipeRead { slot, pipe } => {
                    let p = *pipe as usize;
                    let buffered = self.receivers[p]
                        .as_ref()
                        .expect("kernel reads undeclared pipe endpoint")
                        .has_buffered();
                    if !buffered {
                        // about to park on an empty pipe: deliver whatever
                        // this kernel still owes its own consumers first
                        self.try_flush_all_sends()?;
                    }
                    let popped = self.receivers[p].as_mut().unwrap().pop();
                    let bits = match popped {
                        Ok(b) => b,
                        Err(()) => return Err(self.closed(p)),
                    };
                    self.profile.pipe_reads += 1;
                    self.slots[*slot as usize] = Val::from_bits(self.pipe_tys[p], bits);
                }
            }
        }
        Ok(())
    }
}

/// Options for a launch.
#[derive(Debug, Clone)]
pub struct ExecOptions {
    /// Collect site/loop profiles (small constant per-op cost).
    pub profile: bool,
    /// Per-token pipe transport with channel capacity exactly the
    /// declared depth — the historical semantics. Chunked transfers let a
    /// producer run up to `~2 * depth` tokens ahead, which is fine when
    /// the functional trace is interleaving-independent but widens the
    /// race window of depth-*sensitive* programs (NW's split is only
    /// valid while the memory kernel stays under a row's width ahead).
    /// `Harness::launch` sets this automatically from
    /// `unit_depth_invariant` / the workload's benign-races vouch; it
    /// defaults to false (chunked) for race-free standalone use.
    pub exact_pipes: bool,
    /// Launch-graph overlap mode: the coordinator models the workload's
    /// launch *graph* (wavefronts of DAG-unordered launches co-scheduled
    /// through `sim::des::simulate_graph`) instead of summing launches
    /// one at a time. Functional interpretation is unaffected — launches
    /// still execute in host order; only the *modelled* time changes.
    /// Part of the engine's content address (`overlap=on` key line); off
    /// by default so every historical key and cycle count is untouched.
    pub overlap: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { profile: true, exact_pipes: false, overlap: false }
    }
}

/// Result of one launch group (all kernels ran to completion).
#[derive(Debug)]
pub struct GroupRun {
    pub profiles: Vec<KernelProfile>,
}

/// Launch every kernel of `prog` concurrently against `image` and wait for
/// completion. This is one host-side `clEnqueue*` + `clFinish` round.
pub fn run_group(prog: &Program, image: &MemoryImage, opts: &ExecOptions) -> Result<GroupRun, ExecError> {
    // Pipe numbering and endpoints.
    let mut pipe_ix = HashMap::new();
    for (i, p) in prog.pipes.iter().enumerate() {
        pipe_ix.insert(p.name.clone(), i as u32);
    }
    let pipe_tys: Vec<Ty> = prog.pipes.iter().map(|p| p.ty).collect();
    let pipe_names: Vec<String> = prog.pipes.iter().map(|p| p.name.clone()).collect();

    let compiled: Vec<CompiledKernel> = prog
        .kernels
        .iter()
        .map(|k| compile_kernel(k, image, &pipe_ix))
        .collect::<Result<_, _>>()?;

    // Create channels; hand endpoints to the right kernels. One chunk in
    // flight per pipe; the chunk size carries the depth bound.
    let mut senders: Vec<Vec<Option<PipeTx>>> = (0..prog.kernels.len())
        .map(|_| (0..prog.pipes.len()).map(|_| None).collect())
        .collect();
    let mut receivers: Vec<Vec<Option<PipeRx>>> = (0..prog.kernels.len())
        .map(|_| (0..prog.pipes.len()).map(|_| None).collect())
        .collect();
    for (pi, pd) in prog.pipes.iter().enumerate() {
        // exact mode: one token per chunk, capacity = declared depth —
        // bit-for-bit the old sync_channel(depth) producer lead
        let (chunk, slots) = if opts.exact_pipes {
            (1, pd.depth.max(1))
        } else {
            (chunk_for_depth(pd.depth), chunks_in_flight(pd.depth))
        };
        let (ctx, crx) = sync_channel::<Vec<u64>>(slots);
        let (rtx, rrx) = channel::<Vec<u64>>();
        let mut tx = Some(PipeTx {
            tx: ctx,
            recycle: rrx,
            buf: Vec::with_capacity(chunk),
            chunk,
            depth: pd.depth.max(1) as u64,
            dropped: 0,
        });
        let mut rx = Some(PipeRx { rx: crx, recycle: rtx, buf: vec![], pos: 0 });
        for (ki, k) in prog.kernels.iter().enumerate() {
            crate::ir::stmt::visit_body(&k.body, &mut |s| match s {
                Stmt::PipeWrite { pipe, .. } if pipe == &pd.name => {
                    if let Some(t) = tx.take() {
                        senders[ki][pi] = Some(t);
                    }
                }
                Stmt::PipeRead { pipe, .. } if pipe == &pd.name => {
                    if let Some(r) = rx.take() {
                        receivers[ki][pi] = Some(r);
                    }
                }
                _ => {}
            });
        }
    }

    let n = compiled.len();
    let mut results: Vec<Result<KernelProfile, ExecError>> =
        (0..n).map(|_| Err(ExecError::Panic { kernel: String::new() })).collect();

    std::thread::scope(|scope| {
        let mut handles = vec![];
        for ((ck, sends), recvs) in compiled.iter().zip(senders).zip(receivers) {
            let profiling = opts.profile;
            let pipe_tys = pipe_tys.clone();
            let pipe_names = pipe_names.clone();
            handles.push(scope.spawn(move || {
                let start = std::time::Instant::now();
                let mut r = Runner {
                    k: ck,
                    slots: vec![Val::I(0); ck.nslots as usize],
                    senders: sends,
                    receivers: recvs,
                    pipe_tys,
                    pipe_names,
                    profile: KernelProfile::new(&ck.name, ck.n_sites as usize),
                    loop_stats: vec![LoopStats::default(); ck.loop_ids.len()],
                    profiling,
                };
                // drain partial chunks before the endpoints drop; on an
                // error, still deliver what was written where there is
                // room (per-token channels delivered every write), but
                // never block a failing kernel
                let mut out = r.exec(&ck.body);
                if out.is_ok() {
                    out = r.flush_all_sends();
                } else {
                    let _ = r.try_flush_all_sends();
                }
                // fold dense counters back into the LoopId-keyed profile
                for (lix, st) in r.loop_stats.iter().enumerate() {
                    if st.invocations > 0 {
                        let e = r.profile.loops.entry(ck.loop_ids[lix]).or_default();
                        e.invocations += st.invocations;
                        e.iters += st.iters;
                    }
                }
                r.profile.host_nanos = start.elapsed().as_nanos() as u64;
                out.map(|_| r.profile)
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            results[i] = match h.join() {
                Ok(res) => res,
                Err(_) => Err(ExecError::Panic { kernel: compiled[i].name.clone() }),
            };
        }
    });

    let mut profiles = vec![];
    for r in results {
        profiles.push(r?);
    }
    Ok(GroupRun { profiles })
}

/// Global counter of interpreted launches (used by benches/EXPERIMENTS).
pub static LAUNCHES: AtomicU64 = AtomicU64::new(0);

/// `run_group` + launch accounting.
pub fn launch(prog: &Program, image: &MemoryImage, opts: &ExecOptions) -> Result<GroupRun, ExecError> {
    LAUNCHES.fetch_add(1, Ordering::Relaxed);
    run_group(prog, image, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{PipeDecl, Program};
    use crate::transform::examples::fig2_kernel;

    fn saxpy() -> Kernel {
        KernelBuilder::new("saxpy", KernelKind::SingleWorkItem)
            .buf_ro("x", Ty::F32)
            .buf_ro("y", Ty::F32)
            .buf_wo("out", Ty::F32)
            .scalar("n", Ty::I32)
            .scalar("a", Ty::F32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("out", v("i"), p("a") * ld("x", v("i")) + ld("y", v("i")))],
            )])
            .finish()
    }

    fn saxpy_image(n: usize) -> MemoryImage {
        let mut m = MemoryImage::new();
        let xs: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let ys: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5).collect();
        m.add_f32s("x", &xs).add_f32s("y", &ys).add_zeros("out", Ty::F32, n);
        m.set_i("n", n as i64).set_f("a", 2.0);
        m
    }

    #[test]
    fn saxpy_single_kernel() {
        let img = saxpy_image(100);
        let prog = Program::single(saxpy());
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let out = img.buf("out").unwrap().to_f32s();
        for (i, o) in out.iter().enumerate() {
            assert_eq!(*o, 2.0 * i as f32 + 0.5 * i as f32);
        }
        // profile: 1 loop with 100 iters, 3 sites (2 loads + 1 store)
        let p = &run.profiles[0];
        assert_eq!(p.loop_stats(LoopId(0)).iters, 100);
        assert_eq!(p.sites.len(), 3);
        assert_eq!(p.sites[0].count, 100);
        assert!(p.sites[0].seq_frac() > 0.98);
    }

    #[test]
    fn feedforward_pair_produces_same_result() {
        let base = saxpy();
        let img1 = saxpy_image(256);
        let img2 = saxpy_image(256);
        run_group(&Program::single(base.clone()), &img1, &ExecOptions::default()).unwrap();
        let ff = crate::transform::feedforward(&base, 4).unwrap();
        let run = run_group(&ff, &img2, &ExecOptions::default()).unwrap();
        assert_eq!(img1.buf("out").unwrap().to_f32s(), img2.buf("out").unwrap().to_f32s());
        // token conservation
        let wr: u64 = run.profiles.iter().map(|p| p.pipe_writes).sum();
        let rd: u64 = run.profiles.iter().map(|p| p.pipe_reads).sum();
        assert_eq!(wr, rd);
        assert_eq!(wr, 512); // 2 loads x 256 iters
    }

    #[test]
    fn fig2_all_variants_agree() {
        use crate::transform::{apply_variant, Variant};
        // small CSR graph
        let row = vec![0i64, 2, 4, 5, 7];
        let col = vec![1i64, 2, 0, 3, 0, 1, 2];
        let car = vec![-1i64, -1, 3, -1];
        let nv = vec![0.3f32, 0.1, 0.9, 0.7];
        let image = || {
            let mut m = MemoryImage::new();
            m.add_i64s("row", &row)
                .add_i64s("col", &col)
                .add_i64s("c_array", &car)
                .add_f32s("node_value", &nv)
                .add_zeros("min_array", Ty::F32, 4)
                .add_zeros("stop", Ty::I32, 1);
            m.set_i("num_nodes", 4).set_i("num_edges", 7);
            m
        };
        let base_img = image();
        run_group(
            &Program::single(fig2_kernel()),
            &base_img,
            &ExecOptions::default(),
        )
        .unwrap();
        let want = base_img.buf("min_array").unwrap().to_f32s();
        assert_eq!(base_img.buf("stop").unwrap().get(0), Val::I(1));

        for variant in [
            Variant::FeedForward { depth: 1 },
            Variant::FeedForward { depth: 100 },
            Variant::MxCx { parts: 2, depth: 1 },
            Variant::M1Cx { consumers: 2, depth: 1 },
        ] {
            let prog = apply_variant(&fig2_kernel(), variant).unwrap();
            let img = image();
            run_group(&prog, &img, &ExecOptions::default()).unwrap();
            assert_eq!(
                img.buf("min_array").unwrap().to_f32s(),
                want,
                "variant {variant:?}"
            );
        }
    }

    /// Chunked transfers must still admit at least the declared depth of
    /// written-but-unread tokens (producer buffer + in-flight chunks) for
    /// *every* depth — deeper pipes than the chunk cap get more chunk
    /// slots — and depth 1 must stay per-token exact.
    #[test]
    fn chunk_sizes_honor_declared_minimum_depth() {
        assert_eq!(chunk_for_depth(0), 1); // depth 0 normalizes to 1
        assert_eq!(chunk_for_depth(1), 1);
        assert_eq!(chunk_for_depth(2), 1);
        assert_eq!(chunk_for_depth(3), 2);
        assert_eq!(chunk_for_depth(100), 50);
        assert_eq!(chunk_for_depth(1000), 500);
        assert_eq!(chunk_for_depth(1_000_000), 1024, "chunks are memory-capped");
        assert_eq!(chunks_in_flight(1), 1);
        assert_eq!(chunks_in_flight(2048), 2);
        assert_eq!(chunks_in_flight(4096), 4, "deep pipes scale slots, not chunk size");
        for d in (1..=4096usize).chain([10_000, 1_000_000]) {
            let (chunk, cap) = (chunk_for_depth(d), chunks_in_flight(d));
            // completable writes with zero consumer progress: cap chunks
            // delivered + chunk-1 buffered below the flush threshold
            assert!(
                cap * chunk + chunk - 1 >= d,
                "depth {d}: chunk {chunk} x {cap} slots completes fewer than depth writes"
            );
        }
    }

    /// Exact mode (per-token, capacity = declared depth — what
    /// depth-sensitive launch units run under) must produce the same
    /// results and the same profiles as the chunked transport on a
    /// race-free program.
    #[test]
    fn exact_pipes_mode_matches_chunked_results() {
        let base = saxpy();
        let img1 = saxpy_image(300);
        let img2 = saxpy_image(300);
        let ff = crate::transform::feedforward(&base, 100).unwrap();
        let exact = ExecOptions { exact_pipes: true, ..ExecOptions::default() };
        let r1 = run_group(&ff, &img1, &exact).unwrap();
        let r2 = run_group(&ff, &img2, &ExecOptions::default()).unwrap();
        assert_eq!(img1.buf("out").unwrap().to_f32s(), img2.buf("out").unwrap().to_f32s());
        for (a, b) in r1.profiles.iter().zip(&r2.profiles) {
            let (mut a, mut b) = (a.clone(), b.clone());
            a.host_nanos = 0;
            b.host_nanos = 0;
            assert_eq!(a, b, "profiles must not depend on the transport mode");
        }
    }

    /// Deep pipes exercise multi-chunk streaming plus the end-of-kernel
    /// partial-chunk drain; the functional result must match depth 1.
    #[test]
    fn deep_pipes_stream_in_chunks_and_drain_partials() {
        let base = saxpy();
        let img1 = saxpy_image(777); // odd size: final chunk is partial
        let img2 = saxpy_image(777);
        let ff1 = crate::transform::feedforward(&base, 1).unwrap();
        let ff1000 = crate::transform::feedforward(&base, 1000).unwrap();
        run_group(&ff1, &img1, &ExecOptions::default()).unwrap();
        let run = run_group(&ff1000, &img2, &ExecOptions::default()).unwrap();
        assert_eq!(img1.buf("out").unwrap().to_f32s(), img2.buf("out").unwrap().to_f32s());
        let wr: u64 = run.profiles.iter().map(|p| p.pipe_writes).sum();
        assert_eq!(wr, 2 * 777, "chunking must not change token counts");
    }

    #[test]
    fn oob_reports_kernel_and_buffer() {
        let k = KernelBuilder::new("bad", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_("i", i(0), p("n"), vec![store("o", v("i"), ld("a", v("i") + i(1)))])])
            .finish();
        let mut img = MemoryImage::new();
        img.add_f32s("a", &[1.0, 2.0]).add_zeros("o", Ty::F32, 2).set_i("n", 2);
        let err = run_group(&Program::single(k), &img, &ExecOptions::default()).unwrap_err();
        assert!(matches!(err, ExecError::OutOfBounds { ref buf, idx: 2, .. } if buf == "a"));
    }

    #[test]
    fn site_numbering_matches_analysis() {
        let k = saxpy();
        let sites = crate::analysis::select_lsus(&k);
        let img = saxpy_image(8);
        let prog = Program::single(k);
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        assert_eq!(run.profiles[0].sites.len(), sites.len());
    }
}
