//! Execution profiles collected by the functional interpreter and consumed
//! by the performance models (trace-driven simulation with online
//! summarization, so memory stays bounded on multi-million-iteration runs).

use crate::ir::LoopId;
use crate::util::json::Json;
use std::collections::HashMap;

/// Address-stream summary for one static memory site. Site ids share the
/// pre-order numbering of `analysis::lsu::select_lsus`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SiteStats {
    /// Dynamic access count.
    pub count: u64,
    /// Accesses whose address was `last + 1` (sequential continuation).
    pub seq: u64,
    /// Accesses that repeated the previous address exactly.
    pub same: u64,
    /// Accesses that touched a different 64-byte line than the previous
    /// access from this site (an upper bound on DRAM bursts issued).
    pub lines: u64,
    last_addr: i64,
    started: bool,
}

impl SiteStats {
    #[inline]
    pub fn record(&mut self, addr: i64) {
        if self.started {
            if addr == self.last_addr + 1 {
                self.seq += 1;
            } else if addr == self.last_addr {
                self.same += 1;
            }
            if (addr >> 4) != (self.last_addr >> 4) {
                self.lines += 1;
            }
        } else {
            self.started = true;
            self.lines = 1;
        }
        self.last_addr = addr;
        self.count += 1;
    }

    /// Fraction of accesses that continued a sequential run.
    pub fn seq_frac(&self) -> f64 {
        if self.count <= 1 {
            return 1.0;
        }
        (self.seq + self.same) as f64 / (self.count - 1) as f64
    }

    pub fn merge(&mut self, other: &SiteStats) {
        self.count += other.count;
        self.seq += other.seq;
        self.same += other.same;
        self.lines += other.lines;
    }

    /// Compact array form for the persisted trace tier:
    /// `[count, seq, same, lines, last_addr, started]`. All six fields are
    /// kept (including the run-state pair) so a deserialized profile is
    /// bit-equal to the live one — the replay/cold byte-identity proof in
    /// `tests/integration_store.rs` depends on it.
    pub fn to_json(&self) -> Json {
        Json::Arr(vec![
            Json::Num(self.count as f64),
            Json::Num(self.seq as f64),
            Json::Num(self.same as f64),
            Json::Num(self.lines as f64),
            Json::Num(self.last_addr as f64),
            Json::Num(if self.started { 1.0 } else { 0.0 }),
        ])
    }

    /// Inverse of [`SiteStats::to_json`]; malformed input is `None` —
    /// including magnitudes past 2^53, where `f64` rounds integers
    /// silently: such a record cannot be trusted to roundtrip bit-equal,
    /// so it must read as corruption (a trace-tier miss), never as a
    /// slightly-wrong profile.
    pub fn from_json(v: &Json) -> Option<SiteStats> {
        let a = v.as_array()?;
        if a.len() != 6 {
            return None;
        }
        let u = |i: usize| -> Option<u64> {
            let n = a[i].as_f64()?;
            (n >= 0.0 && n.fract() == 0.0 && n < MAX_SAFE_COUNT).then_some(n as u64)
        };
        Some(SiteStats {
            count: u(0)?,
            seq: u(1)?,
            same: u(2)?,
            lines: u(3)?,
            last_addr: {
                let n = a[4].as_f64()?;
                (n.fract() == 0.0 && n.abs() < MAX_SAFE_COUNT).then_some(n as i64)?
            },
            started: u(5)? != 0,
        })
    }
}

/// 2^53. Counters and addresses at or above it cannot be trusted to have
/// survived the `f64` JSON number encoding bit-equal (2^53 + 1 rounds to
/// 2^53 itself, so the boundary value is ambiguous too — hence the
/// *strict* comparisons), and the deserializers reject them as corrupt.
const MAX_SAFE_COUNT: f64 = 9_007_199_254_740_992.0;

/// Per-static-loop dynamic counts.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LoopStats {
    /// Number of times the loop was entered.
    pub invocations: u64,
    /// Total iterations across invocations.
    pub iters: u64,
}

/// The full profile of one kernel execution (one launch).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KernelProfile {
    pub kernel: String,
    pub loops: HashMap<LoopId, LoopStats>,
    /// Indexed by site id (shared load/store numbering).
    pub sites: Vec<SiteStats>,
    pub pipe_writes: u64,
    pub pipe_reads: u64,
    /// Wall-clock of the functional interpretation (for the §Perf log, not
    /// part of the modelled FPGA time).
    pub host_nanos: u64,
}

impl KernelProfile {
    pub fn new(kernel: &str, n_sites: usize) -> KernelProfile {
        KernelProfile {
            kernel: kernel.to_string(),
            sites: vec![SiteStats::default(); n_sites],
            ..Default::default()
        }
    }

    pub fn loop_stats(&self, id: LoopId) -> LoopStats {
        self.loops.get(&id).copied().unwrap_or_default()
    }

    pub fn total_bytes(&self) -> u64 {
        self.sites.iter().map(|s| s.count * 4).sum()
    }

    /// Merge a same-shape profile (accumulating across host launches).
    pub fn merge(&mut self, other: &KernelProfile) {
        debug_assert_eq!(self.sites.len(), other.sites.len());
        for (a, b) in self.sites.iter_mut().zip(&other.sites) {
            a.merge(b);
        }
        for (id, ls) in &other.loops {
            let e = self.loops.entry(*id).or_default();
            e.invocations += ls.invocations;
            e.iters += ls.iters;
        }
        self.pipe_writes += other.pipe_writes;
        self.pipe_reads += other.pipe_reads;
        self.host_nanos += other.host_nanos;
    }

    /// Serialize for the persistent trace tier (`coordinator::store`).
    /// Loops are written sorted by `LoopId` so the document is canonical;
    /// `host_nanos` is deliberately *not* persisted — it is wall clock of
    /// the recording host, not part of the modelled trace, and keeping it
    /// out makes trace files deterministic across machines.
    pub fn to_json(&self) -> Json {
        let mut loops: Vec<(LoopId, LoopStats)> =
            self.loops.iter().map(|(id, ls)| (*id, *ls)).collect();
        loops.sort_by_key(|(id, _)| id.0);
        Json::Obj(vec![
            ("kernel".into(), Json::Str(self.kernel.clone())),
            ("pipe_writes".into(), Json::Num(self.pipe_writes as f64)),
            ("pipe_reads".into(), Json::Num(self.pipe_reads as f64)),
            (
                "loops".into(),
                Json::Arr(
                    loops
                        .iter()
                        .map(|(id, ls)| {
                            Json::Arr(vec![
                                Json::Num(f64::from(id.0)),
                                Json::Num(ls.invocations as f64),
                                Json::Num(ls.iters as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("sites".into(), Json::Arr(self.sites.iter().map(SiteStats::to_json).collect())),
        ])
    }

    /// The canonical compact byte form of this profile — what the
    /// persistent profile pool (`coordinator::store`) hashes and writes.
    /// One distinct profile ⇒ one byte string ⇒ one pool key: the
    /// content address of a pooled profile is FNV-1a over exactly these
    /// bytes, and the pool reader verifies a loaded file re-serializes to
    /// the hash it was filed under. `host_nanos` is not serialized (see
    /// [`KernelProfile::to_json`]), so wall clock never splits the pool.
    pub fn canonical_compact(&self) -> String {
        self.to_json().to_compact()
    }

    /// Inverse of [`KernelProfile::to_json`] (`host_nanos` reads as 0).
    pub fn from_json(v: &Json) -> Option<KernelProfile> {
        let ctr = |n: &f64| *n >= 0.0 && n.fract() == 0.0 && *n < MAX_SAFE_COUNT;
        let mut loops = HashMap::new();
        for l in v.get("loops")?.as_array()? {
            let a = l.as_array()?;
            if a.len() != 3 {
                return None;
            }
            let id = LoopId(a[0].as_f64().filter(|n| ctr(n) && *n <= f64::from(u32::MAX))? as u32);
            loops.insert(
                id,
                LoopStats {
                    invocations: a[1].as_f64().filter(ctr)? as u64,
                    iters: a[2].as_f64().filter(ctr)? as u64,
                },
            );
        }
        Some(KernelProfile {
            kernel: v.get("kernel")?.as_str()?.to_string(),
            loops,
            sites: v
                .get("sites")?
                .as_array()?
                .iter()
                .map(SiteStats::from_json)
                .collect::<Option<Vec<_>>>()?,
            pipe_writes: v.get("pipe_writes")?.as_f64().filter(ctr)? as u64,
            pipe_reads: v.get("pipe_reads")?.as_f64().filter(ctr)? as u64,
            host_nanos: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_has_high_seq_frac() {
        let mut s = SiteStats::default();
        for a in 0..1000 {
            s.record(a);
        }
        assert!(s.seq_frac() > 0.99);
        assert_eq!(s.count, 1000);
        // 1000 words over 16-word lines: ~63 line transitions
        assert!(s.lines >= 62 && s.lines <= 64, "lines={}", s.lines);
    }

    #[test]
    fn random_stream_has_low_seq_frac() {
        let mut s = SiteStats::default();
        let mut x: i64 = 12345;
        for _ in 0..1000 {
            x = (x.wrapping_mul(6364136223846793005).wrapping_add(144115188075855872)) % 100_000;
            s.record(x.abs());
        }
        assert!(s.seq_frac() < 0.05, "seq_frac={}", s.seq_frac());
    }

    /// Trace-tier roundtrip: every field the performance models read
    /// (counts, sequentiality, loop trips, pipe ops) must survive JSON —
    /// including the SiteStats run-state pair, so a replayed profile is
    /// `==` the recorded one. `host_nanos` is wall clock and reads as 0.
    #[test]
    fn profile_json_roundtrips_exactly() {
        let mut p = KernelProfile::new("k_mem", 2);
        for a in [0i64, 1, 2, 2, 9] {
            p.sites[0].record(a);
        }
        p.sites[1].record(-3);
        p.loops.insert(LoopId(0), LoopStats { invocations: 1, iters: 5 });
        p.loops.insert(LoopId(2), LoopStats { invocations: 5, iters: 40 });
        p.pipe_writes = 10;
        p.pipe_reads = 0;
        p.host_nanos = 0; // recorded traces zero this before serializing
        let text = p.to_json().to_pretty();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(KernelProfile::from_json(&parsed), Some(p.clone()));
        // canonical bytes: re-serializing the roundtripped profile is stable
        assert_eq!(KernelProfile::from_json(&parsed).unwrap().to_json().to_pretty(), text);
        // seq_frac (what the model consumes) survives
        let q = KernelProfile::from_json(&parsed).unwrap();
        assert_eq!(q.sites[0].seq_frac(), p.sites[0].seq_frac());
    }

    #[test]
    fn malformed_profile_json_is_rejected_not_panicking() {
        for text in [
            "{}",
            r#"{"kernel": "k", "pipe_writes": 1.5, "pipe_reads": 0, "loops": [], "sites": []}"#,
            r#"{"kernel": "k", "pipe_writes": 1, "pipe_reads": 0, "loops": [[0, 1]], "sites": []}"#,
            r#"{"kernel": "k", "pipe_writes": 1, "pipe_reads": 0, "loops": [], "sites": [[1, 0, 0]]}"#,
        ] {
            let doc = crate::util::json::parse(text).unwrap();
            assert_eq!(KernelProfile::from_json(&doc), None, "accepted: {text}");
        }
    }

    /// The profile pool's content-address contract: canonical bytes are
    /// stable across a JSON roundtrip (same bytes ⇒ same FNV ⇒ same pool
    /// file), and `host_nanos` never perturbs them (wall clock must not
    /// split the pool).
    #[test]
    fn canonical_compact_is_roundtrip_stable_and_clock_free() {
        let mut p = KernelProfile::new("k_mem", 2);
        for a in [0i64, 1, 5, 5] {
            p.sites[0].record(a);
        }
        p.loops.insert(LoopId(1), LoopStats { invocations: 2, iters: 9 });
        p.pipe_writes = 4;
        let bytes = p.canonical_compact();
        let parsed = crate::util::json::parse(&bytes).unwrap();
        let rt = KernelProfile::from_json(&parsed).unwrap();
        assert_eq!(rt.canonical_compact(), bytes);
        let mut clocked = p.clone();
        clocked.host_nanos = 123_456;
        assert_eq!(clocked.canonical_compact(), bytes, "host_nanos must not split the pool");
    }

    #[test]
    fn merge_accumulates() {
        let mut a = SiteStats::default();
        let mut b = SiteStats::default();
        for i in 0..10 {
            a.record(i);
            b.record(i);
        }
        a.merge(&b);
        assert_eq!(a.count, 20);
    }
}
