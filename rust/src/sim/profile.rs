//! Execution profiles collected by the functional interpreter and consumed
//! by the performance models (trace-driven simulation with online
//! summarization, so memory stays bounded on multi-million-iteration runs).

use crate::ir::LoopId;
use std::collections::HashMap;

/// Address-stream summary for one static memory site. Site ids share the
/// pre-order numbering of `analysis::lsu::select_lsus`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SiteStats {
    /// Dynamic access count.
    pub count: u64,
    /// Accesses whose address was `last + 1` (sequential continuation).
    pub seq: u64,
    /// Accesses that repeated the previous address exactly.
    pub same: u64,
    /// Accesses that touched a different 64-byte line than the previous
    /// access from this site (an upper bound on DRAM bursts issued).
    pub lines: u64,
    last_addr: i64,
    started: bool,
}

impl SiteStats {
    #[inline]
    pub fn record(&mut self, addr: i64) {
        if self.started {
            if addr == self.last_addr + 1 {
                self.seq += 1;
            } else if addr == self.last_addr {
                self.same += 1;
            }
            if (addr >> 4) != (self.last_addr >> 4) {
                self.lines += 1;
            }
        } else {
            self.started = true;
            self.lines = 1;
        }
        self.last_addr = addr;
        self.count += 1;
    }

    /// Fraction of accesses that continued a sequential run.
    pub fn seq_frac(&self) -> f64 {
        if self.count <= 1 {
            return 1.0;
        }
        (self.seq + self.same) as f64 / (self.count - 1) as f64
    }

    pub fn merge(&mut self, other: &SiteStats) {
        self.count += other.count;
        self.seq += other.seq;
        self.same += other.same;
        self.lines += other.lines;
    }
}

/// Per-static-loop dynamic counts.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LoopStats {
    /// Number of times the loop was entered.
    pub invocations: u64,
    /// Total iterations across invocations.
    pub iters: u64,
}

/// The full profile of one kernel execution (one launch).
#[derive(Debug, Clone, Default)]
pub struct KernelProfile {
    pub kernel: String,
    pub loops: HashMap<LoopId, LoopStats>,
    /// Indexed by site id (shared load/store numbering).
    pub sites: Vec<SiteStats>,
    pub pipe_writes: u64,
    pub pipe_reads: u64,
    /// Wall-clock of the functional interpretation (for the §Perf log, not
    /// part of the modelled FPGA time).
    pub host_nanos: u64,
}

impl KernelProfile {
    pub fn new(kernel: &str, n_sites: usize) -> KernelProfile {
        KernelProfile {
            kernel: kernel.to_string(),
            sites: vec![SiteStats::default(); n_sites],
            ..Default::default()
        }
    }

    pub fn loop_stats(&self, id: LoopId) -> LoopStats {
        self.loops.get(&id).copied().unwrap_or_default()
    }

    pub fn total_bytes(&self) -> u64 {
        self.sites.iter().map(|s| s.count * 4).sum()
    }

    /// Merge a same-shape profile (accumulating across host launches).
    pub fn merge(&mut self, other: &KernelProfile) {
        debug_assert_eq!(self.sites.len(), other.sites.len());
        for (a, b) in self.sites.iter_mut().zip(&other.sites) {
            a.merge(b);
        }
        for (id, ls) in &other.loops {
            let e = self.loops.entry(*id).or_default();
            e.invocations += ls.invocations;
            e.iters += ls.iters;
        }
        self.pipe_writes += other.pipe_writes;
        self.pipe_reads += other.pipe_reads;
        self.host_nanos += other.host_nanos;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_has_high_seq_frac() {
        let mut s = SiteStats::default();
        for a in 0..1000 {
            s.record(a);
        }
        assert!(s.seq_frac() > 0.99);
        assert_eq!(s.count, 1000);
        // 1000 words over 16-word lines: ~63 line transitions
        assert!(s.lines >= 62 && s.lines <= 64, "lines={}", s.lines);
    }

    #[test]
    fn random_stream_has_low_seq_frac() {
        let mut s = SiteStats::default();
        let mut x: i64 = 12345;
        for _ in 0..1000 {
            x = (x.wrapping_mul(6364136223846793005).wrapping_add(144115188075855872)) % 100_000;
            s.record(x.abs());
        }
        assert!(s.seq_frac() < 0.05, "seq_frac={}", s.seq_frac());
    }

    #[test]
    fn merge_accumulates() {
        let mut a = SiteStats::default();
        let mut b = SiteStats::default();
        for i in 0..10 {
            a.record(i);
            b.record(i);
        }
        a.merge(&b);
        assert_eq!(a.count, 20);
    }
}
