//! Table/figure rendering: markdown to stdout, CSV to `results/`.

use std::fmt::Write as _;
use std::path::Path;

/// A rendered table: header + rows of cells.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// GitHub-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let mut w = vec![0usize; self.header.len()];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(line, " {:w$} |", c, w = w[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let mut sep = String::from("|");
        for wi in &w {
            let _ = write!(sep, "{:-<w$}|", "", w = wi + 2);
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write CSV under `results/<name>.csv` (directory created on demand).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format helpers shared by the experiment renderers.
pub fn fx(v: f64) -> String {
    if v >= 100.0 {
        format!("{v:.0}")
    } else if v >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

pub fn ms(seconds: f64) -> String {
    format!("{:.1}", seconds * 1e3)
}

pub fn mbps(bytes_per_s: f64) -> String {
    format!("{:.0}", bytes_per_s / 1e6)
}

/// Signed percent with two decimals from a fraction (regret/regression
/// columns): `pct(0.031)` renders `+3.10`.
pub fn pct(frac: f64) -> String {
    format!("{:+.2}", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_and_csv_agree_on_cells() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| 1 |"));
        let csv = t.to_csv();
        assert!(csv.contains("1,\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fx_ranges() {
        assert_eq!(fx(123.4), "123");
        assert_eq!(fx(13.84), "13.8");
        assert_eq!(fx(0.96), "0.96");
    }

    #[test]
    fn pct_is_signed() {
        assert_eq!(pct(0.031), "+3.10");
        assert_eq!(pct(-0.05), "-5.00");
        assert_eq!(pct(0.0), "+0.00");
    }
}
