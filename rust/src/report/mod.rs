//! Table/figure rendering: markdown to stdout, CSV to `results/`.

use std::fmt::Write as _;
use std::path::Path;

/// A rendered table: header + rows of cells.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// GitHub-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let mut w = vec![0usize; self.header.len()];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(line, " {:w$} |", c, w = w[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let mut sep = String::from("|");
        for wi in &w {
            let _ = write!(sep, "{:-<w$}|", "", w = wi + 2);
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write CSV under `results/<name>.csv` (directory created on demand).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format helpers shared by the experiment renderers.
pub fn fx(v: f64) -> String {
    if v >= 100.0 {
        format!("{v:.0}")
    } else if v >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

pub fn ms(seconds: f64) -> String {
    format!("{:.1}", seconds * 1e3)
}

pub fn mbps(bytes_per_s: f64) -> String {
    format!("{:.0}", bytes_per_s / 1e6)
}

/// Signed percent with two decimals from a fraction (regret/regression
/// columns): `pct(0.031)` renders `+3.10`.
pub fn pct(frac: f64) -> String {
    format!("{:+.2}", frac * 100.0)
}

// ---------------------------------------------------------------------------
// Results-sink rendering + diffing (`pipefwd report`), shared by the
// CLI and the daemon so both produce identical documents.
// ---------------------------------------------------------------------------

use crate::coordinator::experiments::Measurement;
use crate::coordinator::service::counters_fields;
use crate::util::json;

/// The `report --format table` rendering, shared by the file and store
/// paths.
pub fn measurements_table(title: &str, ms_list: &[Measurement]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "Benchmark",
            "Variant",
            "Scale",
            "Time (ms)",
            "Logic (%)",
            "BRAM",
            "Max II",
            "Max BW (MB/s)",
            "Launches",
        ],
    );
    for m in ms_list {
        t.row(vec![
            m.workload.clone(),
            m.variant.clone(),
            m.scale.clone(),
            ms(m.seconds),
            format!("{:.2}", m.logic_pct),
            m.brams.to_string(),
            m.max_ii.to_string(),
            mbps(m.max_bw),
            m.launches.to_string(),
        ]);
    }
    t
}

fn load_measurements(path: &str) -> Result<Vec<Measurement>, String> {
    let doc = json::read_file(Path::new(path))?;
    Ok(doc
        .get("measurements")
        .and_then(|m| m.as_array())
        .ok_or_else(|| format!("{path}: no measurements array"))?
        .iter()
        .filter_map(Measurement::from_json)
        .collect())
}

/// `report --diff`: compare two artifacts and render a markdown report.
/// Returns `(rendered, gate_failures)`.
///
/// Two results sinks are compared configuration by configuration: gate
/// failures are modelled-performance regressions above `threshold`
/// percent plus configurations that vanished (silent loss of coverage).
/// Two counters documents — any mix of `pipefwd-counters-v1`, `-v2`,
/// and `-v3` — diff field by field informationally (never a gate
/// failure; fields absent from an older document render as `-`). Mixing
/// the two kinds is an error: the comparison would be meaningless.
pub fn sink_diff(
    old_path: &str,
    new_path: &str,
    threshold: f64,
) -> Result<(String, usize), String> {
    let old_doc = json::read_file(Path::new(old_path))?;
    let new_doc = json::read_file(Path::new(new_path))?;
    match (counters_fields(&old_doc), counters_fields(&new_doc)) {
        (Some(o), Some(n)) => Ok(counters_diff(old_path, new_path, &o, &n)),
        (None, None) => bench_sink_diff(old_path, new_path, threshold),
        _ => Err(format!(
            "cannot diff {old_path} against {new_path}: one is a counters document, \
             the other a results sink"
        )),
    }
}

/// Field-by-field counters comparison (v1, v2, and v3 interchangeably).
fn counters_diff(
    old_path: &str,
    new_path: &str,
    old: &[(&'static str, f64)],
    new: &[(&'static str, f64)],
) -> (String, usize) {
    let old_map: std::collections::HashMap<&str, f64> = old.iter().copied().collect();
    let new_map: std::collections::HashMap<&str, f64> = new.iter().copied().collect();
    let mut t = Table::new(
        &format!("Counters diff: {old_path} vs {new_path}"),
        &["Counter", "Old", "New", "Delta"],
    );
    // canonical field order; the union of both documents
    for k in crate::coordinator::service::COUNTER_FIELDS {
        let (o, n) = (old_map.get(k), new_map.get(k));
        if o.is_none() && n.is_none() {
            continue;
        }
        let show = |v: Option<&f64>| v.map(|x| format!("{x:.0}")).unwrap_or_else(|| "-".into());
        let delta = match (o, n) {
            (Some(o), Some(n)) => format!("{:+.0}", n - o),
            _ => "-".into(),
        };
        t.row(vec![k.to_string(), show(o), show(n), delta]);
    }
    let mut out = t.to_markdown();
    out.push_str("\ncounters diff is informational (never a gate failure)\n");
    (out, 0)
}

/// The results-sink comparison (the original `report --diff` gate).
fn bench_sink_diff(
    old_path: &str,
    new_path: &str,
    threshold: f64,
) -> Result<(String, usize), String> {
    let old = load_measurements(old_path)?;
    let new = load_measurements(new_path)?;
    let mut old_by_key = std::collections::HashMap::new();
    for m in &old {
        old_by_key.insert((m.workload.clone(), m.variant.clone(), m.scale.clone()), m);
    }

    let mut t = Table::new(
        &format!("Modelled-performance diff (threshold {threshold}%)"),
        &["Benchmark", "Variant", "Scale", "Old (ms)", "New (ms)", "Delta (%)", "Status"],
    );
    let mut regressions = 0;
    let mut added = 0;
    for m in &new {
        let key = (m.workload.clone(), m.variant.clone(), m.scale.clone());
        let Some(o) = old_by_key.get(&key) else {
            added += 1;
            continue;
        };
        let delta_pct = if o.seconds > 0.0 {
            (m.seconds / o.seconds - 1.0) * 100.0
        } else if m.seconds > 0.0 {
            f64::INFINITY // 0 -> nonzero: unambiguously slower
        } else {
            0.0
        };
        let status = if delta_pct > threshold {
            regressions += 1;
            "REGRESSION"
        } else if delta_pct < -threshold {
            "improved"
        } else {
            "ok"
        };
        t.row(vec![
            m.workload.clone(),
            m.variant.clone(),
            m.scale.clone(),
            ms(o.seconds),
            ms(m.seconds),
            format!("{delta_pct:+.2}"),
            status.into(),
        ]);
    }
    // configurations that vanished are a gate failure too: a variant that
    // silently stopped producing measurements must not pass as "no
    // regressions"
    let new_keys: std::collections::HashSet<(String, String, String)> = new
        .iter()
        .map(|m| (m.workload.clone(), m.variant.clone(), m.scale.clone()))
        .collect();
    let mut removed = 0;
    for m in &old {
        if !new_keys.contains(&(m.workload.clone(), m.variant.clone(), m.scale.clone())) {
            removed += 1;
            t.row(vec![
                m.workload.clone(),
                m.variant.clone(),
                m.scale.clone(),
                ms(m.seconds),
                "-".into(),
                "-".into(),
                "REMOVED".into(),
            ]);
        }
    }
    let mut out = t.to_markdown();
    out.push_str(&format!(
        "\n{} configuration(s) compared, {regressions} regression(s) > {threshold}%, \
         {added} new, {removed} removed\n",
        t.rows.len() - removed
    ));
    Ok((out, regressions + removed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_and_csv_agree_on_cells() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| 1 |"));
        let csv = t.to_csv();
        assert!(csv.contains("1,\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fx_ranges() {
        assert_eq!(fx(123.4), "123");
        assert_eq!(fx(13.84), "13.8");
        assert_eq!(fx(0.96), "0.96");
    }

    #[test]
    fn pct_is_signed() {
        assert_eq!(pct(0.031), "+3.10");
        assert_eq!(pct(-0.05), "-5.00");
        assert_eq!(pct(0.0), "+0.00");
    }

    fn tmp(name: &str, text: &str) -> String {
        let dir = std::env::temp_dir().join(format!("pipefwd-report-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, text).unwrap();
        p.to_string_lossy().into_owned()
    }

    fn sink(seconds: &[(&str, f64)]) -> String {
        let ms: Vec<String> = seconds
            .iter()
            .map(|(v, s)| {
                format!(
                    r#"{{"workload": "fw", "variant": "{v}", "scale": "tiny",
                         "seconds": {s}, "cycles": 1.0, "logic_pct": 1.0, "max_bw": 1.0,
                         "brams": 1, "max_ii": 1, "launches": 1}}"#
                )
            })
            .collect();
        format!(r#"{{"schema": "pipefwd-bench-v1", "measurements": [{}]}}"#, ms.join(","))
    }

    #[test]
    fn bench_diff_counts_regressions_and_removed_configs() {
        let old = tmp("diff-old.json", &sink(&[("baseline", 1.0), ("ff(d1)", 1.0)]));
        // ff(d1) regresses 50%, baseline vanishes
        let new = tmp("diff-new.json", &sink(&[("ff(d1)", 1.5)]));
        let (rendered, failures) = sink_diff(&old, &new, 5.0).unwrap();
        assert_eq!(failures, 2, "{rendered}");
        assert!(rendered.contains("REGRESSION"));
        assert!(rendered.contains("REMOVED"));
        // identical sinks: clean gate
        let (_, failures) = sink_diff(&old, &old, 5.0).unwrap();
        assert_eq!(failures, 0);
    }

    #[test]
    fn counters_diff_accepts_v1_v2_mix_and_never_gates() {
        let v1 = tmp(
            "counters-v1.json",
            r#"{"schema": "pipefwd-counters-v1", "command": "run", "scale": "tiny",
                "cache_hits": 3, "store_hits": 0, "simulations": 5, "trace_hits": 2,
                "trace_runs": 1, "wall_ms": 10}"#,
        );
        let v2 = tmp(
            "counters-v2.json",
            r#"{"schema": "pipefwd-counters-v2", "command": "run", "scale": "tiny",
                "cache_hits": 4, "store_hits": 0, "simulations": 0, "trace_hits": 2,
                "trace_runs": 0, "queue_depth_max": 3, "clients_served": 7,
                "requests_deduped": 9, "wall_ms": 12}"#,
        );
        let (rendered, failures) = sink_diff(&v1, &v2, 5.0).unwrap();
        assert_eq!(failures, 0);
        assert!(rendered.contains("clients_served"), "{rendered}");
        assert!(rendered.contains('-'), "v1-absent fields render as -");

        // a v3 document (reliability counters) diffs against a v2 one
        // the same way — still informational, never a gate
        let v3 = tmp(
            "counters-v3.json",
            r#"{"schema": "pipefwd-counters-v3", "command": "run", "scale": "tiny",
                "cache_hits": 4, "store_hits": 0, "simulations": 0, "trace_hits": 2,
                "trace_runs": 0, "queue_depth_max": 3, "clients_served": 7,
                "requests_deduped": 9, "connections_reused": 5, "retries": 2,
                "journal_replays": 1, "store_degraded": 0, "wall_ms": 14}"#,
        );
        let (rendered, failures) = sink_diff(&v2, &v3, 5.0).unwrap();
        assert_eq!(failures, 0);
        assert!(rendered.contains("journal_replays"), "{rendered}");
        assert!(rendered.contains("retries"), "{rendered}");

        // mixing a counters doc with a results sink is refused
        let s = tmp("diff-sink.json", &sink(&[("baseline", 1.0)]));
        assert!(sink_diff(&v1, &s, 5.0).is_err());
    }
}
