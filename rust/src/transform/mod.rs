//! The paper's transformation recipe (§3, steps 1-14) as compiler passes.
//!
//! Pipeline, per kernel:
//! 1. [`ndrange::ndrange_to_swi`] if the baseline is NDRange (step 1)
//! 2. [`privatize::privatize`] if a removable true MLCD exists (NW, §4.2)
//! 3. [`feasibility::check_feasible`] (steps 3-4)
//! 4. [`normalize::name_loads`] (step 5)
//! 5. [`feedforward::feedforward`] — split + pipes (steps 6-9) with DCE
//!    and simplification (steps 10-11, 13) applied to both halves
//! 6. [`replicate::replicate`] for multiple producers/consumers (step 12)
//! 7. [`vectorize::vectorize`] for the §4.2 vector-type case study
//!
//! Step 14 (host-side enqueue of all kernels on separate queues) is the
//! execution engine's launch-group mechanism (`sim::exec`).
//!
//! One pass lives above the kernel level: [`task_sequence::task_sequence`]
//! rewrites the *host's launch schedule* (a convergence workload's
//! re-launch chain) into dependence-respecting persistent stages — the
//! launch-graph overlap transform consumed by `run --overlap`.

pub mod dce;
pub mod examples;
pub mod feasibility;
pub mod feedforward;
pub mod ndrange;
pub mod normalize;
pub mod privatize;
pub mod replicate;
pub mod simplify;
pub mod task_sequence;
pub mod vectorize;

pub use dce::dce_kernel;
pub use feasibility::{check_feasible, FeasibilityError};
pub use feedforward::feedforward;
pub use ndrange::ndrange_to_swi;
pub use normalize::name_loads;
pub use privatize::privatize;
pub use replicate::{replicate, replicate_1p};
pub use simplify::simplify_kernel;
pub use task_sequence::{task_sequence, TaskSchedule};
pub use vectorize::vectorize;

use crate::ir::{Kernel, Program};

/// The design variants the experiments compare (Tables 2-3, Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Single work-item baseline (paper's comparison base).
    Baseline,
    /// Feed-forward split, one producer + one consumer, given pipe depth.
    FeedForward { depth: usize },
    /// Feed-forward with R producers and R consumers (R=2 is "M2C2").
    MxCx { parts: usize, depth: usize },
    /// Feed-forward with one shared producer and N consumers (§3, explored
    /// and found inferior).
    M1Cx { consumers: usize, depth: usize },
    /// Feed-forward + vector-type (width-W) case study.
    Vectorized { width: usize, depth: usize },
}

impl Variant {
    pub fn label(&self) -> String {
        match self {
            Variant::Baseline => "baseline".into(),
            Variant::FeedForward { depth } => format!("ff(d{depth})"),
            Variant::MxCx { parts, depth } => format!("m{parts}c{parts}(d{depth})"),
            Variant::M1Cx { consumers, depth } => format!("m1c{consumers}(d{depth})"),
            Variant::Vectorized { width, depth } => format!("ff_v{width}(d{depth})"),
        }
    }
}

/// Apply a variant to a single work-item baseline kernel.
pub fn apply_variant(kernel: &Kernel, variant: Variant) -> Result<Program, FeasibilityError> {
    match variant {
        Variant::Baseline => Ok(Program::single(kernel.clone())),
        Variant::FeedForward { depth } => feedforward(kernel, depth),
        Variant::MxCx { parts, depth } => Ok(replicate(&feedforward(kernel, depth)?, parts)),
        Variant::M1Cx { consumers, depth } => {
            Ok(replicate_1p(&feedforward(kernel, depth)?, consumers))
        }
        Variant::Vectorized { width, depth } => {
            let vk = vectorize(kernel, width);
            feedforward(&vk, depth)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::validate_program;
    use crate::transform::examples::fig2_kernel;

    #[test]
    fn all_variants_build_and_validate() {
        let k = fig2_kernel();
        for variant in [
            Variant::Baseline,
            Variant::FeedForward { depth: 1 },
            Variant::FeedForward { depth: 100 },
            Variant::MxCx { parts: 2, depth: 1 },
            Variant::MxCx { parts: 4, depth: 1 },
            Variant::M1Cx { consumers: 2, depth: 1 },
        ] {
            let prog = apply_variant(&k, variant).unwrap();
            assert_eq!(validate_program(&prog), Ok(()), "variant {variant:?}");
        }
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(Variant::MxCx { parts: 2, depth: 1 }.label(), "m2c2(d1)");
        assert_eq!(Variant::FeedForward { depth: 100 }.label(), "ff(d100)");
    }
}
