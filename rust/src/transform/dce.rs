//! Dead-code elimination — the paper's steps 11 and 13 (run twice: after
//! the split, and again after control-flow simplification).
//!
//! Backward liveness over the structured IR. Side-effecting statements
//! (`Store`, `PipeWrite`, `PipeRead`) are always kept — a `PipeRead` whose
//! value is dead must still consume its token or the feed-forward pair
//! would deadlock. Loop bodies are processed twice so loop-carried scalar
//! uses (accumulators) are seen.

use crate::ir::{Expr, Kernel, Stmt};
use std::collections::HashSet;

fn expr_uses(e: &Expr, live: &mut HashSet<String>) {
    e.visit(&mut |node| {
        if let Expr::Var(v) = node {
            live.insert(v.clone());
        }
    });
}

/// Process a body backward; returns the kept statements.
/// `live` on entry = variables live *after* the body; on exit = live before.
fn dce_body(body: &[Stmt], live: &mut HashSet<String>) -> Vec<Stmt> {
    let mut kept_rev: Vec<Stmt> = vec![];
    for s in body.iter().rev() {
        match s {
            Stmt::Store { buf, idx, val } => {
                expr_uses(idx, live);
                expr_uses(val, live);
                kept_rev.push(Stmt::Store { buf: buf.clone(), idx: idx.clone(), val: val.clone() });
            }
            Stmt::PipeWrite { pipe, val } => {
                expr_uses(val, live);
                kept_rev.push(Stmt::PipeWrite { pipe: pipe.clone(), val: val.clone() });
            }
            Stmt::PipeRead { var, ty, pipe } => {
                // Token consumption is a side effect: always kept.
                live.remove(var);
                kept_rev.push(Stmt::PipeRead { var: var.clone(), ty: *ty, pipe: pipe.clone() });
            }
            Stmt::Let { var, ty, expr } => {
                if live.contains(var) {
                    live.remove(var);
                    expr_uses(expr, live);
                    kept_rev.push(Stmt::Let { var: var.clone(), ty: *ty, expr: expr.clone() });
                }
                // Dead `Let` (including dead loads) is dropped — exactly the
                // paper's "values not further used".
            }
            Stmt::Assign { var, expr } => {
                if live.contains(var) {
                    // The variable stays live above (other assignments /
                    // initial Let feed later iterations or reads).
                    expr_uses(expr, live);
                    kept_rev.push(Stmt::Assign { var: var.clone(), expr: expr.clone() });
                }
            }
            Stmt::If { cond, then_b, else_b } => {
                let mut live_t = live.clone();
                let mut live_e = live.clone();
                let then_k = dce_body(then_b, &mut live_t);
                let else_k = dce_body(else_b, &mut live_e);
                if then_k.is_empty() && else_k.is_empty() {
                    continue; // drop the whole If (empty control-flow path)
                }
                live.extend(live_t);
                live.extend(live_e);
                expr_uses(cond, live);
                kept_rev.push(Stmt::If { cond: cond.clone(), then_b: then_k, else_b: else_k });
            }
            Stmt::For { id, var, lo, hi, body } => {
                // Two passes over the body to account for loop-carried uses.
                let mut live_in = live.clone();
                let _ = dce_body(body, &mut live_in);
                let mut live_round2: HashSet<String> = live.union(&live_in).cloned().collect();
                let body_k = dce_body(body, &mut live_round2);
                if body_k.is_empty() {
                    continue; // drop empty loop
                }
                live.extend(live_round2);
                live.remove(var);
                expr_uses(lo, live);
                expr_uses(hi, live);
                kept_rev.push(Stmt::For {
                    id: *id,
                    var: var.clone(),
                    lo: lo.clone(),
                    hi: hi.clone(),
                    body: body_k,
                });
            }
        }
    }
    kept_rev.reverse();
    kept_rev
}

/// Remove dead code from a kernel. Buffer/scalar parameter lists are pruned
/// to what the body still references.
pub fn dce_kernel(kernel: &Kernel) -> Kernel {
    let mut k = kernel.clone();
    let mut live = HashSet::new();
    k.body = dce_body(&k.body, &mut live);
    prune_params(&mut k);
    k
}

/// Drop buffer/scalar params no longer referenced by the body.
pub fn prune_params(k: &mut Kernel) {
    let mut bufs = HashSet::new();
    let mut params = HashSet::new();
    crate::ir::stmt::visit_body(&k.body, &mut |s| {
        if let Stmt::Store { buf, .. } = s {
            bufs.insert(buf.clone());
        }
        s.visit_own_exprs(&mut |e| {
            e.visit(&mut |node| match node {
                Expr::Load { buf, .. } => {
                    bufs.insert(buf.clone());
                }
                Expr::Param(p) => {
                    params.insert(p.clone());
                }
                _ => {}
            });
        });
    });
    k.bufs.retain(|b| bufs.contains(&b.name));
    k.scalars.retain(|s| params.contains(&s.name));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{validate_kernel, KernelKind, Ty};

    #[test]
    fn drops_dead_lets_and_unused_params() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_ro("unused", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .scalar("dead", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![
                    let_f("x", ld("a", v("i"))),
                    let_f("y", ld("unused", v("i"))), // dead
                    let_i("z", p("dead") + i(1)),     // dead
                    store("o", v("i"), v("x")),
                ],
            )])
            .finish();
        let d = dce_kernel(&k);
        assert_eq!(validate_kernel(&d), Ok(()));
        assert_eq!(d.load_count(), 1);
        assert!(d.buf("unused").is_none());
        assert!(d.scalar("dead").is_none());
        assert!(d.buf("a").is_some());
        assert!(d.scalar("n").is_some());
    }

    #[test]
    fn keeps_loop_carried_accumulator() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![
                let_f("acc", f(0.0)),
                for_("i", i(0), p("n"), vec![assign("acc", v("acc") + ld("a", v("i")))]),
                store("o", i(0), v("acc")),
            ])
            .finish();
        let d = dce_kernel(&k);
        assert_eq!(d.body.len(), 3); // nothing removed
        assert_eq!(d.load_count(), 1);
    }

    #[test]
    fn drops_empty_if_and_for() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![
                // whole loop computes a dead value
                for_("i", i(0), p("n"), vec![let_f("x", ld("a", v("i")))]),
                if_(p("n").gt(i(0)), vec![let_f("y", f(1.0))]),
                store("o", i(0), f(7.0)),
            ])
            .finish();
        let d = dce_kernel(&k);
        assert_eq!(d.body.len(), 1);
        assert!(matches!(d.body[0], crate::ir::Stmt::Store { .. }));
    }

    #[test]
    fn pipe_ops_never_removed() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![
                    pread("x", Ty::I32, "c0"), // dead value, live token
                    pwrite("c1", v("i")),
                ],
            )])
            .finish();
        let d = dce_kernel(&k);
        let mut reads = 0;
        let mut writes = 0;
        crate::ir::stmt::visit_body(&d.body, &mut |s| match s {
            crate::ir::Stmt::PipeRead { .. } => reads += 1,
            crate::ir::Stmt::PipeWrite { .. } => writes += 1,
            _ => {}
        });
        assert_eq!((reads, writes), (1, 1));
    }

    #[test]
    fn conditional_store_keeps_condition_chain() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("c", Ty::I32)
            .buf_wo("o", Ty::I32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "t",
                i(0),
                p("n"),
                vec![
                    let_i("flag", ld("c", v("t"))),
                    if_(v("flag").eq_(i(-1)), vec![store("o", v("t"), i(1))]),
                ],
            )])
            .finish();
        let d = dce_kernel(&k);
        assert_eq!(d.load_count(), 1); // the condition load is live
    }
}
