//! MLCD removal by privatization — the paper's NW trick (§4.2): a true
//! same-buffer dependency of iteration distance 1 ("read at K depends on
//! the write at K-1") is replaced by carrying the written value in a
//! private variable across iterations, after which the kernel has no true
//! MLCD and the feed-forward split becomes applicable.

use crate::analysis::pattern::affine_wrt;
use crate::analysis::{analyze_lcd, walk_with_loops};
use crate::ir::{Expr, Kernel, Stmt, Ty};

#[derive(Debug, PartialEq)]
pub enum PrivatizeError {
    NothingToPrivatize(String),
    Unsupported(String, crate::ir::LoopId),
}

impl std::fmt::Display for PrivatizeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PrivatizeError::NothingToPrivatize(kernel) => {
                write!(f, "kernel {kernel}: no provably-true distance-1 MLCD to privatize")
            }
            PrivatizeError::Unsupported(kernel, loop_id) => {
                write!(f, "kernel {kernel}: unsupported shape for privatization (loop {loop_id:?})")
            }
        }
    }
}

impl std::error::Error for PrivatizeError {}

/// Carry variable introduced by the pass.
pub const CARRY_VAR: &str = "_carry";

/// Rewrite the (single) provably-true distance-1 MLCD: the load of
/// `buf[i-1]` inside the serialized loop becomes a read of a private
/// variable that each iteration updates with its stored value.
pub fn privatize(kernel: &Kernel) -> Result<Kernel, PrivatizeError> {
    let lcd = analyze_lcd(kernel);
    let target = lcd
        .mlcds
        .iter()
        .find(|m| m.provably_true && m.distance == Some(1))
        .ok_or_else(|| PrivatizeError::NothingToPrivatize(kernel.name.clone()))?
        .clone();

    // Find the serialized loop's var so we can match the load/store pair.
    let mut loop_var = None;
    walk_with_loops(kernel, &mut |s, _| {
        if let Stmt::For { id, var, .. } = s {
            if *id == target.loop_id {
                loop_var = Some(var.clone());
            }
        }
    });
    let loop_var = loop_var.ok_or_else(|| {
        PrivatizeError::Unsupported(kernel.name.clone(), target.loop_id)
    })?;

    let mut k = kernel.clone();
    let carry_ty = k.buf(&target.buf).map(|b| b.elem).unwrap_or(Ty::F32);
    let mut changed = false;
    k.body = rewrite(
        std::mem::take(&mut k.body),
        &target.buf,
        &target.loop_id,
        &loop_var,
        carry_ty,
        &mut changed,
    );
    if !changed {
        return Err(PrivatizeError::Unsupported(kernel.name.clone(), target.loop_id));
    }
    Ok(k)
}

/// Inside the target loop: replace `Load(buf, i-1)` (distance-1 w.r.t. the
/// stored index) with `CARRY_VAR`; after each `Store(buf, i, val)` insert
/// `CARRY_VAR = val`; before the loop insert the initial carry load at
/// `lo - 1`.
fn rewrite(
    body: Vec<Stmt>,
    buf: &str,
    target: &crate::ir::LoopId,
    loop_var: &str,
    carry_ty: Ty,
    changed: &mut bool,
) -> Vec<Stmt> {
    let mut out = vec![];
    for s in body {
        match s {
            Stmt::For { id, var, lo, hi, body: inner } if id == *target => {
                // Initial carry: the store's address one iteration before
                // the loop starts, i.e. store_idx[var := lo - 1].
                let store_idx = find_store_idx(&inner, buf)
                    .expect("privatize: serialized loop has a store to the target buffer");
                let before = Expr::Bin(
                    crate::ir::BinOp::Sub,
                    Box::new(lo.clone()),
                    Box::new(Expr::I(1)),
                );
                let init_idx = store_idx.clone().subst_var(&var, &before);
                out.push(Stmt::Let {
                    var: CARRY_VAR.into(),
                    ty: carry_ty,
                    expr: Expr::Load { buf: buf.to_string(), idx: Box::new(init_idx) },
                });
                let (s_stride, s_const, s_res) = affine_wrt(&store_idx, &var)
                    .expect("privatize: store index must be affine in the loop var");
                let new_inner = rewrite_loop_body(
                    inner,
                    buf,
                    loop_var,
                    carry_ty,
                    (s_stride, s_const, &s_res),
                    changed,
                );
                out.push(Stmt::For { id, var, lo, hi, body: new_inner });
            }
            Stmt::For { id, var, lo, hi, body: inner } => {
                out.push(Stmt::For {
                    id,
                    var,
                    lo,
                    hi,
                    body: rewrite(inner, buf, target, loop_var, carry_ty, changed),
                });
            }
            Stmt::If { cond, then_b, else_b } => out.push(Stmt::If {
                cond,
                then_b: rewrite(then_b, buf, target, loop_var, carry_ty, changed),
                else_b: rewrite(else_b, buf, target, loop_var, carry_ty, changed),
            }),
            other => out.push(other),
        }
    }
    out
}

/// The index expression of the (first) store to `buf` in a loop body.
fn find_store_idx(body: &[Stmt], buf: &str) -> Option<Expr> {
    let mut found = None;
    crate::ir::stmt::visit_body(body, &mut |s| {
        if found.is_none() {
            if let Stmt::Store { buf: b, idx, .. } = s {
                if b == buf {
                    found = Some(idx.clone());
                }
            }
        }
    });
    found
}

fn rewrite_loop_body(
    body: Vec<Stmt>,
    buf: &str,
    loop_var: &str,
    carry_ty: Ty,
    store_aff: (i64, i64, &str),
    changed: &mut bool,
) -> Vec<Stmt> {
    let mut out = vec![];
    for s in body {
        match s {
            Stmt::Let { var, ty, expr } => {
                let expr = replace_dist1_load(expr, buf, loop_var, store_aff, changed);
                out.push(Stmt::Let { var, ty, expr });
            }
            Stmt::Assign { var, expr } => {
                let expr = replace_dist1_load(expr, buf, loop_var, store_aff, changed);
                out.push(Stmt::Assign { var, expr });
            }
            Stmt::Store { buf: sb, idx, val } => {
                let val = replace_dist1_load(val, buf, loop_var, store_aff, changed);
                if sb == buf {
                    // Materialize the stored value once so the carry update
                    // does not duplicate its computation (or its loads).
                    let tmp = format!("{CARRY_VAR}_val");
                    out.push(Stmt::Let { var: tmp.clone(), ty: carry_ty, expr: val });
                    out.push(Stmt::Store { buf: sb, idx, val: Expr::Var(tmp.clone()) });
                    out.push(Stmt::Assign { var: CARRY_VAR.into(), expr: Expr::Var(tmp) });
                } else {
                    out.push(Stmt::Store { buf: sb, idx, val });
                }
            }
            Stmt::If { cond, then_b, else_b } => {
                let cond = replace_dist1_load(cond, buf, loop_var, store_aff, changed);
                out.push(Stmt::If {
                    cond,
                    then_b: rewrite_loop_body(then_b, buf, loop_var, carry_ty, store_aff, changed),
                    else_b: rewrite_loop_body(else_b, buf, loop_var, carry_ty, store_aff, changed),
                });
            }
            s @ Stmt::For { .. } => out.push(s), // nested loops untouched
            other => out.push(other),
        }
    }
    out
}

/// Replace exactly the distance-1 load: same stride and symbolic residue as
/// the store, constant offset one stride behind (other loads of the buffer
/// — e.g. NW's previous-row reads — are left alone).
fn replace_dist1_load(
    e: Expr,
    buf: &str,
    loop_var: &str,
    store_aff: (i64, i64, &str),
    changed: &mut bool,
) -> Expr {
    let (s_stride, s_const, s_res) = store_aff;
    let hit = std::cell::Cell::new(false);
    let out = e.map(&|node| match &node {
        Expr::Load { buf: b, idx } if b == buf => {
            if let Some((stride, off, res)) = affine_wrt(idx, loop_var) {
                if stride == s_stride && res == s_res && s_const - off == s_stride {
                    hit.set(true);
                    return Expr::Var(CARRY_VAR.into());
                }
            }
            node
        }
        _ => node,
    });
    if hit.get() {
        *changed = true;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{validate_kernel, KernelKind};
    use crate::transform::feasibility::check_feasible;

    fn nw_like() -> Kernel {
        KernelBuilder::new("nw", KernelKind::SingleWorkItem)
            .buf_rw("m", Ty::I32)
            .buf_ro("s", Ty::I32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "j",
                i(1),
                p("n"),
                vec![store(
                    "m",
                    v("j"),
                    (ld("m", v("j") - i(1)) + ld("s", v("j"))).max(i(0)),
                )],
            )])
            .finish()
    }

    #[test]
    fn privatization_unlocks_feasibility() {
        let k = nw_like();
        assert!(check_feasible(&k).is_err());
        let p = privatize(&k).unwrap();
        assert_eq!(validate_kernel(&p), Ok(()));
        assert!(check_feasible(&p).is_ok(), "still infeasible: {:?}", check_feasible(&p));
        // the dependent load is gone; only the s[j] load and the initial
        // carry load remain
        assert_eq!(p.load_count(), 2);
        let src = crate::ir::pretty::kernel_to_string(&p);
        assert!(src.contains(&format!("int {CARRY_VAR} = m[(1 - 1)];")));
    }

    #[test]
    fn errors_when_nothing_to_privatize() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_("x", i(0), p("n"), vec![store("o", v("x"), ld("a", v("x")))])])
            .finish();
        assert!(matches!(privatize(&k), Err(PrivatizeError::NothingToPrivatize(_))));
    }

    #[test]
    fn privatized_kernel_semantics_shape() {
        // The rewritten body must update the carry after the store.
        let p = privatize(&nw_like()).unwrap();
        let src = crate::ir::pretty::kernel_to_string(&p);
        let store_pos = src.find("m[j] =").unwrap();
        let carry_pos = src.rfind(&format!("{CARRY_VAR} = ")).unwrap();
        assert!(carry_pos > store_pos);
    }
}
