//! Feasibility check for the feed-forward design model (§3 "Limitations").
//!
//! The model is not applicable when the kernel carries a *true* memory
//! loop-carried dependency: concurrent execution of the dependent load
//! (memory kernel) and store (compute kernel) would produce wrong results.
//! Two gates, matching the paper:
//!
//! 1. a syntactically provable cross-iteration same-buffer dependency
//!    (e.g. NW's `m[j] = f(m[j-1])`) is rejected outright;
//! 2. otherwise the programmer must have vouched that no true MLCD exists
//!    (`Kernel::assume_no_true_mlcd`) — the paper: "Programmers must only
//!    use this design model when they can guarantee that there is no true
//!    MLCD involved".

use crate::analysis::{analyze_lcd, MlcdInfo};
use crate::ir::Kernel;

#[derive(Debug, PartialEq)]
pub enum FeasibilityError {
    TrueMlcd { kernel: String, buf: String, distance: i64 },
    NoGuarantee { kernel: String, buf: String },
    ReplicationUnsupported { workload: String },
}

impl std::fmt::Display for FeasibilityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FeasibilityError::TrueMlcd { kernel, buf, distance } => write!(
                f,
                "kernel {kernel}: provably true memory loop-carried dependency on `{buf}` \
                 (iteration distance {distance}); the feed-forward model would compute wrong \
                 results — resolve it first (e.g. transform::privatize) "
            ),
            FeasibilityError::NoGuarantee { kernel, buf } => write!(
                f,
                "kernel {kernel}: no programmer guarantee of MLCD-freedom \
                 (Kernel::assume_no_true_mlcd is false) and the analysis cannot prove \
                 independence of the accesses on `{buf}`"
            ),
            FeasibilityError::ReplicationUnsupported { workload } => write!(
                f,
                "workload {workload}: static range replication would break \
                 inter-iteration data flow (cross-replica dependency)"
            ),
        }
    }
}

impl std::error::Error for FeasibilityError {}

/// Check that the feed-forward split may be applied to `kernel`.
pub fn check_feasible(kernel: &Kernel) -> Result<(), FeasibilityError> {
    let lcd = analyze_lcd(kernel);
    if let Some(m) = lcd.mlcds.iter().find(|m| m.provably_true) {
        return Err(FeasibilityError::TrueMlcd {
            kernel: kernel.name.clone(),
            buf: m.buf.clone(),
            distance: m.distance.unwrap_or(0),
        });
    }
    if !kernel.assume_no_true_mlcd {
        if let Some(m) = first_unproven(&lcd.mlcds) {
            return Err(FeasibilityError::NoGuarantee {
                kernel: kernel.name.clone(),
                buf: m.buf.clone(),
            });
        }
    }
    Ok(())
}

fn first_unproven(mlcds: &[MlcdInfo]) -> Option<&MlcdInfo> {
    mlcds.iter().find(|m| m.distance.is_none())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, Ty};

    #[test]
    fn rejects_nw_like_true_dependency() {
        let k = KernelBuilder::new("nw", KernelKind::SingleWorkItem)
            .buf_rw("m", Ty::I32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "j",
                i(1),
                p("n"),
                vec![store("m", v("j"), ld("m", v("j") - i(1)) + i(1))],
            )])
            .finish();
        assert!(matches!(check_feasible(&k), Err(FeasibilityError::TrueMlcd { distance: 1, .. })));
    }

    #[test]
    fn accepts_false_mlcd_with_guarantee() {
        // Same-buffer same-index store/load (distance 0 provable): false MLCD.
        let k = KernelBuilder::new("bp", KernelKind::SingleWorkItem)
            .buf_rw("w", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("w", v("i"), ld("w", v("i")) * f(1.5))],
            )])
            .finish();
        assert_eq!(check_feasible(&k), Ok(()));
    }

    #[test]
    fn unprovable_requires_guarantee() {
        let body = vec![for_(
            "t",
            i(0),
            p("n"),
            vec![
                let_i("j", ld("col", v("t"))),
                store("c", v("j"), i(1)),
                let_i("x", ld("c", v("t"))),
                store("o", v("t"), v("x")),
            ],
        )];
        let with = KernelBuilder::new("g", KernelKind::SingleWorkItem)
            .buf_rw("c", Ty::I32)
            .buf_ro("col", Ty::I32)
            .buf_wo("o", Ty::I32)
            .scalar("n", Ty::I32)
            .body(body.clone())
            .finish();
        assert_eq!(check_feasible(&with), Ok(()));

        let without = KernelBuilder::new("g", KernelKind::SingleWorkItem)
            .buf_rw("c", Ty::I32)
            .buf_ro("col", Ty::I32)
            .buf_wo("o", Ty::I32)
            .scalar("n", Ty::I32)
            .no_mlcd_guarantee()
            .body(body)
            .finish();
        assert!(matches!(check_feasible(&without), Err(FeasibilityError::NoGuarantee { .. })));
    }
}
