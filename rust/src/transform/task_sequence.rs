//! The task-sequence transform: rewrite a convergence workload's
//! re-launch chain into persistent co-scheduled stages (MKPipe's move,
//! and the shape of oneAPI's `task_sequence` idiom).
//!
//! Every other pass in this module rewrites *kernel IR*. This one
//! rewrites the **host's launch schedule**: the kernels themselves keep
//! the pipes `feedforward` placed, but the sequential chain the host
//! issued (`clear; kernel; update; clear; kernel; update; …`) becomes a
//! sequence of *stages*, each stage a set of launches the dependence DAG
//! ([`crate::analysis::LaunchDag`]) proves mutually unordered. Launches
//! sharing a stage run as one merged proc group in the graph DES
//! (`sim::des::simulate_graph`), arbitrating a single shared DRAM
//! ledger — the modelled equivalent of persistent kernels fed by
//! inter-iteration pipes.
//!
//! Legality is entirely the dependence layer's: RAW edges always
//! serialize; WAR/WAW edges serialize unless the workload's benign-race
//! vouch lifts them (`analysis::deps` documents the vouch-to-edge
//! mapping). Where the DAG is a chain — NW's read-modify-write over one
//! buffer — the transform returns a schedule identical to the host
//! order and the graph DES degenerates to launch-at-a-time modelling,
//! bit-identical to the sequential path.

use crate::analysis::LaunchDag;
use crate::workloads::{App, ExecTrace};

/// The legalized launch schedule: the re-launch chain regrouped into
/// dependence-respecting stages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskSchedule {
    /// One persistent stage per wavefront, in dependence order; each
    /// stage lists the launch indices (into the trace) co-resident in it,
    /// ascending.
    pub stages: Vec<Vec<usize>>,
    /// Launch index → stage index — the `levels` vector
    /// `sim::des::simulate_graph` consumes directly.
    pub stage_of: Vec<usize>,
}

impl TaskSchedule {
    /// Widest stage (1 = no overlap anywhere).
    pub fn width(&self) -> usize {
        self.stages.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// True when the schedule is the host's chain unchanged: one launch
    /// per stage. This is the transform *refusing* to overlap — the
    /// legal outcome for depth-sensitive chains like NW.
    pub fn is_chain(&self) -> bool {
        self.stages.len() == self.stage_of.len()
    }
}

/// Rewrite `trace`'s launch chain into the widest schedule the
/// dependence DAG admits. `benign` is the workload's
/// `benign_cross_kernel_races` vouch (lifts WAR/WAW edges only — see
/// `analysis::deps`). Errors if the trace names a unit `app` does not
/// carry.
pub fn task_sequence(app: &App, trace: &ExecTrace, benign: bool) -> Result<TaskSchedule, String> {
    let dag = LaunchDag::build(app, trace, benign)?;
    Ok(TaskSchedule { stages: dag.wavefronts(), stage_of: dag.levels.clone() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::Variant;
    use crate::workloads::{by_name, ExecTrace, LaunchRecord};

    fn synthetic_trace(units: &[&str]) -> ExecTrace {
        let mut trace = ExecTrace::default();
        for u in units {
            trace.launches.push(LaunchRecord { unit: u.to_string(), profiles: vec![] });
        }
        trace
    }

    /// NW's shape: every launch read-modify-writes one buffer, so the
    /// transform must hand back the chain untouched — overlap refused.
    #[test]
    fn rmw_chain_is_returned_unchanged() {
        let w = by_name("nw").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let names: Vec<&str> = app.units.iter().map(|u| u.name.as_str()).collect();
        let trace = synthetic_trace(&[names[0], names[0], names[0]]);
        for benign in [false, true] {
            let s = task_sequence(&app, &trace, benign).unwrap();
            assert!(s.is_chain(), "RMW chain must never overlap (benign={benign})");
            assert_eq!(s.width(), 1);
            assert_eq!(s.stage_of, vec![0, 1, 2]);
        }
    }

    /// Pagerank's shape under its vouch: the ping-pong chain collapses
    /// to two persistent stages (all contribs, then all gathers).
    #[test]
    fn vouched_pingpong_collapses_to_two_stages() {
        let w = by_name("pagerank").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let contrib = app.units.iter().find(|u| u.name.contains("contrib")).unwrap();
        let gather = app.units.iter().find(|u| !u.name.contains("contrib")).unwrap();
        let trace = synthetic_trace(&[
            &contrib.name,
            &gather.name,
            &contrib.name,
            &gather.name,
        ]);
        let s = task_sequence(&app, &trace, true).unwrap();
        assert_eq!(s.stages.len(), 2, "ping-pong must collapse to contrib|gather stages");
        assert_eq!(s.width(), 2);
        assert!(!s.is_chain());
        // without the vouch the WAR/WAW edges keep more order
        let strict = task_sequence(&app, &trace, false).unwrap();
        assert!(strict.stages.len() > s.stages.len());
    }

    #[test]
    fn unknown_unit_is_a_clean_error() {
        let w = by_name("nw").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let err = task_sequence(&app, &synthetic_trace(&["nope"]), true).unwrap_err();
        assert!(err.contains("nope"), "{err}");
    }
}
