//! The feed-forward split (paper §3, steps 5-11): one single work-item
//! kernel becomes a *memory kernel* (all global loads, each value written
//! to a pipe) and a *compute kernel* (reads pipes, computes, stores),
//! running concurrently and communicating only through pipes.
//!
//! Pipe-trace correctness invariant: both kernels retain the original
//! control structure around every load site, and every branch condition is
//! computed from the same values (the memory kernel from the loads, the
//! compute kernel from the pipes), so under any input the sequence of
//! writes to each pipe equals the sequence of reads — no token mismatch,
//! no deadlock. Property-tested in `rust/tests/prop_transforms.rs`.

use super::dce::{dce_kernel, prune_params};
use super::feasibility::{check_feasible, FeasibilityError};
use super::normalize::name_loads;
use super::simplify::simplify_kernel;
use crate::ir::{
    Access, Expr, Kernel, KernelKind, PipeDecl, Program, Role, Stmt,
};

/// Names used for the split pair.
pub fn memory_kernel_name(base: &str) -> String {
    format!("{base}_mem")
}

pub fn compute_kernel_name(base: &str) -> String {
    format!("{base}_cmp")
}

/// Apply the feed-forward split to a single work-item kernel, producing a
/// two-kernel program connected by one pipe per static load site.
///
/// `depth` is the requested minimum depth for every created pipe (the
/// paper sweeps 1/100/1000 and finds it does not matter much).
pub fn feedforward(kernel: &Kernel, depth: usize) -> Result<Program, FeasibilityError> {
    assert_eq!(
        kernel.kind,
        KernelKind::SingleWorkItem,
        "feed-forward requires a single work-item kernel (run ndrange_to_swi first)"
    );
    check_feasible(kernel)?;

    // Step 5: named-load normal form.
    let base = name_loads(kernel);

    // Steps 6-9: duplicate into memory/compute bodies, one pipe per site.
    let mut pipes: Vec<PipeDecl> = vec![];
    let mut site = 0usize;
    let mem_body = build_mem(&base.body, &base, &mut pipes, &mut site, depth);
    let mut site2 = 0usize;
    let cmp_body = build_cmp(&base.body, &base, &mut site2);
    debug_assert_eq!(site, site2, "load-site numbering diverged between halves");

    let mut mem = Kernel {
        name: memory_kernel_name(&kernel.name),
        kind: KernelKind::SingleWorkItem,
        role: Role::Memory,
        bufs: base.bufs.clone(),
        scalars: base.scalars.clone(),
        body: mem_body,
        assume_no_true_mlcd: true,
    };
    let mut cmp = Kernel {
        name: compute_kernel_name(&kernel.name),
        kind: KernelKind::SingleWorkItem,
        role: Role::Compute,
        bufs: base.bufs.clone(),
        scalars: base.scalars.clone(),
        body: cmp_body,
        assume_no_true_mlcd: true,
    };

    // Steps 10-11 and 13: DCE, simplify, DCE again.
    mem = dce_kernel(&mem);
    mem = simplify_kernel(&mem);
    mem = dce_kernel(&mem);
    cmp = dce_kernel(&cmp);
    cmp = simplify_kernel(&cmp);
    cmp = dce_kernel(&cmp);
    prune_params(&mut mem);
    prune_params(&mut cmp);
    // The memory kernel only reads.
    for b in &mut mem.bufs {
        if b.access == Access::ReadWrite {
            b.access = Access::ReadOnly;
        }
    }

    Ok(Program {
        name: format!("{}_ff", kernel.name),
        kernels: vec![mem, cmp],
        pipes,
    })
}

fn pipe_name(kernel: &str, site: usize) -> String {
    format!("{kernel}_c{site}")
}

/// Memory-kernel body: every named load gets a pipe write; stores dropped.
fn build_mem(
    body: &[Stmt],
    k: &Kernel,
    pipes: &mut Vec<PipeDecl>,
    site: &mut usize,
    depth: usize,
) -> Vec<Stmt> {
    let mut out = vec![];
    for s in body {
        match s {
            Stmt::Let { var, ty, expr } if is_named_load(expr) => {
                let pn = pipe_name(&k.name, *site);
                pipes.push(PipeDecl { name: pn.clone(), ty: *ty, depth: depth.max(1) });
                *site += 1;
                out.push(s.clone());
                out.push(Stmt::PipeWrite { pipe: pn, val: Expr::Var(var.clone()) });
            }
            Stmt::Store { .. } => {} // step 10: stores leave the memory kernel
            Stmt::If { cond, then_b, else_b } => out.push(Stmt::If {
                cond: cond.clone(),
                then_b: build_mem(then_b, k, pipes, site, depth),
                else_b: build_mem(else_b, k, pipes, site, depth),
            }),
            Stmt::For { id, var, lo, hi, body } => out.push(Stmt::For {
                id: *id,
                var: var.clone(),
                lo: lo.clone(),
                hi: hi.clone(),
                body: build_mem(body, k, pipes, site, depth),
            }),
            other => out.push(other.clone()),
        }
    }
    out
}

/// Compute-kernel body: every named load becomes a pipe read.
fn build_cmp(body: &[Stmt], k: &Kernel, site: &mut usize) -> Vec<Stmt> {
    let mut out = vec![];
    for s in body {
        match s {
            Stmt::Let { var, ty, expr } if is_named_load(expr) => {
                let pn = pipe_name(&k.name, *site);
                *site += 1;
                out.push(Stmt::PipeRead { var: var.clone(), ty: *ty, pipe: pn });
            }
            Stmt::If { cond, then_b, else_b } => out.push(Stmt::If {
                cond: cond.clone(),
                then_b: build_cmp(then_b, k, site),
                else_b: build_cmp(else_b, k, site),
            }),
            Stmt::For { id, var, lo, hi, body } => out.push(Stmt::For {
                id: *id,
                var: var.clone(),
                lo: lo.clone(),
                hi: hi.clone(),
                body: build_cmp(body, k, site),
            }),
            other => out.push(other.clone()),
        }
    }
    out
}

fn is_named_load(e: &Expr) -> bool {
    matches!(e, Expr::Load { idx, .. } if !idx.has_load())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{validate_program, Ty};
    use crate::transform::examples::fig2_kernel;

    #[test]
    fn fig2_splits_cleanly() {
        let k = fig2_kernel();
        let ff = feedforward(&k, 1).unwrap();
        assert_eq!(validate_program(&ff), Ok(()));
        assert_eq!(ff.kernels.len(), 2);
        let mem = &ff.kernels[0];
        let cmp = &ff.kernels[1];
        assert_eq!(mem.role, Role::Memory);
        assert_eq!(cmp.role, Role::Compute);
        // Memory kernel: all the loads, no stores.
        assert!(mem.load_count() > 0);
        assert_eq!(mem.store_count(), 0);
        // Compute kernel: no loads, all the stores.
        assert_eq!(cmp.load_count(), 0);
        assert_eq!(cmp.store_count(), 2); // stop + min_array
        // One pipe per surviving load site; both endpoints wired (checked
        // by validate_program above).
        assert!(!ff.pipes.is_empty());
        // Compute kernel must not reference the graph-structure buffers.
        assert!(cmp.buf("row").is_none());
        assert!(cmp.buf("col").is_none());
        assert!(cmp.buf("c_array").is_none());
    }

    #[test]
    fn pipe_count_matches_load_sites() {
        let k = fig2_kernel();
        let named = name_loads(&k);
        let ff = feedforward(&k, 1).unwrap();
        let mem = &ff.kernels[0];
        // After DCE the memory kernel may have dropped *dead* loads, but
        // every surviving load has exactly one pipe write and the compute
        // kernel one pipe read (validated); the pipe count equals the
        // number of pipe writes.
        let mut writes = 0;
        crate::ir::stmt::visit_body(&mem.body, &mut |s| {
            if matches!(s, Stmt::PipeWrite { .. }) {
                writes += 1;
            }
        });
        assert_eq!(writes, ff.pipes.len());
        assert!(ff.pipes.len() <= named.load_count());
    }

    #[test]
    fn requested_depth_respected() {
        let ff = feedforward(&fig2_kernel(), 100).unwrap();
        assert!(ff.pipes.iter().all(|p| p.depth == 100));
    }

    #[test]
    fn rejects_true_mlcd_kernel() {
        let k = KernelBuilder::new("nw", KernelKind::SingleWorkItem)
            .buf_rw("m", Ty::I32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "j",
                i(1),
                p("n"),
                vec![store("m", v("j"), ld("m", v("j") - i(1)) + i(1))],
            )])
            .finish();
        assert!(feedforward(&k, 1).is_err());
    }

    #[test]
    fn memory_kernel_is_store_free_and_loses_ii_serialization() {
        use crate::analysis::{analyze_lcd, loop_iis};
        // FW-like kernel: serialized baseline, pipelined after split.
        let k = KernelBuilder::new("fw", KernelKind::SingleWorkItem)
            .buf_rw("dist", Ty::F32)
            .scalar("n", Ty::I32)
            .scalar("piv", Ty::I32)
            .body(vec![for_(
                "ij",
                i(0),
                p("n") * p("n"),
                vec![
                    let_i("i2", v("ij") / p("n")),
                    let_i("j2", v("ij") % p("n")),
                    store(
                        "dist",
                        v("ij"),
                        ld("dist", v("ij"))
                            .min(ld("dist", v("i2") * p("n") + p("piv")) + ld("dist", p("piv") * p("n") + v("j2"))),
                    ),
                ],
            )])
            .finish();
        let base_ii = {
            let lcd = analyze_lcd(&k);
            loop_iis(&k, &lcd).iter().map(|l| l.ii).max().unwrap()
        };
        assert!(base_ii > 100, "baseline must be serialized, ii={base_ii}");
        let ff = feedforward(&k, 1).unwrap();
        for kern in &ff.kernels {
            let lcd = analyze_lcd(kern);
            let max_ii = loop_iis(kern, &lcd).iter().map(|l| l.ii).max().unwrap();
            assert_eq!(max_ii, 1, "{} should pipeline at II=1", kern.name);
        }
    }
}
