//! NDRange -> single work-item conversion (paper step 1, §3: "programmers
//! can construct the single work-item version by embedding the body of the
//! NDRange baseline kernel within a nested loop").
//!
//! Our benchmarks are 1-D (or linearized), so the wrapping loop is a single
//! `for (gid = 0; gid < global_size; gid++)`; the work-group/work-item
//! nesting the paper mentions collapses to the same iteration space.

use crate::ir::{Expr, Kernel, KernelKind, ScalarParam, Stmt, Ty};

/// Loop variable introduced for the linearized global id.
pub const GID_VAR: &str = "_gid";

fn replace_gid(body: Vec<Stmt>) -> Vec<Stmt> {
    fn fix(e: Expr) -> Expr {
        e.map(&|n| match n {
            Expr::GlobalId(0) => Expr::Var(GID_VAR.to_string()),
            other => other,
        })
    }
    body.into_iter()
        .map(|s| match s {
            Stmt::Let { var, ty, expr } => Stmt::Let { var, ty, expr: fix(expr) },
            Stmt::Assign { var, expr } => Stmt::Assign { var, expr: fix(expr) },
            Stmt::Store { buf, idx, val } => Stmt::Store { buf, idx: fix(idx), val: fix(val) },
            Stmt::If { cond, then_b, else_b } => Stmt::If {
                cond: fix(cond),
                then_b: replace_gid(then_b),
                else_b: replace_gid(else_b),
            },
            Stmt::For { id, var, lo, hi, body } => Stmt::For {
                id,
                var,
                lo: fix(lo),
                hi: fix(hi),
                body: replace_gid(body),
            },
            Stmt::PipeWrite { pipe, val } => Stmt::PipeWrite { pipe, val: fix(val) },
            s @ Stmt::PipeRead { .. } => s,
        })
        .collect()
}

/// Convert an NDRange kernel to single work-item form. `global_size_param`
/// names the scalar parameter holding the launch size (added if missing).
pub fn ndrange_to_swi(kernel: &Kernel, global_size_param: &str) -> Kernel {
    assert_eq!(kernel.kind, KernelKind::NDRange, "kernel is already single work-item");
    let mut k = kernel.clone();
    k.kind = KernelKind::SingleWorkItem;
    if k.scalar(global_size_param).is_none() {
        k.scalars.push(ScalarParam { name: global_size_param.into(), ty: Ty::I32 });
    }
    let inner = replace_gid(std::mem::take(&mut k.body));
    k.body = vec![Stmt::For {
        id: crate::ir::LoopId(u32::MAX),
        var: GID_VAR.into(),
        lo: Expr::I(0),
        hi: Expr::Param(global_size_param.into()),
        body: inner,
    }];
    let mut next = 0;
    crate::ir::build::assign_loop_ids(&mut k.body, &mut next);
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{validate_kernel, Ty};

    #[test]
    fn wraps_body_and_rewrites_gid() {
        let nd = KernelBuilder::new("scale", KernelKind::NDRange)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .body(vec![store("o", gid(), ld("a", gid()) * f(2.0))])
            .finish();
        let swi = ndrange_to_swi(&nd, "n");
        assert_eq!(swi.kind, KernelKind::SingleWorkItem);
        assert_eq!(validate_kernel(&swi), Ok(()));
        assert!(swi.scalar("n").is_some());
        let src = crate::ir::pretty::kernel_to_string(&swi);
        assert!(src.contains(&format!("for (int {GID_VAR} = 0; {GID_VAR} < n; {GID_VAR}++)")));
        assert!(!src.contains("get_global_id"));
    }

    #[test]
    fn nested_structures_rewritten() {
        let nd = KernelBuilder::new("k", KernelKind::NDRange)
            .buf_ro("a", Ty::I32)
            .buf_wo("o", Ty::I32)
            .body(vec![if_(
                gid().lt(i(100)),
                vec![for_("j", i(0), i(4), vec![store("o", gid() * i(4) + v("j"), ld("a", gid()))])],
            )])
            .finish();
        let swi = ndrange_to_swi(&nd, "gsz");
        assert_eq!(validate_kernel(&swi), Ok(()));
        // loop ids got renumbered: outer wrapping loop is L0
        assert_eq!(swi.loop_ids().len(), 2);
        assert_eq!(swi.loop_ids()[0], crate::ir::LoopId(0));
    }
}
