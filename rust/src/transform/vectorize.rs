//! Vector-type case study (§4.2): widen the innermost loop by W so each
//! iteration moves W adjacent elements — the IR analogue of `float4`
//! loads/pipes. The paper hit an Intel SDK internal error combining pipes
//! with vector types; our substrate has no such flaw, so the experiment
//! completes and reproduces the *shape* they observed on the cases that
//! did build (FW ~3x better, MIS worse).
//!
//! Implemented as loop unrolling with local-variable renaming: after
//! unrolling, copy `u`'s loads have `Strided(W)` patterns whose W sites
//! jointly cover every address — the performance model coalesces them into
//! full-burst traffic, which is exactly the float4 effect.

use crate::ir::{Expr, Kernel, Stmt};

/// Unroll the *innermost* loops of the kernel by `w`. The caller must
/// guarantee all innermost trip counts are divisible by `w` (our datasets
/// are sized accordingly; the functional interpreter would surface any
/// violation as a wrong result against the reference).
pub fn vectorize(kernel: &Kernel, w: usize) -> Kernel {
    assert!(w >= 2, "vector width must be >= 2");
    let mut k = kernel.clone();
    k.name = format!("{}_v{w}", k.name);
    k.body = walk(std::mem::take(&mut k.body), w);
    let mut next = 0;
    crate::ir::build::assign_loop_ids(&mut k.body, &mut next);
    k
}

fn is_innermost(body: &[Stmt]) -> bool {
    let mut has_loop = false;
    for s in body {
        s.visit(&mut |n| {
            if matches!(n, Stmt::For { .. }) {
                has_loop = true;
            }
        });
    }
    !has_loop
}

/// Bounds are host-controlled (constants/params only): the caller can
/// guarantee divisibility by the vector width. Data-dependent bounds
/// (e.g. a CSR edge loop) cannot be safely widened. Constant trips that
/// do not divide the width are rejected here.
fn safe_bounds_w(lo: &Expr, hi: &Expr, w: usize) -> bool {
    let mut has_var = false;
    let mut chk = |e: &Expr| {
        e.visit(&mut |n| {
            if matches!(n, Expr::Var(_) | Expr::Load { .. }) {
                has_var = true;
            }
        })
    };
    chk(lo);
    chk(hi);
    if has_var {
        return false;
    }
    if let (Expr::I(a), Expr::I(b)) = (lo, hi) {
        return (b - a).rem_euclid(w as i64) == 0;
    }
    true // param-driven: dataset sizes are width-aligned by contract
}

/// True if any loop under `body` has safe (host-controlled) bounds.
fn any_safe_loop(body: &[Stmt], w: usize) -> bool {
    let mut found = false;
    for s in body {
        s.visit(&mut |n| {
            if let Stmt::For { lo, hi, .. } = n {
                if safe_bounds_w(lo, hi, w) {
                    found = true;
                }
            }
        });
    }
    found
}

fn walk(body: Vec<Stmt>, w: usize) -> Vec<Stmt> {
    body.into_iter()
        .map(|s| match s {
            Stmt::For { id, var, lo, hi, body } => {
                let innermost_unrollable = is_innermost(&body) && safe_bounds_w(&lo, &hi, w);
                // When the nested loops are data-bounded (MIS's edge loop),
                // widen this enclosing host-controlled loop instead — the
                // paper's vector case study on irregular kernels.
                let fallback_here = !is_innermost(&body)
                    && safe_bounds_w(&lo, &hi, w)
                    && !any_safe_loop(&body, w);
                if innermost_unrollable || fallback_here {
                    unroll(id, var, lo, hi, body, w)
                } else {
                    Stmt::For { id, var, lo, hi, body: walk(body, w) }
                }
            }
            Stmt::If { cond, then_b, else_b } => Stmt::If {
                cond,
                then_b: walk(then_b, w),
                else_b: walk(else_b, w),
            },
            other => other,
        })
        .collect()
}

/// `for (v = lo; v < hi; v++) B` becomes
/// `for (vv = 0; vv < (hi-lo)/w; vv++) { B[v := lo + vv*w + 0] ... B[v := lo + vv*w + w-1] }`
fn unroll(
    id: crate::ir::LoopId,
    var: String,
    lo: Expr,
    hi: Expr,
    body: Vec<Stmt>,
    w: usize,
) -> Stmt {
    let vv = format!("{var}_v");
    let span = Expr::Bin(crate::ir::BinOp::Sub, Box::new(hi), Box::new(lo.clone()));
    let trips = Expr::Bin(crate::ir::BinOp::Div, Box::new(span), Box::new(Expr::I(w as i64)));
    let mut new_body = vec![];
    for u in 0..w {
        // v := lo + vv*w + u
        let idx = Expr::Bin(
            crate::ir::BinOp::Add,
            Box::new(Expr::Bin(
                crate::ir::BinOp::Add,
                Box::new(lo.clone()),
                Box::new(Expr::Bin(
                    crate::ir::BinOp::Mul,
                    Box::new(Expr::Var(vv.clone())),
                    Box::new(Expr::I(w as i64)),
                )),
            )),
            Box::new(Expr::I(u as i64)),
        );
        new_body.extend(instantiate(&body, &var, &idx, u));
    }
    Stmt::For { id, var: vv, lo: Expr::I(0), hi: trips, body: new_body }
}

/// Clone `body` substituting the loop variable and suffixing every locally
/// declared variable with `_u{u}` to avoid redefinitions.
fn instantiate(body: &[Stmt], var: &str, idx: &Expr, u: usize) -> Vec<Stmt> {
    let suffix = format!("_u{u}");
    // names declared in this copy (Let / PipeRead / inner For vars)
    let mut declared = std::collections::HashSet::new();
    for s in body {
        s.visit(&mut |n| match n {
            Stmt::Let { var, .. } | Stmt::PipeRead { var, .. } => {
                declared.insert(var.clone());
            }
            Stmt::For { var, .. } => {
                declared.insert(var.clone());
            }
            _ => {}
        });
    }
    let fix_expr = |e: &Expr| -> Expr {
        e.clone().map(&|n| match &n {
            Expr::Var(v) if v == var => idx.clone(),
            Expr::Var(v) if declared.contains(v) => Expr::Var(format!("{v}{suffix}")),
            _ => n,
        })
    };
    fn go(
        body: &[Stmt],
        fix_expr: &impl Fn(&Expr) -> Expr,
        declared: &std::collections::HashSet<String>,
        suffix: &str,
    ) -> Vec<Stmt> {
        body.iter()
            .map(|s| match s {
                Stmt::Let { var, ty, expr } => Stmt::Let {
                    var: format!("{var}{suffix}"),
                    ty: *ty,
                    expr: fix_expr(expr),
                },
                Stmt::Assign { var, expr } => Stmt::Assign {
                    var: if declared.contains(var) { format!("{var}{suffix}") } else { var.clone() },
                    expr: fix_expr(expr),
                },
                Stmt::Store { buf, idx, val } => Stmt::Store {
                    buf: buf.clone(),
                    idx: fix_expr(idx),
                    val: fix_expr(val),
                },
                Stmt::If { cond, then_b, else_b } => Stmt::If {
                    cond: fix_expr(cond),
                    then_b: go(then_b, fix_expr, declared, suffix),
                    else_b: go(else_b, fix_expr, declared, suffix),
                },
                Stmt::For { id, var, lo, hi, body } => Stmt::For {
                    id: *id,
                    var: format!("{var}{suffix}"),
                    lo: fix_expr(lo),
                    hi: fix_expr(hi),
                    body: go(body, fix_expr, declared, suffix),
                },
                Stmt::PipeWrite { pipe, val } => Stmt::PipeWrite {
                    pipe: pipe.clone(),
                    val: fix_expr(val),
                },
                Stmt::PipeRead { var, ty, pipe } => Stmt::PipeRead {
                    var: format!("{var}{suffix}"),
                    ty: *ty,
                    pipe: pipe.clone(),
                },
            })
            .collect()
    }
    go(body, &fix_expr, &declared, &suffix)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{classify_index, AccessPattern};
    use crate::ir::build::*;
    use crate::ir::{validate_kernel, KernelKind, Ty};

    fn stream_kernel() -> Kernel {
        KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i2",
                i(0),
                p("n"),
                vec![let_f("x", ld("a", v("i2"))), store("o", v("i2"), v("x") * f(2.0))],
            )])
            .finish()
    }

    #[test]
    fn unrolled_kernel_validates_and_has_w_sites() {
        let k = stream_kernel();
        let vk = vectorize(&k, 4);
        assert_eq!(validate_kernel(&vk), Ok(()), "{}", crate::ir::pretty::kernel_to_string(&vk));
        assert_eq!(vk.load_count(), 4);
        assert_eq!(vk.store_count(), 4);
    }

    #[test]
    fn unrolled_loads_are_strided_w() {
        let vk = vectorize(&stream_kernel(), 4);
        // every load index is lo + vv*4 + u: strided by 4 w.r.t. vv
        let mut patterns = vec![];
        crate::ir::stmt::visit_body(&vk.body, &mut |s| {
            if let Stmt::Let { expr: Expr::Load { idx, .. }, .. } = s {
                patterns.push(classify_index(idx, Some("i2_v")));
            }
        });
        assert_eq!(patterns.len(), 4);
        assert!(patterns.iter().all(|p| *p == AccessPattern::Strided(4)));
    }

    #[test]
    fn only_innermost_unrolled() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "r",
                i(0),
                p("n"),
                vec![for_(
                    "c",
                    i(0),
                    p("n"),
                    vec![store("o", v("r") * p("n") + v("c"), ld("a", v("r") * p("n") + v("c")))],
                )],
            )])
            .finish();
        let vk = vectorize(&k, 2);
        assert_eq!(validate_kernel(&vk), Ok(()));
        let src = crate::ir::pretty::kernel_to_string(&vk);
        assert!(src.contains("for (int r = 0")); // outer untouched
        assert!(src.contains("for (int c_v = 0")); // inner widened
    }
}
