//! The paper's worked examples as IR kernels — Fig. 2 (the MIS-flavoured
//! min-over-uncolored-neighbours kernel) and Fig. 3b (the DLCD reduction
//! microkernel). Used by unit tests, the quickstart example and experiment
//! E5.

use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Ty};

/// Fig. 2a: the baseline single work-item kernel.
///
/// ```c
/// for (tid = 0; tid < num_nodes; tid++) {
///   if (c_array[tid] == -1) {
///     *stop = 1;
///     int start = row[tid];
///     int end = (tid+1 < num_nodes) ? row[tid+1] : num_edges;
///     float min = BIGNUM;
///     for (edge = start; edge < end; edge++)
///       if (c_array[col[edge]] == -1)
///         if (node_value[col[edge]] < min) min = node_value[col[edge]];
///     min_array[tid] = min;
///   }
/// }
/// ```
pub fn fig2_kernel() -> Kernel {
    KernelBuilder::new("mis1", KernelKind::SingleWorkItem)
        .buf_ro("c_array", Ty::I32)
        .buf_ro("row", Ty::I32)
        .buf_ro("col", Ty::I32)
        .buf_ro("node_value", Ty::F32)
        .buf_wo("min_array", Ty::F32)
        .buf_wo("stop", Ty::I32)
        .scalar("num_nodes", Ty::I32)
        .scalar("num_edges", Ty::I32)
        .body(vec![for_(
            "tid",
            i(0),
            p("num_nodes"),
            vec![if_(
                ld("c_array", v("tid")).eq_(i(-1)),
                vec![
                    store("stop", i(0), i(1)),
                    let_i("start", ld("row", v("tid"))),
                    let_i(
                        "end",
                        (v("tid") + i(1))
                            .lt(p("num_nodes"))
                            .sel(ld("row", v("tid") + i(1)), p("num_edges")),
                    ),
                    let_f("min", f(1.0e30)),
                    for_(
                        "edge",
                        v("start"),
                        v("end"),
                        vec![if_(
                            ld("c_array", ld("col", v("edge"))).eq_(i(-1)),
                            vec![if_(
                                ld("node_value", ld("col", v("edge"))).lt(v("min")),
                                vec![assign("min", ld("node_value", ld("col", v("edge"))))],
                            )],
                        )],
                    ),
                    store("min_array", v("tid"), v("min")),
                ],
            )],
        )])
        .finish()
}

/// Fig. 3b: the DLCD microkernel (5-tap reduction over a sliding window).
///
/// ```c
/// for (tid = 5; tid < num_nodes; tid++) {
///   r = 0;
///   for (iter = 0; iter < 5; iter++) { a = input[tid-iter]; r += a; }
///   output[tid] = r;
/// }
/// ```
pub fn fig3b_kernel() -> Kernel {
    KernelBuilder::new("dlcd", KernelKind::SingleWorkItem)
        .buf_ro("input", Ty::F32)
        .buf_wo("output", Ty::F32)
        .scalar("num_nodes", Ty::I32)
        .body(vec![for_(
            "tid",
            i(5),
            p("num_nodes"),
            vec![
                let_f("r", f(0.0)),
                for_(
                    "iter",
                    i(0),
                    i(5),
                    vec![
                        let_f("a", ld("input", v("tid") - v("iter"))),
                        assign("r", v("r") + v("a")),
                    ],
                ),
                store("output", v("tid"), v("r")),
            ],
        )])
        .finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::validate_kernel;

    #[test]
    fn examples_validate() {
        assert_eq!(validate_kernel(&fig2_kernel()), Ok(()));
        assert_eq!(validate_kernel(&fig3b_kernel()), Ok(()));
    }

    #[test]
    fn fig3b_has_dlcd_no_mlcd() {
        let lcd = crate::analysis::analyze_lcd(&fig3b_kernel());
        assert!(lcd.mlcds.is_empty());
        assert_eq!(lcd.dlcds.len(), 1);
        assert_eq!(lcd.dlcds[0].var, "r");
    }
}
