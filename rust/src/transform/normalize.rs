//! Load-naming normalization — the paper's step 5 ("allocating a local
//! variable for load instructions ... to increase the clarity of data
//! transfers").
//!
//! After this pass every global `Load` appears exactly as the full RHS of a
//! `Let` whose index expression is load-free, in evaluation order. Nested
//! indirection (`a[b[i]]`) becomes two `Let`s (`_ld0 = b[i]; _ld1 =
//! a[_ld0]`), which is precisely the form the feed-forward split needs:
//! one pipe per static load site.

use crate::ir::{Expr, Kernel, Stmt, Ty};

/// Prefix for compiler-introduced load temporaries.
pub const LOAD_TMP_PREFIX: &str = "_ld";

struct Ctx<'a> {
    kernel: &'a Kernel,
    counter: usize,
}

impl<'a> Ctx<'a> {
    fn fresh(&mut self) -> String {
        let name = format!("{LOAD_TMP_PREFIX}{}", self.counter);
        self.counter += 1;
        name
    }

    fn buf_ty(&self, buf: &str) -> Ty {
        self.kernel.buf(buf).map(|b| b.elem).unwrap_or(Ty::F32)
    }

    /// Hoist every load in `e` (inner-first = evaluation order) into `out`,
    /// returning the load-free rewritten expression.
    fn extract(&mut self, e: Expr, out: &mut Vec<Stmt>) -> Expr {
        match e {
            Expr::Load { buf, idx } => {
                let idx = self.extract(*idx, out);
                let ty = self.buf_ty(&buf);
                let var = self.fresh();
                out.push(Stmt::Let {
                    var: var.clone(),
                    ty,
                    expr: Expr::Load { buf, idx: Box::new(idx) },
                });
                Expr::Var(var)
            }
            Expr::Bin(op, a, b) => {
                let a = self.extract(*a, out);
                let b = self.extract(*b, out);
                Expr::Bin(op, Box::new(a), Box::new(b))
            }
            Expr::Un(op, a) => {
                let a = self.extract(*a, out);
                Expr::Un(op, Box::new(a))
            }
            Expr::Select(c, t, f) => {
                // NOTE: both arms are hoisted unconditionally; `Select` in
                // our benchmarks never guards loads (If statements do), so
                // this preserves the trace. The validator keeps this true.
                let c = self.extract(*c, out);
                let t = self.extract(*t, out);
                let f = self.extract(*f, out);
                Expr::Select(Box::new(c), Box::new(t), Box::new(f))
            }
            other => other,
        }
    }

    fn rewrite_body(&mut self, body: Vec<Stmt>) -> Vec<Stmt> {
        let mut out = vec![];
        for s in body {
            match s {
                Stmt::Let { var, ty, expr } => {
                    // Already-named load with a load-free index: keep as-is.
                    if let Expr::Load { ref idx, .. } = expr {
                        if !idx.has_load() {
                            out.push(Stmt::Let { var, ty, expr });
                            continue;
                        }
                    }
                    let expr = self.extract(expr, &mut out);
                    out.push(Stmt::Let { var, ty, expr });
                }
                Stmt::Assign { var, expr } => {
                    let expr = self.extract(expr, &mut out);
                    out.push(Stmt::Assign { var, expr });
                }
                Stmt::Store { buf, idx, val } => {
                    let idx = self.extract(idx, &mut out);
                    let val = self.extract(val, &mut out);
                    out.push(Stmt::Store { buf, idx, val });
                }
                Stmt::If { cond, then_b, else_b } => {
                    let cond = self.extract(cond, &mut out);
                    let then_b = self.rewrite_body(then_b);
                    let else_b = self.rewrite_body(else_b);
                    out.push(Stmt::If { cond, then_b, else_b });
                }
                Stmt::For { id, var, lo, hi, body } => {
                    let lo = self.extract(lo, &mut out);
                    let hi = self.extract(hi, &mut out);
                    let body = self.rewrite_body(body);
                    out.push(Stmt::For { id, var, lo, hi, body });
                }
                Stmt::PipeWrite { pipe, val } => {
                    let val = self.extract(val, &mut out);
                    out.push(Stmt::PipeWrite { pipe, val });
                }
                s @ Stmt::PipeRead { .. } => out.push(s),
            }
        }
        out
    }
}

/// Normalize a kernel into named-load form.
pub fn name_loads(kernel: &Kernel) -> Kernel {
    let mut k = kernel.clone();
    let mut ctx = Ctx { kernel, counter: 0 };
    k.body = ctx.rewrite_body(std::mem::take(&mut k.body));
    k
}

/// True if every load is the full RHS of a `Let` with a load-free index.
pub fn is_load_named(kernel: &Kernel) -> bool {
    let mut ok = true;
    crate::ir::stmt::visit_body(&kernel.body, &mut |s| {
        match s {
            Stmt::Let { expr: Expr::Load { idx, .. }, .. } => {
                if idx.has_load() {
                    ok = false;
                }
            }
            other => {
                other.visit_own_exprs(&mut |e| {
                    if e.has_load() {
                        ok = false;
                    }
                });
            }
        }
    });
    ok
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{validate_kernel, KernelKind};

    #[test]
    fn hoists_nested_indirection_in_eval_order() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("col", Ty::I32)
            .buf_ro("val", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("val", ld("col", v("i"))) * f(2.0))],
            )])
            .finish();
        assert!(!is_load_named(&k));
        let n = name_loads(&k);
        assert!(is_load_named(&n));
        assert_eq!(validate_kernel(&n), Ok(()));
        // inner (col) hoisted before outer (val)
        let src = crate::ir::pretty::kernel_to_string(&n);
        let col_pos = src.find("_ld0 = col[i]").unwrap();
        let val_pos = src.find("_ld1 = val[_ld0]").unwrap();
        assert!(col_pos < val_pos);
        assert_eq!(n.load_count(), 2);
    }

    #[test]
    fn hoists_condition_loads_before_if() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("c", Ty::I32)
            .buf_wo("o", Ty::I32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "t",
                i(0),
                p("n"),
                vec![if_(ld("c", v("t")).eq_(i(-1)), vec![store("o", v("t"), i(1))])],
            )])
            .finish();
        let n = name_loads(&k);
        assert!(is_load_named(&n));
        let src = crate::ir::pretty::kernel_to_string(&n);
        assert!(src.contains("int _ld0 = c[t];"));
        assert!(src.contains("if ((_ld0 == -1))"));
    }

    #[test]
    fn keeps_already_named_loads() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![let_f("x", ld("a", v("i"))), store("o", v("i"), v("x"))],
            )])
            .finish();
        let n = name_loads(&k);
        let src = crate::ir::pretty::kernel_to_string(&n);
        assert!(src.contains("float x = a[i];"));
        assert!(!src.contains("_ld0"));
    }

    #[test]
    fn idempotent() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_ro("b", Ty::I32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("a", ld("b", v("i"))) + ld("a", v("i")))],
            )])
            .finish();
        let n1 = name_loads(&k);
        let n2 = name_loads(&n1);
        assert_eq!(n1.body, n2.body);
    }
}
