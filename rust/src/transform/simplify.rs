//! Control-flow and expression simplification — the paper's step 13
//! ("checking the control flow statements conditions inside each kernel
//! again and simplifying them if possible"), run between the two DCE
//! passes.
//!
//! * constant folding over expressions,
//! * `if (const)` branch inlining,
//! * constant-empty `for` removal,
//! * removal of empty `if`/`for` shells.

use crate::ir::{Expr, Kernel, Stmt, Val};

/// Fold literal subtrees bottom-up.
pub fn fold_expr(e: Expr) -> Expr {
    e.map(&|node| match &node {
        Expr::Bin(op, a, b) => match (as_lit(a), as_lit(b)) {
            (Some(x), Some(y)) => lit(Expr::eval_bin(*op, x, y)),
            _ => {
                // Algebraic identities: x+0, x*1, x*0, 0+x, 1*x.
                use crate::ir::BinOp::*;
                match (op, as_lit(a), as_lit(b)) {
                    (Add, _, Some(Val::I(0))) => (**a).clone(),
                    (Add, Some(Val::I(0)), _) => (**b).clone(),
                    (Sub, _, Some(Val::I(0))) => (**a).clone(),
                    (Mul, _, Some(Val::I(1))) => (**a).clone(),
                    (Mul, Some(Val::I(1)), _) => (**b).clone(),
                    (Mul, _, Some(Val::I(0))) => Expr::I(0),
                    (Mul, Some(Val::I(0)), _) => Expr::I(0),
                    _ => node,
                }
            }
        },
        Expr::Un(op, a) => match as_lit(a) {
            Some(x) => lit(Expr::eval_un(*op, x)),
            None => node,
        },
        Expr::Select(c, t, f) => match as_lit(c) {
            Some(v) => {
                if v.is_true() {
                    (**t).clone()
                } else {
                    (**f).clone()
                }
            }
            None => node,
        },
        _ => node,
    })
}

fn as_lit(e: &Expr) -> Option<Val> {
    match e {
        Expr::I(v) => Some(Val::I(*v)),
        Expr::F(v) => Some(Val::F(*v)),
        _ => None,
    }
}

fn lit(v: Val) -> Expr {
    match v {
        Val::I(x) => Expr::I(x),
        Val::F(x) => Expr::F(x),
    }
}

fn simplify_body(body: Vec<Stmt>) -> Vec<Stmt> {
    let mut out = vec![];
    for s in body {
        match s {
            Stmt::Let { var, ty, expr } => out.push(Stmt::Let { var, ty, expr: fold_expr(expr) }),
            Stmt::Assign { var, expr } => out.push(Stmt::Assign { var, expr: fold_expr(expr) }),
            Stmt::Store { buf, idx, val } => {
                out.push(Stmt::Store { buf, idx: fold_expr(idx), val: fold_expr(val) })
            }
            Stmt::PipeWrite { pipe, val } => out.push(Stmt::PipeWrite { pipe, val: fold_expr(val) }),
            s @ Stmt::PipeRead { .. } => out.push(s),
            Stmt::If { cond, then_b, else_b } => {
                let cond = fold_expr(cond);
                let then_b = simplify_body(then_b);
                let else_b = simplify_body(else_b);
                match as_lit(&cond) {
                    Some(v) => {
                        // if (const): inline the taken branch
                        let taken = if v.is_true() { then_b } else { else_b };
                        out.extend(taken);
                    }
                    None => {
                        if then_b.is_empty() && else_b.is_empty() {
                            continue; // empty shell
                        }
                        out.push(Stmt::If { cond, then_b, else_b });
                    }
                }
            }
            Stmt::For { id, var, lo, hi, body } => {
                let lo = fold_expr(lo);
                let hi = fold_expr(hi);
                let body = simplify_body(body);
                if body.is_empty() {
                    continue;
                }
                if let (Some(Val::I(a)), Some(Val::I(b))) = (as_lit(&lo), as_lit(&hi)) {
                    if a >= b {
                        continue; // constant-empty range
                    }
                }
                out.push(Stmt::For { id, var, lo, hi, body });
            }
        }
    }
    out
}

/// Simplify a kernel in place (returns a new kernel).
pub fn simplify_kernel(kernel: &Kernel) -> Kernel {
    let mut k = kernel.clone();
    k.body = simplify_body(std::mem::take(&mut k.body));
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, Ty};

    #[test]
    fn folds_constants() {
        let e = fold_expr((i(2) + i(3)) * i(4));
        assert_eq!(e, Expr::I(20));
        let e = fold_expr(v("x") + i(0));
        assert_eq!(e, v("x"));
        let e = fold_expr(v("x") * i(0));
        assert_eq!(e, Expr::I(0));
    }

    #[test]
    fn inlines_constant_branches() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_wo("o", Ty::I32)
            .body(vec![
                if_else(i(1).eq_(i(1)), vec![store("o", i(0), i(42))], vec![store("o", i(0), i(7))]),
                if_(i(0).gt(i(5)), vec![store("o", i(1), i(9))]),
            ])
            .finish();
        let s = simplify_kernel(&k);
        assert_eq!(s.body.len(), 1);
        assert!(matches!(&s.body[0], Stmt::Store { val: Expr::I(42), .. }));
    }

    #[test]
    fn drops_constant_empty_loop_and_empty_shells() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_wo("o", Ty::I32)
            .scalar("n", Ty::I32)
            .body(vec![
                for_("i", i(5), i(5), vec![store("o", v("i"), i(1))]),
                if_(p("n").gt(i(0)), vec![]),
                store("o", i(0), i(2)),
            ])
            .finish();
        let s = simplify_kernel(&k);
        assert_eq!(s.body.len(), 1);
    }
}
