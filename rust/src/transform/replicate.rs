//! Multiple producers / multiple consumers (paper step 12 and §4.2,
//! "M2C2"): instantiate the memory/compute pair R times, each replica
//! working on a contiguous block of the outer iteration space (static load
//! balancing — the paper found dynamic balancing's busy-waits
//! counterproductive on FPGA).
//!
//! Also supports the paper's explored-and-rejected 1-producer/N-consumer
//! shape (`replicate_1p`), used by the E4d sweep to reproduce the finding
//! that separate producers beat a shared one.

use crate::ir::{Expr, Kernel, PipeDecl, Program, Stmt};

/// Split `[lo, hi)` into `parts` contiguous integer ranges at the IR level.
fn range_bounds(lo: &Expr, hi: &Expr, r: usize, parts: usize) -> (Expr, Expr) {
    let span = Expr::Bin(
        crate::ir::BinOp::Sub,
        Box::new(hi.clone()),
        Box::new(lo.clone()),
    );
    let chunk = |k: usize| -> Expr {
        // lo + span * k / parts  (evaluated in i64, ordered to avoid
        // overflow-free is fine at our sizes)
        Expr::Bin(
            crate::ir::BinOp::Add,
            Box::new(lo.clone()),
            Box::new(Expr::Bin(
                crate::ir::BinOp::Div,
                Box::new(Expr::Bin(
                    crate::ir::BinOp::Mul,
                    Box::new(span.clone()),
                    Box::new(Expr::I(k as i64)),
                )),
                Box::new(Expr::I(parts as i64)),
            )),
        )
    };
    let lo_r = if r == 0 { lo.clone() } else { chunk(r) };
    let hi_r = if r + 1 == parts { hi.clone() } else { chunk(r + 1) };
    (lo_r, hi_r)
}

/// Rename every pipe endpoint in a body with a replica suffix.
fn suffix_pipes(body: &mut [Stmt], suffix: &str) {
    for s in body.iter_mut() {
        match s {
            Stmt::PipeWrite { pipe, .. } => pipe.push_str(suffix),
            Stmt::PipeRead { pipe, .. } => pipe.push_str(suffix),
            Stmt::If { then_b, else_b, .. } => {
                suffix_pipes(then_b, suffix);
                suffix_pipes(else_b, suffix);
            }
            Stmt::For { body, .. } => suffix_pipes(body, suffix),
            _ => {}
        }
    }
}

/// Build replica `r` of `parts` for one kernel: its *top-level* loop's
/// bounds are narrowed to the r-th contiguous block; pipes are suffixed.
/// Panics if the kernel body has no top-level loop (all feed-forward
/// kernels in this codebase are a single outer loop, possibly after a
/// preamble of scalar `Let`s).
fn replica(k: &Kernel, r: usize, parts: usize) -> Kernel {
    let mut nk = k.clone();
    nk.name = format!("{}_r{r}", k.name);
    let suffix = format!("_r{r}");
    let mut narrowed = false;
    for s in nk.body.iter_mut() {
        if let Stmt::For { lo, hi, .. } = s {
            let (lo_r, hi_r) = range_bounds(lo, hi, r, parts);
            *lo = lo_r;
            *hi = hi_r;
            narrowed = true;
            break;
        }
    }
    assert!(narrowed, "kernel {} has no top-level loop to split", k.name);
    suffix_pipes(&mut nk.body, &suffix);
    let mut next = 0;
    crate::ir::build::assign_loop_ids(&mut nk.body, &mut next);
    nk
}

/// R memory kernels + R compute kernels over contiguous blocks ("MxCx").
/// `prog` must be a feed-forward pair (2 kernels). R=2 gives the paper's
/// M2C2 configuration.
pub fn replicate(prog: &Program, parts: usize) -> Program {
    assert!(parts >= 1);
    assert_eq!(prog.kernels.len(), 2, "replicate expects a feed-forward pair");
    if parts == 1 {
        return prog.clone();
    }
    let mut kernels = vec![];
    let mut pipes: Vec<PipeDecl> = vec![];
    for r in 0..parts {
        for k in &prog.kernels {
            kernels.push(replica(k, r, parts));
        }
        for pd in &prog.pipes {
            pipes.push(PipeDecl {
                name: format!("{}_r{r}", pd.name),
                ty: pd.ty,
                depth: pd.depth,
            });
        }
    }
    Program { name: format!("{}_m{parts}c{parts}", prog.name), kernels, pipes }
}

/// One shared producer + N consumers ("M1CN", explored and found inferior
/// by the paper): the memory kernel runs the N consumer ranges back to
/// back, each feeding that consumer's pipe set.
pub fn replicate_1p(prog: &Program, consumers: usize) -> Program {
    assert!(consumers >= 1);
    assert_eq!(prog.kernels.len(), 2, "replicate_1p expects a feed-forward pair");
    if consumers == 1 {
        return prog.clone();
    }
    let mem = &prog.kernels[0];
    let cmp = &prog.kernels[1];

    // Producer: concatenate the per-range bodies sequentially.
    let mut mem_body = vec![];
    for r in 0..consumers {
        let rep = replica(mem, r, consumers);
        mem_body.extend(rep.body);
    }
    let mut prod = mem.clone();
    prod.name = format!("{}_1p", mem.name);
    prod.body = mem_body;
    let mut next = 0;
    crate::ir::build::assign_loop_ids(&mut prod.body, &mut next);

    let mut kernels = vec![prod];
    let mut pipes = vec![];
    for r in 0..consumers {
        kernels.push(replica(cmp, r, consumers));
        for pd in &prog.pipes {
            pipes.push(PipeDecl {
                name: format!("{}_r{r}", pd.name),
                ty: pd.ty,
                depth: pd.depth,
            });
        }
    }
    Program { name: format!("{}_m1c{consumers}", prog.name), kernels, pipes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::validate_program;
    use crate::transform::examples::fig2_kernel;
    use crate::transform::feedforward::feedforward;

    #[test]
    fn m2c2_has_four_kernels_and_doubled_pipes() {
        let ff = feedforward(&fig2_kernel(), 1).unwrap();
        let m2 = replicate(&ff, 2);
        assert_eq!(m2.kernels.len(), 4);
        assert_eq!(m2.pipes.len(), 2 * ff.pipes.len());
        assert_eq!(validate_program(&m2), Ok(()));
    }

    #[test]
    fn m1c2_has_one_producer() {
        let ff = feedforward(&fig2_kernel(), 1).unwrap();
        let m1 = replicate_1p(&ff, 2);
        assert_eq!(m1.kernels.len(), 3);
        assert_eq!(validate_program(&m1), Ok(()));
        // The producer writes to both replicas' pipe sets.
        let prod = &m1.kernels[0];
        let mut pipes_written = std::collections::HashSet::new();
        crate::ir::stmt::visit_body(&prod.body, &mut |s| {
            if let Stmt::PipeWrite { pipe, .. } = s {
                pipes_written.insert(pipe.clone());
            }
        });
        assert_eq!(pipes_written.len(), m1.pipes.len());
    }

    #[test]
    fn parts_1_is_identity() {
        let ff = feedforward(&fig2_kernel(), 1).unwrap();
        assert_eq!(replicate(&ff, 1), ff);
    }
}
