//! Minimal JSON tree + writer + parser (std-only stand-in for
//! `serde_json`, unavailable offline). Object keys keep insertion order so
//! serialization is deterministic — the bench sink relies on byte-identical
//! output between the serial and parallel engines.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object construction without the `.into()` noise — the wire-protocol
    /// codec (`coordinator::service`) builds many small documents.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Non-negative integral number as `usize` (counters like the tune
    /// report's `probes`/`budget` fields); fractional or negative numbers
    /// are `None`, not truncated.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9.0e15 => Some(*n as usize),
            _ => None,
        }
    }

    /// Non-negative integral number as `u64` (the engine's tier counters
    /// travel through counters/stats documents); same no-truncation
    /// contract as [`Json::as_usize`].
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9.0e15 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Compact one-line serialization.
    pub fn to_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indentation and a trailing newline.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * level) {
            out.push(' ');
        }
    }
}

/// Deterministic number formatting: integers without a fraction, everything
/// else via Rust's shortest-round-trip float display. Non-finite values have
/// no JSON representation and become `null`.
fn fmt_num(n: f64) -> String {
    if !n.is_finite() {
        return "null".to_string();
    }
    if n == n.trunc() && n.abs() < 9.0e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Read and parse a JSON file. IO and parse failures both come back as a
/// descriptive string naming the path, so callers can treat any failure as
/// "not a valid document" (the persistent measurement store treats that as
/// a cache miss).
pub fn read_file(path: &std::path::Path) -> Result<Json, String> {
    // `store.read` injection site: a read that returns garbage is
    // indistinguishable from on-disk corruption, which every caller
    // already treats as "no such document".
    if super::fault::fire("store.read") {
        return Err(format!("fault: injected read corruption at {}", path.display()));
    }
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("parsing {}: {e}", path.display()))
}

/// Write a document as canonical pretty JSON via a temp file + rename in
/// the destination directory, so concurrent writers of the same path never
/// expose a torn file — readers see either the old bytes or the new bytes.
pub fn write_file_atomic(path: &std::path::Path, doc: &Json) -> std::io::Result<()> {
    write_bytes_atomic(path, doc.to_pretty())
}

/// [`write_file_atomic`] with compact (single-line) serialization — for
/// bulk records like the measurement store's trace tier, where the pretty
/// form would triple the disk footprint for no reader.
pub fn write_file_atomic_compact(path: &std::path::Path, doc: &Json) -> std::io::Result<()> {
    let mut text = doc.to_compact();
    text.push('\n');
    write_bytes_atomic(path, text)
}

/// Atomically write pre-serialized compact JSON (plus the conventional
/// trailing newline). The measurement store's profile pool hashes the
/// canonical compact bytes to derive the file name *before* writing — this
/// entry point avoids re-serializing (and the risk of the hashed and
/// written bytes drifting apart).
pub fn write_text_atomic(path: &std::path::Path, compact: &str) -> std::io::Result<()> {
    let mut text = compact.to_string();
    text.push('\n');
    write_bytes_atomic(path, text)
}

fn write_bytes_atomic(path: &std::path::Path, bytes: String) -> std::io::Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty());
    let file = path.file_name().unwrap_or_default().to_string_lossy().to_string();
    let tmp_name = format!(
        ".{file}.tmp-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    );
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };
    // `store.write` injection site: model a crash/ENOSPC mid-write —
    // half the bytes reach the temp file, the rename never happens.
    // Readers never see the torn file (wrong name); the dropping is
    // swept by `Store::open`'s healing pass like real crash debris.
    if super::fault::fire("store.write") {
        let _ = std::fs::write(&tmp, &bytes.as_bytes()[..bytes.len() / 2]);
        return Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("fault: injected torn write at {} (simulated ENOSPC)", path.display()),
        ));
    }
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path).inspect_err(|_| {
        let _ = std::fs::remove_file(&tmp);
    })
}

/// Parse a JSON document (the subset this crate writes, plus standard
/// escapes). Returns a descriptive error with a byte offset on failure.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.i)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = vec![];
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            fields.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.i)),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number `{s}`: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let cp = if (0xd800..0xdc00).contains(&hi)
                                && self.b[self.i..].starts_with(b"\\u")
                            {
                                self.i += 2;
                                let lo = self.hex4()?;
                                0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                            } else {
                                hi
                            };
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            continue; // hex4 already advanced past the digits
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar (multi-byte safe)
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| format!("invalid utf-8 in string: {e}"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".to_string());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|e| e.to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|e| format!("bad \\u escape `{s}`: {e}"))?;
        self.i += 4;
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obj_builder_matches_literal_form() {
        let a = Json::obj(vec![("k", Json::Num(1.0)), ("s", Json::Str("x".into()))]);
        let b = Json::Obj(vec![("k".into(), Json::Num(1.0)), ("s".into(), Json::Str("x".into()))]);
        assert_eq!(a, b);
        assert_eq!(a.to_compact(), "{\"k\":1,\"s\":\"x\"}");
    }

    #[test]
    fn as_u64_accepts_only_nonnegative_integers() {
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Str("7".into()).as_u64(), None);
    }

    #[test]
    fn roundtrip_document() {
        let doc = Json::Obj(vec![
            ("name".into(), Json::Str("fw \"quoted\"\n".into())),
            ("n".into(), Json::Num(42.0)),
            ("bw".into(), Json::Num(0.125)),
            ("ok".into(), Json::Bool(true)),
            ("none".into(), Json::Null),
            ("xs".into(), Json::Arr(vec![Json::Num(1.0), Json::Num(-2.5)])),
            ("empty".into(), Json::Obj(vec![])),
        ]);
        for text in [doc.to_compact(), doc.to_pretty()] {
            assert_eq!(parse(&text).unwrap(), doc, "text: {text}");
        }
    }

    #[test]
    fn integers_have_no_fraction() {
        assert_eq!(Json::Num(3.0).to_compact(), "3");
        assert_eq!(Json::Num(-3.0).to_compact(), "-3");
        assert_eq!(Json::Num(0.5).to_compact(), "0.5");
        assert_eq!(Json::Num(f64::NAN).to_compact(), "null");
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(40.0).as_usize(), Some(40));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(0.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Str("40".into()).as_usize(), None);
    }

    #[test]
    fn parser_handles_escapes_and_unicode() {
        let v = parse(r#"{"s": "a\tbA😀"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\tbA\u{1f600}");
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn file_roundtrip_is_atomic_and_canonical() {
        let dir = std::env::temp_dir().join(format!("pipefwd-json-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("doc.json");
        let doc = Json::Obj(vec![("k".into(), Json::Num(1.5))]);
        write_file_atomic(&path, &doc).unwrap();
        assert_eq!(read_file(&path).unwrap(), doc);
        assert_eq!(std::fs::read_to_string(&path).unwrap(), doc.to_pretty());
        // overwrite goes through the same rename path
        let doc2 = Json::Arr(vec![Json::Bool(true)]);
        write_file_atomic(&path, &doc2).unwrap();
        assert_eq!(read_file(&path).unwrap(), doc2);
        // no temp droppings left behind
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| e.as_ref().unwrap().file_name().to_string_lossy().contains(".tmp-"))
            .collect();
        assert!(leftovers.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_file_reports_io_and_parse_errors() {
        let dir = std::env::temp_dir().join(format!("pipefwd-json-err-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(read_file(&dir.join("absent.json")).is_err());
        let bad = dir.join("bad.json");
        std::fs::write(&bad, "{ not json").unwrap();
        assert!(read_file(&bad).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn deterministic_output_is_stable() {
        let doc = Json::Arr(vec![Json::Num(1.0), Json::Str("x".into())]);
        assert_eq!(doc.to_compact(), doc.to_compact());
        assert_eq!(doc.to_pretty(), "[\n  1,\n  \"x\"\n]\n");
    }
}
