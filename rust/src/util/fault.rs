//! Deterministic fault injection for the robustness harness.
//!
//! A [`FaultPlan`] is a seeded schedule of failures at *named injection
//! sites* threaded through the codebase's IO/network seams (see
//! [`SITES`]). The plan is configured once per process — from
//! `--fault-plan SPEC`, the `PIPEFWD_FAULT_PLAN` environment variable,
//! or programmatically in tests via [`install`] — and every decision it
//! makes is a pure function of the plan seed and the per-site call
//! index, driven by [`crate::util::rng::Rng`] (xorshift64*). Two runs of
//! the same binary with the same plan observe the same Nth-call verdict
//! at every site, regardless of wall clock.
//!
//! # Spec grammar
//!
//! ```text
//! SPEC   := CLAUSE ( ';' CLAUSE )*
//! CLAUSE := 'seed=' u64            -- plan seed (default 1)
//!         | SITE '=' RATE LIMIT?   -- arm a site
//! SITE   := one of `SITES` (e.g. store.write, net.read, engine.panic)
//! RATE   := probability in [0,1] (e.g. 0.25), or 'always'
//! LIMIT  := 'x' u64                -- fire at most this many times
//! ```
//!
//! Example: `seed=42;store.write=0.25x4;net.read=0.1;engine.panic=1x1`
//! — with seed 42, fail up to four store writes at 25 % each, reset 10 %
//! of daemon reads, and panic exactly one engine worker.
//!
//! # Cost when disarmed
//!
//! [`fire`] is the only call on hot paths. With no plan installed it is
//! a single relaxed atomic load and an immediate `false` — the branch is
//! trivially predictable and the slow path is `#[cold]`, so release
//! binaries pay effectively nothing. An *empty* plan (no spec anywhere)
//! therefore leaves every byte of engine/store/daemon behavior
//! identical to a build without this module.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use super::rng::Rng;

/// Catalog of named injection sites. Each is documented at its hook:
///
/// | site           | seam                                                  |
/// |----------------|-------------------------------------------------------|
/// | `store.read`   | `util::json::read_file` — read returns garbage        |
/// | `store.write`  | `util::json` atomic writes — torn temp file + ENOSPC  |
/// | `store.evict`  | budget eviction delete loop — batch dies mid-delete   |
/// | `net.accept`   | daemon accept loop — connection reset after accept    |
/// | `net.read`     | daemon request read — drop mid-request                |
/// | `net.write`    | daemon response write — truncate the NDJSON stream    |
/// | `engine.panic` | engine measurement under claim — worker panics        |
pub const SITES: &[&str] = &[
    "store.read",
    "store.write",
    "store.evict",
    "net.accept",
    "net.read",
    "net.write",
    "engine.panic",
];

/// One armed site: fire with probability `rate` on each call, at most
/// `max` times total (`None` = unbounded).
#[derive(Debug, Clone, PartialEq)]
pub struct Rule {
    pub site: String,
    pub rate: f64,
    pub max: Option<u64>,
}

/// A parsed, seeded fault schedule. Inert until [`install`]ed.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<Rule>,
}

impl FaultPlan {
    /// Parse the spec grammar (module docs). `Err` carries a message
    /// naming the offending clause.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan { seed: 1, rules: vec![] };
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let (key, val) = clause
                .split_once('=')
                .ok_or_else(|| format!("fault plan clause `{clause}` is not key=value"))?;
            let (key, val) = (key.trim(), val.trim());
            if key == "seed" {
                plan.seed = val
                    .parse::<u64>()
                    .map_err(|_| format!("fault plan seed `{val}` is not a u64"))?;
                continue;
            }
            if !SITES.contains(&key) {
                return Err(format!(
                    "unknown fault site `{key}` (known: {})",
                    SITES.join(", ")
                ));
            }
            let (rate_s, max) = match val.split_once('x') {
                Some((r, m)) => {
                    let m = m.trim();
                    let m = m
                        .parse::<u64>()
                        .map_err(|_| format!("fault limit `{m}` for `{key}` is not a u64"))?;
                    (r.trim(), Some(m))
                }
                None => (val, None),
            };
            let rate = if rate_s == "always" {
                1.0
            } else {
                let r: f64 = rate_s
                    .parse()
                    .map_err(|_| format!("fault rate `{rate_s}` for `{key}` is not a number"))?;
                if !(0.0..=1.0).contains(&r) {
                    return Err(format!("fault rate {r} for `{key}` is outside [0, 1]"));
                }
                r
            };
            plan.rules.push(Rule { site: key.to_string(), rate, max });
        }
        Ok(plan)
    }

    fn is_armed(&self) -> bool {
        self.rules.iter().any(|r| r.rate > 0.0 && r.max != Some(0))
    }
}

/// Live per-site state: its own deterministic RNG stream (seeded from
/// the plan seed and the site name, so arming one site never perturbs
/// another's schedule) plus the fired count against `max`.
struct SiteState {
    rule: Rule,
    rng: Rng,
    fired: u64,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static FIRED_TOTAL: AtomicU64 = AtomicU64::new(0);
static STATE: Mutex<Vec<SiteState>> = Mutex::new(Vec::new());

fn site_seed(plan_seed: u64, site: &str) -> u64 {
    // FNV-1a over the site name folded into the plan seed: distinct,
    // stable streams per site.
    let mut h: u64 = 0xcbf29ce484222325 ^ plan_seed;
    for b in site.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Arm a plan process-wide, replacing any previous one and resetting
/// all counters. Installing a plan with no effective rules disarms the
/// fast path entirely (equivalent to [`clear`]).
pub fn install(plan: FaultPlan) {
    let mut state = STATE.lock().unwrap();
    state.clear();
    for rule in &plan.rules {
        state.push(SiteState {
            rule: rule.clone(),
            rng: Rng::new(site_seed(plan.seed, &rule.site)),
            fired: 0,
        });
    }
    FIRED_TOTAL.store(0, Ordering::Relaxed);
    ACTIVE.store(plan.is_armed(), Ordering::SeqCst);
}

/// Disarm fault injection (the default state).
pub fn clear() {
    let mut state = STATE.lock().unwrap();
    state.clear();
    ACTIVE.store(false, Ordering::SeqCst);
}

/// Install from an explicit spec (`--fault-plan`) or, failing that, the
/// `PIPEFWD_FAULT_PLAN` environment variable. No-op when neither is set.
pub fn install_from(spec: Option<&str>) -> Result<(), String> {
    let env = std::env::var("PIPEFWD_FAULT_PLAN").ok();
    let spec = spec.map(str::to_string).or(env);
    match spec {
        Some(s) if !s.trim().is_empty() => {
            install(FaultPlan::parse(&s)?);
            Ok(())
        }
        _ => Ok(()),
    }
}

/// Whether any site is armed. One relaxed load — safe on hot paths.
#[inline]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Total faults fired since the plan was installed (all sites).
pub fn fired_total() -> u64 {
    FIRED_TOTAL.load(Ordering::Relaxed)
}

/// Deterministic verdict for one call at `site`. The Nth call at a
/// given site always gets the same verdict for the same plan; which
/// *operation* is the Nth call depends on thread interleaving, which is
/// why recovery — not the schedule — must make outcomes reproducible.
#[inline]
pub fn fire(site: &str) -> bool {
    if !active() {
        return false;
    }
    fire_slow(site)
}

#[cold]
fn fire_slow(site: &str) -> bool {
    let mut state = STATE.lock().unwrap();
    let Some(s) = state.iter_mut().find(|s| s.rule.site == site) else {
        return false;
    };
    if let Some(max) = s.rule.max {
        if s.fired >= max {
            return false;
        }
    }
    if !s.rng.chance(s.rule.rate) {
        return false;
    }
    s.fired += 1;
    FIRED_TOTAL.fetch_add(1, Ordering::Relaxed);
    true
}

/// Panic with a recognizable payload if `site` fires — the
/// `engine.panic` hook. Callers sit under `catch_unwind` (the daemon's
/// worker pool) or a claim guard that releases on unwind, so an
/// injected panic is recoverable by retrying the request.
#[inline]
pub fn maybe_panic(site: &str) {
    if fire(site) {
        panic!("fault: injected panic at `{site}`");
    }
}

/// An injected IO error if `site` fires — the store/net error hook.
#[inline]
pub fn maybe_io_error(site: &str) -> std::io::Result<()> {
    if fire(site) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("fault: injected io error at `{site}` (simulated ENOSPC)"),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Fault state is process-global and the library's unit tests run
    // concurrently: a test here that *arms* a plan would inject faults
    // into unrelated store/net tests mid-flight. Only tests that leave
    // the fast path disarmed belong in this module — everything that
    // actually fires lives in `tests/integration_faults.rs`, a separate
    // process that serializes its own cases.

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse("seed=42; store.write=0.25x4 ;net.read=0.1;engine.panic=always x1")
            .unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(p.seed, 42);
        assert_eq!(p.rules.len(), 3);
        assert_eq!(p.rules[0], Rule { site: "store.write".into(), rate: 0.25, max: Some(4) });
        assert_eq!(p.rules[1], Rule { site: "net.read".into(), rate: 0.1, max: None });
        assert_eq!(p.rules[2], Rule { site: "engine.panic".into(), rate: 1.0, max: Some(1) });
    }

    #[test]
    fn parse_rejects_bad_specs() {
        for bad in [
            "store.write",          // no value
            "nope.site=0.5",        // unknown site
            "store.write=1.5",      // rate out of range
            "store.write=0.5xzz",   // bad limit
            "seed=minus-one",       // bad seed
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn disarmed_is_inert_and_free() {
        assert!(!active());
        assert!(!fire("store.write"));
        assert_eq!(maybe_io_error("store.write").map_err(|e| e.to_string()), Ok(()));
        maybe_panic("engine.panic"); // must not panic
    }

    #[test]
    fn empty_plan_never_arms() {
        install(FaultPlan::parse("seed=7").unwrap());
        assert!(!active(), "a plan with no rules must stay disarmed");
        install(FaultPlan::parse("seed=7;store.write=0x5;net.read=0.5x0").unwrap());
        assert!(!active(), "zero-rate / zero-limit rules must stay disarmed");
        clear();
    }

    #[test]
    fn install_from_rejects_bad_and_tolerates_absent_specs() {
        install_from(None).unwrap(); // env unset in tests → stays clear
        assert!(!active());
        assert!(install_from(Some("bogus")).is_err());
        assert!(!active());
    }
}
