//! Minimal bench harness (std-only stand-in for `criterion`, unavailable
//! offline). Benches are `harness = false` binaries that regenerate the
//! paper's tables/figures and report both the *modelled* FPGA numbers and
//! the wall-clock cost of the simulation itself (the §Perf signal).

use std::time::Instant;

pub struct BenchReport {
    name: String,
    rows: Vec<(String, f64)>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        println!("==== bench: {name} ====");
        BenchReport { name: name.to_string(), rows: vec![] }
    }

    /// Time one sample of `f`, print and record it.
    pub fn sample<T>(&mut self, label: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        println!("{:>40}  wall {:>9.3}s", format!("{}/{label}", self.name), dt);
        self.rows.push((label.to_string(), dt));
        out
    }

    /// Total wall time of all samples.
    pub fn total(&self) -> f64 {
        self.rows.iter().map(|(_, t)| t).sum()
    }

    pub fn finish(self) {
        println!("{:>40}  wall {:>9.3}s", format!("{}/total", self.name), self.total());
    }
}

/// Scale selection for benches: `PIPEFWD_BENCH_SCALE=tiny|small|paper`.
pub fn bench_scale() -> crate::workloads::Scale {
    match std::env::var("PIPEFWD_BENCH_SCALE").as_deref() {
        Ok("tiny") => crate::workloads::Scale::Tiny,
        Ok("paper") => crate::workloads::Scale::Paper,
        _ => crate::workloads::Scale::Small,
    }
}

/// Engine worker count for benches: `PIPEFWD_BENCH_JOBS=N` (default: all
/// available cores).
pub fn bench_jobs() -> usize {
    std::env::var("PIPEFWD_BENCH_JOBS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|n| *n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_records_and_returns() {
        let mut b = BenchReport::new("t");
        let x = b.sample("s", || 41 + 1);
        assert_eq!(x, 42);
        assert_eq!(b.rows.len(), 1);
        assert!(b.total() >= 0.0);
    }
}
