//! Property-testing mini-framework (std-only stand-in for `proptest`,
//! unavailable offline) plus a random-kernel generator used to fuzz the
//! transformation pipeline.
//!
//! `check` runs a property over many seeded cases and reports the failing
//! seed, so failures reproduce with `PIPEFWD_PROP_SEED=<seed>`.

use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Stmt, Ty};
use crate::sim::mem::MemoryImage;
use crate::util::rng::Rng;

/// Run `prop` over `cases` seeded inputs; panic with the failing seed.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    let (start, count) = match std::env::var("PIPEFWD_PROP_SEED") {
        Ok(s) => (s.parse::<u64>().expect("PIPEFWD_PROP_SEED must be a u64"), 1),
        Err(_) => (0x5EED_0000, cases),
    };
    for c in 0..count {
        let seed = start.wrapping_add(c);
        let mut rng = Rng::new(seed);
        if let Err(e) = prop(&mut rng) {
            panic!(
                "property `{name}` failed (case {c}, seed {seed}): {e}\n\
                 reproduce with PIPEFWD_PROP_SEED={seed}"
            );
        }
    }
}

/// A generated kernel plus a matching input image factory.
pub struct GenKernel {
    pub kernel: Kernel,
    pub n: usize,
    seed: u64,
    n_inputs: usize,
    has_perm: bool,
}

impl GenKernel {
    /// Fresh memory image with deterministic contents for this kernel.
    pub fn image(&self) -> MemoryImage {
        let mut rng = Rng::new(self.seed ^ 0xDA7A);
        let mut m = MemoryImage::new();
        for b in 0..self.n_inputs {
            let data: Vec<f32> = (0..self.n).map(|_| rng.f32_range(-2.0, 2.0)).collect();
            m.add_f32s(&format!("in{b}"), &data);
        }
        if self.has_perm {
            m.add_i64s("perm", &rng.permutation(self.n));
        }
        m.add_zeros("out", Ty::F32, self.n);
        m.add_zeros("out2", Ty::F32, self.n);
        m.set_i("n", self.n as i64);
        m
    }
}

/// Generate a random feed-forward-eligible single work-item kernel:
/// reads from read-only inputs (sequential, offset, or permuted indices),
/// mixes arithmetic, conditionals and an optional inner reduction loop,
/// stores to write-only outputs. No same-buffer load+store pairs, so the
/// split is always feasible and all variants must agree exactly.
pub fn gen_kernel(rng: &mut Rng) -> GenKernel {
    let seed = rng.next_u64();
    let mut g = Rng::new(seed);
    let n_inputs = 1 + g.below(3) as usize; // 1..=3 input buffers
    let has_perm = g.chance(0.5);
    let n = 64 + 16 * g.below(8) as usize;

    let mut body: Vec<Stmt> = vec![];
    let mut exprs: Vec<String> = vec![]; // defined float vars

    // loads
    let n_loads = 1 + g.below(4) as usize;
    for l in 0..n_loads {
        let buf = format!("in{}", g.below(n_inputs as u64));
        let idx = match g.below(3) {
            0 => v("t"),
            1 => (v("t") + i(g.range(1, 8))) % p("n"),
            _ => {
                if has_perm {
                    ld("perm", v("t"))
                } else {
                    v("t")
                }
            }
        };
        let name = format!("x{l}");
        body.push(let_f(&name, ld(&buf, idx)));
        exprs.push(name);
    }

    // arithmetic
    let n_ops = 1 + g.below(5) as usize;
    for o in 0..n_ops {
        let a = exprs[g.below(exprs.len() as u64) as usize].clone();
        let b = exprs[g.below(exprs.len() as u64) as usize].clone();
        let e = match g.below(4) {
            0 => v(&a) + v(&b),
            1 => v(&a) * f(0.5) + v(&b),
            2 => v(&a).min(v(&b) + f(0.25)),
            _ => v(&a).max(v(&b)) - f(0.125),
        };
        let name = format!("y{o}");
        body.push(let_f(&name, e));
        exprs.push(name);
    }

    // optional conditional store path
    let last = exprs.last().unwrap().clone();
    if g.chance(0.6) {
        let c0 = exprs[g.below(exprs.len() as u64) as usize].clone();
        body.push(if_else(
            v(&c0).gt(f(0.0)),
            vec![store("out2", v("t"), v(&last) * f(2.0))],
            vec![store("out2", v("t"), f(-1.0))],
        ));
    } else {
        body.push(store("out2", v("t"), v(&last)));
    }

    // optional inner reduction loop (a DLCD the split must relocate)
    if g.chance(0.5) {
        let trip = g.range(2, 6);
        let src = format!("in{}", g.below(n_inputs as u64));
        body.push(let_f("red", f(0.0)));
        body.push(for_(
            "j",
            i(0),
            i(trip),
            vec![assign(
                "red",
                v("red") + ld(&src, (v("t") + v("j")) % p("n")),
            )],
        ));
        body.push(store("out", v("t"), v(&last) + v("red")));
    } else {
        body.push(store("out", v("t"), v(&last) * f(3.0)));
    }

    let mut kb = KernelBuilder::new("genk", KernelKind::SingleWorkItem);
    for b in 0..n_inputs {
        kb = kb.buf_ro(&format!("in{b}"), Ty::F32);
    }
    if has_perm {
        kb = kb.buf_ro("perm", Ty::I32);
    }
    let kernel = kb
        .buf_wo("out", Ty::F32)
        .buf_wo("out2", Ty::F32)
        .scalar("n", Ty::I32)
        .body(vec![for_("t", i(0), p("n"), body)])
        .finish();
    GenKernel { kernel, n, seed, n_inputs, has_perm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::validate_kernel;

    #[test]
    fn generated_kernels_always_validate() {
        check("gen_validates", 50, |rng| {
            let g = gen_kernel(rng);
            validate_kernel(&g.kernel).map_err(|e| e.to_string())
        });
    }

    #[test]
    fn generated_kernels_are_ff_feasible() {
        check("gen_feasible", 50, |rng| {
            let g = gen_kernel(rng);
            crate::transform::check_feasible(&g.kernel).map_err(|e| e.to_string())
        });
    }
}
