//! Deterministic xorshift64* PRNG — std-only stand-in for the `rand` crate
//! (unavailable in this offline environment). Used by dataset generation
//! and the property-testing mini-framework; seeded runs are reproducible.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.wrapping_mul(2685821657736338717).max(1) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(2685821657736338717)
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo).max(1) as u64) as i64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        ((self.next_u64() >> 11) as f64 / (1u64 << 53) as f64) < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n as i64).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for x in p {
            assert!(!seen[x as usize]);
            seen[x as usize] = true;
        }
    }
}
