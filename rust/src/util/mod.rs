//! Std-only utilities replacing unavailable third-party crates (this image
//! is offline): PRNG, property-testing mini-framework, bench harness.
pub mod bench;
pub mod rng;
pub mod testing;
