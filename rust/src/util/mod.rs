//! Std-only utilities replacing unavailable third-party crates (this image
//! is offline): PRNG, property-testing mini-framework, bench harness,
//! error handling (`anyhow` stand-in), JSON (`serde_json` stand-in).
pub mod bench;
pub mod error;
pub mod fault;
pub mod json;
pub mod rng;
pub mod testing;
