//! Std-only stand-in for `anyhow` (this image is offline): a string-backed
//! error type, a `Result` alias, `Context` extension methods, and the
//! `anyhow!`/`bail!` macros.

use std::fmt;

/// A boxed-string error, API-compatible with the slice of `anyhow` this
/// crate uses (`anyhow!`, `bail!`, `.context(..)`, `.with_context(..)`).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<crate::sim::exec::ExecError> for Error {
    fn from(e: crate::sim::exec::ExecError) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style adapters for `Result` and `Option`.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] from a message (drop-in for `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err` built from a message (drop-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");

        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn fails(n: u32) -> Result<()> {
            if n > 3 {
                bail!("too big: {n}");
            }
            Err(anyhow!("always: {}", n))
        }
        assert_eq!(fails(5).unwrap_err().to_string(), "too big: 5");
        assert_eq!(fails(1).unwrap_err().to_string(), "always: 1");
    }
}
