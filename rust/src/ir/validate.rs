//! Structural validation of kernels and programs.
//!
//! Catches builder/transform bugs early: undefined variables/buffers/pipes,
//! double definitions, writes to read-only buffers, NDRange builtins in
//! single work-item kernels, pipes with other than exactly one producer and
//! one consumer, and non-positive pipe depths.

use super::expr::Expr;
use super::kernel::{Access, Kernel, KernelKind, Program};
use super::stmt::Stmt;
use std::collections::HashSet;

#[derive(Debug, PartialEq)]
pub enum ValidateError {
    UndefinedVar { kernel: String, name: String },
    Redefined { kernel: String, name: String },
    UndefinedBuf { kernel: String, name: String },
    UndefinedParam { kernel: String, name: String },
    StoreToReadOnly { kernel: String, name: String },
    LoadFromWriteOnly { kernel: String, name: String },
    GlobalIdInSwi { kernel: String },
    UndefinedPipe { kernel: String, name: String },
    PipeEndpoints { name: String, writers: usize, readers: usize },
    DuplicatePipe { name: String },
    DuplicateKernel { name: String, kernel: String },
}

impl std::fmt::Display for ValidateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ValidateError::UndefinedVar { kernel, name } => {
                write!(f, "kernel {kernel}: undefined variable `{name}`")
            }
            ValidateError::Redefined { kernel, name } => write!(
                f,
                "kernel {kernel}: variable `{name}` defined twice in the same scope chain"
            ),
            ValidateError::UndefinedBuf { kernel, name } => {
                write!(f, "kernel {kernel}: undefined buffer `{name}`")
            }
            ValidateError::UndefinedParam { kernel, name } => {
                write!(f, "kernel {kernel}: undefined scalar param `{name}`")
            }
            ValidateError::StoreToReadOnly { kernel, name } => {
                write!(f, "kernel {kernel}: store to read-only buffer `{name}`")
            }
            ValidateError::LoadFromWriteOnly { kernel, name } => {
                write!(f, "kernel {kernel}: load from write-only buffer `{name}`")
            }
            ValidateError::GlobalIdInSwi { kernel } => {
                write!(f, "kernel {kernel}: get_global_id in single work-item kernel")
            }
            ValidateError::UndefinedPipe { kernel, name } => {
                write!(f, "kernel {kernel}: undeclared pipe `{name}`")
            }
            ValidateError::PipeEndpoints { name, writers, readers } => write!(
                f,
                "pipe {name}: {writers} writer kernel(s) and {readers} reader kernel(s); \
                 need exactly 1/1"
            ),
            ValidateError::DuplicatePipe { name } => write!(f, "pipe {name}: declared twice"),
            ValidateError::DuplicateKernel { name, kernel } => {
                write!(f, "program {name}: duplicate kernel name `{kernel}`")
            }
        }
    }
}

impl std::error::Error for ValidateError {}

struct Scope {
    vars: Vec<HashSet<String>>,
}

impl Scope {
    fn new() -> Self {
        Scope { vars: vec![HashSet::new()] }
    }

    fn push(&mut self) {
        self.vars.push(HashSet::new());
    }

    fn pop(&mut self) {
        self.vars.pop();
    }

    fn defined(&self, name: &str) -> bool {
        self.vars.iter().any(|s| s.contains(name))
    }

    fn define(&mut self, name: &str) -> bool {
        if self.defined(name) {
            return false;
        }
        self.vars.last_mut().unwrap().insert(name.to_string());
        true
    }
}

fn check_expr(k: &Kernel, e: &Expr, scope: &Scope, pipes: Option<&Program>) -> Result<(), ValidateError> {
    let mut err = None;
    e.visit(&mut |node| {
        if err.is_some() {
            return;
        }
        match node {
            Expr::Var(n) => {
                if !scope.defined(n) {
                    err = Some(ValidateError::UndefinedVar { kernel: k.name.clone(), name: n.clone() });
                }
            }
            Expr::Param(n) => {
                if k.scalar(n).is_none() {
                    err = Some(ValidateError::UndefinedParam { kernel: k.name.clone(), name: n.clone() });
                }
            }
            Expr::Load { buf, .. } => match k.buf(buf) {
                None => err = Some(ValidateError::UndefinedBuf { kernel: k.name.clone(), name: buf.clone() }),
                Some(b) if b.access == Access::WriteOnly => {
                    err = Some(ValidateError::LoadFromWriteOnly { kernel: k.name.clone(), name: buf.clone() })
                }
                _ => {}
            },
            Expr::GlobalId(_) => {
                if k.kind == KernelKind::SingleWorkItem {
                    err = Some(ValidateError::GlobalIdInSwi { kernel: k.name.clone() });
                }
            }
            _ => {}
        }
    });
    let _ = pipes;
    match err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

fn check_body(
    k: &Kernel,
    body: &[Stmt],
    scope: &mut Scope,
    prog: Option<&Program>,
) -> Result<(), ValidateError> {
    for s in body {
        match s {
            Stmt::Let { var, expr, .. } => {
                check_expr(k, expr, scope, prog)?;
                if !scope.define(var) {
                    return Err(ValidateError::Redefined { kernel: k.name.clone(), name: var.clone() });
                }
            }
            Stmt::Assign { var, expr } => {
                check_expr(k, expr, scope, prog)?;
                if !scope.defined(var) {
                    return Err(ValidateError::UndefinedVar { kernel: k.name.clone(), name: var.clone() });
                }
            }
            Stmt::Store { buf, idx, val } => {
                check_expr(k, idx, scope, prog)?;
                check_expr(k, val, scope, prog)?;
                match k.buf(buf) {
                    None => {
                        return Err(ValidateError::UndefinedBuf { kernel: k.name.clone(), name: buf.clone() })
                    }
                    Some(b) if b.access == Access::ReadOnly => {
                        return Err(ValidateError::StoreToReadOnly { kernel: k.name.clone(), name: buf.clone() })
                    }
                    _ => {}
                }
            }
            Stmt::If { cond, then_b, else_b } => {
                check_expr(k, cond, scope, prog)?;
                scope.push();
                check_body(k, then_b, scope, prog)?;
                scope.pop();
                scope.push();
                check_body(k, else_b, scope, prog)?;
                scope.pop();
            }
            Stmt::For { var, lo, hi, body, .. } => {
                check_expr(k, lo, scope, prog)?;
                check_expr(k, hi, scope, prog)?;
                scope.push();
                if !scope.define(var) {
                    return Err(ValidateError::Redefined { kernel: k.name.clone(), name: var.clone() });
                }
                check_body(k, body, scope, prog)?;
                scope.pop();
            }
            Stmt::PipeWrite { pipe, val } => {
                check_expr(k, val, scope, prog)?;
                if let Some(pr) = prog {
                    if pr.pipe(pipe).is_none() {
                        return Err(ValidateError::UndefinedPipe { kernel: k.name.clone(), name: pipe.clone() });
                    }
                }
            }
            Stmt::PipeRead { var, pipe, .. } => {
                if let Some(pr) = prog {
                    if pr.pipe(pipe).is_none() {
                        return Err(ValidateError::UndefinedPipe { kernel: k.name.clone(), name: pipe.clone() });
                    }
                }
                if !scope.define(var) {
                    return Err(ValidateError::Redefined { kernel: k.name.clone(), name: var.clone() });
                }
            }
        }
    }
    Ok(())
}

/// Validate one kernel in isolation (pipe declarations unchecked).
pub fn validate_kernel(k: &Kernel) -> Result<(), ValidateError> {
    let mut scope = Scope::new();
    check_body(k, &k.body, &mut scope, None)
}

/// Validate a whole program, including pipe endpoint wiring.
pub fn validate_program(prog: &Program) -> Result<(), ValidateError> {
    // Unique kernel names.
    let mut names = HashSet::new();
    for k in &prog.kernels {
        if !names.insert(&k.name) {
            return Err(ValidateError::DuplicateKernel { name: prog.name.clone(), kernel: k.name.clone() });
        }
    }
    // Unique pipe names.
    let mut pnames = HashSet::new();
    for p in &prog.pipes {
        if !pnames.insert(&p.name) {
            return Err(ValidateError::DuplicatePipe { name: p.name.clone() });
        }
    }
    // Per-kernel checks with pipe resolution.
    for k in &prog.kernels {
        let mut scope = Scope::new();
        check_body(k, &k.body, &mut scope, Some(prog))?;
    }
    // Pipe endpoints: exactly one writer kernel and one reader kernel each.
    for p in &prog.pipes {
        let mut writers = 0;
        let mut readers = 0;
        for k in &prog.kernels {
            let mut w = false;
            let mut r = false;
            super::stmt::visit_body(&k.body, &mut |s| match s {
                Stmt::PipeWrite { pipe, .. } if pipe == &p.name => w = true,
                Stmt::PipeRead { pipe, .. } if pipe == &p.name => r = true,
                _ => {}
            });
            writers += w as usize;
            readers += r as usize;
        }
        if writers != 1 || readers != 1 {
            return Err(ValidateError::PipeEndpoints { name: p.name.clone(), writers, readers });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, PipeDecl, Program, Ty};

    fn ok_kernel() -> Kernel {
        KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("o", v("i"), ld("a", v("i")))],
            )])
            .finish()
    }

    #[test]
    fn accepts_valid_kernel() {
        assert_eq!(validate_kernel(&ok_kernel()), Ok(()));
    }

    #[test]
    fn rejects_undefined_var() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .body(vec![assign("x", i(1))])
            .finish();
        assert!(matches!(validate_kernel(&k), Err(ValidateError::UndefinedVar { .. })));
    }

    #[test]
    fn rejects_store_to_readonly() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .body(vec![store("a", i(0), f(1.0))])
            .finish();
        assert!(matches!(validate_kernel(&k), Err(ValidateError::StoreToReadOnly { .. })));
    }

    #[test]
    fn rejects_gid_in_swi() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_wo("o", Ty::I32)
            .body(vec![store("o", gid(), i(1))])
            .finish();
        assert!(matches!(validate_kernel(&k), Err(ValidateError::GlobalIdInSwi { .. })));
    }

    #[test]
    fn rejects_loop_var_shadowing() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .body(vec![let_i("i", i(0)), for_("i", i(0), i(4), vec![])])
            .finish();
        assert!(matches!(validate_kernel(&k), Err(ValidateError::Redefined { .. })));
    }

    #[test]
    fn pipe_endpoint_rules() {
        let m = KernelBuilder::new("m", KernelKind::SingleWorkItem)
            .body(vec![pwrite("c0", i(1))])
            .finish();
        let c = KernelBuilder::new("c", KernelKind::SingleWorkItem)
            .buf_wo("o", Ty::I32)
            .body(vec![pread("x", Ty::I32, "c0"), store("o", i(0), v("x"))])
            .finish();
        let prog = Program {
            name: "p".into(),
            kernels: vec![m.clone(), c],
            pipes: vec![PipeDecl { name: "c0".into(), ty: Ty::I32, depth: 1 }],
        };
        assert_eq!(validate_program(&prog), Ok(()));

        // A pipe with a writer but no reader is rejected.
        let bad = Program {
            name: "p".into(),
            kernels: vec![m],
            pipes: vec![PipeDecl { name: "c0".into(), ty: Ty::I32, depth: 1 }],
        };
        assert!(matches!(validate_program(&bad), Err(ValidateError::PipeEndpoints { .. })));
    }

    #[test]
    fn rejects_undeclared_pipe() {
        let m = KernelBuilder::new("m", KernelKind::SingleWorkItem)
            .body(vec![pwrite("nope", i(1))])
            .finish();
        let prog = Program { name: "p".into(), kernels: vec![m], pipes: vec![] };
        assert!(matches!(validate_program(&prog), Err(ValidateError::UndefinedPipe { .. })));
    }
}
